"""Fig. 4: accuracy heatmap over (dimension x memory columns).

Reduced grid {64,128,256} x {32,64,128,256} (the paper sweeps 64..1024);
the qualitative findings under test: accuracy rises with D, rises with C
for the many-samples datasets (mnist/fmnist) and peaks at moderate C for
ISOLET (few samples/class -> too many columns overfit)."""
import time

import jax

from benchmarks.common import dataset, row, section
from repro.core import EncoderConfig, MemhdConfig, MemhdModel

DIMS = (64, 128, 256)
COLS = (32, 64, 128, 256)


def main() -> None:
    for name in ("mnist", "isolet"):
        ds = dataset(name)
        section(f"Fig. 4 heatmap ({name})")
        grid = {}
        for d in DIMS:
            for c in COLS:
                if c < ds.classes:
                    continue
                enc = EncoderConfig(kind="projection",
                                    features=ds.features, dim=d)
                amc = MemhdConfig(dim=d, columns=c, classes=ds.classes,
                                  epochs=5, kmeans_iters=6, lr=0.015)
                m = MemhdModel.create(jax.random.key(0), enc, amc)
                t0 = time.perf_counter()
                m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
                us = (time.perf_counter() - t0) * 1e6
                acc = m.score(ds.test_x, ds.test_y)
                grid[(d, c)] = acc
                row(f"fig4/{name}/D{d}xC{c}", us, f"acc={acc:.4f}")
        # Derived: higher D helps at fixed C (paper's main diagonal).
        for c in COLS:
            if (DIMS[0], c) in grid and (DIMS[-1], c) in grid:
                row(f"fig4/{name}/dim_gain_C{c}", 0.0,
                    f"{grid[(DIMS[-1], c)] - grid[(DIMS[0], c)]:+.4f}")


if __name__ == "__main__":
    main()
