"""Fig. 3: accuracy vs memory (KB) — MEMHD sizes vs binary-HDC baselines.

Synthetic-data caveat (DESIGN.md §5): absolute accuracies differ from the
paper's real-data numbers; the *orderings* (MEMHD above baselines at equal
memory; memory savings at equal accuracy) are the reproduction target.
"""
import time

import jax

from benchmarks.common import EPOCHS, dataset, row, section
from repro.core import (
    BaselineConfig, EncoderConfig, MemhdConfig, MemhdModel, fit_baseline,
)

# (D, C) MEMHD geometries per dataset (paper: squares for MNIST/FMNIST,
# fixed 128 columns for ISOLET).
MEMHD_SIZES = {
    "mnist": [(64, 64), (128, 128), (256, 256), (512, 512)],
    "fmnist": [(64, 64), (128, 128), (256, 256), (512, 512)],
    "isolet": [(128, 128), (256, 128), (512, 128)],
}
BASELINE_DIMS = [1024, 2048]


def run_memhd(ds, d, c) -> tuple:
    enc = EncoderConfig(kind="projection", features=ds.features, dim=d)
    amc = MemhdConfig(dim=d, columns=c, classes=ds.classes, epochs=EPOCHS,
                      kmeans_iters=8, lr=0.015)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    t0 = time.perf_counter()
    m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
    fit_us = (time.perf_counter() - t0) * 1e6
    return m.score(ds.test_x, ds.test_y), m.memory_kb, fit_us


def run_baseline(ds, kind, d) -> tuple:
    cfg = BaselineConfig(kind=kind, dim=d, classes=ds.classes,
                         epochs=EPOCHS, n_models=8)
    t0 = time.perf_counter()
    bl = fit_baseline(jax.random.key(0), cfg, ds.train_x, ds.train_y)
    fit_us = (time.perf_counter() - t0) * 1e6
    mem_kb = (bl.memory_bits) / 8 / 1024
    return bl.score(ds.test_x, ds.test_y), mem_kb, fit_us


def main() -> None:
    for name in ("mnist", "fmnist", "isolet"):
        section(f"Fig. 3 ({name}) accuracy vs memory [{dataset(name).source}]")
        ds = dataset(name)
        results = {}
        for d, c in MEMHD_SIZES[name]:
            acc, kb, us = run_memhd(ds, d, c)
            results[f"memhd_{d}x{c}"] = (acc, kb)
            row(f"fig3/{name}/memhd_{d}x{c}", us,
                f"acc={acc:.4f};mem_kb={kb:.1f}")
        for kind in ("basic", "quanthd", "lehdc", "searchd"):
            for d in BASELINE_DIMS:
                if kind in ("quanthd", "lehdc") and d > 1024:
                    continue  # iterative baselines: runtime budget
                acc, kb, us = run_baseline(ds, kind, d)
                results[f"{kind}_{d}"] = (acc, kb)
                row(f"fig3/{name}/{kind}_{d}D", us,
                    f"acc={acc:.4f};mem_kb={kb:.1f}")

        # Derived claim: best MEMHD beats every baseline while being
        # smaller (the Fig. 3 qualitative shape).
        best_memhd = max((v for k, v in results.items()
                          if k.startswith("memhd")), key=lambda t: t[0])
        best_base = max((v for k, v in results.items()
                         if not k.startswith("memhd")), key=lambda t: t[0])
        row(f"fig3/{name}/memhd_minus_best_baseline_acc", 0.0,
            f"{best_memhd[0] - best_base[0]:+.4f}")
        row(f"fig3/{name}/memory_ratio_baseline_over_memhd", 0.0,
            f"{best_base[1] / best_memhd[1]:.2f}x")


if __name__ == "__main__":
    main()
