"""Staged vs fused feature->prediction pipeline latency.

The serving question behind ``kernels/encode_fused.py``: the staged
path runs encode (float einsum), binarize, bitpack and packed search as
FOUR host dispatches, materializing the (B, D) float hypervector and
its bipolar binarization in HBM between stages; the fused path is ONE
dispatch whose only intermediate is the (B, ceil(D/8)) packed rows.
This bench measures exactly that difference: each staged stage is its
own jitted call, synced like the pre-fusion serving loop, while the
fused path is the single-jit chain ``predict_features`` serves.

Both paths time the jnp oracles (interpret-mode Pallas is a
correctness tool, not a throughput proxy — see kernel_bench.py); the
computation per stage is identical, so the delta isolates dispatch +
intermediate-materialization cost. Bit-exact (idx and sim, ties
included) parity is asserted per geometry. Emits one JSON row per
geometry plus the standard CSV rows.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, section, time_fn
from repro.kernels import ref

# The paper's deployment geometries (Table II): encode/pack overhead is
# a real fraction of the pipeline here, which is where fusion pays.
GEOMS = [(784, 128, 128), (784, 256, 256), (617, 512, 128),
         (784, 512, 256)]  # (f, D, C)
BATCH = 256


def main() -> None:
    section("Staged vs fused feature->prediction pipeline")
    rng = np.random.default_rng(0)
    total_staged = total_fused = 0.0
    for f, d, c in GEOMS:
        feats = jnp.asarray(rng.random((BATCH, f), dtype=np.float32))
        proj = jnp.asarray(rng.choice([-1., 1.], size=(f, d))
                           .astype(np.float32))
        am = jnp.asarray(rng.choice([-1., 1.], size=(c, d))
                         .astype(np.float32))
        apt = ref.pack_rows(am).T

        # Staged: four dispatches, float H + bipolar Q round-tripped
        # through HBM, host sync at each stage boundary.
        enc = jax.jit(lambda x, m: ref.binary_mvm(x, m))
        binz = jax.jit(lambda h: jnp.where(h >= 0, 1.0, -1.0))
        pack = jax.jit(ref.pack_rows)
        search = jax.jit(lambda qp, a: ref.am_search_packed(qp, a, d))

        def staged(x, m, a):
            h = jax.block_until_ready(enc(x, m))
            q = jax.block_until_ready(binz(h))
            qp = jax.block_until_ready(pack(q))
            return search(qp, a)

        # Fused: the whole chain under one jit — the dispatch shape of
        # ``predict_features`` / ``ops.search_from_features``.
        fused = jax.jit(lambda x, m, a: ref.am_search_packed(
            ref.encode_pack(x, m), a, d))

        si, ss = staged(feats, proj, apt)
        fi, fs = fused(feats, proj, apt)
        np.testing.assert_array_equal(np.asarray(fi), np.asarray(si))
        np.testing.assert_array_equal(np.asarray(fs), np.asarray(ss))

        staged_us = time_fn(staged, feats, proj, apt, iters=7)
        fused_us = time_fn(fused, feats, proj, apt, iters=7)
        total_staged += staged_us
        total_fused += fused_us

        rec = {
            "bench": "pipeline",
            "geometry": f"f{f}/{d}x{c}",
            "batch": BATCH,
            "staged_us": round(staged_us, 1),
            "fused_us": round(fused_us, 1),
            "speedup": round(staged_us / fused_us, 2),
            "staged_qps": round(BATCH / staged_us * 1e6, 1),
            "fused_qps": round(BATCH / fused_us * 1e6, 1),
            "float_h_bytes_saved": BATCH * d * 4,
            "bit_exact": True,
        }
        print(json.dumps(rec), flush=True)
        row(f"pipeline/f{f}/{d}x{c}", fused_us,
            f"staged_us={staged_us:.1f};"
            f"speedup={staged_us / fused_us:.2f}x")
    # The point of the fusion: across the geometry sweep the
    # single-dispatch path must beat the staged one. The printed rows
    # are the measurement; the assert is a regression backstop with 10%
    # headroom so scheduler noise on a loaded box can't fail the suite.
    assert total_fused < total_staged * 1.10, (total_fused, total_staged)
    row("pipeline/total", total_fused,
        f"staged_us={total_staged:.1f};"
        f"speedup={total_staged / total_fused:.2f}x")


if __name__ == "__main__":
    main()
