"""Packed (XOR+popcount) vs unpacked (float MXU) associative search.

Compares the two deployment paths over the paper geometries: bit-exact
parity of (idx, sim), resident-AM bytes (the Table-I 1-bit accounting
vs byte/float cells), and CPU wall time of the jit'd oracle for each
domain (interpret-mode Pallas is a correctness tool, not a throughput
proxy — see kernel_bench.py). Emits one JSON row per geometry plus the
standard CSV rows.
"""
import json

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, section, time_fn
from repro.core.imc import ImcArrayConfig, map_memhd
from repro.kernels import ops, ref
from repro.kernels.am_search_packed import imc_cycles_for as packed_cycles

GEOMS = [(128, 128), (256, 256), (512, 128), (1024, 1024)]
BATCH = 256


def main() -> None:
    section("Packed vs unpacked associative search")
    rng = np.random.default_rng(0)
    arr = ImcArrayConfig()
    for d, c in GEOMS:
        q = jnp.asarray(rng.choice([-1., 1.], size=(BATCH, d))
                        .astype(np.float32))
        am = jnp.asarray(rng.choice([-1., 1.], size=(c, d))
                         .astype(np.float32))
        qp = ops.pack_rows(q)
        apt = ops.pack_rows(am).T

        # Bit-exact parity: packed kernel == unpacked kernel == jnp argmax.
        ui, us = ops.am_search(q[:16], am)
        pi, ps = ops.am_search_packed(qp[:16], apt, n_dims=d)
        np.testing.assert_array_equal(np.asarray(pi), np.asarray(ui))
        np.testing.assert_array_equal(np.asarray(ps), np.asarray(us))

        unpacked_us = time_fn(
            jax.jit(lambda qq, aa: ref.am_search(qq, aa)), q, am.T,
            iters=5)
        packed_us = time_fn(
            jax.jit(lambda qq, aa: ref.am_search_packed(qq, aa, d)),
            qp, apt, iters=5)

        packed_bytes = int(apt.size)
        float_bytes = c * d * 4
        cycles = map_memhd(d, c, arr).cycles
        assert packed_cycles(apt.shape) == cycles
        rec = {
            "bench": "packed_vs_unpacked",
            "geometry": f"{d}x{c}",
            "batch": BATCH,
            "unpacked_us": round(unpacked_us, 1),
            "packed_us": round(packed_us, 1),
            "resident_bytes_packed": packed_bytes,
            "resident_bytes_cells": c * d,      # 1 byte/cell
            "resident_bytes_float32": float_bytes,
            "memory_ratio_vs_cells": round(c * d / packed_bytes, 2),
            "memory_ratio_vs_float32": round(float_bytes / packed_bytes,
                                             2),
            "imc_cycles": cycles,
            "bit_exact": True,
        }
        print(json.dumps(rec), flush=True)
        row(f"packed_vs_unpacked/{d}x{c}", packed_us,
            f"unpacked_us={unpacked_us:.1f};"
            f"ratio_vs_cells={c * d / packed_bytes:.0f}x")


if __name__ == "__main__":
    main()
