"""Fig. 6: accuracy across initial-cluster ratios R (0.2 .. 1.0).

Paper findings reproduced: R has little effect when C is large relative
to k (512x512 there, 128 cols here with C>>k) and matters when C is
tight; ISOLET (k=26) prefers large R."""
import time

import jax

from benchmarks.common import dataset, row, section
from repro.core import EncoderConfig, MemhdConfig, MemhdModel

RS = (0.2, 0.4, 0.6, 0.8, 1.0)


def main() -> None:
    for name, d, c in (("mnist", 256, 128), ("mnist", 256, 32),
                       ("isolet", 256, 128)):
        ds = dataset(name)
        section(f"Fig. 6 R sweep ({name}, {d}x{c})")
        accs = {}
        for r in RS:
            enc = EncoderConfig(kind="projection", features=ds.features,
                                dim=d)
            amc = MemhdConfig(dim=d, columns=c, classes=ds.classes,
                              epochs=6, kmeans_iters=6, lr=0.015,
                              init_ratio=r)
            m = MemhdModel.create(jax.random.key(0), enc, amc)
            t0 = time.perf_counter()
            m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
            us = (time.perf_counter() - t0) * 1e6
            accs[r] = m.score(ds.test_x, ds.test_y)
            row(f"fig6/{name}_{d}x{c}/R{r}", us, f"acc={accs[r]:.4f}")
        spread = max(accs.values()) - min(accs.values())
        row(f"fig6/{name}_{d}x{c}/spread", 0.0, f"{spread:.4f}")


if __name__ == "__main__":
    main()
