"""Coarse-to-fine hierarchical search vs the flat packed scan.

Sweeps the centroid count C from paper scale (512) to the huge-label
regime (100k) and, per C, the shortlist width S of the two-stage
pipeline (``am_shortlist`` over G ~ 1.4*sqrt(C) super-centroids, then
``am_search_sparse`` over the shortlisted cluster tiles). Measures:

* ``flat_c{C}`` — the linear ``am_search_packed`` scan, the baseline
  whose cost grows linearly in C;
* ``hier_c{C}_s{S}`` — the full two-stage dispatch (shortlist + tile
  gather + sparse top-k), with speedup vs flat and recall@1 vs the
  exact search as derived metrics.

The AM is synthesized with *planted* cluster structure (prototype
hypervectors + bit-flip noise; queries are noisy copies of real
centroids) — the regime the hierarchical index is for; an iid-random AM
has no cluster structure to exploit, and every index degenerates to
recall ~ S/G on it. Recall@1 is tie-robust: a hit is "the returned
top-1 similarity equals the exact maximum similarity".

Asserted in-bench (the ISSUE-7 acceptance contract):
* at C >= 32768 the hierarchical path is >= 5x faster (min over
  timing samples) than the flat scan at the same batch, with
  recall@1 >= 99%;
* at C = 512 the degenerate S = G sweep point is bit-exact with
  ``am_search_packed`` (idx and sim), the parity anchor.

Recorded through benchmarks/record.py; committed baselines in
benchmarks/baselines/BENCH_hierarchical_search.json are gated by
benchmarks/gate.py.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import record
from benchmarks.common import row, section, time_fn_stats

D = 1024            # hypervector dimension (huge-label serving scale)
BATCH = 256         # queries per timed call (256 -> recall floor allows
                    # 2 misses at 99%)
PROTO_FLIP = 0.08   # centroid = cluster prototype with this bit-flip rate
QUERY_FLIP = 0.10   # query = source centroid with this bit-flip rate
CHUNK = 16384       # host-side generation / exact-reference chunk rows

# Per-C sweep. ``g_plant`` is the number of planted prototypes in the
# synthetic AM; ``g`` is the index's group count — over-partitioned ~1.4x
# past sqrt(C) at scale, the standard IVF trick: with G > true clusters,
# k-means splits clusters (benign: each shard's majority-vote super still
# matches its prototype) instead of merging them (fatal: a blended super
# ranks low for BOTH constituent clusters' queries). The last S of each
# sweep is the serving recommendation the asserts check; C=512 also
# sweeps S=G (exact anchor).
CONFIGS = (
    {"c": 512, "g_plant": 23, "g": 23, "s_sweep": (4, 23)},
    {"c": 4096, "g_plant": 64, "g": 64, "s_sweep": (4, 16)},
    {"c": 32768, "g_plant": 181, "g": 256, "s_sweep": (16, 8)},
    {"c": 100_000, "g_plant": 316, "g": 448, "s_sweep": (16, 8)},
)
SPEEDUP_C = 32768      # configs at/above this C must hit the floors
SPEEDUP_FLOOR = 5.0
RECALL_FLOOR = 0.99


def planted_am(rng, c: int, g: int) -> tuple[np.ndarray, np.ndarray]:
    """(C, D) int8 bipolar AM with planted cluster structure."""
    protos = rng.choice(np.array([-1, 1], np.int8), size=(g, D))
    assign = rng.integers(0, g, size=c)
    am = np.empty((c, D), np.int8)
    for i in range(0, c, CHUNK):
        blk = protos[assign[i:i + CHUNK]]
        flips = rng.random(blk.shape, dtype=np.float32) < PROTO_FLIP
        am[i:i + CHUNK] = np.where(flips, -blk, blk)
    return am, assign


def noisy_queries(rng, am: np.ndarray) -> np.ndarray:
    src = rng.integers(0, am.shape[0], size=BATCH)
    q = am[src]
    flips = rng.random(q.shape, dtype=np.float32) < QUERY_FLIP
    return np.where(flips, -q, q).astype(np.int8)


def exact_best_sims(q: np.ndarray, am: np.ndarray) -> np.ndarray:
    """(B,) exact max dot similarity, chunked over C (the (B, Dp, C)
    oracle broadcast would be ~1.6 GB at C=100k)."""
    qf = q.astype(np.float32)
    best = np.full(q.shape[0], -np.inf, np.float32)
    for i in range(0, am.shape[0], CHUNK):
        sims = qf @ am[i:i + CHUNK].astype(np.float32).T
        best = np.maximum(best, sims.max(axis=1))
    return best


@functools.partial(jax.jit, static_argnames=("n_dims", "s", "k",
                                             "max_tiles"))
def hier_search(qp, spt, slab, col_ids, tile_start, tile_count, *,
                n_dims: int, s: int, k: int, max_tiles: int):
    """The full two-stage serving dispatch under one jit."""
    from repro.kernels import ops
    short, _ = ops.am_shortlist(qp, spt, n_dims=n_dims, s=s)
    return ops.am_search_sparse(qp, slab, col_ids, short, tile_start,
                                tile_count, n_dims=n_dims, k=k,
                                max_tiles=max_tiles)


def main() -> None:
    from repro.deploy import hierarchical as hier
    from repro.kernels import ops

    rec = record.active()
    if rec is not None:
        rec.meta.update(d=D, batch=BATCH, proto_flip=PROTO_FLIP,
                        query_flip=QUERY_FLIP)

    for cfg in CONFIGS:
        c, g = cfg["c"], cfg["g"]
        section(f"C={c} (G={g}, planted={cfg['g_plant']}, D={D}, "
                f"B={BATCH})")
        rng = np.random.default_rng(c)
        am, _ = planted_am(rng, c, cfg["g_plant"])
        q = noisy_queries(rng, am)
        exact = exact_best_sims(q, am)

        qp = jnp.asarray(hier.pack_rows_np(q))
        apt = jnp.asarray(hier.pack_rows_np(am).T)
        flat_fn = jax.jit(lambda qp, apt: ops.am_search_packed(
            qp, apt, n_dims=D))
        flat_stats = time_fn_stats(flat_fn, qp, apt)
        flat_idx, flat_sim = jax.tree.map(np.asarray, flat_fn(qp, apt))
        flat_min = flat_stats["min_us"]
        row(f"flat_c{c}", flat_stats["p50_us"],
            f"C={c} linear packed scan", c=c)

        spt, layout = hier.build_search_state(
            jax.random.PRNGKey(c), am, g, kmeans_iters=8,
            kmeans_sample=16384)
        slab = jnp.asarray(layout.slab)
        col_ids = jnp.asarray(layout.col_ids)
        t_start = jnp.asarray(layout.tile_start)
        t_count = jnp.asarray(layout.tile_count)

        for s in cfg["s_sweep"]:
            fn = functools.partial(hier_search, n_dims=D, s=s, k=1,
                                   max_tiles=layout.max_tiles)
            hier_stats = time_fn_stats(fn, qp, spt, slab, col_ids, t_start,
                               t_count)
            hier_min = hier_stats["min_us"]
            idx, sim = jax.tree.map(
                np.asarray, fn(qp, spt, slab, col_ids, t_start, t_count))
            recall = float(np.mean(sim[:, 0] == exact))
            speedup = flat_min / hier_min if hier_min else 0.0
            row(f"hier_c{c}_s{s}", hier_stats["p50_us"],
                f"speedup={speedup:.1f}x recall@1={recall:.4f}",
                c=c, g=g, s=s, max_tiles=layout.max_tiles,
                speedup=round(speedup, 2), recall=recall)

            if s == g:
                # Degenerate S = G contract: bit-exact with the flat scan.
                assert np.array_equal(idx[:, 0], flat_idx), (
                    f"C={c} S=G diverged from am_search_packed")
                assert np.array_equal(sim[:, 0], flat_sim), (
                    f"C={c} S=G sims diverged from am_search_packed")
                print(f"  S=G={g}: bit-exact with flat packed scan OK")

            if c >= SPEEDUP_C and s == cfg["s_sweep"][-1]:
                assert speedup >= SPEEDUP_FLOOR, (
                    f"C={c} S={s}: hierarchical only {speedup:.2f}x vs "
                    f"flat (floor {SPEEDUP_FLOOR}x)")
                assert recall >= RECALL_FLOOR, (
                    f"C={c} S={s}: recall@1 {recall:.4f} < {RECALL_FLOOR}")
                print(f"  asserts OK: {speedup:.1f}x >= {SPEEDUP_FLOOR}x, "
                      f"recall {recall:.4f} >= {RECALL_FLOOR}")


if __name__ == "__main__":
    main()
