"""Persistent benchmark recording: one ``BENCH_<name>.json`` per bench.

Before this sink existed every bench printed CSV to stdout and the
numbers evaporated with the terminal — five PRs of kernel and serving
work with no recorded perf trajectory. Now ``benchmarks.run`` opens a
recorder around each bench module, every ``common.row(...)`` call is
mirrored into it as a structured metric (``common.time_fn`` attaches
its full sample statistics — min/p50/p95/p99 — to the matching row),
and the finished record is written as a schema-versioned JSON artifact:

    benchmarks/results/BENCH_<name>.json      (override: $MEMHD_BENCH_DIR
                                               or run.py --record-dir)

``benchmarks.gate`` diffs these against the committed baselines in
``benchmarks/baselines/`` and fails CI on slowdowns or missing metrics;
``launch/serve_memhd.py --record-dir`` routes its serving report
through ``from_report`` so QPS/latency land in the same trajectory.

Schema (v1) — the top-level key set and the per-metric required keys
are FROZEN (tests/test_bench_harness.py); extend by adding optional
per-metric keys or bumping ``SCHEMA_VERSION``:

    {
      "schema_version": 1,
      "bench": "<name>",               # BENCH_<name>.json
      "created_unix": 1733...,
      "git_sha": "abc1234" | null,
      "jax_backend": "cpu" | "tpu" | ...,
      "jax_version": "0.4...",
      "meta": {...},                   # geometry / workload metadata
      "metrics": {
        "<row name>": {
          "us_per_call": 12.5,         # required
          "derived": "...",            # required (stringified)
          # attached when the row came from a time_fn measurement:
          "min_us": ..., "p50_us": ..., "p95_us": ..., "p99_us": ...,
          "mean_us": ..., "n_samples": 5, "samples_us": [...],
          # plus any structured extras the bench passed to row(**extra)
        }, ...
      }
    }
"""
from __future__ import annotations

import json
import math
import os
import statistics
import subprocess
import time
from typing import Dict, List, Optional

SCHEMA_VERSION = 1
RECORD_PREFIX = "BENCH_"
ENV_DIR = "MEMHD_BENCH_DIR"

# The frozen schema: tests/test_bench_harness.py asserts these exactly.
TOP_LEVEL_KEYS = frozenset({
    "schema_version", "bench", "created_unix", "git_sha",
    "jax_backend", "jax_version", "meta", "metrics",
})
METRIC_REQUIRED_KEYS = frozenset({"us_per_call", "derived"})
TIMING_KEYS = frozenset({
    "min_us", "p50_us", "p95_us", "p99_us", "mean_us", "n_samples",
    "samples_us",
})

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_ACTIVE: Optional["Recorder"] = None


def _dispatch_breakdown() -> Optional[Dict[str, Dict[str, int]]]:
    """{kernel: {tier: count}} from the obs registry (None if repro
    isn't importable — record.py must stay usable standalone)."""
    try:
        from repro.kernels import ops
    except ImportError:
        return None
    return ops.dispatch_breakdown()


def _obs_meta(baseline: Optional[Dict[str, Dict[str, int]]]) -> Optional[Dict]:
    """The record's ``meta["obs"]`` block: XLA compile count plus the
    dispatch-tier counts THIS bench added over ``baseline`` (the
    process-wide registry accumulates across benches in one run.py
    process, so the per-bench delta is what's attributable). The gate
    reads ``dispatch_tiers`` to flag a kernel silently falling off its
    fast path even when timings stay inside the noise floor."""
    current = _dispatch_breakdown()
    if current is None:
        return None
    tiers: Dict[str, Dict[str, int]] = {}
    base = baseline or {}
    for kernel, by_tier in current.items():
        for tier, n in by_tier.items():
            delta = n - base.get(kernel, {}).get(tier, 0)
            if delta > 0:
                tiers.setdefault(kernel, {})[tier] = delta
    try:
        from repro.obs import jaxmon
        compiles = jaxmon.compiles()
    except ImportError:
        compiles = 0
    return {"compiles_total": compiles, "dispatch_tiers": tiers}


def results_dir() -> str:
    """Default artifact directory (gitignored; $MEMHD_BENCH_DIR wins)."""
    return os.environ.get(ENV_DIR) or os.path.join(
        _REPO_ROOT, "benchmarks", "results")


def baselines_dir() -> str:
    """The committed per-PR baseline set the regression gate diffs against."""
    return os.path.join(_REPO_ROOT, "benchmarks", "baselines")


def git_sha() -> Optional[str]:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_REPO_ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except OSError:
        return None


def timing_stats(samples_s: List[float]) -> Dict[str, object]:
    """Full sample statistics for one timed call, in microseconds.

    ``p50_us`` is the TRUE median (``statistics.median`` — the old
    ``sorted[n // 2]`` was the upper-middle element for even n); the
    min rides along so single-sample jitter on a shared 1-core CI
    container is visible next to the central tendency. p95/p99 use the
    nearest-rank definition (== max for the usual 3-5 samples, still
    meaningful once a bench passes more iters).
    """
    if not samples_s:
        raise ValueError("timing_stats needs at least one sample")
    us = sorted(s * 1e6 for s in samples_s)

    def rank(p: float) -> float:
        return us[min(len(us) - 1, max(0, math.ceil(p / 100 * len(us)) - 1))]

    return {
        "min_us": us[0],
        "p50_us": float(statistics.median(us)),
        "p95_us": rank(95),
        "p99_us": rank(99),
        "mean_us": float(statistics.fmean(us)),
        "n_samples": len(us),
        "samples_us": [round(u, 3) for u in us],
    }


class Recorder:
    """Accumulates one bench run's structured metrics into a record."""

    def __init__(self, bench: str, out_dir: Optional[str] = None,
                 meta: Optional[Dict] = None):
        self.bench = bench
        self.out_dir = out_dir or results_dir()
        self.meta: Dict = dict(meta or {})
        self.metrics: Dict[str, Dict] = {}
        # Count XLA compiles from here on (idempotent; no-op when the
        # repro package isn't importable) and remember the dispatch
        # counters' state so record() can attribute this bench's delta.
        try:
            from repro.obs import jaxmon
            jaxmon.install()
        except ImportError:
            pass
        self._obs_baseline = _dispatch_breakdown()
        # Pending time_fn stats, keyed by their exact median float: the
        # next row() whose us_per_call is that median claims them, so
        # every timed row carries min/p50/p95/p99 with zero changes in
        # the bench modules.
        self._pending: Dict[float, Dict] = {}

    def note_timing(self, stats: Dict) -> None:
        if len(self._pending) > 64:  # unclaimed stats: drop the backlog
            self._pending.clear()
        self._pending[float(stats["p50_us"])] = stats

    def emit(self, name: str, us_per_call: float, derived,
             **extra) -> None:
        metric: Dict[str, object] = {
            "us_per_call": float(us_per_call),
            "derived": str(derived),
        }
        stats = self._pending.pop(float(us_per_call), None)
        if stats is not None:
            metric.update(stats)
        metric.update(extra)
        self.metrics[name] = metric

    def record(self) -> Dict:
        import jax
        meta = dict(self.meta)
        obs_meta = _obs_meta(self._obs_baseline)
        if obs_meta is not None:
            meta["obs"] = obs_meta
        return {
            "schema_version": SCHEMA_VERSION,
            "bench": self.bench,
            "created_unix": int(time.time()),
            "git_sha": git_sha(),
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "meta": meta,
            "metrics": self.metrics,
        }

    @property
    def path(self) -> str:
        return os.path.join(self.out_dir,
                            f"{RECORD_PREFIX}{self.bench}.json")

    def write(self) -> str:
        os.makedirs(self.out_dir, exist_ok=True)
        with open(self.path, "w") as f:
            json.dump(self.record(), f, indent=1)
            f.write("\n")
        return self.path


def start(bench: str, out_dir: Optional[str] = None,
          meta: Optional[Dict] = None) -> Recorder:
    """Open the process-wide active recorder (row()/time_fn feed it)."""
    global _ACTIVE
    _ACTIVE = Recorder(bench, out_dir=out_dir, meta=meta)
    return _ACTIVE


def active() -> Optional[Recorder]:
    return _ACTIVE


def finish(write: bool = True) -> Optional[str]:
    """Close the active recorder; returns the written path (or None)."""
    global _ACTIVE
    rec, _ACTIVE = _ACTIVE, None
    if rec is None or not write:
        return None
    return rec.write()


def emit_row(name: str, us_per_call: float, derived, **extra) -> None:
    """Structured mirror of ``common.row`` — no-op without a recorder."""
    if _ACTIVE is not None:
        _ACTIVE.emit(name, us_per_call, derived, **extra)


def note_timing(stats: Dict) -> None:
    if _ACTIVE is not None:
        _ACTIVE.note_timing(stats)


def from_report(bench: str, report: Dict, out_dir: Optional[str] = None,
                ) -> str:
    """Wrap a flat JSON report (e.g. serve_memhd's) into a BENCH record.

    Numeric scalar fields become metrics (``value`` carries the number;
    ``lat_ms_*`` fields additionally populate ``us_per_call`` so the
    regression gate treats them as lower-is-better timings); everything
    else lands in ``meta``. Writes immediately, independent of the
    active recorder.
    """
    rec = Recorder(bench, out_dir=out_dir)
    for key, val in report.items():
        if isinstance(val, bool) or not isinstance(val, (int, float)):
            rec.meta[key] = val
            continue
        us = float(val) * 1e3 if key.startswith("lat_ms") else 0.0
        rec.emit(key, us, val, value=float(val))
    return rec.write()
