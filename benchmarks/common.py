"""Shared benchmark utilities: timing, dataset cache, row emission.

Every benchmark prints rows ``name,us_per_call,derived`` (derived =
the figure/table quantity being reproduced: accuracy, ratio, cycles...).
Rows are ALSO mirrored into the active ``benchmarks.record`` recorder
(opened by ``benchmarks.run`` around each bench) so each run persists a
structured ``BENCH_<name>.json`` artifact instead of evaporating with
stdout; ``time_fn`` attaches its full sample stats (min/p50/p95/p99) to
the matching row automatically.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax

from benchmarks import record

_DATA_CACHE: Dict[str, object] = {}

# Budget knobs: small enough for the 1-core CPU container, large enough
# that the paper's orderings are visible. Real-data runs would lift these.
TRAIN_PER_CLASS = {"mnist": 300, "fmnist": 300, "isolet": 120}
TEST_PER_CLASS = {"mnist": 60, "fmnist": 60, "isolet": 40}
EPOCHS = 8


def dataset(name: str):
    if name not in _DATA_CACHE:
        from repro.data import load_dataset
        _DATA_CACHE[name] = load_dataset(
            name, train_per_class=TRAIN_PER_CLASS[name],
            test_per_class=TEST_PER_CLASS[name])
    return _DATA_CACHE[name]


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """True-median wall-time per call in microseconds (blocks on jax
    arrays). The full sample statistics (min alongside the median, so
    jitter on the 1-core CI container is visible; p95/p99 for larger
    ``iters``) are registered with the active recorder and attach to
    the next ``row`` emitted with this median as its ``us_per_call``.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append(time.perf_counter() - t0)
    stats = record.timing_stats(samples)
    record.note_timing(stats)
    return stats["p50_us"]


def time_fn_stats(fn: Callable, *args, iters: int = 3,
                  warmup: int = 1) -> dict:
    """Like ``time_fn`` but returns the full stats dict. Ratio asserts
    (speedup floors) should compare ``min_us``, not the p50: min is the
    noise-robust estimator on a loaded 1-core container, where one
    descheduled sample can halve a p50-based ratio. Emit rows with the
    ``p50_us`` so the recorder's pending stats still attach."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    stats = record.timing_stats(samples)
    record.note_timing(stats)
    return stats


def row(name: str, us_per_call: float, derived, **extra) -> str:
    """Emit one bench row: CSV to stdout + structured to the recorder.

    ``extra`` keys land verbatim in the metric's JSON record (use for
    structured values the CSV ``derived`` string flattens away).
    """
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    record.emit_row(name, us_per_call, derived, **extra)
    return line


def section(title: str):
    print(f"\n# === {title} ===", flush=True)
