"""Shared benchmark utilities: timing, dataset cache, CSV emission.

Every benchmark prints rows ``name,us_per_call,derived`` (derived =
the figure/table quantity being reproduced: accuracy, ratio, cycles...).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict

import jax

_DATA_CACHE: Dict[str, object] = {}

# Budget knobs: small enough for the 1-core CPU container, large enough
# that the paper's orderings are visible. Real-data runs would lift these.
TRAIN_PER_CLASS = {"mnist": 300, "fmnist": 300, "isolet": 120}
TEST_PER_CLASS = {"mnist": 60, "fmnist": 60, "isolet": 40}
EPOCHS = 8


def dataset(name: str):
    if name not in _DATA_CACHE:
        from repro.data import load_dataset
        _DATA_CACHE[name] = load_dataset(
            name, train_per_class=TRAIN_PER_CLASS[name],
            test_per_class=TEST_PER_CLASS[name])
    return _DATA_CACHE[name]


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us_per_call: float, derived) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def section(title: str):
    print(f"\n# === {title} ===", flush=True)
