"""Fig. 7: normalized AM energy and cycles across baseline models.

Energy ~ sequential array passes (NeuroSim-calibrated constants in
ImcArrayConfig); reproduces the paper's headline ratios: MEMHD 80x more
efficient than BasicHDC(10240D), 4x more than LeHDC(400D), and
"partitioning keeps energy constant"."""
from benchmarks.common import row, section
from repro.core.imc import ImcArrayConfig, map_basic, map_memhd, \
    map_partitioned

# Fig. 7 model zoo: equal-accuracy operating points from the paper.
MODELS = {
    "basichdc_10240d": (10240, 10),
    "searchd_8000d": (8000, 640),    # k x N = 10 x 64 binary vectors
    "quanthd_1600d": (1600, 10),
    "lehdc_400d": (400, 10),
}
MEMHD = (128, 128)


def main() -> None:
    section("Fig. 7: normalized AM energy & cycles (128x128 arrays)")
    arr = ImcArrayConfig()
    memhd = map_memhd(*MEMHD, arr)
    row("fig7/memhd_128x128/cycles", 0.0, memhd.cycles)
    row("fig7/memhd_128x128/energy_pj", 0.0, f"{memhd.energy_pj(arr):.1f}")
    for name, (d, cols) in MODELS.items():
        c = map_basic(d, cols, arr)
        ratio = c.energy_pj(arr) / memhd.energy_pj(arr)
        row(f"fig7/{name}/cycles", 0.0, c.cycles)
        row(f"fig7/{name}/arrays", 0.0, c.arrays)
        row(f"fig7/{name}/energy_vs_memhd", 0.0, f"{ratio:.1f}x")
    # Partitioning invariance (the Fig. 7 plateau):
    e0 = map_basic(10240, 10, arr).energy_pj(arr)
    for p in (5, 10):
        ep = map_partitioned(10240, 10, p, arr).energy_pj(arr)
        row(f"fig7/partition_p{p}/energy_ratio_vs_basic", 0.0,
            f"{ep / e0:.3f}")
    assert map_basic(10240, 10, arr).energy_pj(arr) \
        / memhd.energy_pj(arr) == 80.0
    assert map_basic(400, 10, arr).energy_pj(arr) \
        / memhd.energy_pj(arr) == 4.0


if __name__ == "__main__":
    main()
