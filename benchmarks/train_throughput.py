"""Training-engine throughput: scan-compiled epochs vs the host loop.

The tentpole claim of the device-resident QAIL engine: the pre-refactor
``qail_epoch_hostloop`` dispatches one jit call AND pulls one device
scalar PER MINIBATCH, while ``qail_epoch_scan`` runs the whole epoch as
one ``lax.scan`` dispatch with a single optional sync. This benchmark
measures both on identical data/state and reports:

  * samples/sec for each engine (and the speedup ratio — the acceptance
    bar is >= 5x on the CPU config),
  * host syncs per epoch (n_batches vs 1),
  * eval-accuracy parity after a full training run (must agree within
    +-0.2%), and epochs-to-accuracy for the scan engine.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, row, section, time_fn_stats
from repro.core import EncoderConfig, MemhdConfig, MemhdModel, encoding, qail

# 10 timed epochs per engine (~0.5 s total): the min-based speedup
# ratio needs enough draws for both mins to converge — with 3, one
# noisy triple flips the 5x floor assert on the shared CPU runner.
EPOCHS_TIMED = 10
TARGET_ACC = 0.70


def main() -> None:
    section("QAIL training engine: scan epochs vs host loop")
    ds = dataset("mnist")
    enc = EncoderConfig(kind="projection", features=ds.features, dim=256)
    amc = MemhdConfig(dim=256, columns=64, classes=ds.classes, epochs=8,
                      kmeans_iters=10, lr=0.02, batch_size=32)
    model = MemhdModel.create(jax.random.key(0), enc, amc)
    model, _ = model.initialize_am(jax.random.key(1), ds.train_x,
                                   ds.train_y)

    h = model.encode(ds.train_x)
    q = encoding.binarize_query(h)
    n = h.shape[0]
    n_batches = -(-n // amc.batch_size)
    hb, qb, yb, mask = qail.prebatch(h, q, ds.train_y, amc.batch_size)
    state0 = model.am_state

    def hostloop_epoch():
        st, _ = qail.qail_epoch_hostloop(state0, amc, h, q, ds.train_y)
        return st["fp"]

    def scan_epoch():
        # Fresh copy per call: the scan engine donates (consumes) its
        # state argument on accelerator backends.
        st0 = jax.tree.map(jnp.copy, state0)
        st, miss = qail.qail_epoch_scan(st0, amc, hb, qb, yb, mask)
        return st["fp"], miss

    host_stats = time_fn_stats(hostloop_epoch, iters=EPOCHS_TIMED)
    scan_stats = time_fn_stats(scan_epoch, iters=EPOCHS_TIMED)
    us_host, us_scan = host_stats["p50_us"], scan_stats["p50_us"]
    sps_host = n / (us_host / 1e6)
    sps_scan = n / (us_scan / 1e6)
    # Min-based ratio: one descheduled p50 sample mid-suite halves the
    # measured speedup and flips the floor assert on a loaded runner.
    speedup = host_stats["min_us"] / scan_stats["min_us"]
    row("train_epoch_hostloop", us_host, f"{sps_host:.0f} samples/s")
    row("train_epoch_scan", us_scan, f"{sps_scan:.0f} samples/s")
    row("train_scan_speedup", us_scan, f"{speedup:.1f}x")
    row("train_syncs_per_epoch_hostloop", 0.0, n_batches)
    row("train_syncs_per_epoch_scan", 0.0, 1)

    # Accuracy parity of the two engines after a full training run.
    eval_q = model.encode_query(ds.test_x)
    st_h = state0
    st_s = jax.tree.map(jnp.copy, state0)  # donated epoch-to-epoch below
    epochs_to_target = None
    for ep in range(1, amc.epochs + 1):
        st_h, _ = qail.qail_epoch_hostloop(st_h, amc, h, q, ds.train_y)
        st_s, _ = qail.qail_epoch_scan(st_s, amc, hb, qb, yb, mask)
        if epochs_to_target is None:
            acc_ep = qail.evaluate(st_s, eval_q, ds.test_y)
            if acc_ep >= TARGET_ACC:
                epochs_to_target = ep
    acc_host = qail.evaluate(st_h, eval_q, ds.test_y)
    acc_scan = qail.evaluate(st_s, eval_q, ds.test_y)
    row("train_eval_acc_hostloop", 0.0, f"{acc_host:.4f}")
    row("train_eval_acc_scan", 0.0, f"{acc_scan:.4f}")
    row("train_epochs_to_acc", 0.0,
        f"{epochs_to_target}@{TARGET_ACC}" if epochs_to_target
        else f">={amc.epochs}@{TARGET_ACC}")

    assert abs(acc_host - acc_scan) <= 0.002 + 1e-9, (acc_host, acc_scan)
    assert speedup >= 5.0, f"scan engine only {speedup:.1f}x over host loop"
    np.testing.assert_allclose(np.asarray(st_h["fp"]),
                               np.asarray(st_s["fp"]),
                               rtol=1e-5, atol=1e-5)


if __name__ == "__main__":
    main()
