"""Perf regression gate: diff BENCH_*.json runs against the baselines.

The loud half of the perf trajectory: ``benchmarks.run`` records every
bench into ``BENCH_<name>.json`` (see ``benchmarks.record``); this gate
compares a fresh run against the committed baseline set and exits
non-zero when the trajectory regresses:

  * a baseline bench has no current record           -> FAIL
  * a baseline metric is missing from the current run -> FAIL
  * a baseline metric had timing stats but the current one lost them
    (a bench silently stopped timing)                 -> FAIL
  * a timed metric slowed down by more than
    ``--max-slowdown-pct`` percent                    -> FAIL
  * schema_version mismatch                           -> FAIL
  * a kernel the baseline dispatched on a better execution tier now
    dispatches on a worse one (pallas > xla-oracle > ref, from the
    records' ``meta.obs.dispatch_tiers``) — a kernel silently falling
    off its fast path regresses even when its timings sit inside the
    noise floor                                       -> FAIL

New benches / new metrics in the current run pass (they become
baselines when ``--update-baselines`` refreshes the committed set).
Timings compare on ``min_us`` (the most machine-stable statistic of a
small sample; falls back to ``us_per_call`` for rows timed outside
``time_fn``) and ignore sub-``--min-us`` measurements, which are pure
scheduler noise. Structural checks are exact on any machine; the
timing threshold is meant to be strict for same-machine comparisons
(default 100%) and opened up for cross-machine CI (the workflow passes
``--max-slowdown-pct 300`` — catches an accidental O(n^2) or a kernel
falling off its fast path, not runner jitter).

Usage:
  python -m benchmarks.run --fast                   # records a run
  python -m benchmarks.gate                         # diff vs baselines
  python -m benchmarks.gate --update-baselines      # refresh baselines
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys
from typing import Dict, List, Optional, Tuple

from benchmarks import record

DEFAULT_MAX_SLOWDOWN_PCT = 100.0
DEFAULT_MIN_US = 50.0

# Execution-tier ordering for the dispatch-tier regression check:
# higher is the faster/realer path. Unknown tiers rank lowest.
TIER_RANK = {"ref": 0, "xla-oracle": 1, "pallas": 2}


def _dispatch_tiers(rec: Dict) -> Dict[str, Dict[str, int]]:
    return ((rec.get("meta") or {}).get("obs") or {}).get(
        "dispatch_tiers") or {}


def _best_tier(by_tier: Dict[str, int]) -> Optional[str]:
    best = None
    for tier, n in by_tier.items():
        if n > 0 and (best is None
                      or TIER_RANK.get(tier, -1) > TIER_RANK.get(best, -1)):
            best = tier
    return best


def compare_tiers(bench: str, base: Dict, cur: Dict,
                  ) -> Tuple[List[str], List[str]]:
    """Dispatch-tier diff for one bench -> (failures, notes).

    Per kernel both records exercised: the best tier serving it must
    not drop (pallas -> xla-oracle is exactly the silent fallback this
    check exists to catch). Kernels only the baseline saw, or baselines
    recorded before tier data existed, are notes — not failures."""
    failures: List[str] = []
    notes: List[str] = []
    base_tiers, cur_tiers = _dispatch_tiers(base), _dispatch_tiers(cur)
    if not base_tiers:
        return failures, notes  # pre-obs baseline: nothing to hold to
    if not cur_tiers:
        notes.append(f"{bench}: baseline has dispatch-tier data but "
                     f"the current record has none")
        return failures, notes
    for kernel, by_tier in sorted(base_tiers.items()):
        cur_by_tier = cur_tiers.get(kernel)
        if cur_by_tier is None:
            notes.append(f"{bench}: kernel {kernel} no longer "
                         f"dispatched (was {_best_tier(by_tier)})")
            continue
        b, c = _best_tier(by_tier), _best_tier(cur_by_tier)
        if (b is not None and c is not None
                and TIER_RANK.get(c, -1) < TIER_RANK.get(b, -1)):
            failures.append(
                f"{bench}: kernel {kernel} fell from tier {b} to {c} "
                f"(silent fast-path fallback)")
    return failures, notes


def load_dir(path: str) -> Dict[str, Dict]:
    """{bench_name: record} for every BENCH_*.json under ``path``."""
    out: Dict[str, Dict] = {}
    pattern = os.path.join(path, f"{record.RECORD_PREFIX}*.json")
    for fn in sorted(glob.glob(pattern)):
        with open(fn) as f:
            rec = json.load(f)
        name = rec.get("bench") or os.path.basename(fn)[
            len(record.RECORD_PREFIX):-len(".json")]
        out[name] = rec
    return out


def timing_us(metric: Dict) -> Optional[float]:
    """The gate's lower-is-better timing for one metric, if it has one."""
    if "min_us" in metric:
        return float(metric["min_us"])
    us = float(metric.get("us_per_call", 0.0))
    return us if us > 0.0 else None


def compare(baseline: Dict[str, Dict], current: Dict[str, Dict], *,
            max_slowdown_pct: float = DEFAULT_MAX_SLOWDOWN_PCT,
            min_us: float = DEFAULT_MIN_US,
            ) -> Tuple[List[str], List[str]]:
    """Diff two {bench: record} trees -> (failures, notes)."""
    failures: List[str] = []
    notes: List[str] = []
    for bench, base in sorted(baseline.items()):
        cur = current.get(bench)
        if cur is None:
            failures.append(f"{bench}: no current BENCH record "
                            f"(bench vanished from the run)")
            continue
        if cur.get("schema_version") != base.get("schema_version"):
            failures.append(
                f"{bench}: schema_version {cur.get('schema_version')} "
                f"!= baseline {base.get('schema_version')}")
            continue
        for name, bm in base.get("metrics", {}).items():
            cm = cur.get("metrics", {}).get(name)
            if cm is None:
                failures.append(f"{bench}/{name}: metric missing from "
                                f"the current run")
                continue
            t_base, t_cur = timing_us(bm), timing_us(cm)
            if t_base is None:
                continue  # untimed metric: presence is the contract
            if t_cur is None:
                failures.append(f"{bench}/{name}: baseline is timed "
                                f"but the current metric has no timing")
                continue
            if t_base < min_us or t_cur < min_us:
                continue  # sub-noise-floor measurement
            ratio = t_cur / t_base
            if ratio > 1.0 + max_slowdown_pct / 100.0:
                failures.append(
                    f"{bench}/{name}: {t_base:.1f}us -> {t_cur:.1f}us "
                    f"({(ratio - 1) * 100:.0f}% slower, limit "
                    f"{max_slowdown_pct:.0f}%)")
            elif ratio < 0.5:
                notes.append(f"{bench}/{name}: {(1 / ratio):.1f}x faster "
                             f"({t_base:.1f}us -> {t_cur:.1f}us)")
        extra_m = set(cur.get("metrics", {})) - set(base.get("metrics", {}))
        if extra_m:
            notes.append(f"{bench}: {len(extra_m)} new metric(s) not in "
                         f"baseline")
        tier_fails, tier_notes = compare_tiers(bench, base, cur)
        failures.extend(tier_fails)
        notes.extend(tier_notes)
    for bench in sorted(set(current) - set(baseline)):
        notes.append(f"{bench}: new bench (no baseline yet — refresh "
                     f"with --update-baselines)")
    return failures, notes


def update_baselines(current_dir: str, baseline_dir: str) -> List[str]:
    os.makedirs(baseline_dir, exist_ok=True)
    copied = []
    pattern = os.path.join(current_dir, f"{record.RECORD_PREFIX}*.json")
    for fn in sorted(glob.glob(pattern)):
        dst = os.path.join(baseline_dir, os.path.basename(fn))
        shutil.copyfile(fn, dst)
        copied.append(dst)
    return copied


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=record.baselines_dir(),
                    help="committed baseline dir (BENCH_*.json)")
    ap.add_argument("--current", default=record.results_dir(),
                    help="fresh run dir (benchmarks.run --record-dir)")
    ap.add_argument("--max-slowdown-pct", type=float,
                    default=DEFAULT_MAX_SLOWDOWN_PCT,
                    help="fail when a timed metric slows by more than "
                         "this percent (default %(default)s)")
    ap.add_argument("--min-us", type=float, default=DEFAULT_MIN_US,
                    help="ignore timings below this noise floor")
    ap.add_argument("--update-baselines", action="store_true",
                    help="copy the current records over the baselines "
                         "instead of gating")
    args = ap.parse_args(argv)

    if args.update_baselines:
        copied = update_baselines(args.current, args.baseline)
        if not copied:
            print(f"gate: no {record.RECORD_PREFIX}*.json under "
                  f"{args.current} to promote", file=sys.stderr)
            return 1
        for p in copied:
            print(f"gate: baseline <- {p}")
        return 0

    baseline = load_dir(args.baseline)
    current = load_dir(args.current)
    # An empty side means the gate is pointed at the wrong place — the
    # silent-success failure mode this PR exists to kill.
    if not baseline:
        print(f"gate: no baselines under {args.baseline} "
              f"(seed them with --update-baselines)", file=sys.stderr)
        return 1
    if not current:
        print(f"gate: no current records under {args.current} "
              f"(run: python -m benchmarks.run --fast "
              f"--record-dir {args.current})", file=sys.stderr)
        return 1

    failures, notes = compare(
        baseline, current, max_slowdown_pct=args.max_slowdown_pct,
        min_us=args.min_us)
    for n in notes:
        print(f"gate: note: {n}")
    if failures:
        for f_ in failures:
            print(f"gate: FAIL: {f_}", file=sys.stderr)
        print(f"gate: {len(failures)} regression(s) vs "
              f"{args.baseline}", file=sys.stderr)
        return 1
    print(f"gate: OK — {len(baseline)} bench(es), no regressions "
          f"(threshold {args.max_slowdown_pct:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
