"""Ablations of MEMHD's §III-B/C design choices (beyond the paper's own
figures, but directly about its method):

  * step-4 normalization: l2-equalization vs none
  * Eq.-6 update payload: encoded FP hypervector vs binarized query
  * binarization threshold: global mean (paper) vs per-centroid mean
  * allocation: confusion-driven (paper) vs R=1.0 (no allocation loop)

Each ablation flips exactly one knob from the reference configuration.
"""
import time

import jax

from benchmarks.common import dataset, row, section
from repro.core import EncoderConfig, MemhdConfig, MemhdModel

REF = dict(dim=256, columns=128, epochs=8, kmeans_iters=8, lr=0.015,
           init_ratio=0.8, update_with="encoded", normalize="l2",
           threshold="mean")

ABLATIONS = {
    "reference": {},
    "no_normalization": {"normalize": "none"},
    "binary_updates": {"update_with": "binary"},
    "per_centroid_threshold": {"threshold": "per_centroid"},
    "no_allocation_loop_R1": {"init_ratio": 1.0},
}


def main() -> None:
    for name in ("mnist", "isolet"):
        ds = dataset(name)
        section(f"Ablations ({name})")
        accs = {}
        for tag, overrides in ABLATIONS.items():
            kw = dict(REF, classes=ds.classes, **overrides)
            enc = EncoderConfig(kind="projection", features=ds.features,
                                dim=kw["dim"])
            amc = MemhdConfig(**kw)
            m = MemhdModel.create(jax.random.key(0), enc, amc)
            t0 = time.perf_counter()
            m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
            us = (time.perf_counter() - t0) * 1e6
            accs[tag] = m.score(ds.test_x, ds.test_y)
            row(f"ablation/{name}/{tag}", us, f"acc={accs[tag]:.4f}")
        for tag in ABLATIONS:
            if tag != "reference":
                row(f"ablation/{name}/{tag}_delta", 0.0,
                    f"{accs[tag] - accs['reference']:+.4f}")


if __name__ == "__main__":
    main()
