"""Online serving benchmark: deadline-aware batching + live folds.

Three phased runs of the ``repro.serve.OnlineEngine`` over one live
deployment (packed backend, single device):

  1. **steady** — an open-loop Poisson stream with per-request 250 ms
     deadlines and no model updates. Asserts the p99-latency floor
     (p99 <= deadline) and zero steady-state recompiles — the
     deadline-aware batcher must close batches early enough that the
     budget holds even while it waits to fill buckets.
  2. **fold (shape-stable)** — labeled drifted feedback folds through
     QAIL mid-stream; same geometry, so the generation swap must be
     shape-stable and cost zero steady-state recompiles.
  3. **fold (class growth)** — feedback labeled with a never-seen
     class grows the AM live; post-swap arrivals for the new class
     must be predicted (hit rate >= 0.5).

Rows: the steady-phase per-batch service p50 is the machine-bound
timing the regression gate tracks; deadline/fold/accuracy rows carry
their numbers as derived values with in-bench assertions (they measure
policy and learning, not raw machine speed).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row

DEADLINE_MS = 250.0
RATE_QPS = 400.0
N_STEADY = 80
N_PHASE = 30
MAX_BATCH = 64
MAX_WAIT_MS = 20.0
DRIFT = 0.4


def main() -> None:
    import jax

    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    from repro.data import load_dataset
    from repro.serve import (
        OnlineEngine, StreamingUpdater, apply_drift, feedback_burst,
        merge_events, poisson_arrivals,
    )

    ds = load_dataset("mnist", train_per_class=120, test_per_class=30)
    known = ds.classes - 1  # last class appended live in phase 3
    tr_x, tr_y = np.asarray(ds.train_x), np.asarray(ds.train_y)
    te_x, te_y = np.asarray(ds.test_x), np.asarray(ds.test_y)
    mask = tr_y < known
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=4 * known, classes=known,
                      epochs=3, kmeans_iters=3)
    model = MemhdModel.create(jax.random.key(0), enc, amc)
    model, _ = model.fit(jax.random.key(1), tr_x[mask], tr_y[mask])

    upd = StreamingUpdater(model, model.deploy(target="packed"),
                           fold_epochs=2)
    eng = OnlineEngine(upd, max_batch=MAX_BATCH, depth=2,
                       max_wait_ms=MAX_WAIT_MS)
    kw = dict(rate_qps=RATE_QPS, max_size=6, deadline_ms=DEADLINE_MS,
              labels_pool=te_y)

    # -- phase 1: steady deadline stream, no folds ------------------------
    rep = eng.serve(poisson_arrivals(te_x, n_requests=N_STEADY,
                                     classes=range(known), seed=1,
                                     **kw))
    assert rep["requests"] == N_STEADY
    assert rep["recompiles_steady_state"] == 0, rep
    p99 = rep["lat_ms_p99"]
    # The p99-deadline floor: the whole point of deadline-aware
    # admission. A miss here means the batcher waited past the budget.
    assert p99 is not None and p99 <= DEADLINE_MS, (
        f"steady p99 {p99}ms blew the {DEADLINE_MS}ms deadline")
    assert rep["deadline_miss_rate"] == 0.0, rep["deadline_miss_rate"]
    row("online/steady_service_p50", rep["service_ms_p50"] * 1e3,
        f"avg_batch={rep['avg_batch_rows']}",
        rows_per_s=rep["rows_per_s"])
    row("online/steady_p99", 0.0, f"{p99}ms<= {DEADLINE_MS}ms",
        p99_ms=p99, p50_ms=rep["lat_ms_p50"],
        deadline_miss_rate=rep["deadline_miss_rate"])

    # -- phase 2: shape-stable drift fold ---------------------------------
    fb = feedback_burst(apply_drift(tr_x[mask], DRIFT), tr_y[mask],
                        t=0.0, fold=True)
    arr = poisson_arrivals(apply_drift(te_x, DRIFT),
                           n_requests=N_PHASE, classes=range(known),
                           rid_base=10_000, seed=2, **kw)
    rep = eng.serve(merge_events(fb, arr))
    gen = rep["generations"][0]
    assert gen["shape_stable"] is True, gen
    assert rep["recompiles_steady_state"] == 0, rep
    assert rep["recompiles_excluded"]["rewarm"] == 0, rep
    row("online/fold_stable", 0.0, f"{gen['fold_ms']}ms",
        fold_ms=gen["fold_ms"], n_samples=gen["n_samples"],
        shape_stable=True)

    # -- phase 3: live class append ---------------------------------------
    new = tr_y == known
    fb = feedback_burst(tr_x[new], tr_y[new], t=0.0, fold=True)
    arr = poisson_arrivals(te_x, n_requests=N_PHASE, classes=[known],
                           rid_base=20_000, seed=3, **kw)
    rep = eng.serve(merge_events(fb, arr))
    gen = rep["generations"][0]
    assert gen["shape_stable"] is False and gen["n_new_classes"] == 1
    assert rep["recompiles_steady_state"] == 0, rep
    hits = total = 0
    for a in arr:
        pred = np.asarray(eng.responses[a.request.rid])
        hits += int((pred == known).sum())
        total += pred.shape[0]
    hit_rate = hits / total
    assert hit_rate >= 0.5, f"appended class hit rate {hit_rate:.2f}"
    row("online/fold_grow", 0.0, f"{gen['fold_ms']}ms",
        fold_ms=gen["fold_ms"], classes=gen["classes"],
        rewarm_compiles=rep["recompiles_excluded"]["rewarm"])
    row("online/append_hit_rate", 0.0, round(hit_rate, 3),
        generation=rep["model_generation"])


if __name__ == "__main__":
    main()
