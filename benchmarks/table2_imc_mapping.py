"""Table II: computation cycles, arrays, AM utilization on 128x128 arrays.

Closed-form from the IMC mapping model; asserted against the paper's
numbers (80x / 71x / 20x / 17.5x / 100%)."""
from benchmarks.common import row, section
from repro.core.imc import ImcArrayConfig, table2


def main() -> None:
    section("Table II: IMC mapping (128x128 array)")
    t = table2(ImcArrayConfig())
    for group, methods in t.items():
        for name, cost in methods.items():
            row(f"table2/{group}/{name}/cycles", 0.0, cost.total_cycles)
            row(f"table2/{group}/{name}/arrays", 0.0, cost.total_arrays)
            row(f"table2/{group}/{name}/am_util", 0.0,
                f"{cost.am.utilization:.4f}")

    a = t["mnist_fmnist"]
    b = t["isolet"]
    row("table2/mnist/cycle_improvement_vs_basic", 0.0,
        a["basic"].total_cycles / a["memhd"].total_cycles)      # 80x
    row("table2/mnist/array_improvement_vs_p10", 0.0,
        a["partition_p10"].total_arrays // a["memhd"].total_arrays)  # 71x
    row("table2/isolet/cycle_improvement_vs_basic", 0.0,
        b["basic"].total_cycles / b["memhd"].total_cycles)      # 20x
    row("table2/isolet/array_improvement_vs_p4", 0.0,
        b["partition_p4"].total_arrays / b["memhd"].total_arrays)  # 17.5x
    assert a["basic"].total_cycles / a["memhd"].total_cycles == 80.0
    assert b["basic"].total_cycles / b["memhd"].total_cycles == 20.0


if __name__ == "__main__":
    main()
