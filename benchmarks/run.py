"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark. Use
``--only fig3`` (prefix match; comma-separate for several, e.g.
``--only table2,fig_robustness``) to run a subset; ``--fast`` skips the
accuracy sweeps (minutes) and runs the closed-form + kernel benches.
"""
import argparse
import sys
import time
import traceback

BENCHES = [
    ("table2", "benchmarks.table2_imc_mapping"),
    ("fig7", "benchmarks.fig7_energy"),
    ("kernel", "benchmarks.kernel_bench"),
    ("packed", "benchmarks.packed_vs_unpacked"),
    ("pipeline", "benchmarks.pipeline_bench"),
    ("train_throughput", "benchmarks.train_throughput"),
    ("serve_scaling", "benchmarks.serve_scaling"),
    ("fig_robustness", "benchmarks.fig_robustness"),
    ("fig3", "benchmarks.fig3_accuracy_memory"),
    ("fig4", "benchmarks.fig4_heatmap"),
    ("fig5", "benchmarks.fig5_init"),
    ("fig6", "benchmarks.fig6_r_sweep"),
    ("ablation", "benchmarks.ablations"),
    ("roofline", "benchmarks.roofline_report"),
]
FAST = {"table2", "fig7", "kernel", "packed", "pipeline",
        "train_throughput", "fig_robustness", "roofline"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    only = [o for o in args.only.split(",") if o] if args.only else None
    failures = []
    for name, module in BENCHES:
        if only and not any(name.startswith(o) for o in only):
            continue
        if args.fast and name not in FAST:
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001 — keep the suite running
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        print(f"# FAILED benches: {failures}", file=sys.stderr)
        sys.exit(1)
    print("# all benches passed")


if __name__ == "__main__":
    main()
