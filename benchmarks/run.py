"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark and records
every bench into a persistent ``BENCH_<name>.json`` artifact (schema in
``benchmarks/record.py``; default dir ``benchmarks/results/``, override
with ``--record-dir`` / ``$MEMHD_BENCH_DIR``, disable with
``--no-record``). ``benchmarks.gate`` diffs those artifacts against the
committed ``benchmarks/baselines/`` set and fails on regressions.

Selection: ``--only fig3`` (prefix match; comma-separate for several,
e.g. ``--only table2,fig_robustness``) runs a subset and each token's
resolution is printed before anything runs; a token matching zero
benches exits non-zero immediately. An explicit ``--only`` OVERRIDES
``--fast`` — ``--fast`` alone runs the curated fast set (skips the
minutes-long accuracy sweeps). ``--list`` prints the resolved
selection and exits without running.
"""
import argparse
import sys
import time
import traceback
from typing import List, Tuple

from benchmarks import record

BENCHES = [
    ("table2", "benchmarks.table2_imc_mapping"),
    ("fig7", "benchmarks.fig7_energy"),
    ("kernel", "benchmarks.kernel_bench"),
    ("packed", "benchmarks.packed_vs_unpacked"),
    ("pipeline", "benchmarks.pipeline_bench"),
    ("train_throughput", "benchmarks.train_throughput"),
    ("serve_scaling", "benchmarks.serve_scaling"),
    ("online_serving", "benchmarks.online_serving"),
    ("fig_robustness", "benchmarks.fig_robustness"),
    ("fig3", "benchmarks.fig3_accuracy_memory"),
    ("fig4", "benchmarks.fig4_heatmap"),
    ("fig5", "benchmarks.fig5_init"),
    ("fig6", "benchmarks.fig6_r_sweep"),
    ("ablation", "benchmarks.ablations"),
    ("roofline", "benchmarks.roofline_report"),
    ("hillclimb", "benchmarks.hillclimb"),
    ("hierarchical_search", "benchmarks.hierarchical_search"),
    ("multibit_frontier", "benchmarks.multibit_frontier"),
]
FAST = {"table2", "fig7", "kernel", "packed", "pipeline",
        "train_throughput", "fig_robustness", "roofline",
        "hierarchical_search", "online_serving", "multibit_frontier"}


def resolve_selection(only: str | None, fast: bool,
                      ) -> List[Tuple[str, str]]:
    """Resolve --only/--fast into the bench list, loudly.

    An explicit ``--only`` overrides ``--fast`` (the old intersection
    semantics made ``--fast --only fig3`` run NOTHING and still print
    the all-passed banner). Every ``--only`` token's matches are
    printed before running; a token that matches zero benches is a
    hard error (exit 2), as is an empty overall selection.
    """
    names = [n for n, _ in BENCHES]
    if only is not None:
        tokens = [tok for tok in only.split(",") if tok]
        if not tokens:
            print("run: error: --only given but empty; known benches: "
                  + ", ".join(names), file=sys.stderr)
            raise SystemExit(2)
        selected: List[str] = []
        for tok in tokens:
            matches = [n for n in names if n.startswith(tok)]
            print(f"# --only {tok} -> "
                  f"{','.join(matches) if matches else '<nothing>'}",
                  flush=True)
            if not matches:
                print(f"run: error: --only token {tok!r} matched zero "
                      f"benches; known benches: {', '.join(names)}",
                      file=sys.stderr)
                raise SystemExit(2)
            selected += [m for m in matches if m not in selected]
        if fast:
            print("# note: explicit --only overrides --fast "
                  f"(running {','.join(selected)})", flush=True)
        keep = set(selected)
        return [(n, m) for n, m in BENCHES if n in keep]
    if fast:
        return [(n, m) for n, m in BENCHES if n in FAST]
    return list(BENCHES)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench name prefixes; "
                         "overrides --fast")
    ap.add_argument("--fast", action="store_true",
                    help="run the curated fast set (no accuracy sweeps)")
    ap.add_argument("--list", action="store_true",
                    help="print the resolved selection and exit")
    ap.add_argument("--record-dir", default=None,
                    help="where BENCH_<name>.json artifacts go "
                         "(default: benchmarks/results/)")
    ap.add_argument("--no-record", action="store_true",
                    help="skip writing BENCH_*.json artifacts")
    args = ap.parse_args(argv)

    selection = resolve_selection(args.only, args.fast)
    if not selection:  # unreachable belt-and-braces: never run nothing
        print("run: error: selection resolved to zero benches",
              file=sys.stderr)
        raise SystemExit(2)
    if args.list:
        for name, module in selection:
            print(f"{name}\t{module}")
        return

    print("name,us_per_call,derived")
    failures = []
    written = []
    for name, module in selection:
        t0 = time.time()
        if not args.no_record:
            record.start(name, out_dir=args.record_dir)
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
            path = record.finish(write=not args.no_record)
            if path:
                written.append(path)
                print(f"# {name} recorded -> {path}", flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001 — keep the suite running
            record.finish(write=False)  # discard the partial record
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        print(f"# FAILED benches: {failures}", file=sys.stderr)
        sys.exit(1)
    print(f"# all {len(selection)} selected benches passed"
          + (f" ({len(written)} BENCH records)" if written else ""))


if __name__ == "__main__":
    main()
