"""Multi-bit memory/accuracy frontier: bits-per-cell vs deployment accuracy.

Sweeps the resident-AM precision ladder at the flagship geometry —
1-bit (the paper's packed deployment), 2-bit and 4-bit (the bit-sliced
``target="multibit"`` backend, quantization-aware fine-tuned via
``fit(cell_bits=...)``) — against the 32-bit unpacked float path, and
across the paper geometries for residence/timing. The acceptance
contract of the multi-bit backend lives here: at least one of the
{2, 4}-bit points must hold iso-accuracy with the unpacked path
(within 0.5 pt) at >= 2x less resident AM memory.
"""
import json

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.common import dataset, row, section, time_fn
from repro.core import EncoderConfig, MemhdConfig, MemhdModel
from repro.imcsim import multibit_finetune
from repro.kernels import ref

BITS = (1, 2, 4)
GEOMS = [(128, 128), (256, 256), (512, 128)]
FLAGSHIP = (128, 128)
FINETUNE_EPOCHS = 4
ISO_ACC_PT = 0.005       # iso-accuracy tolerance: 0.5 accuracy points
MIN_MEM_REDUCTION = 2.0  # vs the unpacked float path


def _train(ds):
    d, c = FLAGSHIP
    enc = EncoderConfig(kind="projection", features=ds.features, dim=d)
    amc = MemhdConfig(dim=d, columns=c, classes=ds.classes, epochs=6,
                      kmeans_iters=10, lr=0.02)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
    return m


def main() -> None:
    d, c = FLAGSHIP
    section(f"multibit_frontier: bits/cell vs accuracy ({d}x{c})")
    ds = dataset("mnist")
    model = _train(ds)

    unpacked = model.deploy(target="unpacked")
    unpacked_acc = unpacked.score(ds.test_x, ds.test_y)
    row("multibit_frontier/unpacked_acc", 0.0, f"{unpacked_acc:.3f}",
        resident_bytes=unpacked.resident_bytes)

    frontier = []
    for bits in BITS:
        if bits == 1:
            dep = model.deploy(target="packed")
        else:
            tuned, _ = multibit_finetune(
                model, jax.random.key(2), ds.train_x, ds.train_y, bits,
                epochs=FINETUNE_EPOCHS)
            dep = tuned.deploy(target="multibit", cell_bits=bits)
        acc = dep.score(ds.test_x, ds.test_y)
        reduction = unpacked.resident_bytes / dep.resident_bytes
        rec = {
            "bench": "multibit_frontier",
            "bits": bits,
            "backend": dep.backend,
            "accuracy": round(float(acc), 4),
            "resident_bytes": dep.resident_bytes,
            "memory_bits": (dep.memory_bits if bits > 1
                            else dep.enc_cfg.memory_bits
                            + dep.am_cfg.am_memory_bits),
            "mem_reduction_vs_unpacked": round(reduction, 2),
            "iso_accuracy": bool(acc >= unpacked_acc - ISO_ACC_PT),
        }
        frontier.append(rec)
        print(json.dumps(rec), flush=True)
        row(f"multibit_frontier/b{bits}_acc", 0.0, f"{acc:.3f}",
            **{k: v for k, v in rec.items() if k != "bench"})

    # Acceptance: >= 1 multi-bit point holds iso-accuracy at >= 2x less
    # resident AM memory than the unpacked float path.
    winners = [r for r in frontier if r["bits"] > 1 and r["iso_accuracy"]
               and r["mem_reduction_vs_unpacked"] >= MIN_MEM_REDUCTION]
    assert winners, (
        f"no multi-bit point holds iso-accuracy (within {ISO_ACC_PT}) at "
        f">= {MIN_MEM_REDUCTION}x memory reduction: {frontier} "
        f"(unpacked acc {unpacked_acc:.4f})")
    best = min(winners, key=lambda r: r["bits"])
    row("multibit_frontier/best", 0.0,
        f"b{best['bits']}:{best['mem_reduction_vs_unpacked']:.0f}x",
        **{k: v for k, v in best.items() if k != "bench"})

    # Residence + oracle timing across the paper geometries (random
    # codes: the kernel searches the integer code domain, accuracy is
    # geometry-independent here).
    section("multibit_frontier: residence/timing across geometries")
    rng = np.random.default_rng(0)
    for gd, gc in GEOMS:
        q = jnp.asarray(rng.choice([-1., 1.], size=(256, gd))
                        .astype(np.float32))
        for bits in (2, 4):
            qmax = 2 ** (bits - 1) - 1
            codes = rng.integers(-qmax, qmax + 1, size=(gc, gd))
            planes = ref.pack_planes(jnp.asarray(codes + qmax), bits)
            us = time_fn(
                jax.jit(lambda qq, pp, b=bits: ref.am_search_multibit(
                    qq, pp, cell_bits=b)), q, planes, iters=3)
            plane_bytes = int(planes.size)
            row(f"multibit_frontier/{gd}x{gc}_b{bits}", us,
                f"bytes={plane_bytes};"
                f"vs_f32={gd * gc * 4 / plane_bytes:.1f}x")


if __name__ == "__main__":
    main()
