"""Robustness figure: deployment accuracy vs. device fidelity.

The figure the paper doesn't have but every IMC deployment needs:
the flagship 128x128 MEMHD model deployed through the device-fidelity
simulator (``repro.imcsim``) across ADC resolution, conductance-noise
sigma, and stuck-at fault rate, plus the noise-aware QAIL recovery row
at the headline noisy point (chip-in-the-loop fine-tune, same device
instance). Also asserts the fidelity-parity contract: an ideal sim
(16-bit ADC, no perturbations) must reproduce the digital accuracy
exactly, and the kernel timing row measures the simulated analog search
against the exact digital kernel.
"""
import time

import jax

from benchmarks.common import dataset, row, section, time_fn
from repro.core import EncoderConfig, ImcSimConfig, MemhdConfig, MemhdModel
from repro.imcsim import (
    imc_accuracy, recovery_experiment, sweep_adc_bits, sweep_fault_rate,
    sweep_noise_sigma,
)
from repro.kernels import ops

ADC_BITS = (16, 8, 6, 4, 3)
NOISE_SIGMAS = (0.0, 0.25, 0.5, 1.0)
FAULT_RATES = (0.0, 0.02, 0.05, 0.1)
HEADLINE_SIGMA = 0.5   # the documented recovery setting
DEVICE_SEED = 7
FINETUNE_EPOCHS = 10


def _train(ds):
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=128, classes=ds.classes, epochs=6,
                      kmeans_iters=10, lr=0.02)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
    return m


def main() -> None:
    section("fig_robustness: accuracy vs device fidelity (128x128)")
    ds = dataset("mnist")
    t0 = time.time()
    model = _train(ds)
    digital = model.score(ds.test_x, ds.test_y)
    row("fig_robustness/train_s", (time.time() - t0) * 1e6,
        f"{digital:.3f}")

    base = ImcSimConfig(seed=DEVICE_SEED)
    ideal = imc_accuracy(model, ds.test_x, ds.test_y, base)
    assert ideal == digital, (ideal, digital)  # fidelity-parity contract
    row("fig_robustness/ideal_sim_acc", 0.0, f"{ideal:.3f}")

    # Kernel timing: simulated analog search vs the exact digital kernel.
    q = model.encode_query(ds.test_x)
    am = model.am_state["binary"]
    us_dig = time_fn(lambda: ops.am_search(q, am))
    us_imc = time_fn(lambda: ops.am_search_imc(q, am, sim=base))
    row("fig_robustness/am_search_us", us_dig, "digital")
    row("fig_robustness/am_search_imc_us", us_imc,
        f"{us_imc / us_dig:.1f}x")

    for r in sweep_adc_bits(model, ds.test_x, ds.test_y, ADC_BITS, base):
        row(f"fig_robustness/adc_b{r['adc_bits']}", 0.0,
            f"{r['accuracy']:.3f}")
    for r in sweep_noise_sigma(model, ds.test_x, ds.test_y,
                               NOISE_SIGMAS, base):
        row(f"fig_robustness/noise_s{r['noise_sigma']}", 0.0,
            f"{r['accuracy']:.3f}")
    for r in sweep_fault_rate(model, ds.test_x, ds.test_y,
                              FAULT_RATES, base):
        row(f"fig_robustness/fault_r{r['fault_rate']}", 0.0,
            f"{r['accuracy']:.3f}")

    # Noise-aware QAIL recovery at the documented headline point.
    import dataclasses
    noisy = dataclasses.replace(base, noise_sigma=HEADLINE_SIGMA)
    rep = recovery_experiment(
        model, jax.random.key(2), ds.train_x, ds.train_y,
        ds.test_x, ds.test_y, noisy, epochs=FINETUNE_EPOCHS)
    row("fig_robustness/recovery_before", 0.0,
        f"{rep['noisy_accuracy_before']:.3f}")
    row("fig_robustness/recovery_after", 0.0,
        f"{rep['noisy_accuracy_after']:.3f}")
    row("fig_robustness/recovered_frac", 0.0,
        f"{rep['recovered_frac']:.2f}")


if __name__ == "__main__":
    main()
