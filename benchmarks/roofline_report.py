"""Roofline table from the dry-run artifacts (reports/dryrun/*.json).

Prints per-cell terms (compute / memory / collective, seconds), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and ranks hillclimb
candidates: worst roofline fraction, most collective-bound, and the MoE
flagship. Also emits the EXPERIMENTS.md §Roofline markdown table to
reports/roofline_table.md.

The kernel section rooflines the repro's own dispatch layer: one probe
dispatch per registered kernel (all nine), the execution tier that
actually served it (``ops.dispatch_breakdown`` — a silent oracle
fallback is visible here), and the analytic arithmetic intensity
(flops per HBM byte at the probe geometry) that decides which side of
the machine balance point each kernel lands on.
"""
import glob
import json
import os

from benchmarks.common import row, section

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def load_reports(mesh: str = "16x16"):
    out = []
    for fn in sorted(glob.glob(os.path.join(REPORT_DIR, "*.json"))):
        if "__" + mesh + ".json" not in fn:
            continue
        with open(fn) as f:
            rep = json.load(f)
        if rep.get("status") == "ok" and not rep.get("overrides"):
            out.append(rep)
    return out


def kernel_dispatch_section() -> None:
    """One probe dispatch per registered kernel: served tier + analytic
    arithmetic intensity (flops per HBM byte) at the probe geometry."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.types import ImcArrayConfig, ImcSimConfig
    from repro.deploy import hierarchical as hier
    from repro.kernels import ops, ref

    section("Roofline: kernel dispatch tiers + arithmetic intensity")
    rng = np.random.default_rng(0)
    b, f, d, c, bits = 8, 32, 128, 16, 2

    def bip(shape):
        return jnp.asarray(rng.choice([-1., 1.], size=shape)
                           .astype(np.float32))

    feats = jnp.asarray(rng.random((b, f), dtype=np.float32))
    proj = bip((f, d))
    q, am = bip((b, d)), bip((c, d))
    qp = ops.pack_rows(q)
    apt = ops.pack_rows(am).T
    qmax = 2 ** (bits - 1) - 1
    codes = rng.integers(-qmax, qmax + 1, size=(c, d))
    planes = ref.pack_planes(jnp.asarray(codes + qmax), bits)
    g = 2
    assign = rng.integers(0, g, size=c).astype(np.int32)
    layout = hier.build_layout(np.asarray(apt), assign, g)
    short = jnp.zeros((b, 1), jnp.int32)
    owners = jnp.arange(c, dtype=jnp.int32) % 3
    labels = jnp.zeros((b,), jnp.int32)
    mask = jnp.ones((b,), jnp.float32)
    sim = ImcSimConfig(arr=ImcArrayConfig(rows=128, cols=128))

    # (kernel, probe thunk, flops, hbm bytes) — flops count the MVM /
    # popcount work, bytes the operand + result traffic (packed operands
    # at 1/8 byte per cell, bit planes at bits/8).
    probes = [
        ("binary_mvm", lambda: ops.encode_mvm(feats, proj),
         2 * b * f * d, 4 * (b * f + f * d + b * d)),
        ("encode_pack", lambda: ops.encode_pack(feats, proj),
         2 * b * f * d + b * d, 4 * (b * f + f * d) + b * d // 8),
        ("am_search", lambda: ops.am_search(q, am),
         2 * b * d * c + b * c, 4 * (b * d + d * c) + 8 * b),
        ("am_search_imc", lambda: ops.am_search_imc(q, am, sim=sim),
         2 * b * d * c + 2 * b * c, 4 * (b * d + d * c) + 8 * b),
        ("am_search_multibit",
         lambda: ops.am_search_multibit(q, planes),
         bits * 2 * b * d * c + 2 * b * c,
         4 * b * d + bits * (d // 8) * c + 8 * b),
        ("am_search_packed",
         lambda: ops.am_search_packed(qp, apt, n_dims=d),
         2 * b * c * (d // 8), (b + c) * (d // 8) + 8 * b),
        ("am_shortlist",
         lambda: ops.am_shortlist(qp, apt, n_dims=d, s=2),
         2 * b * c * (d // 8) + 2 * b * c,
         (b + c) * (d // 8) + 2 * 4 * b),
        ("am_search_sparse",
         lambda: ops.am_search_sparse(
             qp, jnp.asarray(layout.slab), jnp.asarray(layout.col_ids),
             short, jnp.asarray(layout.tile_start),
             jnp.asarray(layout.tile_count), n_dims=d, k=1,
             max_tiles=layout.max_tiles),
         2 * b * layout.slab.shape[1] * (d // 8),
         (b + layout.slab.shape[1]) * (d // 8) + 8 * b),
        ("qail_update",
         lambda: ops.qail_update(q, q, am.T, owners, labels, mask,
                                 lr=0.5),
         2 * b * d * c + 4 * b * d, 4 * (b * d + 2 * d * c)),
    ]
    for kernel, probe, flops, nbytes in probes:
        before = ops.dispatch_breakdown().get(kernel, {})
        probe()
        after = ops.dispatch_breakdown().get(kernel, {})
        tiers = [t for t in after
                 if after.get(t, 0) > before.get(t, 0)]
        tier = tiers[0] if tiers else "uncounted"
        ai = flops / nbytes
        row(f"roofline/kernel/{kernel}", 0.0,
            f"tier={tier};ai={ai:.1f}flops/B",
            tier=tier, flops=flops, hbm_bytes=nbytes,
            arithmetic_intensity=round(ai, 2))
    assert len(probes) == 9, "keep this table in sync with ops.py"


def main() -> None:
    kernel_dispatch_section()
    section("Roofline: single-pod (16x16) baselines from dry-run")
    reps = load_reports("16x16")
    if not reps:
        row("roofline/no_reports_found", 0.0,
            "run: python -m repro.launch.dryrun --all --mesh both")
        return

    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
             "dominant | useful | MFU-bound | fits 16GB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for rep in reps:
        r = rep["roofline"]
        name = f"{rep['arch']}/{rep['shape']}"
        row(f"roofline/{name}/terms", 0.0,
            f"comp={r['t_compute']:.4g};mem={r['t_memory']:.4g};"
            f"coll={r['t_collective']:.4g};dom={r['dominant']}")
        row(f"roofline/{name}/useful_flops_ratio", 0.0,
            f"{r['useful_flops_ratio']:.3f}")
        row(f"roofline/{name}/mfu_bound", 0.0, f"{r['mfu_bound']:.4f}")
        lines.append(
            f"| {rep['arch']} | {rep['shape']} | {r['t_compute']:.4g} | "
            f"{r['t_memory']:.4g} | {r['t_collective']:.4g} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['mfu_bound']:.4f} | "
            f"{rep.get('memory', {}).get('fits_16GB', 'n/a')} |")

    out_md = os.path.join(REPORT_DIR, "..", "roofline_table.md")
    with open(out_md, "w") as f:
        f.write("\n".join(lines) + "\n")
    row("roofline/table_written", 0.0, os.path.abspath(out_md))

    # Hillclimb candidate ranking.
    train_reps = [x for x in reps if x.get("step") == "train"]
    if train_reps:
        worst = min(train_reps, key=lambda x: x["roofline"]["mfu_bound"])
        row("roofline/worst_mfu_bound", 0.0,
            f"{worst['arch']}/{worst['shape']}="
            f"{worst['roofline']['mfu_bound']:.4f}")
    coll = [x for x in reps if x["roofline"]["dominant"] == "collective"]
    if coll:
        most_coll = max(coll,
                        key=lambda x: x["roofline"]["t_collective"])
        row("roofline/most_collective_bound", 0.0,
            f"{most_coll['arch']}/{most_coll['shape']}="
            f"{most_coll['roofline']['t_collective']:.3g}s")


if __name__ == "__main__":
    main()
