"""Roofline table from the dry-run artifacts (reports/dryrun/*.json).

Prints per-cell terms (compute / memory / collective, seconds), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and ranks hillclimb
candidates: worst roofline fraction, most collective-bound, and the MoE
flagship. Also emits the EXPERIMENTS.md §Roofline markdown table to
reports/roofline_table.md.
"""
import glob
import json
import os

from benchmarks.common import row, section

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def load_reports(mesh: str = "16x16"):
    out = []
    for fn in sorted(glob.glob(os.path.join(REPORT_DIR, "*.json"))):
        if "__" + mesh + ".json" not in fn:
            continue
        with open(fn) as f:
            rep = json.load(f)
        if rep.get("status") == "ok" and not rep.get("overrides"):
            out.append(rep)
    return out


def main() -> None:
    section("Roofline: single-pod (16x16) baselines from dry-run")
    reps = load_reports("16x16")
    if not reps:
        row("roofline/no_reports_found", 0.0,
            "run: python -m repro.launch.dryrun --all --mesh both")
        return

    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
             "dominant | useful | MFU-bound | fits 16GB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for rep in reps:
        r = rep["roofline"]
        name = f"{rep['arch']}/{rep['shape']}"
        row(f"roofline/{name}/terms", 0.0,
            f"comp={r['t_compute']:.4g};mem={r['t_memory']:.4g};"
            f"coll={r['t_collective']:.4g};dom={r['dominant']}")
        row(f"roofline/{name}/useful_flops_ratio", 0.0,
            f"{r['useful_flops_ratio']:.3f}")
        row(f"roofline/{name}/mfu_bound", 0.0, f"{r['mfu_bound']:.4f}")
        lines.append(
            f"| {rep['arch']} | {rep['shape']} | {r['t_compute']:.4g} | "
            f"{r['t_memory']:.4g} | {r['t_collective']:.4g} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['mfu_bound']:.4f} | "
            f"{rep.get('memory', {}).get('fits_16GB', 'n/a')} |")

    out_md = os.path.join(REPORT_DIR, "..", "roofline_table.md")
    with open(out_md, "w") as f:
        f.write("\n".join(lines) + "\n")
    row("roofline/table_written", 0.0, os.path.abspath(out_md))

    # Hillclimb candidate ranking.
    train_reps = [x for x in reps if x.get("step") == "train"]
    if train_reps:
        worst = min(train_reps, key=lambda x: x["roofline"]["mfu_bound"])
        row("roofline/worst_mfu_bound", 0.0,
            f"{worst['arch']}/{worst['shape']}="
            f"{worst['roofline']['mfu_bound']:.4f}")
    coll = [x for x in reps if x["roofline"]["dominant"] == "collective"]
    if coll:
        most_coll = max(coll,
                        key=lambda x: x["roofline"]["t_collective"])
        row("roofline/most_collective_bound", 0.0,
            f"{most_coll['arch']}/{most_coll['shape']}="
            f"{most_coll['roofline']['t_collective']:.3g}s")


if __name__ == "__main__":
    main()
