"""§Perf hillclimb driver: run named variants of the three selected cells.

Each variant is (cell, overrides) run through the same dry-run path as
the baselines; artifacts land in reports/dryrun/ with override tags and
are compared in EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m benchmarks.hillclimb --variant dsv3_accum4
  PYTHONPATH=src python -m benchmarks.hillclimb --variant memhd_baseline
  PYTHONPATH=src python -m benchmarks.hillclimb --list

Registered in ``benchmarks.run`` as the ``hillclimb`` bench: the
no-args path runs the paper-representative memhd cell at a reduced
geometry in a SUBPROCESS (the 16x16 production mesh needs
``--xla_force_host_platform_device_count`` set before jax initializes,
which is impossible once the parent run has touched jax) and emits the
roofline terms as bench rows.
"""
import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

if __name__ == "__main__":
    # Only effective when this module IS the entry point (flag must be
    # set before jax initializes); the registered-bench path relies on
    # the subprocess re-exec instead.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512")


def _musicgen_padded_heads():
    """Heads 24 -> 32 so attention shards over the 16-way model axis."""
    from repro.configs import get_config
    cfg = get_config("musicgen-medium")
    blocks = []
    for b in cfg.blocks:
        attn = dataclasses.replace(b.attn, n_heads=32, n_kv_heads=32)
        blocks.append(dataclasses.replace(b, attn=attn))
    return {"blocks": tuple(blocks)}


def _mamba_chunk(q: int):
    from repro.configs import get_config
    cfg = get_config("mamba2-130m")
    blocks = []
    for b in cfg.blocks:
        blocks.append(dataclasses.replace(
            b, ssm=dataclasses.replace(b.ssm, chunk=q)))
    return {"blocks": tuple(blocks)}


def _dsv3_capacity(cf: float):
    from repro.configs import get_config
    cfg = get_config("deepseek-v3-671b")
    blocks = []
    for b in cfg.blocks:
        if b.ffn.kind == "moe":
            b = dataclasses.replace(
                b, ffn=dataclasses.replace(b.ffn, capacity_factor=cf))
        blocks.append(b)
    return {"blocks": tuple(blocks)}


VARIANTS = {
    # --- deepseek-v3-671b x train_4k (most collective-bound) -------------
    "dsv3_accum8": ("deepseek-v3-671b", "train_4k",
                    lambda: {"grad_accum": 8}),
    "dsv3_accum4": ("deepseek-v3-671b", "train_4k",
                    lambda: {"grad_accum": 4}),
    "dsv3_accum2": ("deepseek-v3-671b", "train_4k",
                    lambda: {"grad_accum": 2}),
    "dsv3_cf1_accum4": ("deepseek-v3-671b", "train_4k",
                        lambda: dict(_dsv3_capacity(1.0), grad_accum=4)),
    "dsv3_ep256_accum4": (
        "deepseek-v3-671b", "train_4k",
        lambda: {"grad_accum": 4,
                 "rule_overrides": (("experts", ("model", "data")),)}),
    "dsv3_ep256_accum2": (
        "deepseek-v3-671b", "train_4k",
        lambda: {"grad_accum": 2,
                 "rule_overrides": (("experts", ("model", "data")),)}),
    # --- musicgen-medium x train_4k (worst roofline fraction) ------------
    "musicgen_pad32": ("musicgen-medium", "train_4k",
                       lambda: _musicgen_padded_heads()),
    "musicgen_pad32_accum8": (
        "musicgen-medium", "train_4k",
        lambda: dict(_musicgen_padded_heads(), grad_accum=8)),
    "musicgen_pad32_accum4": (
        "musicgen-medium", "train_4k",
        lambda: dict(_musicgen_padded_heads(), grad_accum=4)),
    "musicgen_accum4": ("musicgen-medium", "train_4k",
                        lambda: {"grad_accum": 4}),
    # --- extras beyond the three required threads ---------------------
    "qwen_decode_int8kv": ("qwen1.5-32b", "decode_32k",
                           lambda: {"kv_cache_quant": True}),
    "gemma3_500k_seqpar": ("gemma3-12b", "long_500k",
                           lambda: {"seq_parallel_decode": True}),
    "mamba2_chunk128": ("mamba2-130m", "train_4k",
                        lambda: _mamba_chunk(128)),
    "mamba2_chunk512": ("mamba2-130m", "train_4k",
                        lambda: _mamba_chunk(512)),
    "musicgen_pad32_fsdp": (
        "musicgen-medium", "train_4k",
        lambda: dict(_musicgen_padded_heads(), fsdp=True)),
}


def run_variant(name: str) -> dict:
    arch, shape, make_overrides = VARIANTS[name]
    from repro.launch.dryrun import run_cell
    rep = run_cell(arch, shape, multi_pod=False,
                   overrides=make_overrides())
    return rep


def run_memhd(dim: int = 1024, columns: int = 1024,
              samples: int = 61_440) -> dict:
    """The paper-representative cell: distributed QAIL epoch."""
    import jax
    from repro.core.distributed import dryrun_epoch
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    rep = dryrun_epoch(mesh, dim=dim, columns=columns, n_samples=samples)
    out = {"arch": "memhd-qail", "shape": f"{dim}x{columns}x{samples}",
           "mesh": "16x16", "status": "ok", "step": "memhd", **rep}
    d = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")
    os.makedirs(d, exist_ok=True)
    fn = os.path.join(d, f"memhd-qail__{dim}x{columns}x{samples}__16x16.json")
    with open(fn, "w") as f:
        json.dump(out, f, indent=1, default=str)
    return out


def bench_memhd_cell() -> None:
    """Registered-bench path: the memhd cell in a fresh interpreter.

    Reduced geometry (256x256, 8192 samples) — the cell only lowers and
    compiles (roofline cost model, no training), so this is a compile
    benchmark; the JSON summary the subprocess prints becomes the row's
    derived metrics.
    """
    from benchmarks.common import row

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.hillclimb", "--memhd",
           "--dim", "256", "--columns", "256", "--samples", "8192"]
    t0 = time.perf_counter()
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root)
    elapsed_us = (time.perf_counter() - t0) * 1e6
    if proc.returncode != 0:
        raise RuntimeError(
            f"hillclimb memhd subprocess failed "
            f"(rc={proc.returncode}):\n{proc.stderr[-2000:]}")
    stdout = proc.stdout
    rep = json.loads(stdout[stdout.index("{"):])
    row("hillclimb_memhd_256x256", elapsed_us,
        f"dominant={rep['dominant']} mfu_bound={rep['mfu_bound']:.3f}",
        t_compute=rep["t_compute"], t_memory=rep["t_memory"],
        t_collective=rep["t_collective"], useful=rep["useful"],
        mfu_bound=rep["mfu_bound"], live_gb=rep["live_GB"])


def main(argv=None):
    # benchmarks.run calls main() with no args: run the registered
    # bench path (NOT sys.argv, which would be run.py's own flags).
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default=None)
    ap.add_argument("--memhd", action="store_true")
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--columns", type=int, default=1024)
    ap.add_argument("--samples", type=int, default=61_440)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args([] if argv is None else argv)
    if args.list:
        for k in VARIANTS:
            print(k)
        return
    if args.memhd:
        rep = run_memhd(args.dim, args.columns, args.samples)
    elif args.variant is None:
        bench_memhd_cell()
        return
    else:
        rep = run_variant(args.variant)
    r = rep["roofline"]
    print(json.dumps({
        "variant": args.variant or "memhd",
        "status": rep.get("status"),
        "t_compute": r["t_compute"], "t_memory": r["t_memory"],
        "t_collective": r["t_collective"], "dominant": r["dominant"],
        "useful": r["useful_flops_ratio"], "mfu_bound": r["mfu_bound"],
        "wire_by_kind_GB": {k: round(v / 1e9, 1)
                            for k, v in r["wire_by_kind"].items()},
        "live_GB": round((rep["memory"]["argument_bytes"]
                          + rep["memory"]["temp_bytes"]
                          - rep["memory"].get("alias_bytes", 0)) / 1e9, 1),
        "grad_accum": rep.get("grad_accum"),
    }, indent=1))


if __name__ == "__main__":
    main(sys.argv[1:])
