"""Kernel microbenchmarks + IMC-geometry consistency.

Wall-clock on CPU times the *oracle* (jit'd jnp) path — Pallas interpret
mode executes the kernel body in Python and is a correctness tool, not a
throughput proxy. The structural quantity that carries to TPU is the
kernel grid (== IMC array cycles), asserted here against the cost model
for every paper geometry.

The autotune section is the exception: it times the REAL Pallas
dispatch (interpret off-TPU) at the default vs the tuned batch tile,
because the quantity under test — grid steps per dispatch — is exactly
what interpret mode's per-step overhead exposes and what carries to the
TPU dispatch structure. Tuned and default tilings are asserted
bit-exact against the ref.py oracle before timing.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, section, time_fn
from repro.core.imc import ImcArrayConfig, map_basic, map_memhd
from repro.kernels import autotune, ops, ref
from repro.kernels.am_search import imc_cycles_for as search_cycles
from repro.kernels.binary_mvm import imc_cycles_for as mvm_cycles

GEOMS = [(128, 128), (256, 256), (512, 128), (1024, 1024)]
TUNE_BATCH = 512  # batch the tuned-vs-default microbench dispatches


def main() -> None:
    section("Kernel bench: associative search + encoding")
    rng = np.random.default_rng(0)
    arr = ImcArrayConfig()
    for d, c in GEOMS:
        q = jnp.asarray(rng.choice([-1., 1.], size=(256, d))
                        .astype(np.float32))
        am = jnp.asarray(rng.choice([-1., 1.], size=(c, d))
                         .astype(np.float32))
        amt = am.T

        search_ref = jax.jit(lambda qq, aa: ref.am_search(qq, aa))
        us = time_fn(search_ref, q, amt, iters=5)
        grid = search_cycles((d, c))
        model = map_memhd(d, c, arr).cycles
        row(f"kernel/am_search_{d}x{c}", us,
            f"grid_steps={grid};imc_cycles={model}")
        assert grid == model

        # Spot correctness of the Pallas kernel (interpret mode).
        gi, gs = ops.am_search(q[:8], am)
        wi, ws = ref.am_search(q[:8], amt)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))

    section("Kernel bench: projection encoding (EM)")
    for f, d in ((784, 128), (784, 1024), (617, 512)):
        x = jnp.asarray(rng.normal(size=(256, f)).astype(np.float32))
        w = jnp.asarray(rng.choice([-1., 1.], size=(f, d))
                        .astype(np.float32))
        mvm_ref = jax.jit(lambda xx, ww: ref.binary_mvm(xx, ww))
        us = time_fn(mvm_ref, x, w, iters=5)
        grid = mvm_cycles((256, f), (f, d))
        model = map_basic(f, d, arr).cycles
        row(f"kernel/encode_mvm_{f}x{d}", us,
            f"grid_steps={grid};imc_cycles={model}")
        assert grid == model

    section("Kernel bench: 1-bit pack/unpack")
    x = jnp.asarray(rng.choice([-1., 1.], size=(1024, 1024))
                    .astype(np.float32))
    pack_ref = jax.jit(ref.pack_bits)
    us = time_fn(pack_ref, x, iters=5)
    p = ops.pack_bits(x)
    row("kernel/pack_bits_1024x1024", us,
        f"bytes={p.size};ratio={x.size * 4 / p.size:.0f}x")

    section("Kernel bench: autotuned vs default batch tiles")
    # Real Pallas dispatch at the cache's tuned block_b vs the fixed
    # default — the recorded microbench behind the autotune layer. Each
    # tiling is parity-checked bit-exactly against its ref.py oracle
    # inside autotune before timing; here we assert the winner actually
    # recorded a win wherever the tuned tile differs from the default.
    wins = []
    for kernel, dims in (("am_search_packed", {"D": 128, "C": 128}),
                         ("encode_pack", {"f": 784, "D": 128}),
                         ("qail_update", {"D": 128, "C": 128})):
        spec = autotune.KERNELS[kernel]
        geom = autotune.geometry_key(kernel, **dims)
        entry = autotune.lookup(kernel, geom)
        if entry is None:  # no committed config for this backend: tune
            entry = autotune.autotune_kernel(kernel, dims,
                                             batch=TUNE_BATCH,
                                             save=False)
        tuned_bb = int(entry["block_b"])
        args = spec.make_inputs(np.random.default_rng(0), TUNE_BATCH,
                                dims)
        want = spec.run_ref(*args)
        for bb in {tuned_bb, spec.default_block_b}:
            got = jax.tree.leaves(spec.run(bb, *args))
            for g, w in zip(got, jax.tree.leaves(want)):
                np.testing.assert_array_equal(np.asarray(g),
                                              np.asarray(w))
        tuned_us = time_fn(lambda *a: spec.run(tuned_bb, *a), *args,
                           iters=3)
        default_us = time_fn(
            lambda *a: spec.run(spec.default_block_b, *a), *args,
            iters=3)
        row(f"kernel/autotune/{kernel}_{geom}", tuned_us,
            f"default_us={default_us:.1f};block_b={tuned_bb};"
            f"default_block_b={spec.default_block_b};"
            f"speedup={default_us / tuned_us:.2f}x;bit_exact=True",
            default_us=default_us, block_b=tuned_bb,
            default_block_b=spec.default_block_b)
        if min(tuned_bb, TUNE_BATCH) != min(spec.default_block_b,
                                            TUNE_BATCH):
            wins.append(tuned_us < default_us)
    if wins:
        assert any(wins), ("no autotuned tiling beat its fixed default "
                           "on this backend")
    else:  # tuner found every default already optimal: legal, but loud
        row("kernel/autotune/all_defaults_optimal", 0.0, "no-op")


if __name__ == "__main__":
    main()
