"""Multi-device serving scaling sweep: aggregate QPS vs device count.

Sweeps 1 -> 8 forced host devices x {packed, imc} deployment backends
through the REAL serving stack (``ShardedArtifact`` under the
``serve_batches`` double-buffered driver) at a fixed per-device row
budget (weak scaling), and asserts near-linear aggregate-QPS scaling on
the packed path (>= 3x at 8 devices vs 1).

jax pins the device count at first init, so every (devices, backend)
point runs in a fresh subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the same trick
the multi-device tests use.

Aggregate-QPS accounting on the emulated backend
------------------------------------------------
``--xla_force_host_platform_device_count`` devices on the CPU backend
execute their partitions one after another, so the measured wall time
is the SUM of the per-device partition times — concurrency is the one
thing host emulation cannot give. The serving program, however, is
row-parallel with ZERO cross-device communication (no collectives in
the compiled HLO — asserted per point below), so on concurrent devices
the wall is the max (== mean, balanced shards) partition time instead
of the sum:

    aggregate_qps = emulated_qps * n_devices

Every point reports both numbers (``qps_emulated`` is the serialized
wall-clock rate; ``qps`` is the concurrent-device aggregate), plus the
bit-exactness of the sharded predictions vs the single-device artifact.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

DEVICE_COUNTS = (1, 2, 4, 8)
BACKENDS = ("packed", "imc")
ROWS_PER_DEVICE = 64
N_BATCHES = 12
FEATURES, DIM, COLUMNS, CLASSES = 64, 128, 128, 10

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REPO_SRC = os.path.join(REPO_ROOT, "src")


def _build_model():
    """An untrained model with a random AM — throughput needs no fit."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    from repro.core import am as am_lib

    enc = EncoderConfig(kind="projection", features=FEATURES, dim=DIM)
    amc = MemhdConfig(dim=DIM, columns=COLUMNS, classes=CLASSES)
    model = MemhdModel.create(jax.random.key(0), enc, amc)
    rng = np.random.default_rng(0)
    fp = jnp.asarray(rng.normal(size=(COLUMNS, DIM)).astype(np.float32))
    owners = jnp.asarray(np.arange(COLUMNS) % CLASSES, np.int32)
    state = am_lib.make_am_state(fp, owners, amc.threshold)
    return dataclasses.replace(model, am_state=state)


def _worker(n_devices: int, backend: str) -> None:
    """One sweep point, in its own forced-device-count process."""
    import time

    import jax
    import numpy as np

    from repro.deploy import ShardedArtifact
    from repro.launch.serve_memhd import Request, serve_batches

    assert jax.device_count() == n_devices, (
        jax.device_count(), n_devices)
    model = _build_model()
    dep = model.deploy(target=backend)
    sharded = ShardedArtifact(dep, devices=n_devices)

    rows = ROWS_PER_DEVICE * n_devices
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, feats=rng.normal(
        size=(rows, FEATURES)).astype(np.float32))
        for i in range(N_BATCHES)]

    # Bit-exactness of the sharded path vs the plain artifact.
    probe = reqs[0].feats[: ROWS_PER_DEVICE * n_devices - 3]  # ragged
    bit_exact = bool((np.asarray(sharded.predict(probe))
                      == np.asarray(dep.predict(probe))).all())

    # The serving program must be communication-free — that is what
    # makes the concurrent-device projection below sound.
    lowered = sharded._sharded_fn("predict").lower(
        sharded.artifact, reqs[0].feats)
    hlo = lowered.compile().as_text().lower()
    collectives = any(tok in hlo for tok in
                      ("all-reduce", "collective-permute", "all-to-all",
                       "all-gather", "reduce-scatter"))

    serve_batches(sharded, reqs, max_batch=rows)  # warmup/compile
    t0 = time.perf_counter()
    responses, stats = serve_batches(sharded, reqs, max_batch=rows,
                                     warmup=False, depth=2)
    wall = time.perf_counter() - t0
    assert len(responses) == N_BATCHES
    total_rows = N_BATCHES * rows
    emulated = total_rows / wall
    print("RESULT " + json.dumps({
        "backend": backend,
        "devices": n_devices,
        "rows": total_rows,
        "wall_s": round(wall, 4),
        "lat_ms_p50": stats["lat_ms_p50"],
        "qps_emulated": round(emulated, 1),
        "qps": round(emulated * n_devices, 1),
        "bit_exact": bit_exact,
        "collectives": collectives,
    }))


def _run_point(n_devices: int, backend: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_scaling", "--worker",
         str(n_devices), backend],
        env=env, cwd=REPO_ROOT, capture_output=True, text=True,
        timeout=560)
    if r.returncode != 0:
        raise RuntimeError(
            f"serve_scaling worker d={n_devices} {backend} failed\n"
            f"STDOUT:\n{r.stdout[-2000:]}\nSTDERR:\n{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in worker output:\n{r.stdout}")


def main() -> None:
    results = {}
    for backend in BACKENDS:
        for n in DEVICE_COUNTS:
            rep = results[(backend, n)] = _run_point(n, backend)
            us = rep["wall_s"] / N_BATCHES * 1e6
            print(f"serve_scaling/{backend}_d{n},{us:.0f},"
                  f"qps={rep['qps']:.0f}"
                  f"(emulated {rep['qps_emulated']:.0f})", flush=True)
            assert rep["bit_exact"], (
                f"sharded {backend} d={n} not bit-exact")
            assert not rep["collectives"], (
                f"serving program has collectives at {backend} d={n}; "
                "the aggregate-QPS projection would be invalid")

    # Near-linear aggregate scaling on the packed path: >= 3x at 8 vs 1.
    top = max(DEVICE_COUNTS)
    lo = results[("packed", 1)]["qps"]
    hi = results[("packed", top)]["qps"]
    ratio = hi / lo
    print(f"serve_scaling/packed_scaling_ratio,0,{ratio:.2f}x_at_"
          f"{top}_devices")
    assert ratio >= 3.0, (
        f"packed aggregate QPS scaled only {ratio:.2f}x at "
        f"{top} devices (need >= 3x)")
    # The aggregate number is a projection (emulated_qps * N), so it
    # alone cannot catch real sharding overhead. Separately bound the
    # serialized wall-clock rate: per-row service time at N devices
    # must stay within 2x of the single-device rate (measured ~1x on
    # the packed path — sharding adds no per-row work).
    emu_ratio = (results[("packed", top)]["qps_emulated"]
                 / results[("packed", 1)]["qps_emulated"])
    print(f"serve_scaling/packed_emulated_ratio,0,{emu_ratio:.2f}x")
    assert emu_ratio >= 0.5, (
        f"sharding overhead: serialized per-row throughput fell to "
        f"{emu_ratio:.2f}x of single-device at {top} devices")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--worker":
        _worker(int(sys.argv[2]), sys.argv[3])
    else:
        main()
