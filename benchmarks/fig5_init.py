"""Fig. 5: clustering-based vs random-sampling initialization.

The paper reports 8.69% (MNIST 512x512) / 19.95% (ISOLET 1024x256) higher
*initial* accuracy and convergence in 10-20 epochs vs 30-40. We reproduce
the initial-accuracy gap and the faster convergence ordering on the
(reduced) geometries."""
import time

import jax

from benchmarks.common import dataset, row, section
from repro.core import EncoderConfig, MemhdConfig, MemhdModel

GEOMS = {"mnist": (256, 128), "isolet": (256, 128)}
EPOCHS = 12


def curve(ds, d, c, method):
    enc = EncoderConfig(kind="projection", features=ds.features, dim=d)
    amc = MemhdConfig(dim=d, columns=c, classes=ds.classes, epochs=EPOCHS,
                      kmeans_iters=8, lr=0.015)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    t0 = time.perf_counter()
    m, hist = m.fit(jax.random.key(1), ds.train_x, ds.train_y,
                    init_method=method, eval_feats=ds.test_x,
                    eval_labels=ds.test_y)
    us = (time.perf_counter() - t0) * 1e6
    accs = [r["eval_acc"] for r in hist["curve"] if "eval_acc" in r]
    return accs, us


def epochs_to_reach(accs, target):
    for i, a in enumerate(accs):
        if a >= target:
            return i
    return len(accs)


def main() -> None:
    for name, (d, c) in GEOMS.items():
        ds = dataset(name)
        section(f"Fig. 5 init comparison ({name}, {d}x{c})")
        acc_c, us_c = curve(ds, d, c, "clustering")
        acc_r, us_r = curve(ds, d, c, "random")
        row(f"fig5/{name}/clustering_init_acc", us_c, f"{acc_c[0]:.4f}")
        row(f"fig5/{name}/random_init_acc", us_r, f"{acc_r[0]:.4f}")
        row(f"fig5/{name}/initial_gap", 0.0,
            f"{acc_c[0] - acc_r[0]:+.4f}")
        row(f"fig5/{name}/final_clustering", 0.0, f"{acc_c[-1]:.4f}")
        row(f"fig5/{name}/final_random", 0.0, f"{acc_r[-1]:.4f}")
        # Convergence: epochs for random init to reach clustering's
        # INITIAL accuracy (paper: clustering starts where random needs
        # tens of epochs to get).
        row(f"fig5/{name}/random_epochs_to_match_clustering_init", 0.0,
            epochs_to_reach(acc_r, acc_c[0]))
        assert acc_c[0] > acc_r[0], "clustering init must start higher"


if __name__ == "__main__":
    main()
