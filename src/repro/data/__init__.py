from repro.data.hdc import load_dataset  # noqa: F401
