"""Deterministic, checkpointable LM token pipeline.

Real corpora are absent in this container, so the pipeline synthesizes a
Zipfian token stream with long-range structure (periodic motif re-use) —
enough signal that a ~100M-parameter model's loss visibly drops in a few
hundred steps (examples/train_lm.py), while staying fully deterministic:

    state = PipelineState(seed, position)
    batch, state = next_batch(cfg, state)

``PipelineState`` is two integers; it rides in the checkpoint manifest so
restart resumes the exact stream position (tested). Batches are produced
host-side in numpy and device_put with the step's input sharding by the
caller (the train loop owns placement, not the pipeline).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LmDataConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    # Zipf exponent for the unigram skeleton; motifs add burstiness.
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_count: int = 512


@dataclasses.dataclass(frozen=True)
class PipelineState:
    seed: int = 0
    position: int = 0  # batches already emitted

    def to_json(self) -> dict:
        return {"seed": self.seed, "position": self.position}

    @classmethod
    def from_json(cls, d: dict) -> "PipelineState":
        return cls(seed=int(d["seed"]), position=int(d["position"]))


def _motifs(cfg: LmDataConfig, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed ^ 0x5EED)
    # Motifs are drawn from the mid-frequency band so they are learnable
    # but not trivially predicted by unigram stats alone.
    return rng.integers(cfg.vocab_size // 16, cfg.vocab_size // 2,
                        size=(cfg.motif_count, cfg.motif_len))


def next_batch(cfg: LmDataConfig, state: PipelineState,
               ) -> Tuple[dict, PipelineState]:
    """Produce {tokens, targets, segment_positions} and the next state.

    tokens/targets: (global_batch, seq_len) int32, targets = tokens
    shifted left (next-token prediction), last target = pad id 0.
    """
    rng = np.random.default_rng((state.seed * 1_000_003 + state.position))
    motifs = _motifs(cfg, state.seed)

    b, s = cfg.global_batch, cfg.seq_len
    # Zipf skeleton (clipped into vocab range).
    toks = rng.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
    toks = np.clip(toks, 1, cfg.vocab_size - 1)
    # Paste motifs at random offsets: ~25% of positions get motif content,
    # giving in-context copy structure for attention/SSM to learn.
    n_paste = max(1, (s // cfg.motif_len) // 4)
    for row in range(b):
        ids = rng.integers(0, cfg.motif_count, size=n_paste)
        offs = rng.integers(0, s + 1 - cfg.motif_len, size=n_paste)
        for m, o in zip(ids, offs):
            toks[row, o:o + cfg.motif_len] = motifs[m]
    toks = toks.astype(np.int32)

    batch = {
        "tokens": toks[:, :-1],
        "targets": toks[:, 1:],
    }
    return batch, PipelineState(state.seed, state.position + 1)
