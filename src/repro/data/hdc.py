"""HDC classification datasets: real loaders + structure-faithful synthetics.

MNIST / Fashion-MNIST / ISOLET are not redistributable in this offline
container. The loaders therefore:

1. look for real data as ``$MEMHD_DATA_DIR/<name>.npz`` (keys:
   train_x/train_y/test_x/test_y, features flattened, values in [0,1]);
2. otherwise generate a *synthetic* dataset that is faithful to the real
   dataset's structure: feature count, class count, per-class sample
   counts, and — crucial for this paper — intra-class **multi-modality**
   (each class is a mixture of several latent "styles"; MEMHD's
   multi-centroid AM exists precisely to capture those modes, and the
   single-vector baselines provably cannot).

Every returned bundle carries ``source`` ("real" or "synthetic") so the
benchmarks can annotate which mode produced each number.
"""
from __future__ import annotations

import dataclasses
import hashlib
import logging
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import DatasetSpec, dataset_spec

log = logging.getLogger(__name__)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataBundle:
    name: str
    train_x: Array  # (n_train, f) float32 in [0, 1]
    train_y: Array  # (n_train,) int32
    test_x: Array
    test_y: Array
    spec: DatasetSpec
    source: str  # "real" | "synthetic"

    @property
    def features(self) -> int:
        return self.train_x.shape[-1]

    @property
    def classes(self) -> int:
        return self.spec.classes


def _try_real(name: str, spec: DatasetSpec) -> Optional[DataBundle]:
    root = os.environ.get("MEMHD_DATA_DIR", "")
    if not root:
        return None
    path = os.path.join(root, f"{name}.npz")
    if not os.path.exists(path):
        return None
    with np.load(path) as z:
        bundle = DataBundle(
            name=name,
            train_x=jnp.asarray(z["train_x"], jnp.float32),
            train_y=jnp.asarray(z["train_y"], jnp.int32),
            test_x=jnp.asarray(z["test_x"], jnp.float32),
            test_y=jnp.asarray(z["test_y"], jnp.int32),
            spec=spec, source="real")
    log.info("loaded real dataset %s from %s", name, path)
    return bundle


def synthesize(name: str, spec: DatasetSpec, seed: int = 0,
               train_per_class: Optional[int] = None,
               test_per_class: Optional[int] = None,
               ) -> DataBundle:
    """Mixture-of-latent-modes synthetic generator.

    Each class c has ``spec.latent_modes`` modes; each mode m is a random
    sparse "template" in feature space. A sample is its mode's template
    plus correlated noise plus a small class-common component, then
    squashed into [0, 1]. Mode templates *within* a class are far apart
    (that is the multi-modality the multi-centroid AM exploits), while a
    class-common component keeps single-vector models viable but worse —
    mirroring the accuracy ordering the paper reports.
    """
    tr_n = train_per_class or spec.train_per_class
    te_n = test_per_class or spec.test_per_class
    # Stable per-name salt: python's hash() is randomized per process
    # (PYTHONHASHSEED), which silently broke cross-restart determinism —
    # the train driver's bit-exact resume needs the same bytes after a
    # crash as before it.
    name_salt = int.from_bytes(
        hashlib.sha256(name.encode()).digest()[:2], "little")
    rng = np.random.default_rng(seed + name_salt)
    f, k, m = spec.features, spec.classes, spec.latent_modes

    # Templates: class-common + per-mode; sparse positive structure like
    # pixel/spectral data.
    # Mode-dominant structure: the class-common component alone is a
    # weak prototype (single-vector models plateau), while per-mode
    # templates are strong — the multimodality MEMHD exploits and the
    # published MNIST/FMNIST curves reflect.
    class_common = rng.normal(0, 0.55, (k, f)) * (rng.random((k, f)) < 0.12)
    mode_delta = rng.normal(0, 1.9, (k, m, f)) * (rng.random((k, m, f)) < 0.15)
    templates = class_common[:, None, :] + mode_delta  # (k, m, f)

    def sample(n_per_class: int, offset: int) -> tuple:
        xs, ys = [], []
        for c in range(k):
            modes = rng.integers(0, m, size=n_per_class)
            base = templates[c, modes]  # (n, f)
            noise = rng.normal(0, 0.72, (n_per_class, f))
            raw = base + noise
            xs.append(raw)
            ys.append(np.full((n_per_class,), c, np.int32))
        x = np.concatenate(xs, 0).astype(np.float32)
        y = np.concatenate(ys, 0)
        # Squash to [0, 1] like normalized pixels.
        x = 1.0 / (1.0 + np.exp(-x))
        perm = rng.permutation(x.shape[0])
        return x[perm], y[perm]

    train_x, train_y = sample(tr_n, 0)
    test_x, test_y = sample(te_n, 1)
    return DataBundle(
        name=name,
        train_x=jnp.asarray(train_x), train_y=jnp.asarray(train_y),
        test_x=jnp.asarray(test_x), test_y=jnp.asarray(test_y),
        spec=spec, source="synthetic")


def load_dataset(name: str, seed: int = 0,
                 train_per_class: Optional[int] = None,
                 test_per_class: Optional[int] = None,
                 ) -> DataBundle:
    """Load a dataset by name ("mnist" | "fmnist" | "isolet").

    Real data (``$MEMHD_DATA_DIR/<name>.npz``) is preferred; otherwise a
    structure-faithful synthetic stand-in is generated (see module doc).
    ``train_per_class``/``test_per_class`` subsample (real) or resize
    (synthetic) per-class counts — used by fast CI tests.
    """
    spec = dataset_spec(name)
    real = _try_real(name, spec)
    if real is not None:
        if train_per_class:
            real = _subsample(real, train_per_class, test_per_class)
        return real
    log.info("dataset %s: real data unavailable, synthesizing", name)
    return synthesize(name, spec, seed, train_per_class, test_per_class)


def _subsample(b: DataBundle, train_per_class: int,
               test_per_class: Optional[int]) -> DataBundle:
    def pick(x, y, n_pc):
        xs, ys = [], []
        y_np = np.asarray(y)
        for c in range(b.spec.classes):
            idx = np.nonzero(y_np == c)[0][:n_pc]
            xs.append(np.asarray(x)[idx])
            ys.append(y_np[idx])
        return (jnp.asarray(np.concatenate(xs)),
                jnp.asarray(np.concatenate(ys)))

    tx, ty = pick(b.train_x, b.train_y, train_per_class)
    ex, ey = ((b.test_x, b.test_y) if not test_per_class
              else pick(b.test_x, b.test_y, test_per_class))
    return dataclasses.replace(b, train_x=tx, train_y=ty,
                               test_x=ex, test_y=ey)
