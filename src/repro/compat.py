"""Version-compat shims for the installed jax.

The repo targets current jax APIs; these helpers keep it running on the
0.4.x line the container ships:

* ``shard_map`` — top-level ``jax.shard_map`` is recent; 0.4.x has it
  under ``jax.experimental.shard_map``.
* ``axis_size`` — ``jax.lax.axis_size`` is recent; ``psum(1, axis)``
  constant-folds to a Python int on every release.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map  # noqa: F401


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, on any jax version."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
