"""Fault-tolerant checkpointing: atomic, content-verified, mesh-agnostic.

Design goals (the 1000-node story):

* **Atomicity** — a checkpoint is written into ``step_<N>.tmp/`` and
  renamed to ``step_<N>/`` only after every shard file and the manifest
  hash are on disk. A crash mid-write leaves a ``.tmp`` directory that
  restore ignores and the next save garbage-collects.
* **Verification** — the manifest records a per-file SHA-256; restore
  validates before deserializing, so a torn file is detected, the
  checkpoint skipped, and the previous one used (tested by corrupting a
  file on purpose).
* **Mesh-agnostic layout** — arrays are saved as *logical* (unsharded)
  arrays keyed by pytree path. Restore applies whatever shardings the
  *current* mesh prescribes — this is what makes elastic rescale (512 ->
  256 chips, or 8 -> 4 in tests) a no-op at the checkpoint layer. For
  true 1000-node scale the same manifest format extends to per-shard
  files (key + shard index); the single-host container writes one file
  per leaf.
* **Keep-k** — old steps are pruned, newest first, never the one being
  written; a ``latest`` symlink is refreshed atomically.
* **Iterator state** — the data-pipeline position (and any JSON-able
  extra state) rides in the manifest so resume is bit-exact.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

log = logging.getLogger(__name__)

PyTree = Any

_MANIFEST = "manifest.json"


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep: int = 3
    async_write: bool = False  # reserved; single-host writes are fast


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def _sha256(fn: str) -> str:
    h = hashlib.sha256()
    with open(fn, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    """Save/restore pytrees of jax or numpy arrays, atomically."""

    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.cfg.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.cfg.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[len("step_"):]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Write checkpoint for ``step``; returns the final directory."""
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
        files = {}
        for path, leaf in leaves_with_paths:
            key = _path_str(path)
            arr = np.asarray(jax.device_get(leaf))
            fn = hashlib.md5(key.encode()).hexdigest()[:16] + ".npy"
            np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
            files[key] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": _sha256(os.path.join(tmp, fn)),
            }

        manifest = {
            "step": step,
            "files": files,
            "extra": extra or {},
            "format_version": 1,
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)

        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic on POSIX
        self._update_latest_link(final)
        self._prune()
        log.info("saved checkpoint step=%d -> %s (%d leaves)",
                 step, final, len(files))
        return final

    def _update_latest_link(self, final: str):
        link = os.path.join(self.cfg.directory, "latest")
        tmp_link = link + ".tmp"
        try:
            if os.path.lexists(tmp_link):
                os.remove(tmp_link)
            os.symlink(os.path.basename(final), tmp_link)
            os.replace(tmp_link, link)
        except OSError:  # filesystems without symlinks: plain file
            with open(link, "w") as f:
                f.write(os.path.basename(final))

    def _prune(self):
        steps = self.all_steps()
        for step in steps[: -self.cfg.keep]:
            shutil.rmtree(self._step_dir(step), ignore_errors=True)
        # GC stray tmp dirs from crashed writers.
        for name in os.listdir(self.cfg.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.cfg.directory, name),
                              ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def _verify(self, d: str, manifest: Dict) -> bool:
        for key, meta in manifest["files"].items():
            fn = os.path.join(d, meta["file"])
            if not os.path.exists(fn) or _sha256(fn) != meta["sha256"]:
                log.warning("checkpoint %s: corrupt leaf %r", d, key)
                return False
        return True

    def restore(self, tree_like: PyTree, step: Optional[int] = None,
                ) -> Tuple[Optional[int], PyTree, Dict[str, Any]]:
        """Restore into the structure of ``tree_like``.

        Walks checkpoints newest-first until one verifies. Returns
        (step, tree, extra); (None, tree_like, {}) if nothing usable.
        Restored leaves are plain numpy — callers ``jax.device_put`` them
        with the current mesh's shardings (elastic rescale happens there).
        """
        candidates = ([step] if step is not None
                      else list(reversed(self.all_steps())))
        for s in candidates:
            d = self._step_dir(s)
            mf = os.path.join(d, _MANIFEST)
            if not os.path.exists(mf):
                continue
            with open(mf) as f:
                manifest = json.load(f)
            if not self._verify(d, manifest):
                continue
            leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
                tree_like)
            out = []
            ok = True
            for path, like in leaves_with_paths:
                key = _path_str(path)
                meta = manifest["files"].get(key)
                if meta is None:
                    log.warning("checkpoint %s: missing key %r", d, key)
                    ok = False
                    break
                arr = np.load(os.path.join(d, meta["file"]),
                              allow_pickle=False)
                out.append(arr)
            if not ok:
                continue
            tree = jax.tree_util.tree_unflatten(treedef, out)
            log.info("restored checkpoint step=%d from %s", s, d)
            return s, tree, manifest.get("extra", {})
        return None, tree_like, {}
