"""Shared tile/batch padding utilities for the deployment subsystem.

One home for every "round up to a tile multiple and pad" computation in
the repo. Before this module the same arithmetic was copy-pasted across
the serving driver (``launch/serve_memhd.py``), the padded evaluator
(``core/evaluate.py``) and every Pallas kernel caller
(``-(-n // tile) * tile`` inline, eight times over); now they all call
here.

The row helpers are array-namespace agnostic: numpy in, numpy out (the
serving driver pads on the host, off the device queue) and jax in, jax
out (the evaluator and the kernels pad traced values).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def round_up(n: int, tile: int) -> int:
    """Smallest multiple of ``tile`` that is >= ``n``."""
    if tile <= 0:
        raise ValueError(f"tile must be positive, got {tile}")
    return -(-n // tile) * tile


def _xp(x):
    """numpy for host arrays, jax.numpy for everything else."""
    if isinstance(x, np.ndarray):
        return np
    import jax.numpy as jnp
    return jnp


def pad_rows(x, n_rows: int, *, fill: str = "zero"):
    """Pad axis 0 of ``x`` up to ``n_rows`` rows.

    fill="zero" appends zero rows (a valid encoder input whose
    prediction the caller discards); fill="edge" repeats the last row
    (the padded-evaluator contract — padded labels are -1, so repeated
    rows can never count as correct).
    """
    pad = n_rows - x.shape[0]
    if pad < 0:
        raise ValueError(f"cannot pad {x.shape[0]} rows down to {n_rows}")
    if pad == 0:
        return x
    xp = _xp(x)
    if fill == "zero":
        filler = xp.zeros((pad,) + tuple(x.shape[1:]), x.dtype)
    elif fill == "edge":
        filler = xp.broadcast_to(x[-1:], (pad,) + tuple(x.shape[1:]))
    else:
        raise ValueError(f"bad fill: {fill!r}")
    return xp.concatenate([x, filler], axis=0)


def pad_to_multiple(x, tile: int) -> Tuple[np.ndarray, int]:
    """Zero-pad rows up to the next multiple of ``tile``.

    Returns (padded, n_valid). Zero feature rows encode to the all-ones
    query (sign(0) -> +1) — a valid input whose prediction is discarded.
    """
    n = int(x.shape[0])
    return pad_rows(x, round_up(max(n, 1), tile)), n


def pad_tiles(x, row_tile: int, col_tile: int | None = None, *,
              value=0):
    """Constant-pad a rank-2 array so each axis is a tile multiple.

    The kernel-caller idiom: operands are padded up to the Pallas block
    shape so the grid divides evenly; padded rows/columns default to
    zeros, which every kernel in the repo either ignores by
    construction (zero-padded reduction dims) or masks (padded winner
    columns). Kernels with a non-neutral pad (e.g. the bitpacker's
    -1 tail bits) pass ``value``.
    """
    import jax.numpy as jnp
    r, c = x.shape
    pr = round_up(r, row_tile) - r
    pc = (round_up(c, col_tile) - c) if col_tile else 0
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)), constant_values=value)
    return x


def pad_vec(x, n: int, *, value=0):
    """Pad a rank-1 array up to ``n`` entries with a constant."""
    import jax.numpy as jnp
    pad = n - x.shape[0]
    if pad < 0:
        raise ValueError(f"cannot pad {x.shape[0]} down to {n}")
    if pad == 0:
        return x
    return jnp.pad(x, (0, pad), constant_values=value)
