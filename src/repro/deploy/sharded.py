"""Multi-device sharded serving on top of any deployment backend.

``ShardedArtifact`` wraps a ``DeployedArtifact`` (any registry backend —
the wrapper only uses the protocol surface) and serves its query path
under ``shard_map`` over a 1-D data-parallel mesh: the artifact is
replicated (the AM is the model, and it is tiny by construction — the
paper's whole thesis), the batch axis shards over the devices, and each
shard runs the backend's own kernels on its rows. Predictions are
row-local, so sharded serving is bit-exact with the single-device path.

Ragged batches ride the existing padded-evaluator contract: the batch is
zero-padded up to a device multiple (zero feature rows encode to the
valid all-ones query) and the tail predictions are dropped before the
caller sees them.

    dep = model.deploy(target="packed")
    sharded = ShardedArtifact(dep, devices=8)   # or mesh=...
    preds = sharded.predict(feats)              # == dep.predict(feats)

``launch/serve_memhd.py --devices N`` and ``benchmarks/serve_scaling``
build on exactly this wrapper.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.deploy.padding import pad_rows, round_up

Array = jax.Array

DATA_AXIS = "data"


def serving_mesh(devices: Optional[Sequence] = None,
                 n: Optional[int] = None) -> Mesh:
    """A 1-D data-parallel mesh over the first ``n`` local devices."""
    devs = list(jax.devices()) if devices is None else list(devices)
    if n is not None:
        if n < 1 or n > len(devs):
            raise ValueError(
                f"requested {n} devices, have {len(devs)} "
                f"({[d.platform for d in devs[:4]]}...)")
        devs = devs[:n]
    return Mesh(np.array(devs), (DATA_AXIS,))


class ShardedArtifact:
    """Data-parallel serving wrapper around any deployment artifact.

    Query methods (``predict`` / ``predict_features`` /
    ``predict_query``) run under ``shard_map``; everything else —
    ``backend``, ``serving_mode``, residence accounting, configs —
    delegates to the wrapped artifact, so the wrapper drops into any
    code programmed against the ``DeployedArtifact`` protocol (the
    serving driver, ``build_report``, the benchmarks).
    """

    def __init__(self, artifact, mesh: Optional[Mesh] = None,
                 devices: Optional[int] = None):
        if isinstance(artifact, ShardedArtifact):
            raise TypeError("artifact is already sharded")
        self.artifact = artifact
        self.mesh = mesh if mesh is not None else serving_mesh(n=devices)
        if len(self.mesh.axis_names) != 1:
            raise ValueError("serving mesh must be 1-D (data-parallel)")
        self.n_devices = int(self.mesh.devices.size)
        self._fns: Dict[str, callable] = {}

    def __getattr__(self, name):
        # Only reached for names not set on the wrapper itself.
        return getattr(self.artifact, name)

    # -- live updates ----------------------------------------------------------
    def with_artifact(self, artifact) -> "ShardedArtifact":
        """A wrapper serving ``artifact`` that SHARES this wrapper's mesh
        and jitted shard_map cache.

        This is the sharded half of the online-serving swap contract:
        the artifact is an *operand* of the cached jit functions, so a
        shape-stable new generation hits the already-compiled
        executables (zero recompiles) — but ONLY if the swap reuses the
        same jit objects. A freshly-constructed ``ShardedArtifact``
        would carry a fresh ``_fns`` cache and recompile every method on
        first call. Queries already dispatched against the old wrapper
        keep their old-generation operand — the swap is race-free by
        construction.
        """
        if isinstance(artifact, ShardedArtifact):
            raise TypeError("artifact is already sharded")
        new = ShardedArtifact.__new__(ShardedArtifact)
        new.artifact = artifact
        new.mesh = self.mesh
        new.n_devices = self.n_devices
        new._fns = self._fns  # shared jit objects -> shared compile cache
        return new

    def refresh(self, model) -> "ShardedArtifact":
        """Re-freeze the wrapped artifact from an updated model, keeping
        this wrapper's mesh and compile cache."""
        return self.with_artifact(self.artifact.refresh(model))

    # -- sharded dispatch ------------------------------------------------------
    def _sharded_fn(self, key: str, local):
        """The jitted shard_map of ``local(artifact, rows)``, cached
        under ``key`` (the method name, plus any static args — e.g. the
        top-k width — that the local closure bakes in)."""
        fn = self._fns.get(key)
        if fn is None:
            axis = self.mesh.axis_names[0]
            # check_rep=False: the per-shard body calls Pallas kernels,
            # which have no shard_map replication rule.
            fn = jax.jit(_shard_map(
                local, mesh=self.mesh,
                in_specs=(P(), P(axis)), out_specs=P(axis),
                check_rep=False))
            self._fns[key] = fn
        return fn

    def _call(self, key: str, local, feats):
        if not hasattr(feats, "shape"):
            # Preserve the caller's dtype: forcing f32 here would make
            # the sharded path disagree with the single-device artifact
            # (and warm a different jit signature) for non-f32 streams.
            feats = np.asarray(feats)
        n = int(feats.shape[0])
        m = round_up(max(n, 1), self.n_devices)
        # pad_rows is namespace-agnostic: numpy batches pad on the host
        # (off the device queue), device-resident batches stay on device
        # with async dispatch — no forced device->host round-trip.
        out = self._sharded_fn(key, local)(self.artifact,
                                           pad_rows(feats, m))
        # Outputs are row-sharded pytrees (predict: one array; topk: a
        # (classes, ids, sims) triple) — drop the padded tail rows.
        return jax.tree.map(lambda o: o[:n], out)

    def _method_local(self, method: str):
        def local(art, x):
            return getattr(art, method)(x)
        return local

    # -- protocol surface ------------------------------------------------------
    def predict(self, feats) -> Array:
        return self._call("predict", self._method_local("predict"), feats)

    def predict_features(self, feats) -> Array:
        return self._call("predict_features",
                          self._method_local("predict_features"), feats)

    def predict_query(self, q) -> Array:
        return self._call("predict_query",
                          self._method_local("predict_query"), q)

    def predict_topk(self, feats, k: int):
        """Sharded top-k serving (backends exposing ``predict_topk``).

        Returns the wrapped artifact's ((B, k) classes, (B, k) centroid
        ids, (B, k) sims) triple, rows sharded over the mesh — bit-exact
        with the single-device call.
        """
        k = int(k)

        def local(art, x):
            return art.predict_topk(x, k)

        return self._call(f"predict_topk:{k}", local, feats)

    def score(self, feats, labels, batch: int = 4096) -> float:
        from repro.core import evaluate as eval_lib
        return eval_lib.batched_accuracy(self.predict, feats, labels,
                                         batch)

    @property
    def row_multiple(self) -> int:
        """Rows per batch must divide into this many equal shards."""
        return self.n_devices
