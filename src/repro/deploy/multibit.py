"""Multi-bit serving artifact: the float AM at 2-8 bits per cell.

``MemhdModel.deploy(target="multibit", cell_bits=4)`` freezes a
symmetric ``cell_bits``-bit quantization of the trained *float* AM
shadow (``repro.core.am.quantize_am``) into offset-code bit planes
(``pack_am_planes``: 8 cells/byte along D, one plane per bit) and
serves every query through the bit-sliced Pallas kernel
(``kernels/am_search_multibit``): per-plane {0,1} MVM passes combined
with shifted weights, per-tile ADC, digital accumulation, argmax.

This is the MIMHD-style point between the 1-bit packed path and the
32-bit unpacked path: C x D x cell_bits resident bits (16x / 8x below
float32 at 2 / 4 bits) while reading out against the float shadow's
decision surface instead of the binarized AM's. An optional
``ImcSimConfig`` attaches array geometry, ADC transfer and per-tile
readout drift — storage perturbations (conductance noise / stuck-at
faults) are 1-bit-cell semantics and are rejected here; use
``fit(cell_bits=...)`` (the quantization-aware QAIL hook) to train
against the quantized readout instead.

``MultibitDeployedMemhd`` implements the shared ``DeployedArtifact``
protocol and registers as the ``"multibit"`` backend, so it composes
with ``ShardedArtifact``, ``serve_memhd --target multibit``, and the
online-serving ``refresh`` path (class growth re-quantizes and re-packs
through the registry) exactly like every other backend.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, Optional, Tuple

import jax

from repro.deploy.base import DeployedArtifact, pytree_artifact
from repro.deploy.registry import register_backend

Array = jax.Array


@pytree_artifact
@dataclasses.dataclass
class MultibitDeployedMemhd(DeployedArtifact):
    """Frozen MEMHD model resident as plane-packed multi-bit codes.

    Immutable pytree: the packed bit planes, the quantizer scale, the
    optional readout-drift offsets and the encoder parameters are the
    leaves; configs (including ``cell_bits``) ride in aux, so jit
    specializes per bit width and re-quantized swaps of the same
    geometry keep their compiled executables.
    """

    enc_params: Dict[str, Array]
    am_planes_t: Array             # (cell_bits, ceil(D/8), C) uint8
    am_scale: Array                # () f32 quantizer scale
    tile_offsets: Optional[Array]  # (gd, gc) readout drift, or None
    centroid_class: Array          # (C,) int32
    enc_cfg: Any
    am_cfg: Any
    sim: Optional[Any]             # ImcSimConfig or None
    cell_bits: int

    _leaf_fields: ClassVar[Tuple[str, ...]] = (
        "enc_params", "am_planes_t", "am_scale", "tile_offsets",
        "centroid_class")
    _static_fields: ClassVar[Tuple[str, ...]] = (
        "enc_cfg", "am_cfg", "sim", "cell_bits")

    # -- inference -------------------------------------------------------------
    def predict_query(self, q: Array) -> Array:
        """(B, D) bipolar queries -> (B,) predicted class, via the
        bit-sliced code-domain readout."""
        from repro.kernels import ops
        return ops.predict_multibit(q, self.am_planes_t,
                                    self.centroid_class, sim=self.sim,
                                    offsets=self.tile_offsets)

    def search_query(self, q: Array) -> Tuple[Array, Array]:
        """(best_idx, best_sim) with dequantized similarities."""
        from repro.kernels import ops
        return ops.am_search_multibit(q, self.am_planes_t, sim=self.sim,
                                      scale=self.am_scale,
                                      offsets=self.tile_offsets)

    # -- live updates ----------------------------------------------------------
    def _deploy_opts(self) -> dict:
        # refresh() re-quantizes the updated float AM at the same bit
        # width onto the SAME simulated readout (sim carries the seed).
        return {"cell_bits": self.cell_bits, "sim": self.sim}

    # -- reporting / accounting ------------------------------------------------
    @property
    def backend(self) -> str:
        return "multibit"

    @property
    def serving_mode(self) -> str:
        return f"bit-sliced-int{self.cell_bits}"

    @property
    def resident_bytes(self) -> int:
        n = self.am_planes_t.size + self.am_scale.dtype.itemsize
        if self.tile_offsets is not None:
            n += self.tile_offsets.size * self.tile_offsets.dtype.itemsize
        return int(n)

    @property
    def memory_bits(self) -> int:
        """Table-I accounting at multi-level cells: EM + C*D*cell_bits."""
        return (self.enc_cfg.memory_bits
                + self.am_cfg.am_memory_bits_at(self.cell_bits))

    @property
    def cycles(self) -> int:
        """Array passes per query — multi-level cells hold the whole
        code, so the grid matches the 1-bit kernels' cycle count."""
        from repro.kernels.am_search_multibit import imc_cycles_for
        arr = self._cost_arr()
        return imc_cycles_for(self.am_planes_t.shape, arr.rows, arr.cols)

    def _cost_arr(self):
        if self.sim is not None:
            return self.sim.arr
        from repro.core.imc import ImcArrayConfig
        return ImcArrayConfig()


@register_backend("multibit")
def deploy_multibit(model, cell_bits: int = 4,
                    sim: Optional[Any] = None) -> MultibitDeployedMemhd:
    """Quantize ``model``'s float AM shadow to ``cell_bits``-bit planes."""
    from repro.core import am as am_lib
    from repro.core import imc as imc_lib
    from repro.imcsim import device as device_lib

    if not 2 <= cell_bits <= 8:
        raise ValueError(
            f"cell_bits={cell_bits} outside [2, 8]; the 1-bit point is "
            "target='packed'")
    offsets = None
    if sim is not None:
        if sim.noise_sigma > 0 or sim.fault_p0 > 0 or sim.fault_p1 > 0:
            raise ValueError(
                "conductance noise / stuck-at faults are 1-bit storage "
                "perturbations; the multibit backend models the readout "
                "path only (drift + ADC)")
        imc_lib.assert_consistent_sim(
            model.am_cfg.dim, model.am_cfg.columns, sim.arr)
        if sim.drift_sigma > 0.0:
            _, k_drift = jax.random.split(jax.random.key(sim.seed))
            offsets = device_lib.tile_drift(
                k_drift,
                device_lib.tile_grid(model.am_cfg.dim,
                                     model.am_cfg.columns, sim),
                sim.drift_sigma)
    codes, scale = am_lib.quantize_am(model.am_state["fp"], cell_bits)
    return MultibitDeployedMemhd(
        enc_params=model.enc_params,
        am_planes_t=am_lib.pack_am_planes(codes, cell_bits),
        am_scale=scale,
        tile_offsets=offsets,
        centroid_class=model.am_state["centroid_class"],
        enc_cfg=model.enc_cfg, am_cfg=model.am_cfg, sim=sim,
        cell_bits=cell_bits,
    )
