"""Unified deployment-backend subsystem.

One trained ``MemhdModel`` maps onto different execution substrates —
digital packed-bit search, full-precision search, noisy analog IMC
arrays (PAPER.md §IV) — through ONE abstraction:

* ``base.DeployedArtifact`` — the protocol every serving artifact
  implements (``predict_query`` / ``predict`` / ``predict_features`` /
  ``score`` / ``resident_bytes`` / ``imc_cost``), with the shared
  plumbing (staged predict, padded-evaluator scoring, pytree
  registration via ``@pytree_artifact``) written exactly once.
* ``registry`` — string-keyed backend factories:
  ``model.deploy(target="packed" | "unpacked" | "imc" | "multibit" |
  "hierarchical", **opts)`` is a thin dispatch through
  ``register_backend``/``get_backend``; new backends (remote arrays,
  product-quantized residuals) plug in without touching the model.
* ``sharded.ShardedArtifact`` — multi-device data-parallel serving of
  any backend's query path under ``shard_map`` (AM replicated, batch
  sharded, ragged tails masked by the padded-evaluator contract).
* ``padding`` — the one home for tile/batch padding helpers shared by
  the serving driver, the evaluator, and the Pallas kernel callers.

NOTE: modules in this package import nothing from ``repro.core`` /
``repro.kernels`` at module scope (the kernel callers import
``repro.deploy.padding``); built-in backends self-register lazily.
"""
from repro.deploy.base import DeployedArtifact, pytree_artifact  # noqa: F401
from repro.deploy.digital import (  # noqa: F401
    DeployedMemhd, deploy_packed, deploy_unpacked,
)
from repro.deploy.hierarchical import (  # noqa: F401
    HierarchicalMemhd, deploy_hierarchical,
)
from repro.deploy.multibit import (  # noqa: F401
    MultibitDeployedMemhd, deploy_multibit,
)
from repro.deploy.padding import (  # noqa: F401
    pad_rows, pad_tiles, pad_to_multiple, pad_vec, round_up,
)
from repro.deploy.registry import (  # noqa: F401
    available_backends, deploy, get_backend, register_backend,
)
from repro.deploy.sharded import ShardedArtifact, serving_mesh  # noqa: F401
