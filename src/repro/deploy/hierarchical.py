"""``target="hierarchical"``: coarse-to-fine top-k deployment backend.

Freezes a trained MEMHD model into a two-stage search artifact for huge
label spaces (C·centroids in the 10^5+ regime, where the flat packed
scan's linear cost is the wrong algorithm):

* **offline** — ``cluster_am`` groups the trained AM's C binary
  centroids into G clusters with the same dot-similarity kmeans the
  paper trains with (``core/kmeans.kmeans_dot``), binarizes each
  cluster mean into a packed *super-centroid*, and ``build_layout``
  physically permutes the packed AM so every cluster owns a contiguous
  run of 128-column tiles inside one ``am_search_packed``-contract slab
  (plus a trailing all-invalid null tile that absorbs short-cluster
  padding in the gather);
* **online** — ``kernels/am_shortlist`` scores the query against the G
  super-centroids and keeps the S best clusters, then
  ``kernels/am_search_sparse`` gathers and searches only those
  clusters' tiles with a fused streaming top-k epilogue.

Recall knobs: ``groups`` (G, default ~1.4*sqrt(C)) and ``shortlist`` (S,
default G). **The default S = G is the exact degenerate configuration**
— every cluster is searched and results are bit-exact with the flat
packed scan (the registry-wide parity tests hold verbatim); dialing
S < G buys sublinear query cost at a measured recall cost
(``benchmarks/hierarchical_search.py`` sweeps the trade-off).

The artifact is an ordinary ``DeployedArtifact`` pytree: it jits,
composes with ``ShardedArtifact`` data-parallel serving, and serves
through ``serve_memhd --target hierarchical --topk K``.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.deploy.base import DeployedArtifact, pytree_artifact
from repro.deploy.padding import round_up
from repro.deploy.registry import register_backend

Array = jax.Array

TILE = 128  # packed-slab column tile (the am_search_packed contract)


# -- offline: clustering ------------------------------------------------------

def default_groups(n_cols: int) -> int:
    """G ~ 1.4*sqrt(C): sqrt balances G coarse scores against C/G fine
    columns per cluster; the 1.4x over-partitions the index (the
    standard IVF trick) so K-means prefers splitting natural clusters
    (benign: each shard's super still matches its prototype) over
    merging them (fatal for recall: a blended super ranks low for both
    constituent clusters' queries)."""
    return max(1, min(n_cols, int(round(1.4 * float(np.sqrt(n_cols))))))


def balance_cap(n_cols: int, n_groups: int) -> int:
    """Per-cluster member cap: the mean cluster size plus TILE/4 slack,
    rounded up to a whole number of tiles. The tile rounding keeps the
    ``max_tiles`` budget minimal — the sparse gather's width (and so
    its cost) is ``S * max_tiles`` tiles, so one oversized cluster
    taxes EVERY query. The slack keeps total capacity comfortably above
    C: with capacity == C exactly, balancing degenerates into a forced
    uniform partition, and every member spilled out of a coherent
    natural cluster lands in a FOREIGN cluster whose super never ranks
    for that member's queries — an unfixable recall hole. The 1.25x
    proportional slack lets an unsplit natural cluster (up to ~1.25x
    the mean under over-partitioned G) stay whole."""
    mean = -(-n_cols // max(n_groups, 1))
    return round_up(max(mean, 1) + mean // 4 + TILE // 4, TILE)


def _kmeanspp_seeds(rng: np.random.Generator, x: np.ndarray,
                    g: int) -> np.ndarray:
    """Classic D^2-weighted k-means++ seeding on L2-normalized rows.

    Bipolar rows all share one norm, so dot-sim K-means is spherical
    K-means and squared distance is an affine map of the dot
    similarity. Seeding matters here: random-row init loses ~1/e of
    well-separated clusters to seed collisions, and every lost cluster
    is a recall hole the shortlist can never see past.
    """
    xn = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-8)
    seeds = np.empty(g, np.int64)
    seeds[0] = rng.integers(x.shape[0])
    d2 = np.maximum(2.0 - 2.0 * (xn @ xn[seeds[0]]), 0.0)
    for j in range(1, g):
        total = d2.sum()
        if total <= 0:  # fewer distinct rows than seeds: reuse any row
            seeds[j:] = rng.integers(x.shape[0], size=g - j)
            break
        seeds[j] = rng.choice(x.shape[0], p=d2 / total)
        d2 = np.minimum(d2, np.maximum(2.0 - 2.0 * (xn @ xn[seeds[j]]),
                                       0.0))
    return seeds


def _balance_assignment(sims: np.ndarray, assign: np.ndarray,
                        cap: int) -> np.ndarray:
    """Cap every cluster at ``cap`` members.

    Overflowing clusters keep their ``cap`` most-similar members; the
    spilled tail re-homes to each member's next-best cluster with room
    (by coarse similarity, deterministic). Total capacity
    ``G * cap >= C`` by construction of ``balance_cap``, so every spill
    finds a home.
    """
    g = sims.shape[1]
    assign = assign.astype(np.int64).copy()
    counts = np.bincount(assign, minlength=g)
    for grp in np.nonzero(counts > cap)[0]:
        members = np.nonzero(assign == grp)[0]
        keep = np.argsort(-sims[members, grp], kind="stable")
        for i in members[keep[cap:]]:
            for alt in np.argsort(-sims[i], kind="stable"):
                if alt != grp and counts[alt] < cap:
                    assign[i] = alt
                    counts[alt] += 1
                    counts[grp] -= 1
                    break
    return assign


def cluster_am(key: Array, binary_am, n_groups: int, *,
               n_iters: int = 8, sample: Optional[int] = None,
               chunk: int = 16384, refine_iters: int = 2,
               balance: bool = True) -> tuple[Array, Array]:
    """Cluster the trained AM's centroids into G super-centroids.

    binary_am: (C, D) bipolar centroid rows (any float/int dtype).
    Lloyd iterations run on at most ``sample`` rows (subsampling keeps
    the fit cheap at C ~ 1e5); the final assignment is one full
    dot-similarity pass over all C rows, chunked so the float copy of a
    huge AM never materializes at once. With ``balance`` (default) the
    assignment is capacity-capped at ``balance_cap`` members per
    cluster, bounding the slab's ``max_tiles`` (one runaway cluster
    would widen the per-query sparse gather for every query); the
    majority-vote super-centroids are computed AFTER balancing so they
    describe the clusters actually laid out.

    Returns (super_binary, assignment): (G, D) float32 bipolar
    majority-vote super-centroids and (C,) int32 cluster per centroid.
    """
    from repro.core import kmeans

    c = binary_am.shape[0]
    if not 1 <= n_groups <= c:
        raise ValueError(f"n_groups={n_groups} outside [1, {c}]")
    k_sub, k_fit = jax.random.split(key)
    if sample is not None and sample < c:
        rows = jax.random.choice(k_sub, c, (sample,), replace=False)
        fit = jnp.asarray(np.asarray(binary_am)[np.asarray(rows)],
                          jnp.float32)
    else:
        fit = jnp.asarray(binary_am, jnp.float32)
    fit_np = np.asarray(fit)
    seed_rng = np.random.default_rng(
        int(jax.random.randint(k_fit, (), 0, 2**31 - 1)))
    seeds = _kmeanspp_seeds(seed_rng, fit_np, n_groups)
    cents, _ = kmeans.kmeans_dot(k_fit, fit, n_groups, n_iters,
                                 init=fit[seeds])
    cents_n = kmeans._l2_normalize(cents)

    # Full-set Lloyd refinement: a subsampled fit merges/misses thin
    # clusters once C >> sample, which costs shortlist recall directly
    # (a query whose centroid sits in a mis-clustered group never sees
    # it). A couple of assign/update passes over ALL rows — still
    # chunked — polish the centroids before the assignment freezes.
    for _ in range(max(refine_iters, 0)):
        sums = jnp.zeros((n_groups, binary_am.shape[1]), jnp.float32)
        cnts = jnp.zeros((n_groups,), jnp.float32)
        for i in range(0, c, chunk):
            blk = jnp.asarray(np.asarray(binary_am[i:i + chunk]),
                              jnp.float32)
            a = kmeans.assign_dot(blk, cents_n).astype(jnp.int32)
            sums = sums + jax.ops.segment_sum(blk, a,
                                              num_segments=n_groups)
            cnts = cnts + jax.ops.segment_sum(
                jnp.ones(blk.shape[0], jnp.float32), a,
                num_segments=n_groups)
        cents_n = kmeans._l2_normalize(
            jnp.where(cnts[:, None] > 0, sums, cents_n))

    # Full-set assignment, chunked over C; keep the (C, G) coarse sims
    # on the host — the balancer re-homes spilled members by them.
    sims_parts = []
    for i in range(0, c, chunk):
        blk = jnp.asarray(np.asarray(binary_am[i:i + chunk]), jnp.float32)
        sims_parts.append(np.asarray(blk @ cents_n.T))
    sims = np.concatenate(sims_parts)
    assignment = sims.argmax(axis=-1)
    if balance and n_groups > 1:
        assignment = _balance_assignment(sims, assignment,
                                         balance_cap(c, n_groups))

    # Per-cluster bit-majority on the FINAL assignment, chunked.
    sums = jnp.zeros((n_groups, binary_am.shape[1]), jnp.float32)
    for i in range(0, c, chunk):
        blk = jnp.asarray(np.asarray(binary_am[i:i + chunk]), jnp.float32)
        a = jnp.asarray(assignment[i:i + chunk].astype(np.int32))
        sums = sums + jax.ops.segment_sum(blk, a, num_segments=n_groups)
    super_binary = jnp.where(sums >= 0, 1.0, -1.0).astype(jnp.float32)
    return super_binary, jnp.asarray(assignment.astype(np.int32))


# -- offline: cluster-contiguous slab layout ----------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterLayout:
    """Cluster-contiguous permutation of the packed AM (host arrays).

    slab: (Dp, Ctot) uint8 — packed columns permuted so cluster g
      occupies tiles [tile_start[g], tile_start[g] + tile_count[g]);
      each cluster zero-padded to a whole number of 128-column tiles;
      the LAST tile is the all-invalid null tile.
    col_ids: (Ctot,) int32 — original centroid id of each slab column,
      -1 for padding / null-tile columns.
    """
    slab: np.ndarray
    col_ids: np.ndarray
    tile_start: np.ndarray  # (G,) int32
    tile_count: np.ndarray  # (G,) int32
    max_tiles: int          # static gather width: max(tile_count)

    @property
    def n_tiles(self) -> int:
        return self.slab.shape[1] // TILE

    @property
    def null_tile(self) -> int:
        return self.n_tiles - 1


def build_layout(am_packed_t, assignment, n_groups: int) -> ClusterLayout:
    """Permute the packed AM into the cluster-contiguous tile slab.

    am_packed_t: (Dp, C) uint8 packed AM (``pack_am``); assignment:
    (C,) cluster id per centroid in [0, n_groups). Pure host-side
    numpy — runs once at deploy time.
    """
    apt = np.asarray(am_packed_t)
    assign = np.asarray(assignment, np.int64)
    c = assign.shape[0]
    if apt.shape[1] != c:
        raise ValueError(f"AM has {apt.shape[1]} columns, "
                         f"assignment covers {c}")
    if c and not (0 <= assign.min() and assign.max() < n_groups):
        raise ValueError("assignment out of range")

    # Permutation: sort centroids by (cluster, original id) — stable
    # within a cluster so the original scan order survives.
    order = np.lexsort((np.arange(c), assign))
    sizes = np.bincount(assign, minlength=n_groups)
    tile_count = np.array([round_up(int(s), TILE) // TILE for s in sizes],
                          np.int32)
    tile_start = np.concatenate(
        [[0], np.cumsum(tile_count)[:-1]]).astype(np.int32)
    n_tiles = int(tile_count.sum()) + 1  # + trailing null tile
    total = n_tiles * TILE

    col_ids = np.full(total, -1, np.int32)
    csum = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    offset = np.arange(c) - np.repeat(csum, sizes)
    dest = tile_start[assign[order]].astype(np.int64) * TILE + offset
    col_ids[dest] = order

    slab = np.zeros((apt.shape[0], total), np.uint8)
    slab[:, dest] = apt[:, order]
    max_tiles = int(tile_count.max()) if n_groups else 1
    return ClusterLayout(slab=slab, col_ids=col_ids,
                         tile_start=tile_start, tile_count=tile_count,
                         max_tiles=max_tiles)


def pack_rows_np(x) -> np.ndarray:
    """Host-side ``pack_rows``: (N, D) bipolar -> (N, ceil(D/8)) uint8.

    Same LSB-first layout and zero tail bits as ``kernels.pack_rows``;
    numpy so huge AMs pack without a float32 device copy.
    """
    bits = np.asarray(x) > 0
    return np.packbits(bits, axis=-1, bitorder="little")


# -- the artifact -------------------------------------------------------------

@pytree_artifact
@dataclasses.dataclass
class HierarchicalMemhd(DeployedArtifact):
    """Frozen coarse-to-fine serving artifact (immutable pytree)."""

    enc_params: Dict[str, Array]
    super_packed_t: Array   # (Dp, G) uint8 packed super-centroids
    am_slab_t: Array        # (Dp, Ctot) uint8 cluster-contiguous slab
    col_ids: Array          # (Ctot,) int32 original id per slab column
    tile_start: Array       # (G,) int32
    tile_count: Array       # (G,) int32
    centroid_class: Array   # (C,) int32
    enc_cfg: "EncoderConfig"   # noqa: F821 — aux config
    am_cfg: "MemhdConfig"      # noqa: F821 — aux config
    groups: int = 1            # G
    shortlist: int = 1         # S; S == G is the exact configuration
    max_tiles: int = 1         # static per-cluster gather width

    _leaf_fields: ClassVar[Tuple[str, ...]] = (
        "enc_params", "super_packed_t", "am_slab_t", "col_ids",
        "tile_start", "tile_count", "centroid_class")
    _static_fields: ClassVar[Tuple[str, ...]] = (
        "enc_cfg", "am_cfg", "groups", "shortlist", "max_tiles")

    # -- inference -------------------------------------------------------------
    def search_query(self, q: Array, k: int = 1) -> tuple[Array, Array]:
        """(B, D) bipolar queries -> ((B, k) centroid ids, (B, k) sims).

        The two-stage pipeline: pack, shortlist S clusters against the
        super-AM, sparse-search their tiles with the streaming top-k
        epilogue. Ids are ORIGINAL centroid indices (pre-permutation).
        """
        from repro.kernels import ops
        qp = ops.pack_rows(q)
        short, _ = ops.am_shortlist(qp, self.super_packed_t,
                                    n_dims=self.am_cfg.dim,
                                    s=self.shortlist)
        return ops.am_search_sparse(
            qp, self.am_slab_t, self.col_ids, short, self.tile_start,
            self.tile_count, n_dims=self.am_cfg.dim, k=k,
            max_tiles=self.max_tiles)

    def predict_query(self, q: Array) -> Array:
        """(B, D) bipolar queries -> (B,) predicted class."""
        idx, _ = self.search_query(q, k=1)
        return self.centroid_class[jnp.maximum(idx[:, 0], 0)]

    def topk_query(self, q: Array, k: int) -> tuple[Array, Array, Array]:
        """(B, D) queries -> ((B, k) classes, (B, k) ids, (B, k) sims).

        Exhausted slots (fewer than k candidates in the shortlisted
        clusters) carry class -1 / id -1.
        """
        idx, sims = self.search_query(q, k=k)
        cls = jnp.where(idx >= 0,
                        self.centroid_class[jnp.maximum(idx, 0)], -1)
        return cls, idx, sims

    def predict_topk(self, feats: Array, k: int) -> tuple[Array, Array, Array]:
        """(B, f) raw features -> top-k (classes, centroid ids, sims)."""
        from repro.core import encoding
        q = encoding.encode_query(self.enc_params, self.enc_cfg, feats)
        return self.topk_query(q, k)

    # -- live updates ----------------------------------------------------------
    def refresh(self, model) -> "HierarchicalMemhd":
        """Re-freeze from an updated model.

        Same-C refresh is LAYOUT-PRESERVING: the frozen cluster
        assignment (``col_ids`` permutation and tile geometry) is kept
        and only the resident bits are rewritten — slab values from the
        new binary AM, super-centroids re-voted under the frozen
        membership. Every leaf shape and every static is unchanged, so
        an online swap of the result is recompile-free. A QAIL fold
        nudges centroids, it does not teleport them, so the frozen
        clustering stays near-optimal; re-cluster by re-deploying when
        drift accumulates.

        Class growth (C changed) has no slot in the frozen layout —
        that path re-clusters from scratch through the registry (one
        bounded recompile set at the new geometry).
        """
        binary = np.asarray(model.am_state["binary"], np.float32)
        if binary.shape[0] != int(self.centroid_class.shape[0]):
            from repro.deploy import registry
            return registry.deploy(model, self.backend,
                                   **self._deploy_opts())
        col_ids = np.asarray(self.col_ids)
        packed = pack_rows_np(binary)  # (C, Dp)
        slab = np.zeros((packed.shape[1], col_ids.shape[0]), np.uint8)
        valid = col_ids >= 0
        slab[:, valid] = packed[col_ids[valid]].T

        # Majority re-vote of each super-centroid over its (frozen)
        # member columns; empty clusters keep their old super.
        tile_start = np.asarray(self.tile_start)
        tile_count = np.asarray(self.tile_count)
        supers = np.ones((self.groups, binary.shape[1]), np.float32)
        for g in range(self.groups):
            lo = int(tile_start[g]) * TILE
            members = col_ids[lo:lo + int(tile_count[g]) * TILE]
            members = members[members >= 0]
            if members.size:
                votes = binary[members].sum(axis=0)
                supers[g] = np.where(votes >= 0, 1.0, -1.0)
        return dataclasses.replace(
            self,
            enc_params=model.enc_params,
            super_packed_t=jnp.asarray(pack_rows_np(supers).T),
            am_slab_t=jnp.asarray(slab),
            centroid_class=model.am_state["centroid_class"],
            am_cfg=model.am_cfg)

    def _deploy_opts(self) -> dict:
        # Exact-mode deployments (S == G) stay exact at the new C
        # (both default); a dialed-down shortlist keeps its ratio
        # meaningless across a re-cluster, so keep the absolute S.
        exact = self.shortlist == self.groups
        return {"groups": None, "shortlist": None if exact
                else self.shortlist}

    # -- reporting / accounting ------------------------------------------------
    @property
    def backend(self) -> str:
        return "hierarchical"

    @property
    def serving_mode(self) -> str:
        return f"coarse2fine-g{self.groups}-s{self.shortlist}"

    @property
    def resident_bytes(self) -> int:
        # Super-AM + permuted slab, both uint8; layout index vectors are
        # negligible but real residents, so they count too.
        return int(self.super_packed_t.size + self.am_slab_t.size
                   + self.col_ids.size * 4
                   + self.tile_start.size * 4 + self.tile_count.size * 4)


# -- registry factory ---------------------------------------------------------

def build_search_state(key: Array, binary_am, n_groups: int, *,
                       kmeans_iters: int = 8,
                       kmeans_sample: Optional[int] = 16384):
    """Cluster + pack + lay out a bare (C, D) binary AM.

    The offline half of the backend, exposed separately so benchmarks
    and tests can drive the two kernels without a trained model.
    Returns (super_packed_t, layout): (Dp, G) uint8 jnp array and the
    host-side ``ClusterLayout``.
    """
    super_binary, assignment = cluster_am(
        key, binary_am, n_groups, n_iters=kmeans_iters,
        sample=kmeans_sample)
    layout = build_layout(pack_rows_np(binary_am).T,
                          np.asarray(assignment), n_groups)
    return jnp.asarray(pack_rows_np(np.asarray(super_binary)).T), layout


@register_backend("hierarchical")
def deploy_hierarchical(model, *, groups: Optional[int] = None,
                        shortlist: Optional[int] = None,
                        kmeans_iters: int = 8,
                        kmeans_sample: Optional[int] = 16384,
                        seed: int = 0) -> HierarchicalMemhd:
    """Cluster the trained AM and freeze the coarse-to-fine artifact.

    groups: G super-centroids (default ~1.4*sqrt(C)); shortlist: S
    clusters
    searched per query (default G — the exact configuration, bit-exact
    with the flat scan; lower S for sublinear cost); kmeans_sample:
    Lloyd fits on at most this many centroids (full assignment always).
    """
    from repro.core import am as am_lib

    binary = model.am_state["binary"]
    c = int(binary.shape[0])
    g = default_groups(c) if groups is None else int(groups)
    s = g if shortlist is None else int(shortlist)
    if not 1 <= s <= g:
        raise ValueError(f"shortlist={s} outside [1, groups={g}]")

    key = jax.random.PRNGKey(seed)
    super_binary, assignment = cluster_am(
        key, binary, g, n_iters=kmeans_iters, sample=kmeans_sample)
    layout = build_layout(np.asarray(am_lib.pack_am(binary)),
                          np.asarray(assignment), g)
    super_binary = np.asarray(super_binary)

    return HierarchicalMemhd(
        enc_params=model.enc_params,
        super_packed_t=jnp.asarray(pack_rows_np(super_binary).T),
        am_slab_t=jnp.asarray(layout.slab),
        col_ids=jnp.asarray(layout.col_ids),
        tile_start=jnp.asarray(layout.tile_start),
        tile_count=jnp.asarray(layout.tile_count),
        centroid_class=model.am_state["centroid_class"],
        enc_cfg=model.enc_cfg, am_cfg=model.am_cfg,
        groups=g, shortlist=s, max_tiles=layout.max_tiles,
    )
