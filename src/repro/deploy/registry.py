"""String-keyed deployment-backend registry.

``MemhdModel.deploy(target=..., **backend_opts)`` is a thin dispatch
through this table: a backend is a factory ``(model, **opts) ->
DeployedArtifact`` registered under a target name. The built-in
backends — ``"packed"`` / ``"unpacked"`` (``repro.deploy.digital``) and
``"imc"`` (``repro.imcsim.deploy``) — self-register on first lookup;
future multi-bit or remote backends register the same way:

    from repro.deploy import register_backend

    @register_backend("packed2b")
    def deploy_packed2b(model, *, ...):
        return Packed2bArtifact(...)

Built-ins load lazily (inside ``_ensure_builtins``) so this module —
and through it the padding utilities the kernel callers import — never
drags ``repro.core`` / ``repro.imcsim`` into an import cycle.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Tuple

_BACKENDS: Dict[str, Callable] = {}

# Modules whose import registers the built-in backends.
_BUILTIN_MODULES = ("repro.deploy.digital", "repro.deploy.hierarchical",
                    "repro.deploy.multibit", "repro.imcsim.deploy")


def register_backend(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a deployment factory under ``name``."""

    def deco(factory: Callable) -> Callable:
        prev = _BACKENDS.get(name)
        if prev is not None and prev is not factory:
            raise ValueError(f"deploy backend {name!r} already registered "
                             f"(by {prev.__module__}.{prev.__qualname__})")
        _BACKENDS[name] = factory
        return factory

    return deco


def _ensure_builtins() -> None:
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)


def available_backends() -> Tuple[str, ...]:
    """Registered target names, sorted."""
    _ensure_builtins()
    return tuple(sorted(_BACKENDS))


def get_backend(name: str) -> Callable:
    _ensure_builtins()
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown deploy target {name!r}; registered backends: "
            f"{', '.join(sorted(_BACKENDS))}") from None


def deploy(model, target: str = "packed", **opts):
    """Freeze ``model`` into the serving artifact of backend ``target``."""
    return get_backend(target)(model, **opts)
