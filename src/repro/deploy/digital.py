"""Digital deployment backends: packed 1-bit and unpacked float search.

``DeployedMemhd`` is the frozen digital serving artifact of a trained
MEMHD model (§III-D): the trained binary AM is *resident* and queried
one-shot. Two registry targets share the class:

* ``"packed"`` — the (Dp, C) uint8 residence (1 bit/cell, the Table-I
  accounting) searched by the fused XOR+popcount kernel; ~8x smaller
  than byte-per-cell storage and 32x smaller than the float32 training
  copy. Also the only backend with a fused raw-feature pipeline
  (``predict_features`` — no float hypervector in HBM).
* ``"unpacked"`` — the ±1 float32 (C, D) residence searched by the
  float MXU kernel; the bit-exact parity baseline.

Predictions are identical between the two (and with
``MemhdModel.predict``). The shared predict/score/pytree plumbing lives
in ``repro.deploy.base``; this module only supplies the searches and
the residence accounting.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional, Tuple

import jax

from repro.deploy.base import DeployedArtifact, pytree_artifact
from repro.deploy.registry import register_backend

Array = jax.Array


@pytree_artifact
@dataclasses.dataclass
class DeployedMemhd(DeployedArtifact):
    """Frozen digital serving artifact (packed or unpacked residence).

    Immutable pytree: jits, shards, and checkpoints like the trainer.
    """

    enc_params: Dict[str, Array]
    am_binary: Optional[Array]     # (C, D) float32, unpacked deployment
    am_packed_t: Optional[Array]   # (Dp, C) uint8, packed deployment
    centroid_class: Array          # (C,) int32
    enc_cfg: "EncoderConfig"       # noqa: F821 — aux config
    am_cfg: "MemhdConfig"          # noqa: F821 — aux config
    packed: bool = True
    mode: str = "popcount"         # packed kernel: "popcount" | "unpack"

    _leaf_fields: ClassVar[Tuple[str, ...]] = (
        "enc_params", "am_binary", "am_packed_t", "centroid_class")
    _static_fields: ClassVar[Tuple[str, ...]] = (
        "enc_cfg", "am_cfg", "packed", "mode")

    # -- inference -------------------------------------------------------------
    def predict_query(self, q: Array) -> Array:
        """(B, D) bipolar queries -> (B,) predicted class."""
        from repro.kernels import ops
        if self.packed:
            return ops.predict_packed(q, self.am_packed_t,
                                      self.centroid_class,
                                      n_dims=self.am_cfg.dim,
                                      mode=self.mode)
        return ops.predict_classes(q, self.am_binary, self.centroid_class)

    @property
    def fusable(self) -> bool:
        """True when the single-dispatch fused pipeline applies: packed
        residence + MVM (projection) encoder + binarized queries."""
        return (self.packed and self.enc_cfg.kind == "projection"
                and self.enc_cfg.binarize_query)

    def predict_features(self, feats: Array) -> Array:
        """(B, f) raw features -> (B,) classes, fused single dispatch.

        The whole pipeline — projection MVM, sign binarization, bitpack,
        XOR+popcount search, ownership gather — runs as one jitted chain
        of two Pallas kernels; the float hypervector never touches HBM
        (only the (B, ceil(D/8)) packed rows pass between them).
        Bit-exact with the staged ``predict``. Artifacts the fused
        kernel cannot serve (unpacked residence, id_level encoder,
        un-binarized queries) fall back to the staged path.
        """
        from repro.kernels import ops
        if not self.fusable:
            return self.predict(feats)
        return ops.predict_from_features(
            feats, self.enc_params["projection"], self.am_packed_t,
            self.centroid_class, mode=self.mode)

    # -- live updates ----------------------------------------------------------
    def _deploy_opts(self) -> dict:
        return {"mode": self.mode}

    def refresh(self, model) -> "DeployedMemhd":
        """Cheap re-freeze from an updated model: rewrite the resident
        buffers, keep the statics. Same-C refreshes keep every leaf
        shape, so an online swap of the result is recompile-free."""
        from repro.core import am as am_lib
        binary = model.am_state["binary"]
        return dataclasses.replace(
            self,
            enc_params=model.enc_params,
            am_binary=None if self.packed else binary,
            am_packed_t=am_lib.pack_am(binary) if self.packed else None,
            centroid_class=model.am_state["centroid_class"],
            am_cfg=model.am_cfg)

    # -- reporting / accounting ------------------------------------------------
    @property
    def backend(self) -> str:
        return "packed" if self.packed else "unpacked"

    @property
    def serving_mode(self) -> str:
        return self.mode if self.packed else "float"

    @property
    def resident_bytes(self) -> int:
        if self.packed:
            return int(self.am_packed_t.size)  # uint8
        return int(self.am_binary.size * self.am_binary.dtype.itemsize)


def _freeze(model, *, packed: bool, mode: str) -> DeployedMemhd:
    from repro.core import am as am_lib
    binary = model.am_state["binary"]
    return DeployedMemhd(
        enc_params=model.enc_params,
        am_binary=None if packed else binary,
        am_packed_t=am_lib.pack_am(binary) if packed else None,
        centroid_class=model.am_state["centroid_class"],
        enc_cfg=model.enc_cfg, am_cfg=model.am_cfg,
        packed=packed, mode=mode,
    )


@register_backend("packed")
def deploy_packed(model, *, mode: str = "popcount") -> DeployedMemhd:
    """Pack the binary AM 8 cells/byte; serve via XOR+popcount."""
    return _freeze(model, packed=True, mode=mode)


@register_backend("unpacked")
def deploy_unpacked(model, *, mode: str = "popcount") -> DeployedMemhd:
    """Keep the ±1 float AM; serve via the float MXU search kernel."""
    return _freeze(model, packed=False, mode=mode)
