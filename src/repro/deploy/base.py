"""``DeployedArtifact``: the one deployment protocol every backend implements.

A deployment backend freezes a trained ``MemhdModel`` into an immutable
serving artifact — packed digital bits, float parity AM, simulated
analog device, whatever comes next. Before this module each artifact
re-implemented the same plumbing (staged predict, ``score`` batching,
pytree flatten/unflatten, residence accounting); now it is written here
exactly once and a concrete artifact only supplies:

* its dataclass fields, split into ``_leaf_fields`` (array children)
  and ``_static_fields`` (hashable configs, the pytree aux),
* ``predict_query`` — the backend's actual search, and
* ``resident_bytes`` + ``serving_mode`` — the accounting/reporting hooks.

``@pytree_artifact`` derives the jax pytree registration from those
field declarations, so artifacts jit, shard, and checkpoint like the
trainer with zero per-class boilerplate.

NOTE: to stay import-cycle-free (the kernel callers import
``repro.deploy.padding``), nothing in this package imports
``repro.core`` / ``repro.kernels`` at module level — heavyweight
imports live inside the methods, mirroring the kernel-dispatch idiom.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Tuple

import jax

Array = jax.Array


def pytree_artifact(cls):
    """Register a ``DeployedArtifact`` dataclass as a jax pytree.

    Children/aux derive from the class's ``_leaf_fields`` /
    ``_static_fields`` declarations — the per-artifact ``tree_flatten``
    boilerplate the pre-registry classes each carried is gone.
    """
    leaves, static = cls._leaf_fields, cls._static_fields
    declared = {f.name for f in dataclasses.fields(cls)}
    missing = (set(leaves) | set(static)) - declared
    if missing:
        raise TypeError(f"{cls.__name__} declares non-fields: {missing}")
    if len(leaves) + len(static) != len(declared):
        raise TypeError(f"{cls.__name__}: every field must be listed in "
                        "_leaf_fields or _static_fields")

    def flatten(self):
        return (tuple(getattr(self, f) for f in leaves),
                tuple(getattr(self, f) for f in static))

    def unflatten(aux, children):
        return cls(**dict(zip(leaves, children)),
                   **dict(zip(static, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


class DeployedArtifact:
    """Shared behaviour of every frozen MEMHD serving artifact.

    The protocol surface (what the serving stack programs against):
    ``predict_query`` / ``predict`` / ``predict_features`` / ``score`` /
    ``resident_bytes`` / ``imc_cost`` plus the ``backend`` /
    ``serving_mode`` report labels.
    """

    _leaf_fields: ClassVar[Tuple[str, ...]]
    _static_fields: ClassVar[Tuple[str, ...]]

    # Concrete artifacts carry these as dataclass fields; declared here
    # for the shared method bodies.
    enc_params: Any
    centroid_class: Array
    enc_cfg: Any
    am_cfg: Any

    # -- inference -------------------------------------------------------------
    def predict_query(self, q: Array) -> Array:
        """(B, D) bipolar queries -> (B,) predicted class."""
        raise NotImplementedError

    def predict(self, feats: Array) -> Array:
        """(B, f) raw features -> (B,) classes, staged encode + search."""
        from repro.core import encoding
        q = encoding.encode_query(self.enc_params, self.enc_cfg, feats)
        return self.predict_query(q)

    def predict_features(self, feats: Array) -> Array:
        """Raw-feature serving entry point.

        Backends with a fused feature->prediction pipeline override
        this; the default is the staged ``predict``.
        """
        return self.predict(feats)

    def score(self, feats: Array, labels: Array, batch: int = 4096,
              ) -> float:
        """Accuracy through the shared padded evaluator — every batch
        the jitted predict sees has ONE shape (no ragged recompiles)."""
        from repro.core import evaluate as eval_lib
        return eval_lib.batched_accuracy(self.predict, feats, labels,
                                         batch)

    def score_queries(self, q: Array, labels: Array, batch: int = 4096,
                      ) -> float:
        """Accuracy on pre-encoded queries, same padded evaluator."""
        from repro.core import evaluate as eval_lib
        return eval_lib.batched_accuracy(self.predict_query, q, labels,
                                         batch)

    # -- live-update surface ---------------------------------------------------
    def _deploy_opts(self) -> dict:
        """Backend kwargs that rebuild an equivalent artifact through the
        registry — the options this artifact was deployed with. Backends
        with deploy-time knobs (kernel mode, sim config, cluster
        geometry) override this so ``refresh`` reproduces them."""
        return {}

    def refresh(self, model) -> "DeployedArtifact":
        """Re-freeze this artifact from an updated model.

        The default re-deploys through the registry under the same
        backend target and ``_deploy_opts()``; backends with a cheaper
        same-shape path (rewrite the resident buffers, keep the layout)
        override it. Always returns a NEW artifact — deployment
        artifacts are immutable, and the online-serving swap contract
        (``repro.serve``) depends on old generations staying intact for
        in-flight queries.
        """
        from repro.deploy import registry
        return registry.deploy(model, self.backend, **self._deploy_opts())

    @property
    def swap_signature(self):
        """Hashable (treedef, leaf avals) fingerprint of this artifact.

        Two artifacts with equal signatures present identical jit
        signatures as operands — swapping one for the other re-uses
        every compiled executable (zero recompiles). A changed
        signature (e.g. class growth widened the AM) means the swap
        will trace one bounded set of new executables.
        """
        leaves, treedef = jax.tree_util.tree_flatten(self)
        return (treedef, tuple(
            (tuple(l.shape), str(l.dtype)) for l in leaves))

    # -- reporting / accounting ------------------------------------------------
    @property
    def backend(self) -> str:
        """Registry target name this artifact serves under."""
        raise NotImplementedError

    @property
    def serving_mode(self) -> str:
        """Human-readable kernel/readout mode for the serving report."""
        raise NotImplementedError

    @property
    def resident_bytes(self) -> int:
        """Bytes the resident AM actually occupies on the device."""
        raise NotImplementedError

    # Pre-registry name of ``resident_bytes``; kept for callers/tests.
    @property
    def resident_am_bytes(self) -> int:
        return self.resident_bytes

    @property
    def am_memory_ratio(self) -> float:
        """Byte-per-cell residence / this artifact's resident bytes.

        The smallest addressable unpacked cell is one byte (uint8
        {0,1}): a packed artifact reports ~8x, the float32 AMs 0.25x.
        """
        return (self.am_cfg.columns * self.am_cfg.dim) / self.resident_bytes

    def _cost_arr(self):
        """Array geometry ``imc_cost`` defaults to (backends override)."""
        from repro.core.imc import ImcArrayConfig
        return ImcArrayConfig()

    def imc_cost(self, arr=None):
        """Closed-form IMC mapping of this model's geometry."""
        from repro.core.imc import memhd_pipeline
        return memhd_pipeline(self.enc_cfg.features, self.am_cfg.dim,
                              self.am_cfg.columns, arr or self._cost_arr())
