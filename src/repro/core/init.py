"""Clustering-based initialization of the multi-centroid AM (§III-A).

Two phases, exactly following the paper:

1. **Classwise clustering** — with ratio R, every class gets
   ``n = max(1, floor(C*R / k))`` initial centroids from per-class
   dot-similarity K-means over the encoded training hypervectors.
2. **Cluster allocation** — the remaining ``C - k*n`` columns are handed
   out round-by-round: validate on the full training set with the
   *binarized* AM, build the confusion matrix, give the spare columns to
   the classes with the highest misprediction counts, re-cluster those
   classes with their enlarged budgets, repeat until every column is used
   ("Once all columns are utilized, resulting in a fully utilized IMC
   array, the initialization process is complete").

The orchestration is host-side Python (the loop is data-dependent and
runs once, offline); the inner K-means / evaluation steps are jitted.
"""
from __future__ import annotations

import logging
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import am as am_lib
from repro.core.kmeans import classwise_kmeans
from repro.core.types import MemhdConfig

Array = jax.Array
log = logging.getLogger(__name__)


def confusion_matrix(pred: Array, true: Array, n_classes: int) -> Array:
    """(k, k) counts: rows = true class, cols = predicted class."""
    idx = true.astype(jnp.int32) * n_classes + pred.astype(jnp.int32)
    flat = jnp.bincount(idx, length=n_classes * n_classes)
    return flat.reshape(n_classes, n_classes)


def misprediction_counts(conf: Array) -> Array:
    """Per-class misclassification counts (off-diagonal row sums)."""
    return conf.sum(axis=1) - jnp.diagonal(conf)


def _allocate_round(mispred: np.ndarray, budgets: np.ndarray,
                    spare: int, max_per_class: np.ndarray) -> np.ndarray:
    """Distribute up to ``spare`` new columns proportionally to
    misprediction counts (at least the single worst class gets one).

    Classes already at their sample-count ceiling receive nothing (a
    centroid per sample is the useful maximum).
    """
    room = np.maximum(max_per_class - budgets, 0)
    weights = mispred.astype(np.float64) * (room > 0)
    if weights.sum() <= 0:
        # Nothing mispredicted (or no room): spread round-robin over rooms.
        order = np.argsort(-room)
        add = np.zeros_like(budgets)
        i = 0
        while spare > 0 and room.sum() > 0:
            c = order[i % len(order)]
            if room[c] > 0:
                add[c] += 1
                room[c] -= 1
                spare -= 1
            i += 1
        return add
    shares = weights / weights.sum()
    add = np.floor(shares * spare).astype(np.int64)
    add = np.minimum(add, room)
    # Hand out any remainder one by one to the worst offenders with room.
    rem = spare - int(add.sum())
    order = np.argsort(-weights)
    i = 0
    while rem > 0 and np.any(room - add > 0):
        c = order[i % len(order)]
        if room[c] - add[c] > 0 and weights[c] > 0:
            add[c] += 1
            rem -= 1
        i += 1
        if i > 10 * len(order):  # all weighted classes full; spill over
            weights = (room - add > 0).astype(np.float64)
            order = np.argsort(-weights)
            i = 0
    return add


@jax.jit
def _train_predictions(binary_am: Array, centroid_class: Array,
                       queries: Array) -> Array:
    return am_lib.predict(binary_am, centroid_class, queries)


def clustering_init(
    key: Array,
    cfg: MemhdConfig,
    h_train: Array,
    labels: Array,
    *,
    queries: Array | None = None,
    alloc_rounds_cap: int = 16,
) -> Tuple[Array, Array, List[dict]]:
    """Build the initial (C, D) float AM per §III-A.

    Args:
      key: PRNG key.
      cfg: MEMHD configuration (C, k, R, kmeans_iters...).
      h_train: (n, D) float encoded training hypervectors.
      labels: (n,) int labels.
      queries: (n, D) binarized queries used for the validation passes of
        the allocation loop; defaults to sign(h_train).
      alloc_rounds_cap: safety cap on allocation rounds; each round
        allocates proportionally so a handful of rounds always suffices.

    Returns:
      (fp_am, centroid_class, history) where history logs each allocation
      round (budgets, training accuracy) for the Fig.-5/6 benchmarks.
    """
    k, c_total = cfg.classes, cfg.columns
    if queries is None:
        queries = jnp.where(h_train >= 0, 1.0, -1.0)

    n_init = cfg.initial_clusters_per_class
    budgets = np.full((k,), n_init, np.int64)
    # R=1.0 can still leave a remainder (floor division) — those columns
    # also go through the allocation loop, as do the C(1-R) reserved ones.
    spare = c_total - int(budgets.sum())
    assert spare >= 0, (budgets, c_total)

    labels_np = np.asarray(labels)
    max_per_class = np.asarray(
        [max(1, int((labels_np == c).sum())) for c in range(k)], np.int64)
    budgets = np.minimum(budgets, max_per_class)
    spare = c_total - int(budgets.sum())

    history: List[dict] = []
    keys = jax.random.split(key, alloc_rounds_cap + 1)
    centroids, owners = classwise_kmeans(
        keys[0], h_train, labels, k, list(budgets), cfg.kmeans_iters)

    rounds = 0
    while spare > 0 and rounds < alloc_rounds_cap:
        rounds += 1
        # Validation pass with the *binarized* AM (that is what deployment
        # uses, so allocation should chase deployment errors).
        binary = am_lib.binarize_am(centroids, cfg.threshold)
        preds = _train_predictions(binary, owners, queries)
        conf = confusion_matrix(preds, labels, k)
        mispred = np.asarray(misprediction_counts(conf))
        acc = float(np.asarray(jnp.diagonal(conf)).sum()) / labels_np.shape[0]

        add = _allocate_round(mispred, budgets, spare, max_per_class)
        if add.sum() == 0:
            log.info("allocation saturated with %d spare columns", spare)
            break
        budgets = budgets + add
        spare = c_total - int(budgets.sum())
        history.append({
            "round": rounds,
            "train_acc": acc,
            "mispred": mispred.tolist(),
            "budgets": budgets.tolist(),
            "spare": spare,
        })
        # Re-cluster only classes whose budget changed (the paper
        # re-clusters after each assignment round).
        changed = np.nonzero(add)[0]
        new_centroids, new_owners = classwise_kmeans(
            keys[rounds], h_train, labels, k, list(budgets),
            cfg.kmeans_iters)
        centroids, owners = new_centroids, new_owners
        del changed  # full re-cluster keeps centroid layout canonical

    if spare > 0:
        # Degenerate corner (tiny datasets): hand leftovers to class 0 by
        # duplicating its centroid with jitter so shapes stay (C, D).
        log.warning("%d unallocated columns after cap; duplicating", spare)
        reps_idx = np.where(np.asarray(owners) == int(np.argmax(budgets)))[0]
        extra = jnp.asarray(
            np.asarray(centroids)[reps_idx[:spare] % len(reps_idx)])
        extra = extra + 1e-3 * jax.random.normal(keys[-1], extra.shape)
        centroids = jnp.concatenate([centroids, extra], axis=0)
        owners = jnp.concatenate(
            [owners, jnp.full((spare,), int(np.argmax(budgets)), jnp.int32)])

    assert centroids.shape == (c_total, cfg.dim), centroids.shape
    return centroids, owners, history


def random_sampling_init(
    key: Array,
    cfg: MemhdConfig,
    h_train: Array,
    labels: Array,
) -> Tuple[Array, Array]:
    """The baseline initializer of Fig. 5: centroids are randomly sampled
    training hypervectors, columns split evenly across classes (remainder
    round-robin)."""
    k, c_total = cfg.classes, cfg.columns
    base, rem = divmod(c_total, k)
    budgets = np.asarray([base + (i < rem) for i in range(k)], np.int64)
    labels_np = np.asarray(labels)
    h_np = np.asarray(h_train)
    rng = np.random.default_rng(np.asarray(
        jax.random.key_data(key)).sum() % (2**31))
    cents, owners = [], []
    for c in range(k):
        pool = np.nonzero(labels_np == c)[0]
        take = rng.choice(pool, size=int(budgets[c]),
                          replace=len(pool) < budgets[c])
        cents.append(h_np[take])
        owners.append(np.full((int(budgets[c]),), c, np.int32))
    return (jnp.asarray(np.concatenate(cents, 0)),
            jnp.asarray(np.concatenate(owners, 0)))
