"""Binary-HDC baselines of Table I: BasicHDC, QuantHD, LeHDC, SearcHD.

Each baseline is a small class with the same fit/score surface as
``MemhdModel`` so the Fig.-3/7 benchmarks can sweep them uniformly.

* **BasicHDC** — projection encoding, single-pass AM (class vector = sum
  of its samples' hypervectors), binarized. Directly MVM/IMC-compatible,
  which is why the paper's Table II compares against it.
* **QuantHD** [13] — ID-level encoding, single class vector per class,
  quantization-aware iterative learning: similarity on the binary AM,
  Eq.-(2) updates on the float AM, re-binarize each epoch.
* **LeHDC** [15] — ID-level encoding, BNN-style training: logits are
  dot-similarities of the *sign-binarized* class vectors (straight-through
  estimator), softmax cross-entropy, SGD with momentum on float weights.
* **SearcHD** [14] — ID-level encoding, multi-model N-vector stochastic
  quantization: per class, N binary vectors sampled from the accumulated
  class vector's per-dimension firing probability; inference = argmax over
  all k*N binary vectors.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.core.types import BaselineConfig, EncoderConfig

Array = jax.Array


def _sign(x: Array) -> Array:
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _encoder_cfg(cfg: BaselineConfig, features: int) -> EncoderConfig:
    kind = "projection" if cfg.kind == "basic" else "id_level"
    return EncoderConfig(kind=kind, features=features, dim=cfg.dim)


@dataclasses.dataclass
class BaselineModel:
    """Uniform container: binary AM of shape (M, D) + owner classes (M,)."""

    cfg: BaselineConfig
    enc_cfg: EncoderConfig
    enc_params: Dict[str, Array]
    am: Array                # (M, D) bipolar
    owners: Array            # (M,) int32

    def encode_query(self, feats: Array) -> Array:
        return encoding.encode_query(self.enc_params, self.enc_cfg, feats)

    def predict(self, feats: Array) -> Array:
        q = self.encode_query(feats)
        sims = jnp.einsum("...d,md->...m", q, self.am)
        return self.owners[jnp.argmax(sims, axis=-1)]

    def score(self, feats: Array, labels: Array, batch: int = 2048) -> float:
        n, correct = feats.shape[0], 0
        for b in range(0, n, batch):
            pred = self.predict(feats[b:b + batch])
            correct += int(jnp.sum(pred == labels[b:b + batch]))
        return correct / n

    @property
    def memory_bits(self) -> int:
        return self.enc_cfg.memory_bits + self.cfg.am_memory_bits()


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def _class_sums(h: Array, labels: Array, k: int) -> Array:
    onehot = jax.nn.one_hot(labels, k, dtype=h.dtype)  # (n, k)
    return onehot.T @ h  # (k, D)


@partial(jax.jit, static_argnames=("k", "lr"))
def _quanthd_epoch(fp: Array, binary: Array, q: Array, labels: Array,
                   k: int, lr: float) -> Array:
    """Eq.-(2) updates against a fixed binary AM snapshot (batched)."""
    sims = q @ binary.T  # (n, k)
    preds = jnp.argmax(sims, axis=-1)
    mis = (preds != labels).astype(fp.dtype)  # (n,)
    coef = (lr * mis)[:, None] * q
    fp = fp.at[labels].add(coef)
    fp = fp.at[preds].add(-coef)
    return fp


def fit_basic(key: Array, cfg: BaselineConfig, feats: Array, labels: Array,
              ) -> BaselineModel:
    enc_cfg = _encoder_cfg(cfg, feats.shape[-1])
    k_enc, _ = jax.random.split(key)
    enc_params = encoding.init_encoder(k_enc, enc_cfg)
    h = encoding.encode(enc_params, enc_cfg, feats)
    am = _sign(_class_sums(h, labels, cfg.classes))
    owners = jnp.arange(cfg.classes, dtype=jnp.int32)
    return BaselineModel(cfg, enc_cfg, enc_params, am, owners)


def fit_quanthd(key: Array, cfg: BaselineConfig, feats: Array, labels: Array,
                ) -> BaselineModel:
    enc_cfg = _encoder_cfg(cfg, feats.shape[-1])
    k_enc, _ = jax.random.split(key)
    enc_params = encoding.init_encoder(k_enc, enc_cfg)
    h = encoding.encode(enc_params, enc_cfg, feats)
    q = encoding.binarize_query(h)
    fp = _class_sums(h, labels, cfg.classes)
    binary = _sign(fp - fp.mean())
    for _ in range(cfg.epochs):
        fp = _quanthd_epoch(fp, binary, q, labels, cfg.classes, cfg.lr)
        binary = _sign(fp - fp.mean())
    owners = jnp.arange(cfg.classes, dtype=jnp.int32)
    return BaselineModel(cfg, enc_cfg, enc_params, binary, owners)


# ---------------------------------------------------------------------------
# LeHDC: BNN-style training with a straight-through estimator
# ---------------------------------------------------------------------------

def _ste_sign(x: Array) -> Array:
    """sign(x) in the forward pass, identity gradient (clipped) backward."""
    return x + jax.lax.stop_gradient(_sign(x) - x)


@partial(jax.jit, static_argnames=("k", "lr", "momentum"))
def _lehdc_step(fp: Array, vel: Array, q: Array, labels: Array,
                k: int, lr: float, momentum: float,
                ) -> Tuple[Array, Array, Array]:
    def loss_fn(w):
        logits = q @ _ste_sign(w).T / jnp.sqrt(w.shape[-1] * 1.0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
        return nll

    loss, grad = jax.value_and_grad(loss_fn)(fp)
    vel = momentum * vel - lr * grad
    fp = jnp.clip(fp + vel, -1.0, 1.0)  # BNN weight clipping
    return fp, vel, loss


def fit_lehdc(key: Array, cfg: BaselineConfig, feats: Array, labels: Array,
              batch: int = 512, momentum: float = 0.9) -> BaselineModel:
    enc_cfg = _encoder_cfg(cfg, feats.shape[-1])
    k_enc, k_w = jax.random.split(key)
    enc_params = encoding.init_encoder(k_enc, enc_cfg)
    h = encoding.encode(enc_params, enc_cfg, feats)
    q = encoding.binarize_query(h)
    n = q.shape[0]
    fp = 0.01 * jax.random.normal(k_w, (cfg.classes, cfg.dim))
    vel = jnp.zeros_like(fp)
    for _ in range(cfg.epochs):
        for b in range(0, n, batch):
            fp, vel, _ = _lehdc_step(fp, vel, q[b:b + batch],
                                     labels[b:b + batch], cfg.classes,
                                     cfg.lr, momentum)
    owners = jnp.arange(cfg.classes, dtype=jnp.int32)
    return BaselineModel(cfg, enc_cfg, enc_params, _sign(fp), owners)


# ---------------------------------------------------------------------------
# SearcHD: N-vector stochastic quantization
# ---------------------------------------------------------------------------

def fit_searchd(key: Array, cfg: BaselineConfig, feats: Array, labels: Array,
                ) -> BaselineModel:
    enc_cfg = _encoder_cfg(cfg, feats.shape[-1])
    k_enc, k_q = jax.random.split(key)
    enc_params = encoding.init_encoder(k_enc, enc_cfg)
    h = encoding.encode(enc_params, enc_cfg, feats)
    sums = _class_sums(h, labels, cfg.classes)  # (k, D) non-binary
    # Per-dimension firing probability from the standardized class vector;
    # N stochastic binary samples realize the N-vector quantization. The
    # sharpening temperature keeps the Bernoulli noise from washing out
    # the class signal at moderate D (SearcHD's own evaluations sit at
    # 8000-D where the raw sigmoid suffices).
    std = sums.std(axis=-1, keepdims=True) + 1e-8
    p_fire = jax.nn.sigmoid(3.0 * sums / std)  # (k, D)
    u = jax.random.uniform(
        k_q, (cfg.classes, cfg.n_models, sums.shape[-1]))
    am = jnp.where(u < p_fire[:, None, :], 1.0, -1.0)  # (k, N, D)
    am = am.reshape(cfg.classes * cfg.n_models, sums.shape[-1])
    owners = jnp.repeat(jnp.arange(cfg.classes, dtype=jnp.int32),
                        cfg.n_models)
    return BaselineModel(cfg, enc_cfg, enc_params, am, owners)


FITTERS = {
    "basic": fit_basic,
    "quanthd": fit_quanthd,
    "lehdc": fit_lehdc,
    "searchd": fit_searchd,
}


def fit_baseline(key: Array, cfg: BaselineConfig, feats: Array,
                 labels: Array) -> BaselineModel:
    return FITTERS[cfg.kind](key, cfg, feats, labels)
