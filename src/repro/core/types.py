"""Core configuration types for the MEMHD framework.

Everything here is a plain frozen dataclass: configs are data, passed
explicitly, hashable (so they can be static args to ``jax.jit``), and
serializable into checkpoints' manifests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Configuration of the hypervector encoding module (EM).

    Attributes:
      kind: ``"projection"`` (binary random projection, MVM-based — the
        encoder MEMHD and BasicHDC use; maps directly onto IMC arrays) or
        ``"id_level"`` (ID x Level composition used by SearcHD / QuantHD /
        LeHDC in the paper's baseline table).
      features: input feature count ``f``.
      dim: hypervector dimensionality ``D``.
      levels: number of quantization levels ``L`` for id_level encoding.
      binarize_query: if True the encoded hypervector is binarized
        (sign) before associative search — the binary-HDC setting.
    """

    kind: str = "projection"
    features: int = 784
    dim: int = 1024
    levels: int = 256
    binarize_query: bool = True

    def __post_init__(self):
        if self.kind not in ("projection", "id_level"):
            raise ValueError(f"unknown encoder kind: {self.kind!r}")
        if self.features <= 0 or self.dim <= 0:
            raise ValueError("features and dim must be positive")

    @property
    def memory_bits(self) -> int:
        """Bits of EM storage, following Table I of the paper."""
        if self.kind == "projection":
            return self.features * self.dim  # f x D binary matrix
        return (self.features + self.levels) * self.dim  # (f+L) x D


@dataclasses.dataclass(frozen=True)
class MemhdConfig:
    """Configuration of the MEMHD multi-centroid associative memory.

    ``dim`` x ``columns`` is the paper's D x C geometry: D matches the IMC
    array's row count, C its column count (so ``128x128`` means D=128 and
    C=128 total centroids across all classes).

    Attributes:
      dim: hypervector dimension D (AM row count).
      columns: total number of centroids C (AM column count), summed over
        classes — full utilization means every column holds a centroid.
      classes: number of classes k.
      init_ratio: the paper's R — fraction of columns filled by the
        initial class-wise clustering; the remaining C(1-R) columns are
        allocated by the confusion-matrix driven loop (§III-A2).
      kmeans_iters: Lloyd iterations per (re-)clustering call.
      epochs: quantization-aware iterative-learning epochs (§III-C).
      lr: iterative-learning rate alpha (paper: 0.01-0.1).
      update_with: which representation of the sample updates the float
        AM in Eq. (6): "encoded" (pre-binarization H, default) or
        "binary" (H^b).
      normalize: per-centroid normalization applied to the float AM after
        each epoch, before re-binarization (§III-C step 4). "l2" or "none".
      threshold: binarization threshold for the AM: "mean" (paper,
        §III-B: global mean of the float AM) or "per_centroid".
      batch_size: minibatch size for the batched QAIL variant (the
        sequential variant follows the paper sample-by-sample).
      seed: PRNG seed.
    """

    dim: int = 128
    columns: int = 128
    classes: int = 10
    init_ratio: float = 0.8
    kmeans_iters: int = 25
    epochs: int = 100
    lr: float = 0.02
    update_with: str = "encoded"
    normalize: str = "l2"
    threshold: str = "mean"
    batch_size: int = 256
    seed: int = 0

    def __post_init__(self):
        if self.columns < self.classes:
            raise ValueError(
                f"C={self.columns} must be >= k={self.classes}: every class "
                "needs at least one centroid"
            )
        if not (0.0 < self.init_ratio <= 1.0):
            raise ValueError("init_ratio R must be in (0, 1]")
        if self.update_with not in ("encoded", "binary"):
            raise ValueError(f"bad update_with: {self.update_with!r}")
        if self.normalize not in ("l2", "none"):
            raise ValueError(f"bad normalize: {self.normalize!r}")
        if self.threshold not in ("mean", "per_centroid"):
            raise ValueError(f"bad threshold: {self.threshold!r}")

    @property
    def am_memory_bits(self) -> int:
        """Binary AM footprint in bits (C x D), per Table I."""
        return self.columns * self.dim

    def am_memory_bits_at(self, cell_bits: int = 1) -> int:
        """Table-I AM bits generalized to multi-level cells: C x D cells
        at ``cell_bits`` bits each (``cell_bits=1`` is the paper's
        binary accounting; the ``target="multibit"`` deployment stores
        2-8 bits per cell)."""
        if cell_bits < 1:
            raise ValueError(f"cell_bits={cell_bits} < 1")
        return self.columns * self.dim * cell_bits

    @property
    def initial_clusters_per_class(self) -> int:
        """n = max(1, floor(C*R / k)) — §III-A1."""
        return max(1, int(self.columns * self.init_ratio) // self.classes)


@dataclasses.dataclass(frozen=True)
class ImcArrayConfig:
    """Geometry + energy constants of one IMC array tile.

    The paper evaluates 128x128 SRAM arrays with NeuroSim-derived
    read/write energies [19], [20]. On TPU the same geometry is realized
    as one 128x128 MXU block pass; the *relative* cost model (cycles =
    sequential tile passes, energy ~ tiles processed) is identical, which
    is what Table II and Fig. 7 report.

    Attributes:
      rows / cols: array dimensions (the paper uses 128x128).
      e_read_pass_pj: energy of one full-array MVM (read) pass, pJ.
      e_write_cell_fj: per-cell write energy, fJ (used by the training-
        time write accounting; inference is read-only).
      t_cycle_ns: latency of one array pass, ns.
    """

    rows: int = 128
    cols: int = 128
    e_read_pass_pj: float = 36.7
    e_write_cell_fj: float = 0.58
    t_cycle_ns: float = 5.2

    def __post_init__(self):
        if self.rows <= 0 or self.cols <= 0:
            raise ValueError("array dims must be positive")


@dataclasses.dataclass(frozen=True)
class ImcSimConfig:
    """Device-fidelity knobs for simulated analog AM search (imcsim).

    The digital kernels compute the associative search exactly; a real
    IMC deployment computes it through per-array analog partial sums,
    finite-resolution ADCs and imperfect cells. This config bundles the
    fidelity model that ``kernels/am_search_imc.py`` and
    ``repro.imcsim`` simulate. It is a frozen, hashable dataclass so it
    can ride through ``jax.jit`` as a static argument.

    Attributes:
      arr: geometry of one physical array tile (rows x cols); the
        simulated search is tiled into exactly these blocks and the
        kernel grid equals ``imc.map_memhd(...).cycles``.
      adc_bits: ADC resolution b. Each tile's analog partial sum is
        quantized by a symmetric mid-tread quantizer with step
        ``2*clip / 2**b`` (2^b + 1 codes) before digital accumulation.
        With the default power-of-two clip the step is a power of two,
        so integer-valued bipolar partial sums are reproduced exactly
        whenever ``2*clip / 2**b <= 1`` — e.g. any b >= 8 at the default
        128-row array, which is what makes the >=16-bit parity contract
        bit-exact.
      adc_clip: ADC full-scale range; partial sums are clipped to
        [-clip, +clip] before quantization. None means ``arr.rows`` (the
        physical maximum of a bipolar tile partial sum).
      noise_sigma: std-dev of i.i.d. Gaussian conductance variation
        added to each stored cell (bipolar domain, cell magnitude 1).
      fault_p0 / fault_p1: per-cell stuck-at fault probabilities. A
        stuck-at-0 cell reads bit 0 (bipolar -1), stuck-at-1 reads bit 1
        (bipolar +1), regardless of the written value.
      drift_sigma: std-dev of the per-tile additive readout offset
        (one Gaussian offset per (row-tile, col-tile) array, applied to
        the tile's partial sum before the ADC).
      seed: PRNG seed for the device perturbations; the same config
        always deploys the same simulated device instance.
    """

    arr: ImcArrayConfig = ImcArrayConfig()
    adc_bits: int = 16
    adc_clip: Optional[float] = None
    noise_sigma: float = 0.0
    fault_p0: float = 0.0
    fault_p1: float = 0.0
    drift_sigma: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.adc_bits < 1:
            raise ValueError("adc_bits must be >= 1")
        if self.adc_clip is not None and self.adc_clip <= 0:
            raise ValueError("adc_clip must be positive")
        for name in ("noise_sigma", "drift_sigma"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if not (0.0 <= self.fault_p0 <= 1.0 and 0.0 <= self.fault_p1 <= 1.0
                and self.fault_p0 + self.fault_p1 <= 1.0):
            raise ValueError(
                "fault_p0/fault_p1 must be probabilities with p0 + p1 <= 1")

    @property
    def clip(self) -> float:
        """Effective ADC full-scale range."""
        return float(self.arr.rows if self.adc_clip is None else
                     self.adc_clip)

    @property
    def adc_step(self) -> float:
        """Quantization step of the mid-tread ADC."""
        return 2.0 * self.clip / (2 ** self.adc_bits)

    @property
    def ideal(self) -> bool:
        """True when every perturbation is off (exact-parity regime
        additionally needs ``adc_step <= 1``, see ``adc_bits``)."""
        return (self.noise_sigma == 0.0 and self.drift_sigma == 0.0
                and self.fault_p0 == 0.0 and self.fault_p1 == 0.0)


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    """Configuration for the binary-HDC baselines of Table I.

    Attributes:
      kind: "basic" | "quanthd" | "lehdc" | "searchd".
      dim: hypervector dimensionality D.
      classes: k.
      n_models: SearcHD's N (vector-quantization factor; paper fixes 64).
      epochs: iterative epochs (quanthd / lehdc).
      lr: learning rate.
      seed: PRNG seed.
    """

    kind: str = "basic"
    dim: int = 10240
    classes: int = 10
    n_models: int = 64
    epochs: int = 30
    lr: float = 0.05
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("basic", "quanthd", "lehdc", "searchd"):
            raise ValueError(f"unknown baseline kind: {self.kind!r}")

    def am_memory_bits(self) -> int:
        """Binary AM bits, per Table I."""
        if self.kind == "searchd":
            return self.classes * self.dim * self.n_models
        return self.classes * self.dim


# Dataset shape registry (true dataset geometries; the synthetic
# generators in repro.data.hdc are faithful to these).
@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    features: int
    classes: int
    train_per_class: int
    test_per_class: int
    # Number of latent intra-class modes the synthetic generator uses;
    # chosen to mirror each dataset's known intra-class diversity.
    latent_modes: int = 4


DATASETS = {
    "mnist": DatasetSpec("mnist", features=784, classes=10,
                         train_per_class=6000, test_per_class=1000,
                         latent_modes=6),
    "fmnist": DatasetSpec("fmnist", features=784, classes=10,
                          train_per_class=6000, test_per_class=1000,
                          latent_modes=6),
    "isolet": DatasetSpec("isolet", features=617, classes=26,
                          train_per_class=240, test_per_class=60,
                          latent_modes=3),
}


def dataset_spec(name: str) -> DatasetSpec:
    try:
        return DATASETS[name]
    except KeyError:
        raise KeyError(
            f"unknown dataset {name!r}; known: {sorted(DATASETS)}"
        ) from None
