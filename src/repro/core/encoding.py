"""Hypervector encoding modules (EM).

Two encoders, matching the paper's §II-B:

* ``projection`` — H = M^T F with a binary (bipolar +-1) random projection
  matrix M of shape (f, D). This is the encoder MEMHD itself uses because
  it is a plain MVM and therefore maps directly onto IMC arrays (and, here,
  onto 128x128 MXU tiles — see kernels/binary_mvm.py).
* ``id_level`` — H = sum_i ID_i * L_{x_i} with random bipolar ID vectors
  and thermometer-correlated Level vectors; used by the SearcHD / QuantHD /
  LeHDC baselines (Table I).

All functions are pure and jittable. Encoders are parameterised by
explicit parameter pytrees created with ``init_*`` functions.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.types import EncoderConfig

Array = jax.Array
EncoderParams = Dict[str, Array]


# ---------------------------------------------------------------------------
# Projection encoding
# ---------------------------------------------------------------------------

def init_projection(key: Array, cfg: EncoderConfig) -> EncoderParams:
    """Binary (bipolar) random projection matrix M: (f, D) in {-1, +1}."""
    m = jax.random.rademacher(key, (cfg.features, cfg.dim), dtype=jnp.float32)
    return {"projection": m}


def encode_projection(params: EncoderParams, feats: Array) -> Array:
    """H = M^T F, batched: (..., f) -> (..., D). Float accumulation."""
    m = params["projection"]
    return jnp.einsum("...f,fd->...d", feats.astype(jnp.float32), m)


# ---------------------------------------------------------------------------
# ID-Level encoding
# ---------------------------------------------------------------------------

def _level_vectors(key: Array, levels: int, dim: int) -> Array:
    """Thermometer-correlated level hypervectors.

    L_0 is random bipolar; L_{i+1} flips a fresh disjoint slice of
    ~dim/(2(levels-1)) positions of L_i, so that L_0 and L_{levels-1} are
    ~orthogonal and intermediate levels interpolate — the standard
    construction used by the ID-Level baselines.
    """
    k0, k1 = jax.random.split(key)
    base = jax.random.rademacher(k0, (dim,), dtype=jnp.float32)
    # Random permutation determines the flip order; level i flips the
    # first floor(i * dim/2 / (levels-1)) permuted positions.
    perm = jax.random.permutation(k1, dim)
    idx = jnp.arange(dim)
    # flips_at[j] = rank of position j in the flip order
    rank = jnp.zeros((dim,), jnp.int32).at[perm].set(idx.astype(jnp.int32))
    n_flips = (jnp.arange(levels) * (dim // 2)) // max(levels - 1, 1)
    # (levels, dim): sign flip where rank < n_flips[level]
    flip = rank[None, :] < n_flips[:, None]
    return jnp.where(flip, -base[None, :], base[None, :])


def init_id_level(key: Array, cfg: EncoderConfig) -> EncoderParams:
    k_id, k_lv = jax.random.split(key)
    ids = jax.random.rademacher(
        k_id, (cfg.features, cfg.dim), dtype=jnp.float32)
    lvls = _level_vectors(k_lv, cfg.levels, cfg.dim)
    return {"ids": ids, "levels": lvls}


def quantize_features(feats: Array, levels: int) -> Array:
    """Map features (assumed in [0, 1]) to integer level indices."""
    q = jnp.clip(feats, 0.0, 1.0) * (levels - 1)
    return jnp.round(q).astype(jnp.int32)


def encode_id_level(params: EncoderParams, feats: Array,
                    *, chunk: int = 128) -> Array:
    """H = sum_i ID_i * L_{x_i}: (..., f) -> (..., D).

    Feature-chunked scan keeps the (batch, chunk, D) gather buffer small
    for large D (the 10240-D baselines).
    """
    ids, lvls = params["ids"], params["levels"]
    f, d = ids.shape
    levels = lvls.shape[0]
    x = quantize_features(feats, levels)

    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, f))
    n_chunks = -(-f // chunk)
    pad = n_chunks * chunk - f
    x_pad = jnp.pad(x2, ((0, 0), (0, pad)))
    ids_pad = jnp.pad(ids, ((0, pad), (0, 0)))
    x_c = x_pad.reshape(x2.shape[0], n_chunks, chunk)
    ids_c = ids_pad.reshape(n_chunks, chunk, d)
    # Padded feature columns gather lvls[0]; mask the gather itself to a
    # neutral (zero) level so their contribution is zero by construction
    # rather than via the zero-padded ID rows — H is invariant to the
    # chunk size for any f (asserted in tests/test_kernel_parity.py).
    valid_c = (jnp.arange(n_chunks * chunk) < f).reshape(n_chunks, chunk)

    def body(acc, args):
        xc, idc, vc = args  # (B, chunk), (chunk, D), (chunk,)
        lv = jnp.where(vc[None, :, None], lvls[xc], 0.0)  # (B, chunk, D)
        return acc + jnp.einsum("bcd,cd->bd", lv, idc), None

    acc0 = jnp.zeros((x2.shape[0], d), jnp.float32)
    acc, _ = jax.lax.scan(
        body, acc0, (jnp.swapaxes(x_c, 0, 1), ids_c, valid_c))
    return acc.reshape(*batch_shape, d)


# ---------------------------------------------------------------------------
# Unified interface
# ---------------------------------------------------------------------------

def init_encoder(key: Array, cfg: EncoderConfig) -> EncoderParams:
    if cfg.kind == "projection":
        return init_projection(key, cfg)
    return init_id_level(key, cfg)


def encode(params: EncoderParams, cfg: EncoderConfig, feats: Array) -> Array:
    """Encode features into (float) hypervectors H."""
    if cfg.kind == "projection":
        return encode_projection(params, feats)
    return encode_id_level(params, feats)


def binarize_query(h: Array) -> Array:
    """Bipolar binarization of the query hypervector: sign(H) in {-1,+1}.

    sign(0) is mapped to +1 so the output is strictly bipolar.
    """
    return jnp.where(h >= 0, 1.0, -1.0).astype(h.dtype)


def encode_query(params: EncoderParams, cfg: EncoderConfig,
                 feats: Array) -> Array:
    """Encode + (optionally) binarize — the inference-path encoder."""
    h = encode(params, cfg, feats)
    return binarize_query(h) if cfg.binarize_query else h
