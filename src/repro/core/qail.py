"""Quantization-aware iterative learning (QAIL) — §III-C.

The four steps per training sample, verbatim from the paper:

1. *Dot similarity* — similarity of the binarized query H^b against the
   **binary** AM; an update fires only on misprediction.
2. *Update target selection* — Eq. (4): the mispredicted class's centroid
   with the globally-highest similarity is the push-away target; Eq. (5):
   the true class's most-similar centroid is the pull-toward target.
3. *Iterative learning* — Eq. (6): C_true += alpha*H, C_pred -= alpha*H,
   applied to the **float** shadow AM.
4. *Binary AM update* — per-centroid normalization of the float AM (so no
   centroid dominates) followed by re-binarization (mean threshold).

Three implementations:

* ``qail_epoch_sequential`` — exact paper semantics: one sample at a time
  (``lax.scan``), the binary AM refreshed once per epoch (step 4 happens
  at epoch granularity, matching "iterative learning ... across the entire
  training dataset" + a normalization step per pass).
* ``qail_epoch_scan`` — the device-resident training engine: one
  jit-compiled ``lax.scan`` over a *pre-batched* epoch (``prebatch``),
  with the ``refresh_every`` binary-AM refresh folded into the scan as a
  ``lax.cond``. ONE dispatch and (at most) one host sync per epoch —
  this is what ``MemhdModel.fit``, ``fit_sharded`` and the fault-tolerant
  driver run. ``qail_epoch_batched`` is its convenience wrapper over
  unbatched arrays.
* ``qail_epoch_hostloop`` — the pre-refactor host-side Python loop (one
  jit dispatch + one device sync per minibatch). Kept as the measured
  baseline for ``benchmarks/train_throughput.py`` and as a parity oracle
  for the scan engine; new code should not call it.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import am as am_lib
from repro.core.types import MemhdConfig

Array = jax.Array
AmState = Dict[str, Array]

# Buffer donation only helps (and only works) on accelerator backends;
# on CPU it just emits "donation not usable" warnings.
_DONATE = (0,) if jax.default_backend() in ("tpu", "gpu") else ()

# Incremented each time the scan-epoch body is *traced* (not executed).
# The single-host-sync test asserts a multi-epoch fit traces it once.
_scan_trace_count = 0


def _normalize_fp(fp_am: Array, mode: str) -> Array:
    """§III-C step 4's normalization.

    "ensures an even distribution of learning influence across multiple
    class vectors within the same class, preventing any single vector
    from dominating" — implemented as norm *equalization*: every centroid
    is rescaled to the mean centroid norm. This evens influence without
    collapsing the AM's overall scale (which must stay at sample-
    hypervector magnitude for Eq.-(6)'s lr*H updates to remain
    proportionate nudges).
    """
    if mode == "none":
        return fp_am
    if mode == "l2":
        norm = jnp.linalg.norm(fp_am, axis=-1, keepdims=True)
        mean_norm = jnp.mean(norm)
        return fp_am * (mean_norm / jnp.maximum(norm, 1e-8))
    raise ValueError(f"bad normalize mode: {mode!r}")


def select_update_targets(sims: Array, centroid_class: Array, label: Array,
                          n_classes: int) -> Tuple[Array, Array, Array]:
    """Eqs. (4) and (5) for a single query.

    Args:
      sims: (C,) dot similarities of one query against the binary AM.
      centroid_class: (C,) centroid ownership.
      label: scalar true class l.
      n_classes: k.

    Returns:
      (mispredicted, pred_target, true_target):
        mispredicted: bool scalar — fire an update?
        pred_target: centroid index (l', m) of Eq. (4) (global argmax).
        true_target: centroid index (l, n) of Eq. (5) (argmax within the
          true class).
    """
    pred_target = jnp.argmax(sims)  # Eq. (4): global best centroid
    pred_class = centroid_class[pred_target]
    mispredicted = pred_class != label

    neg = jnp.finfo(sims.dtype).min
    own = centroid_class == label
    true_target = jnp.argmax(jnp.where(own, sims, neg))  # Eq. (5)
    del n_classes
    return mispredicted, pred_target, true_target


@partial(jax.jit, static_argnames=("cfg",))
def qail_epoch_sequential(state: AmState, cfg: MemhdConfig,
                          h: Array, queries: Array, labels: Array,
                          ) -> AmState:
    """One exact (sample-by-sample) QAIL epoch.

    Args:
      state: AM state dict (fp, binary, centroid_class).
      cfg: MEMHD config (lr, normalize, threshold, update_with).
      h: (n, D) float encoded hypervectors (the Eq.-6 update payload when
        ``cfg.update_with == "encoded"``).
      queries: (n, D) binarized queries H^b (similarity payload).
      labels: (n,) int labels.

    Returns:
      Updated AM state (binary refreshed once, at epoch end — step 4).
    """
    centroid_class = state["centroid_class"]
    binary = state["binary"]
    upd = h if cfg.update_with == "encoded" else queries

    def body(fp, inputs):
        q, u, y = inputs
        sims = binary @ q  # (C,) — evaluated against the epoch's binary AM
        mis, pred_t, true_t = select_update_targets(
            sims, centroid_class, y, cfg.classes)
        delta = jnp.where(mis, cfg.lr, 0.0)
        fp = fp.at[true_t].add(delta * u)
        fp = fp.at[pred_t].add(-delta * u)
        return fp, mis

    fp, misses = jax.lax.scan(body, state["fp"], (queries, upd, labels))
    fp = _normalize_fp(fp, cfg.normalize)
    new_state = dict(state, fp=fp,
                     binary=am_lib.binarize_am(fp, cfg.threshold))
    return new_state


@partial(jax.jit, static_argnames=("cfg", "wire_dtype"))
def qail_batch_delta(state: AmState, cfg: MemhdConfig,
                     h: Array, queries: Array, labels: Array,
                     wire_dtype=jnp.bfloat16,
                     mask: Optional[Array] = None,
                     ) -> Tuple[Array, Array]:
    """Eq.-(6) update *delta* for a batch (no state mutation).

    Returns (delta, n_miss) with delta shaped like the float AM. Exposed
    separately so distributed training can control the cross-shard sync:
    ONE fused scatter (true-target and pred-target updates concatenated)
    emitted in ``wire_dtype`` — under GSPMD the all-reduce operand is the
    scatter output, so this is what sets the wire format (§Perf Q2: one
    bf16 reduce instead of two f32 ones, 8x fewer bytes).

    ``mask`` (B,) zeroes padded samples so pre-batched epochs with a
    ragged final batch (``prebatch``) stay exact.
    """
    centroid_class = state["centroid_class"]
    binary = state["binary"]
    upd = h if cfg.update_with == "encoded" else queries

    sims = queries @ binary.T  # (B, C)
    pred_t = jnp.argmax(sims, axis=-1)
    pred_class = centroid_class[pred_t]
    mis = (pred_class != labels).astype(jnp.float32)
    if mask is not None:
        mis = mis * mask

    neg = jnp.finfo(sims.dtype).min
    own = centroid_class[None, :] == labels[:, None]
    true_t = jnp.argmax(jnp.where(own, sims, neg), axis=-1)

    coef = ((cfg.lr * mis)[:, None] * upd).astype(wire_dtype)
    delta = jnp.zeros(state["fp"].shape, wire_dtype)
    delta = delta.at[true_t].add(coef)
    delta = delta.at[pred_t].add(-coef)
    return delta, mis.sum()


@partial(jax.jit, static_argnames=("cfg",))
def qail_batch_update(state: AmState, cfg: MemhdConfig,
                      h: Array, queries: Array, labels: Array,
                      ) -> Tuple[AmState, Array]:
    """Minibatched QAIL update (one batch, one binary-AM snapshot).

    All mispredicted samples in the batch compute their Eq.-(4)/(5)
    targets against the same binary AM and their Eq.-(6) deltas are
    scatter-added. Returns (new_state_without_binary_refresh, n_miss).
    """
    centroid_class = state["centroid_class"]
    binary = state["binary"]
    upd = h if cfg.update_with == "encoded" else queries

    sims = queries @ binary.T  # (B, C)
    pred_t = jnp.argmax(sims, axis=-1)  # (B,)
    pred_class = centroid_class[pred_t]
    mis = (pred_class != labels).astype(jnp.float32)  # (B,)

    neg = jnp.finfo(sims.dtype).min
    own = centroid_class[None, :] == labels[:, None]  # (B, C)
    true_t = jnp.argmax(jnp.where(own, sims, neg), axis=-1)  # (B,)

    coef = (cfg.lr * mis)[:, None] * upd  # (B, D)
    fp = state["fp"]
    fp = fp.at[true_t].add(coef)
    fp = fp.at[pred_t].add(-coef)
    return dict(state, fp=fp), mis.sum()


def refresh_am(fp: Array, binary: Array, cfg: MemhdConfig,
               ) -> Tuple[Array, Array]:
    """Step 4 (normalize + re-binarize) on raw AM buffers.

    The ONE implementation of the binary-AM refresh; the epoch finalize,
    the in-scan ``refresh_every`` cond, and the sharded engine all call
    this so their step-4 semantics cannot diverge.
    """
    del binary
    fp = _normalize_fp(fp, cfg.normalize)
    return fp, am_lib.binarize_am(fp, cfg.threshold)


@partial(jax.jit, static_argnames=("cfg",))
def qail_finalize_epoch(state: AmState, cfg: MemhdConfig) -> AmState:
    """Step 4 (normalize + re-binarize) for the batched variant."""
    fp, binary = refresh_am(state["fp"], state["binary"], cfg)
    return dict(state, fp=fp, binary=binary)


# ---------------------------------------------------------------------------
# Device-resident scan engine
# ---------------------------------------------------------------------------

def prebatch(h: Array, q: Array, labels: Array, batch_size: int,
             ) -> Tuple[Array, Array, Array, Array]:
    """Reshape an epoch's data into device-resident minibatches.

    Pads n up to a multiple of ``batch_size`` (padded samples carry
    label -1 and mask 0, so they can never fire an Eq.-(6) update) and
    returns ``(hb, qb, yb, mask)`` shaped ``(n_batches, batch_size, ...)``
    — the scan axis of ``qail_epoch_scan``. Do this ONCE per fit; the
    same batched arrays serve every epoch.
    """
    n = h.shape[0]
    nb = -(-n // batch_size)
    pad = nb * batch_size - n
    mask = jnp.concatenate([jnp.ones((n,), jnp.float32),
                            jnp.zeros((pad,), jnp.float32)])
    hb = jnp.pad(h, ((0, pad), (0, 0)))
    qb = jnp.pad(q, ((0, pad), (0, 0)))
    yb = jnp.pad(labels.astype(jnp.int32), (0, pad), constant_values=-1)
    d = h.shape[1]
    return (hb.reshape(nb, batch_size, d), qb.reshape(nb, batch_size, d),
            yb.reshape(nb, batch_size), mask.reshape(nb, batch_size))


@partial(jax.jit,
         static_argnames=("cfg", "refresh_every", "use_kernel", "sim",
                          "noise_mode", "cell_bits"),
         donate_argnums=_DONATE)
def qail_epoch_scan(state: AmState, cfg: MemhdConfig,
                    hb: Array, qb: Array, yb: Array, mask: Array,
                    *, refresh_every: int = 1,
                    use_kernel: bool = False,
                    sim=None, noise_key: Array = None,
                    noise_mode: str = "fixed",
                    cell_bits: Optional[int] = None,
                    ) -> Tuple[AmState, Array]:
    """One QAIL epoch as a single compiled ``lax.scan`` over minibatches.

    The whole epoch — sims MVM, Eq.-(4)/(5) target selection, Eq.-(6)
    scatter, and every mid-epoch binary refresh — runs device-resident in
    one dispatch. The AM buffers are donated on accelerator backends, so
    epoch N+1 trains in-place over epoch N's memory — this call CONSUMES
    ``state`` there (the ``state = qail_epoch_scan(state, ...)`` chain is
    the intended use; callers that must keep the old state alive should
    copy it first, or go through ``qail_epoch_batched`` which does).

    Args:
      state: AM state dict (fp, binary, centroid_class).
      cfg: MEMHD config (static).
      hb / qb / yb / mask: ``prebatch`` outputs, shape (n_batches, bs, ...).
      refresh_every: run step 4 (normalize + re-binarize) inside the scan
        every this-many batches. If the last batch refreshed, the epoch
        ends there — no redundant trailing finalize (the pre-refactor
        host loop double-finalized when n_batches % refresh_every == 0).
      use_kernel: route the fused inner step through the Pallas
        ``qail_update`` kernel (TPU; interpret elsewhere) instead of the
        pure-jnp scatter path. Both are oracle-checked against each other
        in tests/test_qail_engine.py.
      sim: optional ``ImcSimConfig`` (static) — the noise-aware QAIL
        hook. When it carries conductance noise or stuck-at faults, each
        batch's sims MVM (and Eq.-4/5 target selection) is evaluated
        against a device-perturbed view of the binary AM
        (``imcsim.device.perturb_binary``), so centroids learn margins
        that survive analog readout. The Eq.-(6) update still lands on
        the clean float shadow AM.
      noise_key: PRNG key for the perturbations; required when ``sim``
        injects noise/faults.
      noise_mode: "fixed" — every batch sees the SAME perturbation
        (keyed by ``noise_key`` alone): chip-in-the-loop training
        against one deterministic device instance, QAIL's
        train-on-the-deployed-representation principle taken down to
        the device level. "fresh" — a new draw per batch
        (fold_in(noise_key, batch)): trains for expected accuracy over
        the device distribution.
      cell_bits: optional (static) — the quantization-aware hook for
        the ``target="multibit"`` deployment. When set (2..8), each
        batch's sims MVM sees the symmetric ``cell_bits``-bit
        quantization of the LIVE float shadow (``am.quantize_am``
        codes; argmax is scale-invariant) instead of the binary AM, so
        Eq.-(4)/(5) targets are selected against the representation the
        multibit backend will actually serve. The Eq.-(6) update still
        lands on the clean float shadow, exactly as the 1-bit paper
        loop (and the noise-aware hook) does. Composes with ``sim``
        conductance noise (drawn per level step, on the code view);
        stuck-at faults are 1-bit-cell semantics and are rejected.

    Returns:
      (state, n_miss) — n_miss is a DEVICE scalar; pulling it is the
      caller's one permitted host sync per epoch.
    """
    global _scan_trace_count
    _scan_trace_count += 1

    centroid_class = state["centroid_class"]
    nb = hb.shape[0]

    noisy = sim is not None and (sim.noise_sigma > 0.0
                                 or sim.fault_p0 > 0.0
                                 or sim.fault_p1 > 0.0)
    if sim is not None and not noisy:
        # The hook injects storage-path effects (conductance noise,
        # stuck-at faults); a sim whose only non-ideality is the ADC or
        # readout drift would silently train plain QAIL — refuse rather
        # than report a bogus "noise-aware" run.
        raise ValueError(
            "sim carries no conductance noise or stuck-at faults; the "
            "noise-aware hook would be a no-op (ADC/drift live in the "
            "readout path, not the training MVM) — pass sim=None or a "
            "sim with noise_sigma/fault_p0/fault_p1 > 0")
    if noisy and noise_key is None:
        raise ValueError("sim injects device noise: pass noise_key")
    if noise_mode not in ("fixed", "fresh"):
        raise ValueError(f"bad noise_mode: {noise_mode!r}")
    if cell_bits is not None:
        if not 2 <= cell_bits <= 8:
            raise ValueError(f"cell_bits={cell_bits} outside [2, 8]")
        if noisy and (sim.fault_p0 > 0.0 or sim.fault_p1 > 0.0):
            raise ValueError(
                "stuck-at faults are 1-bit storage semantics; the "
                "multibit QAT hook composes with conductance noise only")

    def _refresh(args):
        return refresh_am(args[0], args[1], cfg)

    def body(carry, xs):
        fp, binary = carry
        b_idx, hx, qx, yx, mx = xs
        upd = hx if cfg.update_with == "encoded" else qx
        if cell_bits is not None:
            # Quantization-aware view: the live float shadow's
            # cell_bits-bit codes (re-quantized per batch — the multibit
            # analogue of refresh_every=1 for the binary AM).
            codes, _ = am_lib.quantize_am(fp, cell_bits)
            binary_mvm = codes.astype(jnp.float32)
        else:
            binary_mvm = binary
        if noisy:
            from repro.imcsim import device as device_lib
            bkey = (noise_key if noise_mode == "fixed"
                    else jax.random.fold_in(noise_key, b_idx))
            if cell_bits is not None:
                # Code-domain conductance noise: sigma per level step
                # (faults were rejected above).
                binary_mvm = device_lib.conductance_noise(
                    bkey, binary_mvm, sim.noise_sigma)
            else:
                binary_mvm = device_lib.perturb_binary(bkey, binary_mvm,
                                                       sim)
        if use_kernel:
            from repro.kernels import ops
            delta, miss = ops.qail_update(
                qx, upd, binary_mvm.T, centroid_class, yx, mx, lr=cfg.lr)
            fp = fp + delta
        else:
            sims = qx @ binary_mvm.T  # (bs, C)
            pred_t = jnp.argmax(sims, axis=-1)
            mis = (centroid_class[pred_t] != yx).astype(jnp.float32) * mx
            neg = jnp.finfo(sims.dtype).min
            own = centroid_class[None, :] == yx[:, None]
            true_t = jnp.argmax(jnp.where(own, sims, neg), axis=-1)
            coef = (cfg.lr * mis)[:, None] * upd
            fp = fp.at[true_t].add(coef)
            fp = fp.at[pred_t].add(-coef)
            miss = mis.sum()
        fp, binary = jax.lax.cond(
            (b_idx + 1) % refresh_every == 0, _refresh, lambda a: a,
            (fp, binary))
        return (fp, binary), miss

    (fp, binary), misses = jax.lax.scan(
        body, (state["fp"], state["binary"]),
        (jnp.arange(nb), hb, qb, yb, mask))
    state = dict(state, fp=fp, binary=binary)
    if nb % refresh_every != 0:  # last batch didn't refresh inside scan
        state = qail_finalize_epoch(state, cfg)
    return state, misses.sum()


def qail_epoch_batched(state: AmState, cfg: MemhdConfig,
                       h: Array, queries: Array, labels: Array,
                       *, refresh_every: int = 1,
                       use_kernel: bool = False,
                       ) -> Tuple[AmState, Array]:
    """One scan-compiled epoch over unbatched (n, D) arrays.

    Convenience wrapper: ``prebatch`` + ``qail_epoch_scan``. Callers that
    run many epochs (fit, the train driver) should prebatch once and call
    ``qail_epoch_scan`` directly. Unlike the raw engine, this wrapper
    does NOT consume ``state`` — on donating backends it hands the scan a
    copy, so ad-hoc callers (tests, notebooks) can keep reusing theirs.

    Returns:
      (state, miss_rate) — miss rate is a device scalar (pre-update AMs).
    """
    n = h.shape[0]
    hb, qb, yb, mask = prebatch(h, queries, labels, cfg.batch_size)
    if _DONATE:
        state = jax.tree.map(jnp.copy, state)
    state, n_miss = qail_epoch_scan(state, cfg, hb, qb, yb, mask,
                                    refresh_every=refresh_every,
                                    use_kernel=use_kernel)
    return state, n_miss / n


def fold_feedback(state: AmState, cfg: MemhdConfig,
                  h: Array, queries: Array, labels: Array,
                  *, epochs: int = 1, refresh_every: int = 1,
                  use_kernel: bool = False,
                  ) -> Tuple[AmState, float]:
    """Fold a labeled feedback buffer into the AM — the online-learning
    primitive behind ``repro.serve.StreamingUpdater``.

    A lean ``fit(init_method="keep")``: no clustering init, no eval, no
    checkpointing — just ``prebatch`` once and run ``epochs``
    device-resident ``qail_epoch_scan`` passes over the buffer. Every
    label must own at least one centroid (grow the AM first via
    ``MemhdModel.grow_classes`` when feedback carries never-seen
    classes — Eq.-(5)'s ownership-masked argmax silently corrupts the
    update otherwise). Non-consuming: on donating backends the scan gets
    a copy, so the caller's ``state`` — typically the live serving
    model's — survives.

    Returns (new_state, miss_rate) with miss_rate from the LAST epoch
    (one host sync total — earlier epochs' miss scalars are never
    pulled).
    """
    if epochs < 1:
        raise ValueError(f"epochs must be >= 1, got {epochs}")
    n = h.shape[0]
    hb, qb, yb, mask = prebatch(h, queries, labels, cfg.batch_size)
    if _DONATE:
        state = jax.tree.map(jnp.copy, state)
    n_miss = jnp.zeros(())
    for _ in range(epochs):
        state, n_miss = qail_epoch_scan(state, cfg, hb, qb, yb, mask,
                                        refresh_every=refresh_every,
                                        use_kernel=use_kernel)
    return state, float(n_miss) / n


def qail_epoch_hostloop(state: AmState, cfg: MemhdConfig,
                        h: Array, queries: Array, labels: Array,
                        *, refresh_every: int = 1) -> Tuple[AmState, float]:
    """Pre-refactor host-side epoch loop (one dispatch + sync PER BATCH).

    Kept as the measured baseline of benchmarks/train_throughput.py and
    as a semantics oracle for ``qail_epoch_scan`` (which it must match —
    the former double finalize at epoch end when
    ``n_batches % refresh_every == 0`` is fixed in both).
    """
    n = h.shape[0]
    bs = cfg.batch_size
    n_batches = -(-n // bs)
    total_miss = 0.0
    for b in range(n_batches):
        sl = slice(b * bs, min((b + 1) * bs, n))
        state, miss = qail_batch_update(
            state, cfg, h[sl], queries[sl], labels[sl])
        total_miss += float(miss)  # <- the per-batch host sync
        if (b + 1) % refresh_every == 0:
            state = qail_finalize_epoch(state, cfg)
    if n_batches % refresh_every != 0:
        state = qail_finalize_epoch(state, cfg)
    return state, total_miss / n


def evaluate(state: AmState, queries: Array, labels: Array,
             batch: int = 4096) -> float:
    """Classification accuracy of the binary AM on (queries, labels)."""
    from repro.core import evaluate as eval_lib
    return eval_lib.am_accuracy(state, queries, labels, batch=batch)
