"""Quantization-aware iterative learning (QAIL) — §III-C.

The four steps per training sample, verbatim from the paper:

1. *Dot similarity* — similarity of the binarized query H^b against the
   **binary** AM; an update fires only on misprediction.
2. *Update target selection* — Eq. (4): the mispredicted class's centroid
   with the globally-highest similarity is the push-away target; Eq. (5):
   the true class's most-similar centroid is the pull-toward target.
3. *Iterative learning* — Eq. (6): C_true += alpha*H, C_pred -= alpha*H,
   applied to the **float** shadow AM.
4. *Binary AM update* — per-centroid normalization of the float AM (so no
   centroid dominates) followed by re-binarization (mean threshold).

Two implementations:

* ``qail_epoch_sequential`` — exact paper semantics: one sample at a time
  (``lax.scan``), the binary AM refreshed once per epoch (step 4 happens
  at epoch granularity, matching "iterative learning ... across the entire
  training dataset" + a normalization step per pass).
* ``qail_epoch_batched`` — minibatched variant for data-parallel
  execution: updates within a batch are computed against the same binary
  AM snapshot and scatter-added. This is the variant the distributed
  trainer shards with pjit; tests check it tracks the sequential variant.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import am as am_lib
from repro.core.types import MemhdConfig

Array = jax.Array
AmState = Dict[str, Array]


def _normalize_fp(fp_am: Array, mode: str) -> Array:
    """§III-C step 4's normalization.

    "ensures an even distribution of learning influence across multiple
    class vectors within the same class, preventing any single vector
    from dominating" — implemented as norm *equalization*: every centroid
    is rescaled to the mean centroid norm. This evens influence without
    collapsing the AM's overall scale (which must stay at sample-
    hypervector magnitude for Eq.-(6)'s lr*H updates to remain
    proportionate nudges).
    """
    if mode == "none":
        return fp_am
    if mode == "l2":
        norm = jnp.linalg.norm(fp_am, axis=-1, keepdims=True)
        mean_norm = jnp.mean(norm)
        return fp_am * (mean_norm / jnp.maximum(norm, 1e-8))
    raise ValueError(f"bad normalize mode: {mode!r}")


def select_update_targets(sims: Array, centroid_class: Array, label: Array,
                          n_classes: int) -> Tuple[Array, Array, Array]:
    """Eqs. (4) and (5) for a single query.

    Args:
      sims: (C,) dot similarities of one query against the binary AM.
      centroid_class: (C,) centroid ownership.
      label: scalar true class l.
      n_classes: k.

    Returns:
      (mispredicted, pred_target, true_target):
        mispredicted: bool scalar — fire an update?
        pred_target: centroid index (l', m) of Eq. (4) (global argmax).
        true_target: centroid index (l, n) of Eq. (5) (argmax within the
          true class).
    """
    pred_target = jnp.argmax(sims)  # Eq. (4): global best centroid
    pred_class = centroid_class[pred_target]
    mispredicted = pred_class != label

    neg = jnp.finfo(sims.dtype).min
    own = centroid_class == label
    true_target = jnp.argmax(jnp.where(own, sims, neg))  # Eq. (5)
    del n_classes
    return mispredicted, pred_target, true_target


@partial(jax.jit, static_argnames=("cfg",))
def qail_epoch_sequential(state: AmState, cfg: MemhdConfig,
                          h: Array, queries: Array, labels: Array,
                          ) -> AmState:
    """One exact (sample-by-sample) QAIL epoch.

    Args:
      state: AM state dict (fp, binary, centroid_class).
      cfg: MEMHD config (lr, normalize, threshold, update_with).
      h: (n, D) float encoded hypervectors (the Eq.-6 update payload when
        ``cfg.update_with == "encoded"``).
      queries: (n, D) binarized queries H^b (similarity payload).
      labels: (n,) int labels.

    Returns:
      Updated AM state (binary refreshed once, at epoch end — step 4).
    """
    centroid_class = state["centroid_class"]
    binary = state["binary"]
    upd = h if cfg.update_with == "encoded" else queries

    def body(fp, inputs):
        q, u, y = inputs
        sims = binary @ q  # (C,) — evaluated against the epoch's binary AM
        mis, pred_t, true_t = select_update_targets(
            sims, centroid_class, y, cfg.classes)
        delta = jnp.where(mis, cfg.lr, 0.0)
        fp = fp.at[true_t].add(delta * u)
        fp = fp.at[pred_t].add(-delta * u)
        return fp, mis

    fp, misses = jax.lax.scan(body, state["fp"], (queries, upd, labels))
    fp = _normalize_fp(fp, cfg.normalize)
    new_state = dict(state, fp=fp,
                     binary=am_lib.binarize_am(fp, cfg.threshold))
    return new_state


@partial(jax.jit, static_argnames=("cfg", "wire_dtype"))
def qail_batch_delta(state: AmState, cfg: MemhdConfig,
                     h: Array, queries: Array, labels: Array,
                     wire_dtype=jnp.bfloat16,
                     ) -> Tuple[Array, Array]:
    """Eq.-(6) update *delta* for a batch (no state mutation).

    Returns (delta, n_miss) with delta shaped like the float AM. Exposed
    separately so distributed training can control the cross-shard sync:
    ONE fused scatter (true-target and pred-target updates concatenated)
    emitted in ``wire_dtype`` — under GSPMD the all-reduce operand is the
    scatter output, so this is what sets the wire format (§Perf Q2: one
    bf16 reduce instead of two f32 ones, 8x fewer bytes).
    """
    centroid_class = state["centroid_class"]
    binary = state["binary"]
    upd = h if cfg.update_with == "encoded" else queries

    sims = queries @ binary.T  # (B, C)
    pred_t = jnp.argmax(sims, axis=-1)
    pred_class = centroid_class[pred_t]
    mis = (pred_class != labels).astype(jnp.float32)

    neg = jnp.finfo(sims.dtype).min
    own = centroid_class[None, :] == labels[:, None]
    true_t = jnp.argmax(jnp.where(own, sims, neg), axis=-1)

    coef = ((cfg.lr * mis)[:, None] * upd).astype(wire_dtype)
    delta = jnp.zeros(state["fp"].shape, wire_dtype)
    delta = delta.at[true_t].add(coef)
    delta = delta.at[pred_t].add(-coef)
    return delta, mis.sum()


@partial(jax.jit, static_argnames=("cfg",))
def qail_batch_update(state: AmState, cfg: MemhdConfig,
                      h: Array, queries: Array, labels: Array,
                      ) -> Tuple[AmState, Array]:
    """Minibatched QAIL update (one batch, one binary-AM snapshot).

    All mispredicted samples in the batch compute their Eq.-(4)/(5)
    targets against the same binary AM and their Eq.-(6) deltas are
    scatter-added. Returns (new_state_without_binary_refresh, n_miss).
    """
    centroid_class = state["centroid_class"]
    binary = state["binary"]
    upd = h if cfg.update_with == "encoded" else queries

    sims = queries @ binary.T  # (B, C)
    pred_t = jnp.argmax(sims, axis=-1)  # (B,)
    pred_class = centroid_class[pred_t]
    mis = (pred_class != labels).astype(jnp.float32)  # (B,)

    neg = jnp.finfo(sims.dtype).min
    own = centroid_class[None, :] == labels[:, None]  # (B, C)
    true_t = jnp.argmax(jnp.where(own, sims, neg), axis=-1)  # (B,)

    coef = (cfg.lr * mis)[:, None] * upd  # (B, D)
    fp = state["fp"]
    fp = fp.at[true_t].add(coef)
    fp = fp.at[pred_t].add(-coef)
    return dict(state, fp=fp), mis.sum()


@partial(jax.jit, static_argnames=("cfg",))
def qail_finalize_epoch(state: AmState, cfg: MemhdConfig) -> AmState:
    """Step 4 (normalize + re-binarize) for the batched variant."""
    fp = _normalize_fp(state["fp"], cfg.normalize)
    return dict(state, fp=fp, binary=am_lib.binarize_am(fp, cfg.threshold))


def qail_epoch_batched(state: AmState, cfg: MemhdConfig,
                       h: Array, queries: Array, labels: Array,
                       *, refresh_every: int = 1) -> Tuple[AmState, float]:
    """One epoch of minibatched QAIL over a full (host-resident) dataset.

    Args:
      refresh_every: refresh the binary AM every this-many batches
        (1 = per batch, closest to sequential semantics; larger values
        trade fidelity for fewer binarization passes — measured in
        tests/test_qail.py).

    Returns:
      (state, miss_rate) — miss rate across the epoch (pre-update AMs).
    """
    n = h.shape[0]
    bs = cfg.batch_size
    n_batches = -(-n // bs)
    total_miss = 0.0
    for b in range(n_batches):
        sl = slice(b * bs, min((b + 1) * bs, n))
        state, miss = qail_batch_update(
            state, cfg, h[sl], queries[sl], labels[sl])
        total_miss += float(miss)
        if (b + 1) % refresh_every == 0:
            state = qail_finalize_epoch(state, cfg)
    state = qail_finalize_epoch(state, cfg)
    return state, total_miss / n


def evaluate(state: AmState, queries: Array, labels: Array,
             batch: int = 4096) -> float:
    """Classification accuracy of the binary AM on (queries, labels)."""
    n = queries.shape[0]
    correct = 0
    for b in range(0, n, batch):
        pred = am_lib.predict(state["binary"], state["centroid_class"],
                              queries[b:b + batch])
        correct += int(jnp.sum(pred == labels[b:b + batch]))
    return correct / n
