"""IMC array mapping & cost model (cycles / arrays / utilization / energy).

This module reproduces, in closed form, the accounting of Table II and
Fig. 7 of the paper and exposes it as a first-class cost model that other
layers consume:

* the Pallas ``am_search`` kernel asserts its grid size equals
  ``cycles(...)`` from this model (hardware model == kernel geometry);
* the energy benchmark (Fig. 7) evaluates ``energy(...)`` ratios;
* ``launch/dryrun.py`` reports MEMHD array occupancy next to the LM
  rooflines;
* the device-fidelity simulator (``repro.imcsim`` +
  ``kernels/am_search_imc.py``) tiles its simulated analog search into
  exactly this model's (A x A) blocks, so ``assert_consistent_sim``
  holds for any array geometry.

Mapping semantics (validated against every entry of Table II):

An MVM with weight matrix (R rows x C_cols) is tiled onto (A x A) arrays.

* ``basic`` mapping — the weight matrix is tiled directly:
    tiles  = ceil(R/A) * ceil(C_cols/A)
    arrays = tiles                 (weights are resident, one tile each)
    cycles = tiles                 (sequential passes on one physical array)
* ``partitioned`` mapping [9] — the D-dim vector is split into P segments;
  segment matrices sit side-by-side in the column dimension:
    R'      = R / P,  C' = C_cols * P
    arrays  = ceil(R'/A) * ceil(C'/A)
    cycles  = P * ceil(R'/A) * ceil(C_cols/A)   (all segment tiles still
              stream through sequentially — partitioning saves arrays,
              never cycles; exactly the paper's Fig. 1-(b) point)
* ``memhd`` mapping — the AM is (D x C) with D, C chosen to match the
  array, so tiles = ceil(D/A) * ceil(C/A) and (for D=C=A) one-shot search.

Utilization = fraction of mapped-array columns actually used.
Energy      = tiles_processed * e_read_pass (one array MVM pass each) —
              reproducing Fig. 7's "partitioning keeps energy constant,
              MEMHD divides it by the tile count" behaviour.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.types import ImcArrayConfig


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class MappingCost:
    """Cost of mapping one MVM stage (EM or AM) onto IMC arrays."""

    rows: int                  # logical weight rows (vector dim fed in)
    cols: int                  # logical weight cols (outputs)
    partitions: int            # P (1 = unpartitioned)
    cycles: int                # sequential passes on a single array
    arrays: int                # physical arrays to hold all weights
    used_columns: int          # occupied columns across mapped arrays
    total_columns: int         # available columns across mapped arrays

    @property
    def utilization(self) -> float:
        return self.used_columns / self.total_columns

    def energy_pj(self, arr: ImcArrayConfig) -> float:
        """Inference (read) energy: one pass per sequential tile."""
        return self.cycles * arr.e_read_pass_pj

    def latency_ns(self, arr: ImcArrayConfig) -> float:
        return self.cycles * arr.t_cycle_ns


def map_basic(rows: int, cols: int, arr: ImcArrayConfig) -> MappingCost:
    """Direct tiling (the paper's 'Basic' mapping, Fig. 1-(a))."""
    rb = _ceil_div(rows, arr.rows)
    cb = _ceil_div(cols, arr.cols)
    tiles = rb * cb
    return MappingCost(
        rows=rows, cols=cols, partitions=1,
        cycles=tiles, arrays=tiles,
        used_columns=cols * rb,
        total_columns=cb * arr.cols * rb,
    )


def map_partitioned(rows: int, cols: int, partitions: int,
                    arr: ImcArrayConfig) -> MappingCost:
    """Partitioning [9] (Fig. 1-(b)): D split into P segments packed
    across columns. rows must be divisible by partitions."""
    if rows % partitions:
        raise ValueError(f"rows={rows} not divisible by P={partitions}")
    seg_rows = rows // partitions
    packed_cols = cols * partitions
    rb = _ceil_div(seg_rows, arr.rows)
    cb = _ceil_div(packed_cols, arr.cols)
    arrays = rb * cb
    # Every segment's row-tiles still stream sequentially (partial sums
    # for different segments cannot be fused in-array):
    cycles = partitions * rb * _ceil_div(cols, arr.cols)
    return MappingCost(
        rows=rows, cols=cols, partitions=partitions,
        cycles=cycles, arrays=arrays,
        used_columns=packed_cols * rb,
        total_columns=cb * arr.cols * rb,
    )


def map_memhd(dim: int, columns: int, arr: ImcArrayConfig) -> MappingCost:
    """MEMHD mapping: the (D x C) multi-centroid AM tiles the array
    exactly; full utilization by construction when D,C are multiples of
    the array size (the configs enforce that)."""
    return map_basic(dim, columns, arr)


def encoder_cost(features: int, dim: int, arr: ImcArrayConfig,
                 ) -> MappingCost:
    """EM mapping cost: the (f x D) binary projection MVM."""
    return map_basic(features, dim, arr)


@dataclasses.dataclass(frozen=True)
class PipelineCost:
    """EM + AM inference cost for one input sample."""

    em: MappingCost
    am: MappingCost

    @property
    def total_cycles(self) -> int:
        return self.em.cycles + self.am.cycles

    @property
    def total_arrays(self) -> int:
        return self.em.arrays + self.am.arrays

    def energy_pj(self, arr: ImcArrayConfig) -> float:
        return self.em.energy_pj(arr) + self.am.energy_pj(arr)


def memhd_pipeline(features: int, dim: int, columns: int,
                   arr: ImcArrayConfig) -> PipelineCost:
    return PipelineCost(em=encoder_cost(features, dim, arr),
                        am=map_memhd(dim, columns, arr))


def basic_pipeline(features: int, dim: int, classes: int,
                   arr: ImcArrayConfig) -> PipelineCost:
    return PipelineCost(em=encoder_cost(features, dim, arr),
                        am=map_basic(dim, classes, arr))


def partitioned_pipeline(features: int, dim: int, classes: int,
                         partitions: int, arr: ImcArrayConfig,
                         ) -> PipelineCost:
    return PipelineCost(em=encoder_cost(features, dim, arr),
                        am=map_partitioned(dim, classes, partitions, arr))


def table2(arr: ImcArrayConfig | None = None) -> Dict[str, Dict]:
    """Recompute Table II of the paper for the 128x128 array.

    Returns a nested dict keyed by dataset group and mapping method with
    cycles/arrays/utilization for EM, AM and totals — asserted verbatim
    against the paper's numbers in tests/test_imc_model.py.
    """
    arr = arr or ImcArrayConfig()
    out: Dict[str, Dict] = {}

    # (a) MNIST / FMNIST: f=784, baseline D=10240, k=10; MEMHD 128x128.
    out["mnist_fmnist"] = {
        "basic": basic_pipeline(784, 10240, 10, arr),
        "partition_p5": partitioned_pipeline(784, 10240, 10, 5, arr),
        "partition_p10": partitioned_pipeline(784, 10240, 10, 10, arr),
        "memhd": memhd_pipeline(784, 128, 128, arr),
    }
    # (b) ISOLET: f=617, baseline D=10240, k=26; MEMHD 512x128.
    out["isolet"] = {
        "basic": basic_pipeline(617, 10240, 26, arr),
        "partition_p2": partitioned_pipeline(617, 10240, 26, 2, arr),
        "partition_p4": partitioned_pipeline(617, 10240, 26, 4, arr),
        "memhd": memhd_pipeline(617, 512, 128, arr),
    }
    return out


def am_energy_ratio(dim: int, cols: int, baseline_dim: int,
                    baseline_cols: int, arr: ImcArrayConfig | None = None,
                    ) -> float:
    """Fig.-7 style normalized AM energy ratio baseline/MEMHD."""
    arr = arr or ImcArrayConfig()
    e_base = map_basic(baseline_dim, baseline_cols, arr).energy_pj(arr)
    e_memhd = map_memhd(dim, cols, arr).energy_pj(arr)
    return e_base / e_memhd


def mxu_grid(dim: int, columns: int, tile: int = 128) -> tuple:
    """The TPU analogue: Pallas grid for the (D x C) AM search kernel.

    One grid step == one 128x128 MXU block pass == one IMC array cycle;
    kernels/am_search.py asserts ``math.prod(mxu_grid(...)) ==
    map_memhd(...).cycles`` so the silicon model and the kernel stay
    consistent.
    """
    return (_ceil_div(dim, tile), _ceil_div(columns, tile))


def assert_consistent(dim: int, columns: int, arr: ImcArrayConfig | None = None):
    arr = arr or ImcArrayConfig()
    grid = mxu_grid(dim, columns, arr.rows)
    cycles = map_memhd(dim, columns, arr).cycles
    if math.prod(grid) != cycles:
        raise AssertionError(
            f"kernel grid {grid} inconsistent with IMC cycle model {cycles}")


def sim_grid(dim: int, columns: int, arr: ImcArrayConfig | None = None,
             ) -> tuple:
    """(row-tiles, col-tiles) the device-fidelity kernel iterates: the
    tile decomposition of the (D x C) AM onto (rows x cols) arrays.
    Unlike ``mxu_grid`` this honors non-square array geometry."""
    arr = arr or ImcArrayConfig()
    return (_ceil_div(dim, arr.rows), _ceil_div(columns, arr.cols))


def assert_consistent_sim(dim: int, columns: int,
                          arr: ImcArrayConfig | None = None):
    """Hardware model == simulated-kernel geometry, any array shape."""
    arr = arr or ImcArrayConfig()
    grid = sim_grid(dim, columns, arr)
    cycles = map_memhd(dim, columns, arr).cycles
    if math.prod(grid) != cycles:
        raise AssertionError(
            f"imcsim kernel grid {grid} inconsistent with IMC cycle "
            f"model {cycles}")
