"""Distributed MEMHD training: data-parallel QAIL under pjit.

The paper trains on a workstation; here the same algorithm is expressed
as a pod-scale program — the point of integrating MEMHD as a first-class
feature of the framework rather than a side script:

  * encoding (the f x D binary MVM) shards over the batch axes;
  * the AM (C x D, <= a few MB binary) is replicated — it is the *model*,
    and it is tiny by construction (that is the paper's whole thesis);
  * Eq.-(6) scatter-updates from each batch shard are partial sums into
    the replicated float AM; GSPMD inserts the cross-shard psum;
  * step 4 (normalize + re-binarize) is replicated compute.

``dryrun_epoch`` lowers + compiles one full QAIL epoch over an
MNIST-sized dataset on the production mesh and extracts the same
roofline terms as the LM cells — the "most representative of the paper's
technique" row of §Perf.

``make_scan_epoch_sharded`` / ``fit_sharded_epochs`` are the
data-parallel mirror of the device-resident training engine
(``qail.qail_epoch_scan``): the whole epoch is one jitted shard_map'd
``lax.scan`` over prebatched minibatches — per-shard Eq.-(6) deltas,
one bf16 psum per batch, one host sync per epoch. This is what
``MemhdModel.fit_sharded`` runs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import qail
from repro.core.types import EncoderConfig, MemhdConfig

from repro.compat import shard_map as _shard_map

Array = jax.Array


def _batch_axes(mesh) -> tuple:
    """MEMHD shards the batch over EVERY mesh axis.

    The model (binary AM + projection, a few MB) is replicated — that is
    the paper's thesis — so there is nothing for a tensor axis to do;
    leaving "model" out of the batch sharding replicates all compute 16x
    (measured: useful-FLOPs ratio 0.0625 == 1/16; §Perf iteration Q1).
    """
    return tuple(mesh.axis_names)


def make_epoch_fn(enc_cfg: EncoderConfig, am_cfg: MemhdConfig,
                  mesh=None):
    """(enc_params, am_state, feats, labels) -> (am_state, miss_rate).

    One full QAIL epoch: encode -> binary similarity -> Eq. 4/5 target
    selection -> Eq. 6 scatter updates -> normalize -> re-binarize.
    Batched semantics (one binary-AM snapshot per epoch) — the variant
    the paper's §III-C runs per pass over the training set.
    """

    def epoch(enc_params, am_state, feats, labels):
        """shard_map over the whole mesh: per-shard encode + Eq.-6 delta,
        ONE explicit bf16 psum for the AM sync (§Perf Q2 — GSPMD left to
        itself emitted two f32[C,D] all-reduces; the explicit psum pins
        the wire format and fuses the miss-count ride-along)."""
        if mesh is None:
            # Single-device path (tests without meshes).
            m = enc_params["projection"]
            h = jnp.einsum("bf,fd->bd", feats, m)
            q = jnp.where(h >= 0, 1.0, -1.0)
            delta, miss = qail.qail_batch_delta(am_state, am_cfg, h, q,
                                                labels)
            state = dict(am_state,
                         fp=am_state["fp"] + delta.astype(jnp.float32))
            state = qail.qail_finalize_epoch(state, am_cfg)
            return state, miss / feats.shape[0]

        all_axes = tuple(mesh.axis_names)

        def local(m, fp, binary, owners, feats_l, labels_l):
            # bf16 streaming + MXU-native bf16 MVM, f32 accumulation
            # (§Perf Q4): the projection is ±1 so bf16 operands are
            # exact; only the accumulate needs f32.
            h = jnp.einsum("bf,fd->bd", feats_l.astype(jnp.bfloat16),
                           m.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
            q = jnp.where(h >= 0, 1.0, -1.0)
            st = {"fp": fp, "binary": binary, "centroid_class": owners}
            delta, miss = qail.qail_batch_delta(st, am_cfg, h, q, labels_l)
            delta = jax.lax.psum(delta, all_axes)        # bf16 wire
            miss = jax.lax.psum(miss, all_axes)
            new_fp = fp + delta.astype(jnp.float32)
            return new_fp, miss

        from jax.sharding import PartitionSpec as P
        new_fp, miss = _shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(all_axes, None), P(all_axes)),
            out_specs=(P(), P()),
        )(enc_params["projection"], am_state["fp"], am_state["binary"],
          am_state["centroid_class"], feats, labels)
        state = dict(am_state, fp=new_fp)
        state = qail.qail_finalize_epoch(state, am_cfg)
        return state, miss / feats.shape[0]

    return epoch


def shardings_for(mesh, enc_cfg: EncoderConfig, am_cfg: MemhdConfig):
    ba = _batch_axes(mesh)
    repl = NamedSharding(mesh, P())
    return {
        "enc": {"projection": repl},
        "am": {"fp": repl, "binary": repl, "centroid_class": repl},
        "feats": NamedSharding(mesh, P(ba, None)),
        "labels": NamedSharding(mesh, P(ba)),
    }


def fit_distributed(mesh, model, feats: Array, labels: Array,
                    epochs: Optional[int] = None):
    """Run QAIL epochs under pjit on ``mesh``. Returns updated model."""
    import dataclasses

    am_cfg = model.am_cfg
    epochs = am_cfg.epochs if epochs is None else epochs
    sh = shardings_for(mesh, model.enc_cfg, am_cfg)
    epoch = make_epoch_fn(model.enc_cfg, am_cfg, mesh)
    with mesh:
        fitted = jax.jit(
            epoch,
            in_shardings=(sh["enc"], sh["am"], sh["feats"], sh["labels"]),
            out_shardings=(sh["am"], None),
        )
        feats = jax.device_put(feats, sh["feats"])
        labels = jax.device_put(labels, sh["labels"])
        state = jax.device_put(model.am_state, sh["am"])
        enc = jax.device_put(model.enc_params, sh["enc"])
        for _ in range(epochs):
            state, _miss = fitted(enc, state, feats, labels)
    return dataclasses.replace(model, am_state=state)


def make_scan_epoch_sharded(cfg: MemhdConfig, mesh, refresh_every: int = 1):
    """Build a jit-able data-parallel scan epoch over prebatched data.

    (am_state, hb, qb, yb, mask) -> (am_state, n_miss), where the
    prebatched arrays are ``qail.prebatch`` outputs with the per-batch
    axis sharded over every mesh axis. Inside ``shard_map`` each shard
    runs the SAME ``lax.scan`` the single-device engine runs
    (``qail.qail_epoch_scan`` semantics), computing its local Eq.-(6)
    delta with ``qail_batch_delta`` and syncing with ONE bf16 psum per
    batch; the refresh (step 4) is replicated compute, identical on all
    shards because it consumes the psum'd float AM.
    """
    all_axes = tuple(mesh.axis_names)

    def epoch(am_state, hb, qb, yb, mask):
        nb = hb.shape[0]

        def _refresh(args):
            return qail.refresh_am(args[0], args[1], cfg)

        def local(fp, binary, owners, hb_l, qb_l, yb_l, mb_l):
            def body(carry, xs):
                fp, binary = carry
                b_idx, hx, qx, yx, mx = xs
                st = {"fp": fp, "binary": binary, "centroid_class": owners}
                delta, miss = qail.qail_batch_delta(
                    st, cfg, hx, qx, yx, mask=mx)
                delta = jax.lax.psum(delta, all_axes)  # bf16 wire
                miss = jax.lax.psum(miss, all_axes)
                fp = fp + delta.astype(jnp.float32)
                fp, binary = jax.lax.cond(
                    (b_idx + 1) % refresh_every == 0, _refresh,
                    lambda a: a, (fp, binary))
                return (fp, binary), miss

            (fp, binary), misses = jax.lax.scan(
                body, (fp, binary),
                (jnp.arange(nb), hb_l, qb_l, yb_l, mb_l))
            return fp, binary, misses.sum()

        fp, binary, n_miss = _shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P(), P(None, all_axes, None),
                      P(None, all_axes, None), P(None, all_axes),
                      P(None, all_axes)),
            out_specs=(P(), P(), P()),
        )(am_state["fp"], am_state["binary"], am_state["centroid_class"],
          hb, qb, yb, mask)
        state = dict(am_state, fp=fp, binary=binary)
        if nb % refresh_every != 0:
            state = qail.qail_finalize_epoch(state, cfg)
        return state, n_miss

    return epoch


def fit_sharded_epochs(mesh, am_state, cfg: MemhdConfig,
                       hb: Array, qb: Array, yb: Array, mask: Array,
                       *, epochs: int, refresh_every: int = 1,
                       n_samples: Optional[int] = None):
    """Run ``epochs`` data-parallel scan epochs; one host sync per epoch.

    Returns (am_state, curve). The prebatched arrays are device_put with
    the per-batch axis sharded over the whole mesh; the AM is replicated.
    """
    n = n_samples if n_samples is not None else int(mask.sum())
    epoch = make_scan_epoch_sharded(cfg, mesh, refresh_every)
    repl = NamedSharding(mesh, P())
    ba = tuple(mesh.axis_names)
    sh_b2 = NamedSharding(mesh, P(None, ba))
    sh_b3 = NamedSharding(mesh, P(None, ba, None))
    am_sh = {"fp": repl, "binary": repl, "centroid_class": repl}
    with mesh:
        fitted = jax.jit(epoch,
                         in_shardings=(am_sh, sh_b3, sh_b3, sh_b2, sh_b2),
                         out_shardings=(am_sh, None))
        hb = jax.device_put(hb, sh_b3)
        qb = jax.device_put(qb, sh_b3)
        yb = jax.device_put(yb, sh_b2)
        mask = jax.device_put(mask, sh_b2)
        state = jax.device_put(am_state, am_sh)
        curve = []
        for ep in range(1, epochs + 1):
            state, n_miss = fitted(state, hb, qb, yb, mask)
            curve.append({"epoch": ep,
                          "train_miss": float(n_miss) / n})  # 1 sync/epoch
    return state, curve


def make_inference_fn(enc_cfg: EncoderConfig, am_cfg: MemhdConfig):
    """Batched one-shot associative search: feats -> predicted classes.

    The paper's deployment workload (§III-D): projection-encode,
    binarize, similarity MVM against the binary AM, arg-max, ownership
    lookup. Pure feed-forward — shards trivially over every mesh axis
    with a replicated few-MB model.
    """

    def infer(enc_params, binary_am, centroid_class, feats):
        h = jnp.einsum("bf,fd->bd", feats.astype(jnp.bfloat16),
                       enc_params["projection"].astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        q = jnp.where(h >= 0, 1.0, -1.0).astype(jnp.bfloat16)
        sims = jnp.einsum("bd,cd->bc", q,
                          binary_am.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
        return centroid_class[jnp.argmax(sims, axis=-1)]

    return infer


def dryrun_inference(mesh, *, features: int = 784, dim: int = 1024,
                     columns: int = 1024, n_queries: int = 1_048_576,
                     ) -> Dict:
    """Roofline of the batched one-shot search on the production mesh."""
    from repro.distributed import hlo_cost
    from repro.distributed.roofline import roofline

    enc_cfg = EncoderConfig(kind="projection", features=features, dim=dim)
    am_cfg = MemhdConfig(dim=dim, columns=columns)
    infer = make_inference_fn(enc_cfg, am_cfg)
    ba = _batch_axes(mesh)
    repl = NamedSharding(mesh, P())
    with mesh:
        compiled = jax.jit(
            infer,
            in_shardings=({"projection": repl}, repl, repl,
                          NamedSharding(mesh, P(ba, None))),
            out_shardings=NamedSharding(mesh, P(ba)),
        ).lower(
            {"projection": jax.ShapeDtypeStruct((features, dim),
                                                jnp.bfloat16)},
            jax.ShapeDtypeStruct((columns, dim), jnp.bfloat16),
            jax.ShapeDtypeStruct((columns,), jnp.int32),
            jax.ShapeDtypeStruct((n_queries, features), jnp.bfloat16),
        ).compile()

    chips = mesh.devices.size
    totals = hlo_cost.analyze(compiled.as_text(), chips)
    ma = compiled.memory_analysis()
    model_flops = 2.0 * n_queries * (features * dim + dim * columns)
    rep = roofline(
        arch="memhd-search", shape=f"{dim}x{columns}",
        mesh_name="x".join(str(s) for s in mesh.devices.shape),
        chips=chips, flops_per_dev=totals.flops,
        bytes_per_dev=totals.hbm_bytes, wire_by_kind=totals.wire_by_kind,
        model_flops_global=model_flops,
        argument_bytes=float(ma.argument_size_in_bytes),
        temp_bytes=float(ma.temp_size_in_bytes),
        output_bytes=float(ma.output_size_in_bytes),
    )
    return {"roofline": rep.to_json(),
            "memory": {"argument_bytes": int(ma.argument_size_in_bytes),
                       "temp_bytes": int(ma.temp_size_in_bytes)}}


def dryrun_epoch(mesh, *, features: int = 784, dim: int = 1024,
                 columns: int = 1024, classes: int = 10,
                 n_samples: int = 61_440) -> Dict:
    """Lower + compile one distributed QAIL epoch; roofline terms.

    Defaults: MNIST-scale (60k samples padded to a 256/512-divisible
    count) at the paper's largest geometry (1024x1024).
    """
    from repro.distributed import hlo_cost
    from repro.distributed.roofline import roofline

    enc_cfg = EncoderConfig(kind="projection", features=features, dim=dim)
    am_cfg = MemhdConfig(dim=dim, columns=columns, classes=classes)
    sh = shardings_for(mesh, enc_cfg, am_cfg)
    epoch = make_epoch_fn(enc_cfg, am_cfg, mesh)

    enc_sds = {"projection": jax.ShapeDtypeStruct((features, dim),
                                                  jnp.float32)}
    am_sds = {
        "fp": jax.ShapeDtypeStruct((columns, dim), jnp.float32),
        "binary": jax.ShapeDtypeStruct((columns, dim), jnp.float32),
        "centroid_class": jax.ShapeDtypeStruct((columns,), jnp.int32),
    }
    feats_sds = jax.ShapeDtypeStruct((n_samples, features), jnp.float32)
    labels_sds = jax.ShapeDtypeStruct((n_samples,), jnp.int32)

    with mesh:
        compiled = jax.jit(
            epoch,
            in_shardings=(sh["enc"], sh["am"], sh["feats"], sh["labels"]),
            out_shardings=(sh["am"], None),
        ).lower(enc_sds, am_sds, feats_sds, labels_sds).compile()

    chips = mesh.devices.size
    totals = hlo_cost.analyze(compiled.as_text(), chips)
    ma = compiled.memory_analysis()
    # Useful FLOPs: encode MVM + similarity MVM (fwd only; QAIL has no
    # backprop — one of the paper's efficiency arguments).
    model_flops = 2.0 * n_samples * (features * dim + dim * columns)
    rep = roofline(
        arch="memhd-qail", shape=f"{dim}x{columns}", mesh_name="x".join(
            str(s) for s in mesh.devices.shape),
        chips=chips, flops_per_dev=totals.flops,
        bytes_per_dev=totals.hbm_bytes, wire_by_kind=totals.wire_by_kind,
        model_flops_global=model_flops,
        argument_bytes=float(ma.argument_size_in_bytes),
        temp_bytes=float(ma.temp_size_in_bytes),
        output_bytes=float(ma.output_size_in_bytes),
    )
    return {"roofline": rep.to_json(),
            "memory": {"argument_bytes": int(ma.argument_size_in_bytes),
                       "temp_bytes": int(ma.temp_size_in_bytes)}}
