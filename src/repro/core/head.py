"""MemhdHead — the paper's multi-centroid AM as a classification head.

The honest intersection between MEMHD (an HDC *classifier*) and the
assigned generative backbones (DESIGN.md §Arch-applicability): pooled
backbone features are projection-encoded into a D-dimensional bipolar
hypervector and classified by one-shot associative search against a
(C x D) binary multi-centroid AM — exactly the paper's pipeline with
"features" = backbone embeddings instead of pixels.

The head trains with the same clustering-init + QAIL recipe and deploys
onto a single 128x128 IMC array (or one ``am_search`` kernel call) when
D = C = 128.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.memhd import MemhdModel
from repro.core.types import EncoderConfig, MemhdConfig

Array = jax.Array


@dataclasses.dataclass
class MemhdHead:
    """Multi-centroid AM head over pooled backbone features."""

    model: MemhdModel

    @classmethod
    def create(cls, key: Array, feature_dim: int, n_classes: int,
               dim: int = 128, columns: int = 128, **am_kwargs,
               ) -> "MemhdHead":
        enc = EncoderConfig(kind="projection", features=feature_dim,
                            dim=dim)
        am = MemhdConfig(dim=dim, columns=columns, classes=n_classes,
                         **am_kwargs)
        return cls(MemhdModel.create(key, enc, am))

    @staticmethod
    def pool(hidden: Array) -> Array:
        """Mean-pool (B, S, D_model) backbone states to (B, D_model)."""
        return hidden.mean(axis=1)

    def fit(self, key: Array, feats: Array, labels: Array, **kw,
            ) -> Tuple["MemhdHead", Dict]:
        m, hist = self.model.fit(key, feats, labels, **kw)
        return MemhdHead(m), hist

    def predict(self, feats: Array) -> Array:
        return self.model.predict(feats)

    def score(self, feats: Array, labels: Array) -> float:
        return self.model.score(feats, labels)

    @property
    def memory_kb(self) -> float:
        return self.model.memory_kb
