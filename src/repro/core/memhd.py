"""MEMHD end-to-end model: encode -> cluster-init -> QAIL -> deploy.

This is the public, paper-faithful pipeline (Fig. 2):

    model  = MemhdModel.create(key, enc_cfg, am_cfg)
    model, hist = model.fit(key, feats, labels)       # (a)-(c) of Fig. 2
    acc    = model.score(test_feats, test_labels)     # (d) in-memory inference

``MemhdModel`` is an immutable pytree-of-arrays + static configs, so it
jits, shards, and checkpoints like any other model in the framework.

Training at scale
-----------------
``fit`` encodes the training set ONCE and runs every epoch as a single
compiled ``lax.scan`` (``qail.qail_epoch_scan``) — one dispatch and one
host sync per epoch. Pass ``ckpt=CheckpointManager(...)`` and the fit
checkpoints a ``MemhdTrainState`` every ``ckpt_every`` epochs and
auto-resumes bit-exactly from the newest valid one; the fault-tolerant
driver (``repro.launch.train --arch memhd``) builds on exactly this
path. ``fit_sharded`` runs the same scan epochs data-parallel over a
device mesh (per-shard Eq.-(6) deltas, one bf16 all-reduce per batch).
"""
from __future__ import annotations

import dataclasses
import logging
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import am as am_lib
from repro.core import encoding, evaluate as eval_lib, init as init_lib, qail
from repro.core.imc import ImcArrayConfig, memhd_pipeline
from repro.core.types import EncoderConfig, MemhdConfig

Array = jax.Array
log = logging.getLogger(__name__)


def _imc_cost(enc_cfg: EncoderConfig, am_cfg: MemhdConfig,
              arr: ImcArrayConfig | None):
    arr = arr or ImcArrayConfig()
    return memhd_pipeline(enc_cfg.features, am_cfg.dim, am_cfg.columns,
                          arr)


@partial(jax.jit, static_argnames=("enc_cfg",))
def _predict_feats(enc_params, enc_cfg: EncoderConfig, binary: Array,
                   centroid_class: Array, feats: Array) -> Array:
    """encode_query + associative search, one cached executable."""
    q = encoding.encode_query(enc_params, enc_cfg, feats)
    return am_lib.predict(binary, centroid_class, q)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MemhdTrainState:
    """Checkpointable training state: AM buffers + epoch counter.

    A plain pytree (both fields are array leaves), so it flows through
    ``checkpoint.CheckpointManager`` unchanged — the driver's atomic
    save / verified restore / keep-k machinery applies as-is.
    """

    am_state: Dict[str, Array]
    epoch: Array  # () int32

    def tree_flatten(self):
        return (self.am_state, self.epoch), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        am_state, epoch = children
        return cls(am_state, epoch)

    @classmethod
    def create(cls, am_state: Dict[str, Array],
               epoch: int = 0) -> "MemhdTrainState":
        return cls(am_state, jnp.asarray(epoch, jnp.int32))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MemhdModel:
    """Immutable MEMHD model (encoder params + AM state + configs)."""

    enc_params: Dict[str, Array]
    am_state: Dict[str, Array]
    enc_cfg: EncoderConfig
    am_cfg: MemhdConfig

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        return (self.enc_params, self.am_state), (self.enc_cfg, self.am_cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        enc_params, am_state = children
        enc_cfg, am_cfg = aux
        return cls(enc_params, am_state, enc_cfg, am_cfg)

    # -- construction ----------------------------------------------------------
    @classmethod
    def create(cls, key: Array, enc_cfg: EncoderConfig, am_cfg: MemhdConfig,
               ) -> "MemhdModel":
        if enc_cfg.dim != am_cfg.dim:
            raise ValueError(
                f"encoder D={enc_cfg.dim} != AM D={am_cfg.dim}")
        enc_params = encoding.init_encoder(key, enc_cfg)
        # AM starts empty; fit() builds it via clustering init.
        zeros = jnp.zeros((am_cfg.columns, am_cfg.dim), jnp.float32)
        owners = jnp.zeros((am_cfg.columns,), jnp.int32)
        return cls(enc_params, am_lib.make_am_state(zeros, owners,
                                                    am_cfg.threshold),
                   enc_cfg, am_cfg)

    # -- pipeline stages -------------------------------------------------------
    def encode(self, feats: Array) -> Array:
        return encoding.encode(self.enc_params, self.enc_cfg, feats)

    def encode_query(self, feats: Array) -> Array:
        return encoding.encode_query(self.enc_params, self.enc_cfg, feats)

    def initialize_am(self, key: Array, feats: Array, labels: Array,
                      *, method: str = "clustering",
                      h: Optional[Array] = None,
                      q: Optional[Array] = None,
                      ) -> Tuple["MemhdModel", List[dict]]:
        """Clustering-based (or random-sampling baseline) AM init (§III-A).

        Pass pre-encoded ``h`` / ``q`` to reuse an existing encode of
        ``feats`` (``fit`` does — the training set is encoded exactly
        once per fit, not once for init and again for the epochs).
        """
        if h is None:
            h = self.encode(feats)
        if q is None:
            q = encoding.binarize_query(h)
        if method == "clustering":
            fp, owners, history = init_lib.clustering_init(
                key, self.am_cfg, h, labels, queries=q)
        elif method == "random":
            fp, owners = init_lib.random_sampling_init(
                key, self.am_cfg, h, labels)
            history = []
        else:
            raise ValueError(f"unknown init method {method!r}")
        state = am_lib.make_am_state(fp, owners, self.am_cfg.threshold)
        return dataclasses.replace(self, am_state=state), history

    def fit(self, key: Array, feats: Array, labels: Array,
            *, init_method: str = "clustering",
            epochs: Optional[int] = None,
            mode: str = "batched",
            refresh_every: int = 1,
            eval_feats: Optional[Array] = None,
            eval_labels: Optional[Array] = None,
            ckpt=None, ckpt_every: int = 1,
            use_kernel: bool = False,
            noise_sim=None, noise_mode: str = "fixed",
            cell_bits: Optional[int] = None,
            ) -> Tuple["MemhdModel", Dict]:
        """Full training pipeline: init + scan-compiled QAIL epochs.

        The training set is encoded ONCE; both the clustering init and
        every epoch reuse the same device-resident ``h``/``q``/prebatched
        buffers. Each ``batched``-mode epoch is a single
        ``qail_epoch_scan`` dispatch — one host sync per epoch (the
        ``float(miss)`` for the history record).

        Args:
          refresh_every: binary-AM refresh cadence inside the epoch scan
            (1 = per batch; larger trades fidelity for fewer
            binarization passes).
          ckpt: optional ``checkpoint.CheckpointManager``. When given,
            fit auto-resumes from the newest valid ``MemhdTrainState``
            (bit-exact continuation) and checkpoints every ``ckpt_every``
            epochs plus at the end.
          use_kernel: route the epoch's inner step through the Pallas
            ``qail_update`` kernel.
          init_method: "clustering" (paper §III-A), "random", or "keep"
            — keep the CURRENT AM state and skip (re-)initialization;
            the fine-tuning mode ``imcsim.noise_aware`` builds on.
          noise_sim: optional ``ImcSimConfig`` — noise-aware QAIL: the
            training-time sims MVM sees a device-perturbed view of the
            binary AM (batched mode only; see ``qail.qail_epoch_scan``).
          noise_mode: "fixed" (default) trains against the ONE device
            instance ``deploy(target="imc", sim=noise_sim)`` will burn
            in (chip-in-the-loop); "fresh" redraws the perturbation per
            batch (robustness to the device distribution).
          cell_bits: optional — multi-bit quantization-aware QAIL: the
            training-time sims MVM sees the ``cell_bits``-bit quantized
            view of the live float shadow, the representation
            ``deploy(target="multibit", cell_bits=...)`` serves
            (batched mode only; composes with a conductance-noise
            ``noise_sim``; see ``qail.qail_epoch_scan``).

        Returns (model, history) where history holds per-epoch train miss
        rates and (optional) eval accuracies — consumed by the Fig.-5/6
        benchmarks.
        """
        epochs = self.am_cfg.epochs if epochs is None else epochs
        if noise_sim is not None and mode != "batched":
            raise ValueError("noise_sim needs the batched scan engine")
        if cell_bits is not None and mode != "batched":
            raise ValueError("cell_bits needs the batched scan engine")

        # Encode once; init and every epoch share these buffers.
        h = self.encode(feats)
        q = encoding.binarize_query(h)

        start_epoch = 0
        init_hist: List[dict] = []
        curve: List[dict] = []
        state = None
        resumed = False
        if ckpt is not None:
            template = MemhdTrainState.create(self.am_state)
            step, tree, extra = ckpt.restore(template)
            if step is not None:
                state = jax.tree.map(jnp.asarray, tree.am_state)
                start_epoch = step
                curve = list(extra.get("curve", []))
                init_hist = list(extra.get("init", []))
                resumed = True
                log.info("fit resumed from epoch %d", start_epoch)

        if state is None:
            if init_method == "keep":
                model, init_hist = self, []
                state = self.am_state
            else:
                model, init_hist = self.initialize_am(
                    key, feats, labels, method=init_method, h=h, q=q)
                state = model.am_state
        else:
            model = dataclasses.replace(self, am_state=state)

        eval_q = (model.encode_query(eval_feats)
                  if eval_feats is not None else None)

        def _save(ep, st):
            if ckpt is not None:
                ckpt.save(ep, MemhdTrainState.create(st, ep),
                          extra={"curve": curve, "init": init_hist})

        if start_epoch == 0 and not resumed:
            if eval_q is not None:
                acc0 = qail.evaluate(state, eval_q, eval_labels)
                curve.append({"epoch": 0, "eval_acc": acc0})
            _save(0, state)

        if mode == "batched":
            n = h.shape[0]
            hb, qb, yb, mask = qail.prebatch(h, q, labels,
                                             self.am_cfg.batch_size)
        noise_base = None
        if noise_sim is not None:
            from repro.imcsim import device as device_lib
            noise_base = (device_lib.device_instance_key(noise_sim)
                          if noise_mode == "fixed"
                          else jax.random.key(noise_sim.seed))
        for ep in range(start_epoch + 1, epochs + 1):
            if mode == "sequential":
                state = qail.qail_epoch_sequential(
                    state, self.am_cfg, h, q, labels)
                miss = float("nan")
            else:
                nkey = None
                if noise_base is not None:
                    nkey = (noise_base if noise_mode == "fixed"
                            else jax.random.fold_in(noise_base, ep))
                state, n_miss = qail.qail_epoch_scan(
                    state, self.am_cfg, hb, qb, yb, mask,
                    refresh_every=refresh_every, use_kernel=use_kernel,
                    sim=noise_sim, noise_key=nkey, noise_mode=noise_mode,
                    cell_bits=cell_bits)
                miss = float(n_miss) / n  # the ONE host sync this epoch
            rec = {"epoch": ep, "train_miss": miss}
            if eval_q is not None:
                rec["eval_acc"] = qail.evaluate(state, eval_q, eval_labels)
            curve.append(rec)
            if ep % ckpt_every == 0 or ep == epochs:
                _save(ep, state)
        model = dataclasses.replace(model, am_state=state)
        return model, {"init": init_hist, "curve": curve}

    def fit_sharded(self, key: Array, feats: Array, labels: Array,
                    *, mesh=None, epochs: Optional[int] = None,
                    init_method: str = "clustering",
                    refresh_every: int = 1,
                    ) -> Tuple["MemhdModel", Dict]:
        """Data-parallel fit: scan-compiled epochs under ``shard_map``.

        The batch axis of every prebatched minibatch shards over the
        mesh; each shard computes its Eq.-(6) delta (``qail_batch_delta``)
        and the shards sync with ONE bf16 all-reduce per batch (the
        wire-dtype machinery of §Perf Q2). The AM is replicated — it is
        the model, and it is tiny by construction.
        """
        from repro.core import distributed

        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), ("data",))
        epochs = self.am_cfg.epochs if epochs is None else epochs

        h = self.encode(feats)
        q = encoding.binarize_query(h)
        model, init_hist = self.initialize_am(
            key, feats, labels, method=init_method, h=h, q=q)

        n = h.shape[0]
        n_shards = int(mesh.devices.size)
        bs = -(-self.am_cfg.batch_size // n_shards) * n_shards
        hb, qb, yb, mask = qail.prebatch(h, q, labels, bs)

        state, curve = distributed.fit_sharded_epochs(
            mesh, model.am_state, self.am_cfg, hb, qb, yb, mask,
            epochs=epochs, refresh_every=refresh_every, n_samples=n)
        model = dataclasses.replace(model, am_state=state)
        return model, {"init": init_hist, "curve": curve}

    # -- class-incremental growth ------------------------------------------------
    def grow_classes(self, feats: Array, labels: Array,
                     *, centroids_per_class: int = 1,
                     h: Optional[Array] = None,
                     ) -> "MemhdModel":
        """Append never-seen classes to the AM: (C, D) -> (C + k·n, D).

        The extended-learning move (XL-HD): classes beyond the current
        ``am_cfg.classes`` get fresh centroids — the per-class mean of
        their encoded samples (chunk-split when ``centroids_per_class``
        > 1), rescaled to the mean norm of the existing float centroids
        so Eq.-(6) nudges and the global binarization threshold stay
        proportionate — WITHOUT touching the existing centroids or
        retraining. The returned model is a normal ``MemhdModel`` at the
        grown geometry; follow with ``fit(init_method="keep")`` (or
        ``qail.fold_feedback``) to polish the new rows against the old.

        Growth MUST happen before folding feedback that carries the new
        labels: QAIL's Eq.-(5) target selection masks on centroid
        ownership, and a label owning no centroid silently corrupts the
        update (the masked argmax degenerates to centroid 0).

        Args:
          feats: (n, f) raw feature rows; only rows labeled beyond the
            current class count seed new centroids.
          labels: (n,) int labels. New classes must be contiguous from
            ``am_cfg.classes`` (class ids are dense by construction
            everywhere else).
          centroids_per_class: centroids allocated per appended class.
          h: optional pre-encoded ``encode(feats)`` to reuse (the
            encoder is untouched by growth, so any encode stays valid).

        Returns:
          The grown model (new ``am_state`` + ``am_cfg``; encoder
          shared). Raises if no label exceeds the current classes.
        """
        import numpy as np
        old_k = self.am_cfg.classes
        yn = np.asarray(labels, np.int64)
        new_classes = sorted(int(c) for c in np.unique(yn) if c >= old_k)
        if not new_classes:
            raise ValueError(
                f"no labels beyond the current {old_k} classes")
        if new_classes != list(range(old_k, old_k + len(new_classes))):
            raise ValueError(
                f"appended classes must be contiguous from {old_k}, "
                f"got {new_classes}")
        if centroids_per_class < 1:
            raise ValueError("centroids_per_class must be >= 1")
        if h is None:
            h = self.encode(feats)
        hn = np.asarray(h, np.float32)

        fp = self.am_state["fp"]
        owners = self.am_state["centroid_class"]
        scale = float(jnp.mean(jnp.linalg.norm(fp, axis=-1)))
        rows, row_owners = [], []
        for c in new_classes:
            members = hn[yn == c]
            if members.shape[0] == 0:
                raise ValueError(f"class {c} has no samples to seed from")
            for part in np.array_split(members, centroids_per_class):
                m = (part if part.shape[0] else members).mean(axis=0)
                if scale > 0:
                    m = m * (scale / max(float(np.linalg.norm(m)), 1e-8))
                rows.append(m)
                row_owners.append(c)

        fp_new = jnp.concatenate(
            [fp, jnp.asarray(np.stack(rows), jnp.float32)])
        owners_new = jnp.concatenate(
            [owners, jnp.asarray(row_owners, jnp.int32)])
        cfg = dataclasses.replace(
            self.am_cfg,
            columns=self.am_cfg.columns + len(rows),
            classes=old_k + len(new_classes))
        state = am_lib.make_am_state(fp_new, owners_new, cfg.threshold)
        return MemhdModel(self.enc_params, state, self.enc_cfg, cfg)

    # -- inference ---------------------------------------------------------------
    def predict(self, feats: Array) -> Array:
        return _predict_feats(self.enc_params, self.enc_cfg,
                              self.am_state["binary"],
                              self.am_state["centroid_class"], feats)

    def score(self, feats: Array, labels: Array, batch: int = 4096) -> float:
        return eval_lib.batched_accuracy(self.predict, feats, labels, batch)

    # -- deployment --------------------------------------------------------------
    def deploy(self, *, target: Optional[str] = None,
               packed: Optional[bool] = None, mode: Optional[str] = None,
               sim=None, **opts):
        """Freeze the trained model into its serving artifact.

        Canonical form: ``deploy(target=t, **backend_opts)`` with ``t``
        a registered deployment backend (``repro.deploy.registry``):

        * ``"packed"`` (default) — the (Dp, C) uint8 1-bit residence the
          paper's Table I counts, served by the fused XOR+popcount
          kernel (``mode="popcount" | "unpack"``).
        * ``"unpacked"`` — the ±1 float AM and the float ``am_search``
          kernel; the bit-exact parity baseline.
        * ``"imc"`` — a *simulated analog device* (``repro.imcsim``):
          the binary AM is burned in with the stuck-at faults /
          conductance variation of ``sim`` (an ``ImcSimConfig``; seeded,
          so the same config always yields the same device) and queries
          go through the tiled analog-partial-sum + ADC kernel. Ideal
          ``sim`` == bit-exact with the digital artifacts.

        Every artifact implements the same ``DeployedArtifact``
        protocol, so serving code is backend-agnostic; wrap any of them
        in ``repro.deploy.ShardedArtifact`` for multi-device serving.

        Legacy forms keep working: ``deploy(packed=False)`` and
        ``target="digital"`` map onto the registry targets.
        """
        from repro import deploy as deploy_lib
        if target in (None, "digital"):
            if sim is not None:
                raise ValueError(
                    "sim= is only meaningful with target='imc'")
            target = "unpacked" if packed is False else "packed"
        elif packed is not None:
            raise ValueError(
                "packed= is the legacy digital switch; use "
                "target='packed' / target='unpacked' instead")
        if mode is not None:
            opts["mode"] = mode
        if sim is not None:
            opts["sim"] = sim
        return deploy_lib.deploy(self, target, **opts)

    # -- deployment accounting -----------------------------------------------------
    @property
    def memory_bits(self) -> int:
        """EM + AM bits, per Table I (f*D + C*D binary)."""
        return self.enc_cfg.memory_bits + self.am_cfg.am_memory_bits

    @property
    def memory_kb(self) -> float:
        return self.memory_bits / 8 / 1024

    def imc_cost(self, arr: ImcArrayConfig | None = None):
        return _imc_cost(self.enc_cfg, self.am_cfg, arr)


# Re-export shim: the digital serving artifact moved to the unified
# deployment subsystem (repro.deploy.digital); existing imports of
# ``repro.core.memhd.DeployedMemhd`` / ``repro.core.DeployedMemhd``
# keep working.
from repro.deploy.digital import DeployedMemhd  # noqa: E402,F401
