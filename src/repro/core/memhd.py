"""MEMHD end-to-end model: encode -> cluster-init -> QAIL -> deploy.

This is the public, paper-faithful pipeline (Fig. 2):

    model  = MemhdModel.create(key, enc_cfg, am_cfg)
    model, hist = model.fit(feats, labels)           # (a)-(c) of Fig. 2
    acc    = model.score(test_feats, test_labels)    # (d) in-memory inference

``MemhdModel`` is an immutable pytree-of-arrays + static configs, so it
jits, shards, and checkpoints like any other model in the framework.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import am as am_lib
from repro.core import encoding, init as init_lib, qail
from repro.core.imc import ImcArrayConfig, memhd_pipeline
from repro.core.types import EncoderConfig, MemhdConfig

Array = jax.Array
log = logging.getLogger(__name__)


def _batched_accuracy(predict_fn, feats: Array, labels: Array,
                      batch: int) -> float:
    n = feats.shape[0]
    correct = 0
    for b in range(0, n, batch):
        pred = predict_fn(feats[b:b + batch])
        correct += int(jnp.sum(pred == labels[b:b + batch]))
    return correct / n


def _imc_cost(enc_cfg: EncoderConfig, am_cfg: MemhdConfig,
              arr: ImcArrayConfig | None):
    arr = arr or ImcArrayConfig()
    return memhd_pipeline(enc_cfg.features, am_cfg.dim, am_cfg.columns,
                          arr)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MemhdModel:
    """Immutable MEMHD model (encoder params + AM state + configs)."""

    enc_params: Dict[str, Array]
    am_state: Dict[str, Array]
    enc_cfg: EncoderConfig
    am_cfg: MemhdConfig

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        return (self.enc_params, self.am_state), (self.enc_cfg, self.am_cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        enc_params, am_state = children
        enc_cfg, am_cfg = aux
        return cls(enc_params, am_state, enc_cfg, am_cfg)

    # -- construction ----------------------------------------------------------
    @classmethod
    def create(cls, key: Array, enc_cfg: EncoderConfig, am_cfg: MemhdConfig,
               ) -> "MemhdModel":
        if enc_cfg.dim != am_cfg.dim:
            raise ValueError(
                f"encoder D={enc_cfg.dim} != AM D={am_cfg.dim}")
        enc_params = encoding.init_encoder(key, enc_cfg)
        # AM starts empty; fit() builds it via clustering init.
        zeros = jnp.zeros((am_cfg.columns, am_cfg.dim), jnp.float32)
        owners = jnp.zeros((am_cfg.columns,), jnp.int32)
        return cls(enc_params, am_lib.make_am_state(zeros, owners,
                                                    am_cfg.threshold),
                   enc_cfg, am_cfg)

    # -- pipeline stages -------------------------------------------------------
    def encode(self, feats: Array) -> Array:
        return encoding.encode(self.enc_params, self.enc_cfg, feats)

    def encode_query(self, feats: Array) -> Array:
        return encoding.encode_query(self.enc_params, self.enc_cfg, feats)

    def initialize_am(self, key: Array, feats: Array, labels: Array,
                      *, method: str = "clustering",
                      ) -> Tuple["MemhdModel", List[dict]]:
        """Clustering-based (or random-sampling baseline) AM init (§III-A)."""
        h = self.encode(feats)
        q = encoding.binarize_query(h)
        if method == "clustering":
            fp, owners, history = init_lib.clustering_init(
                key, self.am_cfg, h, labels, queries=q)
        elif method == "random":
            fp, owners = init_lib.random_sampling_init(
                key, self.am_cfg, h, labels)
            history = []
        else:
            raise ValueError(f"unknown init method {method!r}")
        state = am_lib.make_am_state(fp, owners, self.am_cfg.threshold)
        return dataclasses.replace(self, am_state=state), history

    def fit(self, key: Array, feats: Array, labels: Array,
            *, init_method: str = "clustering",
            epochs: Optional[int] = None,
            mode: str = "batched",
            eval_feats: Optional[Array] = None,
            eval_labels: Optional[Array] = None,
            ) -> Tuple["MemhdModel", Dict]:
        """Full training pipeline: init + QAIL epochs.

        Returns (model, history) where history holds per-epoch train miss
        rates and (optional) eval accuracies — consumed by the Fig.-5/6
        benchmarks.
        """
        epochs = self.am_cfg.epochs if epochs is None else epochs
        model, init_hist = self.initialize_am(
            key, feats, labels, method=init_method)

        h = model.encode(feats)
        q = encoding.binarize_query(h)
        eval_q = (model.encode_query(eval_feats)
                  if eval_feats is not None else None)

        curve: List[dict] = []
        state = model.am_state
        if eval_q is not None:
            acc0 = qail.evaluate(state, eval_q, eval_labels)
            curve.append({"epoch": 0, "eval_acc": acc0})
        for ep in range(1, epochs + 1):
            if mode == "sequential":
                state = qail.qail_epoch_sequential(
                    state, self.am_cfg, h, q, labels)
                miss = float("nan")
            else:
                state, miss = qail.qail_epoch_batched(
                    state, self.am_cfg, h, q, labels)
            rec = {"epoch": ep, "train_miss": miss}
            if eval_q is not None:
                rec["eval_acc"] = qail.evaluate(state, eval_q, eval_labels)
            curve.append(rec)
        model = dataclasses.replace(model, am_state=state)
        return model, {"init": init_hist, "curve": curve}

    # -- inference ---------------------------------------------------------------
    def predict(self, feats: Array) -> Array:
        q = self.encode_query(feats)
        return am_lib.predict(self.am_state["binary"],
                              self.am_state["centroid_class"], q)

    def score(self, feats: Array, labels: Array, batch: int = 4096) -> float:
        return _batched_accuracy(self.predict, feats, labels, batch)

    # -- deployment --------------------------------------------------------------
    def deploy(self, *, packed: bool = True, mode: str = "popcount",
               ) -> "DeployedMemhd":
        """Freeze the trained model into its serving artifact.

        ``packed=True`` packs the binary AM 8 cells/byte into the (Dp, C)
        uint8 residence that the paper's Table I counts (1 bit/cell) and
        routes ``score``/``predict`` through the fused XOR+popcount
        kernel; ``packed=False`` keeps the ±1 float AM and the float
        ``am_search`` kernel (the parity baseline). Predictions are
        bit-exact between the two.
        """
        binary = self.am_state["binary"]
        am_packed_t = am_lib.pack_am(binary) if packed else None
        return DeployedMemhd(
            enc_params=self.enc_params,
            am_binary=None if packed else binary,
            am_packed_t=am_packed_t,
            centroid_class=self.am_state["centroid_class"],
            enc_cfg=self.enc_cfg, am_cfg=self.am_cfg,
            packed=packed, mode=mode,
        )

    # -- deployment accounting -----------------------------------------------------
    @property
    def memory_bits(self) -> int:
        """EM + AM bits, per Table I (f*D + C*D binary)."""
        return self.enc_cfg.memory_bits + self.am_cfg.am_memory_bits

    @property
    def memory_kb(self) -> float:
        return self.memory_bits / 8 / 1024

    def imc_cost(self, arr: ImcArrayConfig | None = None):
        return _imc_cost(self.enc_cfg, self.am_cfg, arr)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeployedMemhd:
    """Frozen serving artifact of a trained MEMHD model.

    The deployment story of the paper (§III-D): the trained binary AM is
    *resident* in the array and queried one-shot. Here the residence is
    either the packed (Dp, C) uint8 matrix (``packed=True`` — 1 bit per
    cell, the Table-I accounting) searched by the XOR+popcount kernel, or
    the ±1 float32 (C, D) matrix searched by the float MXU kernel
    (``packed=False``). Both produce identical predictions; the packed
    artifact is ~8x smaller than even a 1-byte-per-cell unpacked AM (and
    32x smaller than the float32 training representation).

    Immutable pytree: jits, shards, and checkpoints like the trainer.
    """

    enc_params: Dict[str, Array]
    am_binary: Optional[Array]     # (C, D) float32, unpacked deployment
    am_packed_t: Optional[Array]   # (Dp, C) uint8, packed deployment
    centroid_class: Array          # (C,) int32
    enc_cfg: EncoderConfig
    am_cfg: MemhdConfig
    packed: bool = True
    mode: str = "popcount"         # packed kernel: "popcount" | "unpack"

    def tree_flatten(self):
        children = (self.enc_params, self.am_binary, self.am_packed_t,
                    self.centroid_class)
        aux = (self.enc_cfg, self.am_cfg, self.packed, self.mode)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        enc_params, am_binary, am_packed_t, centroid_class = children
        enc_cfg, am_cfg, packed, mode = aux
        return cls(enc_params, am_binary, am_packed_t, centroid_class,
                   enc_cfg, am_cfg, packed, mode)

    # -- inference -------------------------------------------------------------
    def predict_query(self, q: Array) -> Array:
        """(B, D) bipolar queries -> (B,) predicted class."""
        from repro.kernels import ops
        if self.packed:
            idx, _ = ops.am_search_packed(
                ops.pack_rows(q), self.am_packed_t,
                n_dims=self.am_cfg.dim, mode=self.mode)
        else:
            idx, _ = ops.am_search(q, self.am_binary)
        return self.centroid_class[idx]

    def predict(self, feats: Array) -> Array:
        q = encoding.encode_query(self.enc_params, self.enc_cfg, feats)
        return self.predict_query(q)

    def score(self, feats: Array, labels: Array, batch: int = 4096,
              ) -> float:
        return _batched_accuracy(self.predict, feats, labels, batch)

    # -- deployment accounting -------------------------------------------------
    @property
    def resident_am_bytes(self) -> int:
        """Bytes the resident AM actually occupies in HBM."""
        if self.packed:
            return int(self.am_packed_t.size)  # uint8
        return int(self.am_binary.size * self.am_binary.dtype.itemsize)

    @property
    def am_memory_ratio(self) -> float:
        """Byte-per-cell residence / this artifact's bytes.

        The smallest addressable unpacked cell is one byte (uint8 {0,1}),
        so a packed artifact reports ~8x; the float32 AM the unpacked
        kernel deploys is another 4x on top of that (32x total).
        """
        cell_bytes = self.am_cfg.columns * self.am_cfg.dim  # uint8 cells
        return cell_bytes / self.resident_am_bytes

    def imc_cost(self, arr: ImcArrayConfig | None = None):
        return _imc_cost(self.enc_cfg, self.am_cfg, arr)
