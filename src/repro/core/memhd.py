"""MEMHD end-to-end model: encode -> cluster-init -> QAIL -> deploy.

This is the public, paper-faithful pipeline (Fig. 2):

    model  = MemhdModel.create(key, enc_cfg, am_cfg)
    model, hist = model.fit(feats, labels)           # (a)-(c) of Fig. 2
    acc    = model.score(test_feats, test_labels)    # (d) in-memory inference

``MemhdModel`` is an immutable pytree-of-arrays + static configs, so it
jits, shards, and checkpoints like any other model in the framework.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import am as am_lib
from repro.core import encoding, init as init_lib, qail
from repro.core.imc import ImcArrayConfig, memhd_pipeline
from repro.core.types import EncoderConfig, MemhdConfig

Array = jax.Array
log = logging.getLogger(__name__)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MemhdModel:
    """Immutable MEMHD model (encoder params + AM state + configs)."""

    enc_params: Dict[str, Array]
    am_state: Dict[str, Array]
    enc_cfg: EncoderConfig
    am_cfg: MemhdConfig

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        return (self.enc_params, self.am_state), (self.enc_cfg, self.am_cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        enc_params, am_state = children
        enc_cfg, am_cfg = aux
        return cls(enc_params, am_state, enc_cfg, am_cfg)

    # -- construction ----------------------------------------------------------
    @classmethod
    def create(cls, key: Array, enc_cfg: EncoderConfig, am_cfg: MemhdConfig,
               ) -> "MemhdModel":
        if enc_cfg.dim != am_cfg.dim:
            raise ValueError(
                f"encoder D={enc_cfg.dim} != AM D={am_cfg.dim}")
        enc_params = encoding.init_encoder(key, enc_cfg)
        # AM starts empty; fit() builds it via clustering init.
        zeros = jnp.zeros((am_cfg.columns, am_cfg.dim), jnp.float32)
        owners = jnp.zeros((am_cfg.columns,), jnp.int32)
        return cls(enc_params, am_lib.make_am_state(zeros, owners,
                                                    am_cfg.threshold),
                   enc_cfg, am_cfg)

    # -- pipeline stages -------------------------------------------------------
    def encode(self, feats: Array) -> Array:
        return encoding.encode(self.enc_params, self.enc_cfg, feats)

    def encode_query(self, feats: Array) -> Array:
        return encoding.encode_query(self.enc_params, self.enc_cfg, feats)

    def initialize_am(self, key: Array, feats: Array, labels: Array,
                      *, method: str = "clustering",
                      ) -> Tuple["MemhdModel", List[dict]]:
        """Clustering-based (or random-sampling baseline) AM init (§III-A)."""
        h = self.encode(feats)
        q = encoding.binarize_query(h)
        if method == "clustering":
            fp, owners, history = init_lib.clustering_init(
                key, self.am_cfg, h, labels, queries=q)
        elif method == "random":
            fp, owners = init_lib.random_sampling_init(
                key, self.am_cfg, h, labels)
            history = []
        else:
            raise ValueError(f"unknown init method {method!r}")
        state = am_lib.make_am_state(fp, owners, self.am_cfg.threshold)
        return dataclasses.replace(self, am_state=state), history

    def fit(self, key: Array, feats: Array, labels: Array,
            *, init_method: str = "clustering",
            epochs: Optional[int] = None,
            mode: str = "batched",
            eval_feats: Optional[Array] = None,
            eval_labels: Optional[Array] = None,
            ) -> Tuple["MemhdModel", Dict]:
        """Full training pipeline: init + QAIL epochs.

        Returns (model, history) where history holds per-epoch train miss
        rates and (optional) eval accuracies — consumed by the Fig.-5/6
        benchmarks.
        """
        epochs = self.am_cfg.epochs if epochs is None else epochs
        model, init_hist = self.initialize_am(
            key, feats, labels, method=init_method)

        h = model.encode(feats)
        q = encoding.binarize_query(h)
        eval_q = (model.encode_query(eval_feats)
                  if eval_feats is not None else None)

        curve: List[dict] = []
        state = model.am_state
        if eval_q is not None:
            acc0 = qail.evaluate(state, eval_q, eval_labels)
            curve.append({"epoch": 0, "eval_acc": acc0})
        for ep in range(1, epochs + 1):
            if mode == "sequential":
                state = qail.qail_epoch_sequential(
                    state, self.am_cfg, h, q, labels)
                miss = float("nan")
            else:
                state, miss = qail.qail_epoch_batched(
                    state, self.am_cfg, h, q, labels)
            rec = {"epoch": ep, "train_miss": miss}
            if eval_q is not None:
                rec["eval_acc"] = qail.evaluate(state, eval_q, eval_labels)
            curve.append(rec)
        model = dataclasses.replace(model, am_state=state)
        return model, {"init": init_hist, "curve": curve}

    # -- inference ---------------------------------------------------------------
    def predict(self, feats: Array) -> Array:
        q = self.encode_query(feats)
        return am_lib.predict(self.am_state["binary"],
                              self.am_state["centroid_class"], q)

    def score(self, feats: Array, labels: Array, batch: int = 4096) -> float:
        n = feats.shape[0]
        correct = 0
        for b in range(0, n, batch):
            pred = self.predict(feats[b:b + batch])
            correct += int(jnp.sum(pred == labels[b:b + batch]))
        return correct / n

    # -- deployment accounting -----------------------------------------------------
    @property
    def memory_bits(self) -> int:
        """EM + AM bits, per Table I (f*D + C*D binary)."""
        return self.enc_cfg.memory_bits + self.am_cfg.am_memory_bits

    @property
    def memory_kb(self) -> float:
        return self.memory_bits / 8 / 1024

    def imc_cost(self, arr: ImcArrayConfig | None = None):
        arr = arr or ImcArrayConfig()
        return memhd_pipeline(self.enc_cfg.features, self.am_cfg.dim,
                              self.am_cfg.columns, arr)
