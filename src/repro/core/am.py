"""Multi-centroid associative memory (AM) — the paper's core data structure.

The AM is a (C, D) matrix of centroids plus a (C,) ownership vector mapping
each centroid (column of the IMC array) to its class. Two copies coexist
during training, exactly as in §III-B/C:

* ``fp``   — the float "shadow" AM that iterative learning updates, and
* ``binary`` — its 1-bit quantization (mean threshold), which is what the
  similarity evaluation (and the deployed IMC array / Pallas kernel) uses.

State is a plain dict pytree so it flows through jit/pjit and the
checkpointing substrate unchanged.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
AmState = Dict[str, Array]


# ---------------------------------------------------------------------------
# Quantization (§III-B)
# ---------------------------------------------------------------------------

def binarize_am(fp_am: Array, threshold: str = "mean") -> Array:
    """1-bit quantization of the float AM.

    The paper binarizes with the *mean* of the (near-Gaussian) value
    distribution as the threshold: values > mu -> 1, else 0. We store the
    result bipolar (+-1) because +-1 operands are MXU-native and dot-sim
    rankings over {0,1} vs {-1,+1} encodings are affinely related (see
    tests/test_properties.py::test_bipolar_rank_equivalence).

    Args:
      fp_am: (C, D) float AM.
      threshold: "mean" (global mean, the paper's choice) or
        "per_centroid" (row-wise mean).

    Returns:
      (C, D) bipolar binary AM, same dtype as input.
    """
    if threshold == "mean":
        mu = jnp.mean(fp_am)
    elif threshold == "per_centroid":
        mu = jnp.mean(fp_am, axis=-1, keepdims=True)
    else:
        raise ValueError(f"bad threshold: {threshold!r}")
    return jnp.where(fp_am > mu, 1.0, -1.0).astype(fp_am.dtype)


def to_unipolar(binary_am: Array) -> Array:
    """{-1,+1} -> {0,1}: the bit pattern actually written to IMC cells."""
    return (binary_am > 0).astype(jnp.uint8)


def from_unipolar(bits: Array, dtype=jnp.float32) -> Array:
    """{0,1} -> {-1,+1}."""
    return (bits.astype(dtype) * 2.0 - 1.0)


def quantize_am(fp_am: Array, cell_bits: int) -> Tuple[Array, Array]:
    """Symmetric per-tensor ``cell_bits``-bit quantization of the float AM.

    The multi-bit deployment stores the float shadow at reduced
    precision instead of binarizing it: Qmax = 2^(b-1) - 1 levels per
    sign, codes = clip(round(fp/scale), +-Qmax) — the MIMHD-style
    multi-level-cell representation. ``codes * scale`` dequantizes;
    similarity argmax is scale-invariant so kernels search directly in
    the integer code domain.

    The clip (scale * Qmax) is chosen by a small deterministic grid
    search minimizing quantization MSE, not max|fp|: the QAIL float
    shadow is heavy-tailed, and a max-anchored scale at 2-bit cells
    rounds ~90% of the AM to code 0 (chance accuracy). The grid is a
    fixed fraction ladder of max|fp|, so the search is jit-compatible —
    ``qail_epoch_scan`` re-quantizes inside the scan body.

    Returns:
      (codes, scale): (C, D) int32 codes in [-Qmax, +Qmax] and the ()
      float32 scale (guarded > 0 even for an all-zero AM).
    """
    if not 2 <= cell_bits <= 8:
        raise ValueError(f"cell_bits={cell_bits} outside [2, 8]")
    qmax = 2 ** (cell_bits - 1) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(fp_am)),
                       jnp.finfo(jnp.float32).tiny)
    fracs = jnp.asarray((1.0, 0.7, 0.5, 0.35, 0.25, 0.15, 0.1, 0.05),
                        jnp.float32)
    scales = fracs * amax / qmax                                # (K,)
    cand = jnp.clip(jnp.round(fp_am[None] / scales[:, None, None]),
                    -qmax, qmax)                                # (K, C, D)
    mse = jnp.mean((cand * scales[:, None, None] - fp_am[None]) ** 2,
                   axis=(1, 2))
    best = jnp.argmin(mse)
    scale = scales[best]
    codes = jnp.clip(jnp.round(fp_am / scale), -qmax, qmax)
    return codes.astype(jnp.int32), scale.astype(jnp.float32)


def dequantize_am(codes: Array, scale: Array) -> Array:
    """Inverse of ``quantize_am``: the fake-quantized float view."""
    return codes.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Associative search (§II-D, §III-D)
# ---------------------------------------------------------------------------

def similarities(binary_am: Array, queries: Array) -> Array:
    """Dot similarity of queries against every centroid.

    queries: (..., D), binary_am: (C, D)  ->  (..., C).
    This is the MVM the IMC array / the am_search Pallas kernel performs.
    """
    return jnp.einsum("...d,cd->...c", queries, binary_am)


def predict_from_sims(sims: Array, centroid_class: Array) -> Array:
    """pred = class owning the argmax-similarity centroid (Eq. after §III-D)."""
    best = jnp.argmax(sims, axis=-1)
    return centroid_class[best]


def predict(binary_am: Array, centroid_class: Array, queries: Array) -> Array:
    return predict_from_sims(similarities(binary_am, queries), centroid_class)


def class_max_sims(sims: Array, centroid_class: Array, n_classes: int,
                   ) -> Array:
    """Max similarity per class: (..., C) -> (..., k).

    Used by Eq. (5) (true-class target selection) and by evaluation.
    Implemented with a one-hot masked max so it stays jittable for any
    centroid->class ownership pattern.
    """
    neg = jnp.finfo(sims.dtype).min
    onehot = jax.nn.one_hot(centroid_class, n_classes).astype(bool)  # (C, k)
    masked = jnp.where(onehot, sims[..., :, None], neg)  # (..., C, k)
    return jnp.max(masked, axis=-2)


# ---------------------------------------------------------------------------
# Packed 1-bit residence (§ Table I made literal)
# ---------------------------------------------------------------------------

def pack_am(binary_am: Array) -> Array:
    """(C, D) bipolar AM -> (Dp, C) uint8 packed transposed residence.

    Dp = ceil(D/8); bits are LSB-first along D with tail bits 0, the
    layout of ``kernels.pack_bits`` / ``kernels.ref.pack_rows``. The
    transpose matches the IMC array's column-major centroid placement
    (and the (D, C) operand of the am_search kernels).
    """
    from repro.kernels import ref as kernel_ref
    return kernel_ref.pack_rows(binary_am).T


def packed_am_bytes(dim: int, columns: int) -> int:
    """Resident bytes of the packed (Dp, C) AM: ceil(D/8) * C."""
    return (-(-dim // 8)) * columns


def packed_predict(am_packed_t: Array, centroid_class: Array,
                   queries: Array, n_dims: int) -> Array:
    """Pure-jnp packed-domain prediction (oracle for the kernel path).

    queries: (..., D) bipolar — packed here; am_packed_t: (Dp, C) uint8.
    """
    from repro.kernels import ref as kernel_ref
    q2 = queries.reshape(-1, queries.shape[-1])
    best, _ = kernel_ref.am_search_packed(
        kernel_ref.pack_rows(q2), am_packed_t, n_dims)
    return centroid_class[best].reshape(queries.shape[:-1])


# ---------------------------------------------------------------------------
# Bit-sliced multi-bit residence (MIMHD-style multi-level cells)
# ---------------------------------------------------------------------------

def pack_am_planes(codes: Array, cell_bits: int) -> Array:
    """(C, D) quantized codes -> (cell_bits, Dp, C) uint8 bit planes.

    Codes from ``quantize_am`` are stored as offset codes
    ``u = code + Qmax`` in [0, 2^b - 2], one packed bit plane per bit of
    u, 8 cells/byte LSB-first along D, transposed to the kernels'
    column-major centroid placement (see ``kernels.ref.pack_planes``).
    """
    if not 2 <= cell_bits <= 8:
        raise ValueError(f"cell_bits={cell_bits} outside [2, 8]")
    from repro.kernels import ref as kernel_ref
    qmax = 2 ** (cell_bits - 1) - 1
    return kernel_ref.pack_planes(codes + qmax, cell_bits)


def multibit_am_bytes(dim: int, columns: int, cell_bits: int) -> int:
    """Resident bytes of the (cell_bits, Dp, C) plane-packed AM."""
    return cell_bits * (-(-dim // 8)) * columns


def multibit_predict(am_planes_t: Array, centroid_class: Array,
                     queries: Array, cell_bits: int) -> Array:
    """Pure-jnp multi-bit prediction (oracle for the kernel path)."""
    from repro.kernels import ref as kernel_ref
    q2 = queries.reshape(-1, queries.shape[-1])
    best, _ = kernel_ref.am_search_multibit(
        q2, am_planes_t, cell_bits=cell_bits)
    return centroid_class[best].reshape(queries.shape[:-1])


# ---------------------------------------------------------------------------
# AM state constructors
# ---------------------------------------------------------------------------

def make_am_state(fp_am: Array, centroid_class: Array,
                  threshold: str = "mean") -> AmState:
    fp_am = fp_am.astype(jnp.float32)
    return {
        "fp": fp_am,
        "binary": binarize_am(fp_am, threshold),
        "centroid_class": centroid_class.astype(jnp.int32),
    }


def refresh_binary(state: AmState, threshold: str = "mean") -> AmState:
    return dict(state, binary=binarize_am(state["fp"], threshold))
