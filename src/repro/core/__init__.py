"""MEMHD core: the paper's contribution as composable JAX modules."""
from repro.core.types import (  # noqa: F401
    BaselineConfig, DatasetSpec, EncoderConfig, ImcArrayConfig,
    ImcSimConfig, MemhdConfig, dataset_spec,
)
from repro.core.memhd import (  # noqa: F401
    DeployedMemhd, MemhdModel, MemhdTrainState,
)
from repro.core.baselines import BaselineModel, fit_baseline  # noqa: F401
from repro.core import (  # noqa: F401
    am, encoding, evaluate, imc, init, kmeans, qail,
)
