"""MEMHD core: the paper's contribution as composable JAX modules."""
from repro.core.types import (  # noqa: F401
    BaselineConfig, DatasetSpec, EncoderConfig, ImcArrayConfig, MemhdConfig,
    dataset_spec,
)
from repro.core.memhd import DeployedMemhd, MemhdModel  # noqa: F401
from repro.core.baselines import BaselineModel, fit_baseline  # noqa: F401
from repro.core import am, encoding, imc, init, kmeans, qail  # noqa: F401
