"""One evaluator for every accuracy loop in the repo.

Replaces the three copy-pasted host-side loops (``memhd._batched_accuracy``,
``qail.evaluate``, ``DeployedMemhd.score``). Two properties matter:

* **Padded final batch** — the ragged tail is padded up to the batch
  size (padded labels are -1, which no class id can match), so every
  jitted predict function underneath sees exactly ONE input shape and
  ragged tails stop triggering recompiles.
* **Device-side accumulation** — per-batch correct-counts stay on device
  and are summed there; the only host pull is the final ``int()``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import am as am_lib
from repro.deploy.padding import pad_rows, pad_vec

Array = jax.Array

_count_correct = jax.jit(
    lambda pred, labels: jnp.sum((pred == labels).astype(jnp.int32)))

# Shared jitted AM prediction (binary AM + ownership lookup); cached
# across callers so repeated evaluations at the same geometry reuse one
# executable.
_am_predict = jax.jit(am_lib.predict)


def batched_accuracy(predict_fn: Callable[[Array], Array],
                     inputs: Array, labels: Array,
                     batch: int = 4096) -> float:
    """Accuracy of ``predict_fn`` over (inputs, labels), batched + padded.

    ``predict_fn`` maps a (batch, ...) input block to (batch,) int class
    predictions. The final ragged block is padded by repeating its last
    row (padded labels are -1, so padded rows can never count as
    correct); correct-counts accumulate on device and are pulled once.
    """
    n = int(inputs.shape[0])
    if n == 0:
        return 0.0
    bs = min(batch, n)
    counts = []
    for b in range(0, n, bs):
        x = inputs[b:b + bs]
        y = labels[b:b + bs]
        k = int(x.shape[0])
        if k < bs:  # pad the ragged tail to the uniform batch shape
            x = pad_rows(x, bs, fill="edge")
            y = pad_vec(y, bs, value=-1)
        counts.append(_count_correct(predict_fn(x), y))
    total = counts[0]
    for c in counts[1:]:
        total = total + c
    return int(total) / n


def am_accuracy(state, queries: Array, labels: Array,
                batch: int = 4096) -> float:
    """Accuracy of an AM state dict on pre-encoded (queries, labels)."""
    binary, owners = state["binary"], state["centroid_class"]
    return batched_accuracy(lambda q: _am_predict(binary, owners, q),
                            queries, labels, batch=batch)
