"""K-means clustering with a dot-similarity assignment metric.

The paper (§III-A1) clusters each class's encoded sample hypervectors with
K-means whose distance metric is *dot similarity* — the same metric the
associative search uses — "so that the clustering process is optimized for
subsequent associative search operations".

Assignment: argmax_j  <h_i, c_j / ||c_j||>  (dot similarity against
            norm-equalized centroids — without the normalisation inside
            the assignment, dot-sim K-means degenerates: the largest-norm
            centroid absorbs everything).
Update:     c_j <- mean of assigned samples. The *returned* centroids are
            the raw cluster means: they live at sample-hypervector
            magnitude, which is what makes the paper's Eq.-(6) updates
            (lr * H with lr in [0.01, 0.1]) proportionate nudges.

Empty clusters are re-seeded with the sample that is least similar to its
current centroid (a k-means++-flavoured repair), keeping all K clusters
alive — important here because every AM column must hold a usable
centroid (full utilization).

Pure JAX, fixed iteration count, jittable (shapes static).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _l2_normalize(x: Array, axis: int = -1, eps: float = 1e-8) -> Array:
    return x / (jnp.linalg.norm(x, axis=axis, keepdims=True) + eps)


def assign_dot(h: Array, centroids: Array) -> Array:
    """argmax dot-similarity assignment. h: (n, D), centroids: (K, D)."""
    sims = h @ centroids.T  # (n, K)
    return jnp.argmax(sims, axis=-1)


@partial(jax.jit, static_argnames=("n_clusters", "n_iters"))
def kmeans_dot(key: Array, h: Array, n_clusters: int,
               n_iters: int = 25,
               sample_weight: Array | None = None,
               init: Array | None = None,
               ) -> Tuple[Array, Array]:
    """Run dot-similarity K-means.

    Args:
      key: PRNG key (initial centroid sampling).
      h: (n, D) sample hypervectors (float).
      n_clusters: K.
      n_iters: Lloyd iterations (fixed count — jit-friendly; the paper
        re-clusters repeatedly during allocation so exact convergence per
        call is unnecessary).
      sample_weight: optional (n,) non-negative weights (padding rows in
        callers use weight 0 so they never influence centroids).
      init: optional (K, D) initial centroids (normalized internally).
        Random-row init loses ~1/e of well-separated clusters to seed
        collisions and the one-reseed-per-iteration repair can't recover
        them all; callers who need every cluster found (hierarchical AM
        search) pass k-means++ seeds here.

    Returns:
      (centroids, assignment): ((K, D) float32, (n,) int32).
    """
    n, d = h.shape
    if sample_weight is None:
        sample_weight = jnp.ones((n,), jnp.float32)
    w = sample_weight.astype(jnp.float32)

    if init is not None:
        c0 = _l2_normalize(init.astype(jnp.float32))
    else:
        # Weighted random init: sample K distinct-ish rows.
        p = w / jnp.maximum(w.sum(), 1e-8)
        init_idx = jax.random.choice(key, n, (n_clusters,),
                                     replace=False, p=p)
        c0 = _l2_normalize(h[init_idx])

    def step(carry, _):
        c, _prev = carry
        # Assignment uses norm-equalized centroids (dot-sim K-means).
        sim = h @ _l2_normalize(c).T  # (n, K)
        # Weight-zero rows must not be counted: push their sim to -inf for
        # the *update* path by zeroing their weight contribution below.
        a = jnp.argmax(sim, axis=-1)  # (n,)
        one_hot = jax.nn.one_hot(a, n_clusters, dtype=jnp.float32) * w[:, None]
        counts = one_hot.sum(axis=0)  # (K,)
        sums = one_hot.T @ h  # (K, D)
        new_c = sums / jnp.maximum(counts, 1e-8)[:, None]
        # Empty-cluster repair: re-seed with the sample least similar to
        # its own centroid (most "orphaned" point), weight-masked.
        own_sim = jnp.take_along_axis(sim, a[:, None], axis=1)[:, 0]
        own_sim = jnp.where(w > 0, own_sim, jnp.inf)
        worst = jnp.argmin(own_sim)
        empty = counts < 0.5
        new_c = jnp.where(empty[:, None], h[worst][None, :], new_c)
        return (new_c, a), None

    (c, a), _ = jax.lax.scan(step, (c0, jnp.zeros((n,), jnp.int32)),
                             None, length=n_iters)
    # Final assignment against the final (norm-equalized) centroids.
    a = assign_dot(h, _l2_normalize(c))
    return c, a.astype(jnp.int32)


def classwise_kmeans(key: Array, h: Array, labels: Array, n_classes: int,
                     clusters_per_class: list[int], n_iters: int = 25,
                     ) -> Tuple[Array, Array]:
    """Per-class K-means (§III-A1 "Classwise Clustering").

    Splits samples by class and clusters each class independently with its
    own cluster budget. Classes are padded to a common max sample count so
    each per-class call is a fixed-shape jitted kernel (weight-0 padding).

    Args:
      key: PRNG key.
      h: (n, D) encoded sample hypervectors.
      labels: (n,) int labels in [0, n_classes).
      n_classes: k.
      clusters_per_class: python list, len k — centroid budget per class.
      n_iters: Lloyd iterations.

    Returns:
      (centroids, centroid_class):
        centroids: (C_total, D) float32, where C_total = sum(budgets);
        centroid_class: (C_total,) int32 owner class of each centroid.
    """
    import numpy as np  # host-side orchestration only

    h_np = np.asarray(h)
    y_np = np.asarray(labels)
    cents, owners = [], []
    keys = jax.random.split(key, n_classes)
    for c in range(n_classes):
        kc = int(clusters_per_class[c])
        if kc <= 0:
            continue
        hc = h_np[y_np == c]
        if hc.shape[0] == 0:
            raise ValueError(f"class {c} has no samples to cluster")
        if hc.shape[0] < kc:
            # Fewer samples than requested clusters: tile samples.
            reps = -(-kc // hc.shape[0])
            hc = np.tile(hc, (reps, 1))
        cc, _ = kmeans_dot(keys[c], jnp.asarray(hc), kc, n_iters)
        cents.append(np.asarray(cc))
        owners.append(np.full((kc,), c, np.int32))
    centroids = jnp.asarray(np.concatenate(cents, axis=0))
    centroid_class = jnp.asarray(np.concatenate(owners, axis=0))
    return centroids, centroid_class
