"""``OnlineEngine``: the long-running MEMHD serving loop.

Where ``launch/serve_memhd.py`` is a closed-loop benchmark driver (all
requests exist up front, ``make_batches`` greedily packs them once),
this engine serves an *open-loop timed stream*: requests arrive on a
clock, wait in an admission queue, and are closed into batches by a
**deadline-aware policy** (``plan_batch``) instead of a one-shot greedy
pass:

* requests are admitted head-first (FIFO, never split) up to
  ``max_batch`` rows;
* a batch closes immediately when full, when the tightest admitted
  deadline's slack — against an EWMA service-time model per padded
  batch bucket plus the in-flight pipeline's drain estimate — has
  shrunk to the safety margin, or when the head request has waited
  ``max_wait_ms`` (bounded staleness for best-effort traffic);
* otherwise the engine *waits for more arrivals*, trading a little
  latency headroom for larger (cheaper per row) batches.

Batches pad to a **geometric bucket grid** (tile, 2·tile, 4·tile, …,
max_batch) so the warmup can saturate every jit signature the stream
will ever hit — the zero-steady-state-recompile contract of the
closed-loop driver, carried over. The ``depth``-deep double-buffered
pipeline is kept: up to ``depth`` batches stay in flight while the host
plans the next one.

Live updates ride a ``StreamingUpdater``: labeled ``Feedback`` events
buffer into it, folds produce a new immutable artifact generation, and
the engine swaps it in as an atomic reference replacement. Queries
already dispatched keep their old-generation operand (bit-exact — the
artifact rides *inside* the jit call, not captured by it). Same-shape
swaps hit the warmed executables (zero recompiles, proven in the
report); a class-growth swap re-warms the bucket grid once, inside an
excluded compile window.

Compile accounting is per-phase: ``warmup`` / ``fold`` / ``rewarm``
windows are excluded, and everything else observed between ``serve()``
entry and exit is reported as ``recompiles_steady_state`` — the number
that must stay 0.
"""
from __future__ import annotations

import dataclasses
import logging
import math
import time
from collections import deque
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro import obs
from repro.deploy.padding import round_up
from repro.obs import span
from repro.serve.stream import Arrival, Feedback, OnlineRequest

log = logging.getLogger("serve.engine")

TILE_B = 8  # batch padding granularity (float32 sublane tile)


def batch_buckets(tile: int, max_batch: int) -> List[int]:
    """The geometric padded-rows grid: tile, 2·tile, …, >= max_batch.

    Geometric (not linear) so the warmup set stays logarithmic in
    ``max_batch`` while the worst-case pad overhead is bounded at 2x —
    the standard bucketed-serving trade.
    """
    if tile < 1 or max_batch < 1:
        raise ValueError("tile and max_batch must be >= 1")
    top = round_up(max_batch, tile)
    out = []
    b = tile
    while b < top:
        out.append(b)
        b *= 2
    out.append(top)
    return out


class ServiceModel:
    """EWMA service-time estimate per padded-rows bucket.

    Seeded by the warmup's timed post-compile calls; every drained
    batch refines it. The estimate feeds ``plan_batch``'s slack
    computation — it need only be the right order of magnitude for the
    policy to close batches before deadlines burn.
    """

    def __init__(self, default_s: float = 0.005, alpha: float = 0.25):
        self.default_s = default_s
        self.alpha = alpha
        self._est: Dict[int, float] = {}

    def observe(self, bucket: int, seconds: float) -> None:
        prev = self._est.get(bucket)
        self._est[bucket] = (seconds if prev is None else
                             (1 - self.alpha) * prev + self.alpha * seconds)

    def estimate(self, bucket: int) -> float:
        est = self._est.get(bucket)
        if est is not None:
            return est
        known = sorted(self._est)
        if known:  # nearest known bucket beats the blind default
            near = min(known, key=lambda b: abs(b - bucket))
            return self._est[near] * max(1.0, bucket / near)
        return self.default_s


def plan_batch(queue: Sequence[OnlineRequest], now: float, *,
               max_batch: int, estimate_rows_s: Callable[[int], float],
               inflight_eta_s: float = 0.0, margin_s: float = 0.002,
               max_wait_s: float = 0.05, flush: bool = False) -> int:
    """Deadline-aware admission: close a batch now, or keep waiting?

    Returns how many head-of-queue requests to close into a batch at
    ``now`` (0 = wait for more arrivals). Requests admit FIFO and never
    split; a batch closes when it is full, when the tightest admitted
    deadline could no longer absorb further waiting (its slack against
    estimated completion — in-flight drain + this batch's service —
    has shrunk to ``margin_s``), or when the head request's wait hits
    ``max_wait_s``. ``flush=True`` (no more arrivals can come) closes
    any non-empty batch immediately — waiting buys nothing.
    """
    admit = 0
    rows = 0
    for r in queue:
        if admit and rows + r.size > max_batch:
            break
        admit += 1
        rows += r.size
    if admit == 0:
        return 0
    if rows >= max_batch or flush:
        return admit
    deadlines = [r.t_deadline for r in list(queue)[:admit]
                 if r.t_deadline is not None]
    if deadlines:
        eta = now + inflight_eta_s + estimate_rows_s(rows)
        if min(deadlines) - eta <= margin_s:
            return admit
    if now - queue[0].t_arrival >= max_wait_s:
        return admit
    return 0


@dataclasses.dataclass
class _Inflight:
    requests: List[OnlineRequest]
    n_valid: int
    future: object
    t_dispatch: float
    generation: int
    bucket: int


class OnlineEngine:
    """Async request-queue serving engine with live model updates.

    Args:
      updater: the ``StreamingUpdater`` owning the live model and the
        served artifact (the engine always serves ``updater.artifact``
        — folding swaps generations under the engine atomically).
      max_batch: batch budget in rows; requests larger than this are
        rejected at ingest (requests never split).
      tile: padding granularity; lifted to the artifact's
        ``row_multiple`` (sharded serving needs device-divisible rows).
      depth: double-buffer depth — batches in flight while the host
        plans the next one.
      fused: serve through ``predict_features`` (fused pipeline).
      margin_ms / max_wait_ms: the batching policy's safety margin and
        best-effort staleness bound.
      warmup: pre-compile (and re-warm after class growth) every bucket
        shape — the zero-steady-state-recompile contract.
      events: optional ``obs.EventLog`` shared with the updater.
    """

    def __init__(self, updater, *, max_batch: int = 256,
                 tile: int = TILE_B, depth: int = 2, fused: bool = False,
                 margin_ms: float = 2.0, max_wait_ms: float = 50.0,
                 warmup: bool = True,
                 events: Optional[obs.EventLog] = None):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        obs.install()  # compile accounting needs the jaxmon listener
        self.updater = updater
        self.tile = math.lcm(tile, getattr(updater.artifact,
                                           "row_multiple", 1))
        self.max_batch = max(round_up(max_batch, self.tile), self.tile)
        self.buckets = batch_buckets(self.tile, self.max_batch)
        self.depth = depth
        self.fused = fused
        self.margin_s = margin_ms / 1e3
        self.max_wait_s = max_wait_ms / 1e3
        self.warmup_enabled = warmup
        self.events = events or obs.EventLog(None)
        self.service_model = ServiceModel()
        self.queue: deque = deque()
        self.responses: Dict[int, np.ndarray] = {}
        self.request_lat_ms: Dict[int, float] = {}
        self._inflight: deque = deque()
        self._feature_spec = None  # (n_features, dtype) after first batch
        self._t0 = None
        self._last_ready = 0.0
        self._lat_ms: List[float] = []
        self._service_ms: List[float] = []
        self._batch_rows: List[int] = []
        self._rows_padded = 0
        self._served = 0
        self._deadline_total = 0
        self._deadline_missed = 0
        self._generations: List[Dict] = []
        self._excluded = {"warmup": 0, "fold": 0, "rewarm": 0}
        self._compiles_at_start = None
        self._hist = obs.histogram(
            "online_batch_ms", "online engine per-batch latency by stage")
        self._gauge_q = obs.gauge("online_queue_depth",
                                  "admission-queue length at dispatch")

    # -- plumbing --------------------------------------------------------------
    @property
    def artifact(self):
        """The currently-served artifact (the updater's latest swap)."""
        return self.updater.artifact

    def _predict(self, x):
        a = self.artifact
        return (a.predict_features if self.fused else a.predict)(x)

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    def _bucket_for(self, rows: int) -> int:
        for b in self.buckets:
            if rows <= b:
                return b
        raise ValueError(f"{rows} rows exceed max_batch={self.max_batch}")

    def _estimate_rows_s(self, rows: int) -> float:
        return self.service_model.estimate(self._bucket_for(rows))

    def _inflight_eta_s(self) -> float:
        return sum(self.service_model.estimate(f.bucket)
                   for f in self._inflight)

    @contextmanager
    def _excluded_window(self, kind: str):
        """Compiles observed inside don't count as steady-state."""
        c0 = obs.jaxmon.compiles()
        try:
            yield
        finally:
            self._excluded[kind] += obs.jaxmon.compiles() - c0

    def steady_state_recompiles(self) -> int:
        """XLA compiles since ``serve()`` entry outside the excluded
        warmup / fold / rewarm windows — the number that must stay 0."""
        if self._compiles_at_start is None:
            return 0
        return (obs.jaxmon.compiles() - self._compiles_at_start
                - sum(self._excluded.values()))

    # -- warmup ----------------------------------------------------------------
    def _warm_buckets(self, window: str) -> None:
        n_feats, dtype = self._feature_spec
        with self._excluded_window(window):
            for b in self.buckets:
                x = np.zeros((b, n_feats), dtype)
                jax.block_until_ready(self._predict(x))
                t0 = time.perf_counter()
                jax.block_until_ready(self._predict(x))
                self.service_model.observe(b, time.perf_counter() - t0)

    # -- dispatch / drain ------------------------------------------------------
    def _dispatch(self, requests: List[OnlineRequest]) -> None:
        with span("host_prep", requests=len(requests)):
            feats = (requests[0].feats if len(requests) == 1 else
                     np.concatenate([r.feats for r in requests]))
            rows = feats.shape[0]
            bucket = self._bucket_for(rows)
            with span("pad", rows=rows, bucket=bucket):
                padded = np.zeros((bucket,) + feats.shape[1:],
                                  feats.dtype)
                padded[:rows] = feats
        self._rows_padded += bucket
        self._batch_rows.append(rows)
        self._gauge_q.set(len(self.queue))
        t_disp = self._clock()
        with span("dispatch", rows=bucket):
            fut = self._predict(padded)
        self._inflight.append(_Inflight(
            requests=requests, n_valid=rows, future=fut,
            t_dispatch=t_disp, generation=self.updater.generation,
            bucket=bucket))

    def _drain_one(self) -> None:
        f: _Inflight = self._inflight.popleft()
        with span("device_wait", rows=f.bucket):
            jax.block_until_ready(f.future)
        t_ready = self._clock()
        service = t_ready - max(f.t_dispatch, self._last_ready)
        self._last_ready = t_ready
        self.service_model.observe(f.bucket, service)
        self._service_ms.append(service * 1e3)
        self._hist.observe((t_ready - f.t_dispatch) * 1e3, stage="batch")
        self._hist.observe(service * 1e3, stage="service")
        pred = np.asarray(f.future)[:f.n_valid]
        ofs = 0
        for r in f.requests:
            self.responses[r.rid] = pred[ofs:ofs + r.size]
            ofs += r.size
            self._served += 1
            lat_ms = (t_ready - r.t_arrival) * 1e3
            self._lat_ms.append(lat_ms)
            self.request_lat_ms[r.rid] = lat_ms
            self._hist.observe(lat_ms, stage="request")
            if r.deadline_ms is not None:
                self._deadline_total += 1
                if lat_ms > r.deadline_ms:
                    self._deadline_missed += 1

    # -- live updates ----------------------------------------------------------
    def _quiesce(self) -> None:
        """Dispatch and drain everything already admitted.

        Runs right before a fold: queries that entered the queue before
        the feedback complete on the generation they were admitted
        under, and the (possibly multi-second, compile-bearing) fold
        never holds a half-built batch hostage.
        """
        now = self._clock() if self._t0 is not None else 0.0
        while self.queue:
            if len(self._inflight) >= self.depth:
                self._drain_one()
                continue
            n = plan_batch(self.queue, now, max_batch=self.max_batch,
                           estimate_rows_s=self._estimate_rows_s,
                           flush=True)
            self._dispatch([self.queue.popleft() for _ in range(n)])
        while self._inflight:
            self._drain_one()

    def _fold_and_swap(self) -> None:
        self._quiesce()
        steady_before = self.steady_state_recompiles()
        with span("fold", generation=self.updater.generation + 1):
            with self._excluded_window("fold"):
                result = self.updater.fold()
        if result is None:
            return
        if (not result.shape_stable and self.warmup_enabled
                and self._feature_spec is not None):
            with span("rewarm", generation=result.generation):
                self._warm_buckets("rewarm")
        cfg = self.updater.model.am_cfg
        rec = {
            "generation": result.generation,
            "t": round(self._clock(), 3) if self._t0 is not None else 0.0,
            "shape_stable": result.shape_stable,
            "fold_ms": round(result.fold_ms, 3),
            "n_samples": result.n_samples,
            "n_new_classes": result.n_new_classes,
            "classes": cfg.classes,
            "columns": cfg.columns,
            "steady_recompiles_before_swap": steady_before,
        }
        self._generations.append(rec)
        self.events.emit("generation_swap", **rec)

    # -- the loop --------------------------------------------------------------
    def serve(self, events: Sequence) -> Dict:
        """Replay a timed event stream to completion; returns the report.

        ``events`` is any mix of ``Arrival`` / ``Feedback`` (sorted here
        by ``stream.merge_events`` ordering). The engine runs on a real
        clock starting at the first event's ingestion: it sleeps through
        idle gaps, so a 200-request stream at 50 QPS genuinely takes
        ~4 s of wall time — latency percentiles and deadline misses are
        measured, not simulated.
        """
        from repro.serve.stream import merge_events
        # One serve() = one report: measurement accumulators reset here
        # (``responses`` / ``request_lat_ms`` keep accumulating so
        # callers can run phased scenarios as separate serves and still
        # score every rid afterwards).
        self._lat_ms, self._service_ms, self._batch_rows = [], [], []
        self._rows_padded = 0
        self._served = 0
        self._deadline_total = self._deadline_missed = 0
        self._generations = []
        self._excluded = {"warmup": 0, "fold": 0, "rewarm": 0}
        events = merge_events(list(events))
        first = next((e for e in events if isinstance(e, Arrival)), None)
        if first is not None:
            big = max(e.request.size for e in events
                      if isinstance(e, Arrival))
            if big > self.max_batch:
                raise ValueError(
                    f"request of {big} rows exceeds max_batch="
                    f"{self.max_batch} (requests never split)")
            self._feature_spec = (first.request.feats.shape[1],
                                  first.request.feats.dtype)
        self._compiles_at_start = obs.jaxmon.compiles()
        if self.warmup_enabled and self._feature_spec is not None:
            self._warm_buckets("warmup")
        self._t0 = time.perf_counter()
        self._last_ready = 0.0
        self.events.emit("serve_start", events=len(events),
                         buckets=self.buckets, depth=self.depth)
        i = 0
        while i < len(events) or self.queue or self._inflight:
            now = self._clock()
            while i < len(events) and events[i].t <= now:
                ev = events[i]
                i += 1
                if isinstance(ev, Arrival):
                    self.queue.append(ev.request)
                else:
                    self.updater.ingest(ev.feats, ev.labels)
                    if ev.fold or self.updater.should_fold:
                        self._fold_and_swap()
            flush = i >= len(events)
            n = plan_batch(
                self.queue, now, max_batch=self.max_batch,
                estimate_rows_s=self._estimate_rows_s,
                inflight_eta_s=self._inflight_eta_s(),
                margin_s=self.margin_s, max_wait_s=self.max_wait_s,
                flush=flush)
            if n:
                if len(self._inflight) >= self.depth:
                    self._drain_one()  # pipeline full: free a slot
                    continue
                self._dispatch([self.queue.popleft() for _ in range(n)])
                continue
            # Idle: nothing to close yet. Drain in-flight work if any
            # (blocking on the device doubles as the sleep), else sleep
            # until the next arrival or the forced-dispatch instant.
            if self._inflight:
                self._drain_one()
                continue
            wake = events[i].t if i < len(events) else None
            if self.queue:
                head = self.queue[0]
                t_force = head.t_arrival + self.max_wait_s
                deadlines = [r.t_deadline for r in self.queue
                             if r.t_deadline is not None]
                if deadlines:
                    rows = sum(r.size for r in self.queue)
                    rows = min(rows, self.max_batch)
                    t_force = min(t_force,
                                  min(deadlines) - self._estimate_rows_s(rows)
                                  - self.margin_s)
                wake = t_force if wake is None else min(wake, t_force)
            if wake is None:
                break
            dt = wake - self._clock()
            if dt > 0:
                time.sleep(min(dt, 0.05))
        while self._inflight:
            self._drain_one()
        wall = self._clock()
        obs.counter("serve_rows_total",
                    "feature rows served (pre-padding)"
                    ).inc(sum(self._batch_rows))
        obs.counter("serve_requests_total",
                    "classification requests served").inc(self._served)
        self.events.emit("serve_end", wall_s=round(wall, 3),
                         requests=self._served)
        return self.report(wall)

    # -- reporting -------------------------------------------------------------
    def report(self, wall_s: float) -> Dict:
        """The engine's JSON report (the online analogue of
        ``serve_memhd.build_report``'s stats section)."""
        rows_real = sum(self._batch_rows)
        lat = np.asarray(self._lat_ms) if self._lat_ms else None

        def pct(p):
            return (round(float(np.percentile(lat, p)), 3)
                    if lat is not None else None)

        return {
            "requests": self._served,
            "rows": rows_real,
            "batches": len(self._batch_rows),
            "avg_batch_rows": (round(rows_real / len(self._batch_rows), 2)
                               if self._batch_rows else None),
            "rows_padded": self._rows_padded,
            "pad_overhead": (round(self._rows_padded / rows_real - 1, 3)
                             if rows_real else None),
            "buckets": self.buckets,
            "depth": self.depth,
            "wall_s": round(wall_s, 3),
            "qps": (round(self._served / wall_s, 1)
                    if wall_s else 0.0),
            "rows_per_s": (round(rows_real / wall_s, 1) if wall_s
                           else 0.0),
            "lat_ms_min": (round(float(lat.min()), 3)
                           if lat is not None else None),
            "lat_ms_p50": pct(50),
            "lat_ms_p95": pct(95),
            "lat_ms_p99": pct(99),
            "service_ms_p50": (round(float(np.percentile(
                self._service_ms, 50)), 3) if self._service_ms else None),
            "deadline_total": self._deadline_total,
            "deadline_miss_rate": (
                round(self._deadline_missed / self._deadline_total, 4)
                if self._deadline_total else None),
            "model_generation": self.updater.generation,
            "generations": list(self._generations),
            "recompiles_steady_state": self.steady_state_recompiles(),
            "recompiles_excluded": dict(self._excluded),
        }
