"""Timed event streams for the online serving engine.

The engine (``repro.serve.engine``) consumes a time-ordered list of
events — query ``Arrival``s and labeled ``Feedback`` — and replays them
against a wall clock. This module holds the event types plus the
synthetic generators the driver, the tests, and
``benchmarks/online_serving.py`` build scenarios from:

* ``poisson_arrivals`` — an open-loop Poisson request process over a
  feature pool (the classic serving-benchmark arrival model; the
  closed-loop ``serve_memhd`` driver has no arrival process at all).
* ``feedback_burst`` — a labeled feedback batch at a point in stream
  time, optionally forcing an immediate fold.
* ``apply_drift`` — a deterministic covariate shift of a feature pool
  (convex mix with a feature rotation), used to stage the
  fold-recovers-accuracy scenarios.

Events are plain frozen dataclasses sorted by ``t`` (seconds from
stream start); ``merge_events`` interleaves independently generated
sub-streams.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class OnlineRequest:
    """One classification request with an arrival time and a deadline.

    ``t_arrival`` is seconds from stream start (the engine's clock
    zero); ``deadline_ms`` is the per-request latency budget the
    deadline-aware batcher plans against (None = best-effort).
    """

    rid: int
    feats: np.ndarray  # (n, f)
    t_arrival: float = 0.0
    deadline_ms: Optional[float] = None
    labels: Optional[np.ndarray] = None  # ground truth, scoring only —
    # the engine never reads it (serving is label-blind); the driver and
    # benchmarks use it to report per-phase accuracy.

    @property
    def size(self) -> int:
        return self.feats.shape[0]

    @property
    def t_deadline(self) -> Optional[float]:
        """Absolute deadline in stream seconds, or None."""
        if self.deadline_ms is None:
            return None
        return self.t_arrival + self.deadline_ms / 1e3


@dataclasses.dataclass(frozen=True)
class Arrival:
    """A query request entering the engine's admission queue at ``t``."""

    t: float
    request: OnlineRequest


@dataclasses.dataclass(frozen=True)
class Feedback:
    """Labeled ground truth arriving mid-stream at ``t``.

    The engine hands (feats, labels) to its ``StreamingUpdater``;
    ``fold=True`` forces an immediate fold + artifact swap instead of
    waiting for the updater's buffer policy.
    """

    t: float
    feats: np.ndarray   # (n, f)
    labels: np.ndarray  # (n,)
    fold: bool = False


def merge_events(*streams: Sequence) -> List:
    """Interleave event sub-streams into one time-ordered list.

    Ties break by kind — feedback before arrivals at the same instant,
    so a fold scheduled "at t" applies to queries arriving "at t" —
    then by original order (stable).
    """
    def key(ev):
        return (ev.t, 0 if isinstance(ev, Feedback) else 1)
    out: List = []
    for s in streams:
        out.extend(s)
    out.sort(key=key)
    return out


def poisson_arrivals(feats_pool: np.ndarray, *, n_requests: int,
                     rate_qps: float, max_size: int = 8,
                     deadline_ms: Optional[float] = None,
                     labels_pool: Optional[np.ndarray] = None,
                     classes: Optional[Sequence[int]] = None,
                     start: float = 0.0, rid_base: int = 0,
                     seed: int = 0) -> List[Arrival]:
    """Open-loop Poisson request stream sampled from a feature pool.

    Inter-arrival gaps are exponential with mean ``1/rate_qps``; each
    request draws 1..``max_size`` rows from ``feats_pool`` (restricted
    to rows whose ``labels_pool`` entry is in ``classes``, when given —
    how scenarios serve only currently-known classes before an append).
    """
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
    rng = np.random.default_rng(seed)
    pool = np.arange(feats_pool.shape[0])
    if classes is not None:
        if labels_pool is None:
            raise ValueError("classes filter needs labels_pool")
        pool = pool[np.isin(np.asarray(labels_pool), list(classes))]
    if pool.size == 0:
        raise ValueError("empty feature pool after class filter")
    out: List[Arrival] = []
    t = start
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_qps))
        rows = rng.choice(pool, size=int(rng.integers(1, max_size + 1)))
        req = OnlineRequest(
            rid=rid_base + i, feats=feats_pool[rows], t_arrival=t,
            deadline_ms=deadline_ms,
            labels=(None if labels_pool is None
                    else np.asarray(labels_pool)[rows]))
        out.append(Arrival(t=t, request=req))
    return out


def feedback_burst(feats: np.ndarray, labels: np.ndarray, *, t: float,
                   chunk: Optional[int] = None, fold: bool = False,
                   ) -> List[Feedback]:
    """Labeled feedback at stream time ``t``, optionally chunked.

    With ``chunk`` the burst splits into several ``Feedback`` events at
    the same instant (exercises the updater's buffering); only the last
    carries the ``fold`` flag.
    """
    n = feats.shape[0]
    if n != np.asarray(labels).shape[0]:
        raise ValueError("feats/labels length mismatch")
    step = n if chunk is None else max(int(chunk), 1)
    out: List[Feedback] = []
    for i in range(0, n, step):
        out.append(Feedback(t=t, feats=feats[i:i + step],
                            labels=np.asarray(labels[i:i + step]),
                            fold=False))
    if out and fold:
        out[-1] = dataclasses.replace(out[-1], fold=True)
    return out


def apply_drift(feats: np.ndarray, strength: float,
                shift: int = 7) -> np.ndarray:
    """Deterministic covariate drift: mix each row with a feature roll.

    ``x' = (1 - s)·x + s·roll(x, shift)`` — at s=0 the identity, at
    s=1 a pure feature permutation. A projection encoder sees this as a
    systematic query rotation, so accuracy degrades smoothly with
    ``strength`` and labeled drifted feedback recovers it — the
    fold-on-feedback scenario of tests and the quickstart.
    """
    if not 0.0 <= strength <= 1.0:
        raise ValueError(f"strength must be in [0, 1], got {strength}")
    x = np.asarray(feats)
    return ((1.0 - strength) * x
            + strength * np.roll(x, shift, axis=-1)).astype(x.dtype)
