"""``StreamingUpdater``: live class-incremental AM updates mid-serving.

The updater owns the *trainable* side of an online deployment: the live
``MemhdModel`` (with its float shadow AM — the deployed artifact alone
cannot learn) plus a bounded buffer of labeled feedback. ``fold()``
turns the buffer into a new model generation and a new serving
artifact:

1. **grow** — feedback labeled with never-seen classes first grows the
   AM ``(C, D) -> (C + k, D)`` via ``MemhdModel.grow_classes`` (growth
   MUST precede the fold: QAIL's ownership-masked Eq.-(5) silently
   corrupts updates for labels owning no centroid);
2. **fold** — the whole buffer runs through the device-resident QAIL
   scan (``qail.fold_feedback`` — ``refresh_am`` semantics, float
   shadow updated, binary AM re-binarized);
3. **re-freeze** — the served artifact is rebuilt from the new model
   through ``DeployedArtifact.refresh``: same-C folds take each
   backend's cheap layout-preserving path (identical leaf shapes and
   statics — a swap costs zero recompiles), class growth re-packs
   through the deploy registry (one bounded recompile set at the new
   geometry). ``ShardedArtifact`` wrappers refresh through
   ``with_artifact``, keeping their compiled shard_map cache.

The new artifact is returned to the engine, which swaps it in as an
atomic reference replacement — artifacts are immutable pytrees and the
old generation stays intact for queries already dispatched against it
(the artifact is a jit *operand*, so in-flight work is race-free by
construction).

Observability: ``model_generation`` gauge, ``update_fold_ms``
histogram, and one structured event per generation through an optional
``obs.EventLog``.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro import obs

log = logging.getLogger("serve.updater")


@dataclasses.dataclass(frozen=True)
class UpdateResult:
    """What one ``fold()`` produced."""

    generation: int        # the new model generation (starts at 1)
    artifact: Any          # the re-frozen serving artifact
    shape_stable: bool     # True -> swapping it in recompiles nothing
    fold_ms: float         # wall time of grow + fold + re-freeze
    n_samples: int         # feedback rows folded
    n_new_classes: int     # classes appended by this fold
    miss_rate: float       # QAIL miss rate over the buffer (last epoch)


class StreamingUpdater:
    """Accepts labeled feedback mid-serving and folds it into the AM.

    Args:
      model: the live ``MemhdModel`` (must carry the float shadow AM the
        deployment was frozen from — QAIL updates land on it).
      artifact: the currently-served artifact built from ``model``
        (any registry backend, optionally ``ShardedArtifact``-wrapped).
      fold_epochs: QAIL scan epochs per fold (1 is the streaming
        default; the buffer is small, more epochs overfit it).
      fold_every: auto-fold once the buffer holds this many samples
        (None = only explicit ``fold()`` calls / forced feedback).
      buffer_cap: drop-oldest bound on buffered feedback rows.
      events: optional ``obs.EventLog`` for per-generation records.
    """

    def __init__(self, model, artifact, *, fold_epochs: int = 1,
                 fold_every: Optional[int] = None,
                 buffer_cap: int = 4096,
                 use_kernel: bool = False,
                 events: Optional[obs.EventLog] = None):
        if fold_epochs < 1:
            raise ValueError("fold_epochs must be >= 1")
        if buffer_cap < 1:
            raise ValueError("buffer_cap must be >= 1")
        self.model = model
        self.artifact = artifact
        self.generation = 0
        self.fold_epochs = fold_epochs
        self.fold_every = fold_every
        self.buffer_cap = buffer_cap
        self.use_kernel = use_kernel
        self.events = events or obs.EventLog(None)
        self._feats: List[np.ndarray] = []
        self._labels: List[np.ndarray] = []
        self._buffered = 0
        self._gen_gauge = obs.gauge(
            "model_generation", "current served model generation")
        self._fold_hist = obs.histogram(
            "update_fold_ms", "wall ms per feedback fold "
            "(grow + QAIL scan + artifact re-freeze)")
        self._gen_gauge.set(0)

    # -- feedback intake -------------------------------------------------------
    @property
    def buffered(self) -> int:
        """Feedback rows currently buffered."""
        return self._buffered

    def ingest(self, feats, labels) -> None:
        """Buffer labeled feedback (drop-oldest beyond ``buffer_cap``)."""
        feats = np.asarray(feats)
        labels = np.asarray(labels)
        if feats.shape[0] != labels.shape[0]:
            raise ValueError("feats/labels length mismatch")
        if feats.shape[0] == 0:
            return
        self._feats.append(feats)
        self._labels.append(labels)
        self._buffered += feats.shape[0]
        while self._buffered > self.buffer_cap and len(self._feats) > 1:
            self._buffered -= self._feats.pop(0).shape[0]
            self._labels.pop(0)
        if self._buffered > self.buffer_cap:  # single oversized chunk
            keep = self.buffer_cap
            self._feats[0] = self._feats[0][-keep:]
            self._labels[0] = self._labels[0][-keep:]
            self._buffered = keep

    @property
    def should_fold(self) -> bool:
        """Buffer policy: has the auto-fold threshold been reached?"""
        return (self.fold_every is not None
                and self._buffered >= self.fold_every)

    # -- the fold --------------------------------------------------------------
    def fold(self) -> Optional[UpdateResult]:
        """Fold the buffered feedback into a new model generation.

        Returns the ``UpdateResult`` (the engine swaps
        ``result.artifact`` in), or None when the buffer is empty.
        Blocks until the new artifact's buffers are ready so the swap
        never publishes pending computation.
        """
        if self._buffered == 0:
            return None
        from repro.core import encoding, qail

        feats = np.concatenate(self._feats)
        labels = np.concatenate(self._labels).astype(np.int64)
        self._feats, self._labels, self._buffered = [], [], 0

        with obs.timed_ms(self._fold_hist) as elapsed:
            model = self.model
            old_classes = model.am_cfg.classes
            h = model.encode(feats)
            if int(labels.max()) >= old_classes:
                # Growth first; the encoder is untouched, so ``h``
                # stays valid for the fold below.
                model = model.grow_classes(feats, labels, h=h)
                log.info("grew AM to C=%d (classes %d -> %d)",
                         model.am_cfg.columns, old_classes,
                         model.am_cfg.classes)
            q = encoding.binarize_query(h)
            state, miss = qail.fold_feedback(
                model.am_state, model.am_cfg, h, q, labels,
                epochs=self.fold_epochs, use_kernel=self.use_kernel)
            model = dataclasses.replace(model, am_state=state)

            old_sig = self.artifact.swap_signature
            artifact = self.artifact.refresh(model)
            shape_stable = artifact.swap_signature == old_sig
            jax.block_until_ready(jax.tree_util.tree_leaves(artifact))

        self.model = model
        self.artifact = artifact
        self.generation += 1
        self._gen_gauge.set(self.generation)
        n_new = model.am_cfg.classes - old_classes
        result = UpdateResult(
            generation=self.generation, artifact=artifact,
            shape_stable=shape_stable, fold_ms=elapsed(),
            n_samples=int(labels.shape[0]), n_new_classes=n_new,
            miss_rate=miss)
        self.events.emit("model_fold", generation=self.generation,
                         fold_ms=round(result.fold_ms, 3),
                         n_samples=result.n_samples,
                         n_new_classes=n_new,
                         classes=model.am_cfg.classes,
                         columns=model.am_cfg.columns,
                         shape_stable=shape_stable,
                         miss_rate=round(miss, 4))
        log.info("generation %d: folded %d samples in %.1f ms "
                 "(new classes: %d, shape_stable: %s)",
                 self.generation, result.n_samples, result.fold_ms,
                 n_new, shape_stable)
        return result
