"""repro.serve — the online serving engine.

Open-loop timed serving with live class-incremental learning, built
from three pieces:

  * ``repro.serve.stream`` — the event vocabulary: ``OnlineRequest`` /
    ``Arrival`` / ``Feedback``, plus Poisson arrival generators,
    feedback bursts, and deterministic drift for staging scenarios.
  * ``repro.serve.updater`` — ``StreamingUpdater``: buffers labeled
    feedback, folds it through the device-resident QAIL scan (growing
    the AM first when feedback names never-seen classes), and re-
    freezes a new immutable artifact generation per fold.
  * ``repro.serve.engine`` — ``OnlineEngine``: deadline-aware adaptive
    batching over an admission queue, a depth-deep double-buffered
    pipeline, atomic artifact swaps between generations, and per-phase
    compile accounting (``recompiles_steady_state`` must stay 0).

The closed-loop benchmark path stays in ``repro.launch.serve_memhd``;
this package is what a long-running deployment would actually run.
"""
from repro.serve.engine import (
    OnlineEngine, ServiceModel, batch_buckets, plan_batch,
)
from repro.serve.stream import (
    Arrival, Feedback, OnlineRequest, apply_drift, feedback_burst,
    merge_events, poisson_arrivals,
)
from repro.serve.updater import StreamingUpdater, UpdateResult

__all__ = [
    "OnlineEngine", "ServiceModel", "batch_buckets", "plan_batch",
    "Arrival", "Feedback", "OnlineRequest", "apply_drift",
    "feedback_burst", "merge_events", "poisson_arrivals",
    "StreamingUpdater", "UpdateResult",
]
