"""Model assembly: embeddings -> scanned block groups -> head(s).

One ``TransformerLM`` implementation serves all ten assigned
architectures; the ``ModelConfig.blocks`` schedule decides what each group
of layers computes. Parameters of a group are *stacked* along a leading
``repeat`` axis and the forward pass scans over them (one trace per
group), keeping 96-layer dry-run compiles tractable and matching
production practice (MaxText does the same).

Public surface:
  init_params(key, cfg)                  -> (params, axes)
  forward(params, cfg, batch)            -> logits [, aux]
  loss_fn(params, cfg, batch)            -> scalar loss, metrics
  init_cache(cfg, batch, max_len, dtype) -> decode caches
  decode_step(params, cfg, batch, cache) -> logits, cache
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import BlockSpec, ModelConfig
from repro.models.sharding import shard_act

Array = jax.Array
Params = Dict[str, Any]

VIT_DIM = 1024  # stub ViT feature width for vision_patches frontends


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key: Array, cfg: ModelConfig, b: BlockSpec,
                ) -> Tuple[Params, Params]:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p: Params = {}
    a: Params = {}
    p["ln1"], a["ln1"] = L.init_rms_norm(cfg.d_model, dt)
    has_ffn = not (b.ffn.kind == "dense" and b.ffn.d_ff == 0)
    if has_ffn:
        p["ln2"], a["ln2"] = L.init_rms_norm(cfg.d_model, dt)
    if b.mixer in ("attn", "hybrid"):
        if b.attn.kind == "gqa":
            p["attn"], a["attn"] = L.init_gqa(ks[0], cfg.d_model, b.attn, dt)
        else:
            p["attn"], a["attn"] = L.init_mla(ks[0], cfg.d_model, b.attn, dt)
    if b.mixer in ("ssm", "hybrid"):
        p["ssm"], a["ssm"] = L.init_ssm(ks[1], cfg.d_model, b.ssm, dt)
    if b.cross_attn:
        p["ln_x"], a["ln_x"] = L.init_rms_norm(cfg.d_model, dt)
        p["xattn"], a["xattn"] = L.init_cross_attn(
            ks[2], cfg.d_model, b.attn, dt)
    if b.ffn.kind == "moe":
        p["ffn"], a["ffn"] = L.init_moe_ffn(ks[3], cfg.d_model, b.ffn, dt)
    elif has_ffn:
        p["ffn"], a["ffn"] = L.init_dense_ffn(ks[3], cfg.d_model, b.ffn, dt)
    return p, a


def _stack_group(key: Array, cfg: ModelConfig, b: BlockSpec,
                 ) -> Tuple[Params, Params]:
    keys = jax.random.split(key, b.repeat)
    if L.is_abstract():
        p0, axes = _init_layer(keys[0], cfg, b)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((b.repeat,) + tuple(s.shape),
                                           s.dtype), p0)
    else:
        def init_i(k):
            return _init_layer(k, cfg, b)[0]

        stacked = jax.vmap(init_i)(keys)
        axes = _init_layer_axes(cfg, b)
    # Prepend the scan ("layers") axis to every logical-axes tuple.
    axes = jax.tree.map(lambda ax: (None,) + ax, axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return stacked, axes


def _init_layer_axes(cfg: ModelConfig, b: BlockSpec) -> Params:
    """Axes tree only (no array allocation)."""
    with L.abstract_init():
        _, axes = _init_layer(jax.random.key(0), cfg, b)
    return axes


def init_params(key: Array, cfg: ModelConfig) -> Tuple[Params, Params]:
    """Returns (params, logical_axes) with identical tree structure."""
    dt = _dtype(cfg.param_dtype)
    n_groups = len(cfg.blocks)
    ks = jax.random.split(key, n_groups + 5)
    p: Params = {}
    a: Params = {}

    emb_std = 1.0 / math.sqrt(cfg.d_model)

    def _emb(key, shape):
        return L._maybe_sds(
            lambda: (jax.random.normal(key, shape) * emb_std).astype(dt),
            shape, dt)

    p["embed"] = _emb(ks[0], (cfg.padded_vocab, cfg.d_model))
    a["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings:
        p["unembed"] = _emb(ks[1], (cfg.padded_vocab, cfg.d_model))
        a["unembed"] = ("vocab", "embed")
    if cfg.n_codebooks > 1:
        p["codebook_heads"] = _emb(
            ks[2], (cfg.n_codebooks - 1, cfg.padded_vocab, cfg.d_model))
        a["codebook_heads"] = (None, "vocab", "embed")
    if cfg.frontend == "vision_patches":
        p["patch_proj"] = L._dense_init(ks[3], (VIT_DIM, cfg.d_model), dt)
        a["patch_proj"] = (None, "embed")

    groups = []
    groups_axes = []
    for gi, b in enumerate(cfg.blocks):
        gp, ga = _stack_group(ks[5 + gi], cfg, b)
        groups.append(gp)
        groups_axes.append(ga)
    p["groups"] = groups
    a["groups"] = groups_axes

    p["ln_f"], a["ln_f"] = L.init_rms_norm(cfg.d_model, dt)

    if cfg.mtp_depth:
        mtp_spec = cfg.blocks[-1]
        mp, ma = _init_layer(ks[4], cfg, mtp_spec)
        p["mtp"] = {"block": mp,
                    "proj": L._dense_init(
                        jax.random.fold_in(ks[4], 1),
                        (2 * cfg.d_model, cfg.d_model), dt),
                    "ln": L.init_rms_norm(cfg.d_model, dt)[0]}
        a["mtp"] = {"block": ma, "proj": (None, "embed"),
                    "ln": ("embed",)}
    return p, a


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layer_forward(cfg: ModelConfig, b: BlockSpec, lp: Params, x: Array,
                   positions: Array, cond: Optional[Array],
                   ) -> Tuple[Array, Dict[str, Array]]:
    aux: Dict[str, Array] = {}
    h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
    mix = None
    if b.mixer in ("attn", "hybrid"):
        if b.attn.kind == "gqa":
            att = L.gqa_forward(lp["attn"], b.attn, h, positions)
        else:
            att = L.mla_forward(lp["attn"], b.attn, h, positions,
                                cfg.rms_eps)
        mix = att
    if b.mixer in ("ssm", "hybrid"):
        ss = L.ssd_forward(lp["ssm"], b.ssm, cfg.d_model, h)
        mix = ss if mix is None else 0.5 * (mix + ss)  # hymba fusion
    x = x + mix
    if b.cross_attn and cond is not None:
        hx = L.rms_norm(x, lp["ln_x"], cfg.rms_eps)
        x = x + L.cross_attn_forward(lp["xattn"], b.attn, hx, cond)
    if "ffn" in lp:
        h2 = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        if b.ffn.kind == "dense":
            y = L.dense_ffn(lp["ffn"], b.ffn, h2)
        else:
            y, aux = L.moe_ffn(lp["ffn"], b.ffn, h2)
        x = x + y
    x = shard_act(x, ("batch", "seq", "act_embed"))
    return x, aux


def _group_forward(cfg: ModelConfig, b: BlockSpec, gp: Params, x: Array,
                   positions: Array, cond: Optional[Array],
                   ) -> Tuple[Array, Dict[str, Array]]:
    def body(carry, lp):
        y, aux = _layer_forward(cfg, b, lp, carry, positions, cond)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, auxs = jax.lax.scan(body, x, gp)
    # Sum per-layer aux across the group.
    aux = {k: jnp.sum(v, axis=0) for k, v in auxs.items()} if auxs else {}
    return x, aux


def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
                 ) -> Tuple[Array, Array, Optional[Array]]:
    """Returns (hidden, positions, cond)."""
    dt = _dtype(cfg.activation_dtype)
    if cfg.frontend == "audio_frames":
        x = batch["frame_embeds"].astype(dt)
        b, s = x.shape[:2]
        positions = jnp.arange(s)[None, :].repeat(b, 0)
        cond = batch.get("cond_embeds")
        cond = cond.astype(dt) if cond is not None else None
        return x, positions, cond
    tok = batch["tokens"]
    x = params["embed"][tok].astype(dt)
    if cfg.frontend == "vision_patches":
        patches = batch["patch_feats"].astype(dt) @ params["patch_proj"]
        x = jnp.concatenate([patches.astype(dt), x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.arange(s)[None, :].repeat(b, 0)
    return x, positions, None


def _head(params: Params, cfg: ModelConfig, h: Array) -> Array:
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", h, w.astype(h.dtype))
    logits = shard_act(logits, ("batch", "seq", "act_vocab"))
    if cfg.n_codebooks > 1:
        extra = jnp.einsum("bsd,cvd->bscv", h,
                           params["codebook_heads"].astype(h.dtype))
        logits = jnp.concatenate([logits[:, :, None, :], extra], axis=2)
    return logits


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
            ) -> Tuple[Array, Dict[str, Array]]:
    """Full-sequence forward. Returns (logits, aux).

    logits: (B, S, V) or (B, S, n_codebooks, V) for audio.
    """
    x, positions, cond = embed_inputs(params, cfg, batch)
    x = shard_act(x, ("batch", "seq", "act_embed"))
    aux_total: Dict[str, Array] = {}
    for gi, (b, gp) in enumerate(zip(cfg.blocks, params["groups"])):
        x, aux = _group_forward(cfg, b, gp, x, positions, cond)
        for k, v in aux.items():
            if k == "expert_counts":
                # Kept per group (groups may differ in expert count) for
                # the aux-free router-bias update (DeepSeek-V3);
                # layer-summed within the group.
                aux_total[f"expert_counts_g{gi}"] = v
            else:
                aux_total[k] = aux_total.get(k, 0.0) + v
    h = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
    logits = _head(params, cfg, h)
    aux_total["final_hidden"] = h
    return logits, aux_total


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _xent(logits: Array, targets: Array, mask: Optional[Array]) -> Array:
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
            ) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = forward(params, cfg, batch)
    h = aux.pop("final_hidden")

    if cfg.frontend == "audio_frames" and cfg.n_codebooks > 1:
        loss = _xent(logits, batch["targets"], None)  # (B,S,CB,V) vs (B,S,CB)
    elif cfg.frontend == "vision_patches":
        # Text-only loss; patch positions are context.
        n_p = batch["patch_feats"].shape[1]
        loss = _xent(logits[:, n_p:], batch["targets"], None)
    else:
        loss = _xent(logits, batch["targets"], None)

    metrics = {"lm_loss": loss}
    if "lb_loss" in aux:
        lb = 0.01 * aux["lb_loss"]
        loss = loss + lb
        metrics["lb_loss"] = lb
    for k, v in aux.items():
        if k.startswith("expert_counts_g"):
            metrics[k] = v

    if cfg.mtp_depth and cfg.frontend == "none":
        # DeepSeek-V3 MTP: predict t+2 from [h_i ; emb(t_{i+1})].
        emb_next = params["embed"][batch["targets"]].astype(h.dtype)
        hin = jnp.concatenate([h, emb_next], axis=-1) @ params["mtp"]["proj"]
        positions = jnp.arange(h.shape[1])[None, :].repeat(h.shape[0], 0)
        hm, _ = _layer_forward(cfg, cfg.blocks[-1], params["mtp"]["block"],
                               hin, positions, None)
        hm = L.rms_norm(hm, params["mtp"]["ln"], cfg.rms_eps)
        mtp_logits = _head(params, cfg, hm)[:, :-1]
        mtp_targets = batch["targets"][:, 1:]
        mtp = 0.3 * _xent(mtp_logits, mtp_targets, None)
        loss = loss + mtp
        metrics["mtp_loss"] = mtp

    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype_name: Optional[str] = None) -> list:
    """Per-group stacked decode caches.

    Windowed attention layers allocate ring buffers of min(window, S);
    global layers allocate the full horizon; SSM layers are O(1).
    """
    dt = _dtype(dtype_name or cfg.activation_dtype)
    caches = []
    for b in cfg.blocks:
        def stack(tree, repeat):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (repeat,) + x.shape), tree)

        entry: Dict[str, Any] = {}
        if b.mixer in ("attn", "hybrid"):
            if b.attn.kind == "gqa":
                one = L.init_gqa_cache(b.attn, batch, max_len, dt,
                                       quant=cfg.kv_cache_quant)
            else:
                one = L.init_mla_cache(b.attn, batch, max_len, dt)
            entry["attn"] = stack(one, b.repeat)
        if b.mixer in ("ssm", "hybrid"):
            one_s = L.init_ssm_cache(b.ssm, cfg.d_model, batch, dt)
            entry["ssm"] = stack(one_s, b.repeat)
        caches.append(entry)
    return caches


def _layer_decode(cfg: ModelConfig, b: BlockSpec, lp: Params, x: Array,
                  cache: Dict[str, Any], cond: Optional[Array],
                  ) -> Tuple[Array, Dict[str, Any]]:
    new_cache: Dict[str, Any] = {}
    h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
    mix = None
    if b.mixer in ("attn", "hybrid"):
        if b.attn.kind == "gqa":
            if cfg.kv_cache_quant:
                att, new_cache["attn"] = L.gqa_decode_quant(
                    lp["attn"], b.attn, h, cache["attn"])
            else:
                att, new_cache["attn"] = L.gqa_decode(
                    lp["attn"], b.attn, h, cache["attn"],
                    seq_parallel=cfg.seq_parallel_decode)
        else:
            att, new_cache["attn"] = L.mla_decode(lp["attn"], b.attn, h,
                                                  cache["attn"], cfg.rms_eps)
        mix = att
    if b.mixer in ("ssm", "hybrid"):
        ss, new_cache["ssm"] = L.ssd_decode(lp["ssm"], b.ssm, cfg.d_model,
                                            h, cache["ssm"])
        mix = ss if mix is None else 0.5 * (mix + ss)
    x = x + mix
    if b.cross_attn and cond is not None:
        hx = L.rms_norm(x, lp["ln_x"], cfg.rms_eps)
        x = x + L.cross_attn_forward(lp["xattn"], b.attn, hx, cond)
    if "ffn" in lp:
        h2 = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        if b.ffn.kind == "dense":
            y = L.dense_ffn(lp["ffn"], b.ffn, h2)
        else:
            y, _ = L.moe_ffn(lp["ffn"], b.ffn, h2)
        x = x + y
    return x, new_cache


def decode_step(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
                caches: list) -> Tuple[Array, list]:
    """One decode step for the whole stack.

    batch: {"tokens": (B, 1)} (or {"frame_embeds": (B, 1, D)} for audio;
    vlm decodes text tokens). caches: output of init_cache, with "len"
    already advanced past any prefill.

    Returns (logits, new_caches); logits (B, V) or (B, CB, V).
    """
    dt = _dtype(cfg.activation_dtype)
    if cfg.frontend == "audio_frames":
        x = batch["frame_embeds"].astype(dt)
        cond = batch.get("cond_embeds")
        cond = cond.astype(dt) if cond is not None else None
    else:
        x = params["embed"][batch["tokens"]].astype(dt)
        cond = None

    new_caches = []
    for b, gp, gc in zip(cfg.blocks, params["groups"], caches):
        def body(carry, scanned):
            lp, lc = scanned
            y, nc = _layer_decode(cfg, b, lp, carry, lc, cond)
            return y, nc

        x, nc = jax.lax.scan(body, x, (gp, gc))
        new_caches.append(nc)
    h = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
    logits = _head(params, cfg, h)
    return logits[:, 0], new_caches
