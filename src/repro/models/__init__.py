from repro.models.config import (  # noqa: F401
    AttnSpec, BlockSpec, FfnSpec, ModelConfig, SsmSpec,
)
from repro.models import layers, sharding, transformer  # noqa: F401
