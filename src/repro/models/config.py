"""Model configuration schema: architectures are data, not code forks.

A model is a stack of *block groups*; each group is ``repeat`` identical
layers described by one ``BlockSpec`` (mixer + FFN + geometry). The
forward pass scans within a group (one compile per group, not per layer)
and chains groups in order. This one schema expresses all ten assigned
architectures:

  dense GQA          -> one group, mixer="attn"
  gemma3 5:1 pattern -> repeating [5x local, 1x global] groups
  deepseek dense+MoE -> [k x dense-FFN group, (L-k) x MoE group]
  mamba2             -> one group, mixer="ssm"
  hymba              -> groups with mixer="hybrid" (parallel attn + SSM),
                        full-attention groups at ends/middle
  musicgen           -> cross_attn=True groups + 4 codebook heads
  internvl2          -> vision-patch stub frontend + dense groups
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Attention mixer settings (GQA or MLA)."""

    kind: str = "gqa"                 # "gqa" | "mla"
    n_heads: int = 16
    n_kv_heads: int = 16              # GQA: kv head count (1 = MQA)
    head_dim: int = 128
    qkv_bias: bool = False            # qwen1.5
    rope_theta: float = 10_000.0
    window: Optional[int] = None      # sliding window; None = global
    logit_softcap: Optional[float] = None
    # -- MLA (deepseek) ------------------------------------------------------
    q_lora_rank: Optional[int] = None     # None = direct q projection
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    def __post_init__(self):
        if self.kind not in ("gqa", "mla"):
            raise ValueError(f"bad attn kind {self.kind!r}")
        if self.kind == "gqa" and self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be divisible by n_kv_heads")


@dataclasses.dataclass(frozen=True)
class SsmSpec:
    """Mamba-2 (SSD) mixer settings."""

    d_state: int = 128        # N
    head_dim: int = 64        # P
    expand: int = 2           # d_inner = expand * d_model
    n_groups: int = 1         # B/C groups (G)
    conv_width: int = 4
    chunk: int = 256          # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class FfnSpec:
    """FFN settings: dense or MoE."""

    kind: str = "dense"           # "dense" | "moe"
    d_ff: int = 4096
    activation: str = "silu_glu"  # "silu_glu" | "gelu_glu" | "gelu"
    #                               | "squared_relu"
    # -- MoE ---------------------------------------------------------------------
    n_experts: int = 0            # routed experts
    n_shared: int = 0             # always-on shared experts
    top_k: int = 2
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router: str = "softmax"       # "softmax" | "sigmoid" (dsv3 aux-free)

    def __post_init__(self):
        if self.kind not in ("dense", "moe"):
            raise ValueError(f"bad ffn kind {self.kind!r}")
        ok = ("silu_glu", "gelu_glu", "gelu", "squared_relu")
        if self.activation not in ok:
            raise ValueError(f"bad activation {self.activation!r}")
        if self.kind == "moe" and (self.n_experts <= 0
                                   or self.d_ff_expert <= 0):
            raise ValueError("moe needs n_experts and d_ff_expert")


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """``repeat`` identical transformer layers."""

    repeat: int
    mixer: str = "attn"           # "attn" | "ssm" | "hybrid"
    attn: Optional[AttnSpec] = None
    ssm: Optional[SsmSpec] = None
    ffn: FfnSpec = FfnSpec()
    cross_attn: bool = False      # musicgen: cross-attend to conditioning

    def __post_init__(self):
        if self.mixer in ("attn", "hybrid") and self.attn is None:
            raise ValueError(f"mixer {self.mixer!r} needs attn spec")
        if self.mixer in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError(f"mixer {self.mixer!r} needs ssm spec")
        if self.repeat <= 0:
            raise ValueError("repeat must be positive")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Top-level architecture description."""

    name: str
    d_model: int
    vocab_size: int
    blocks: Tuple[BlockSpec, ...]
    # Modality frontend: "none" (token ids), "audio_frames" (precomputed
    # frame embeddings + codebook heads), "vision_patches" (patch
    # embeddings prepended to token embeddings).
    frontend: str = "none"
    n_codebooks: int = 1            # musicgen: output heads per position
    n_cond_tokens: int = 0          # cross-attention memory length
    n_patches: int = 0              # vlm: patch tokens per sample
    tie_embeddings: bool = True
    rms_eps: float = 1e-5
    mtp_depth: int = 0              # deepseek-v3 multi-token prediction
    # Embedding tables are padded so the vocab dim shards cleanly over
    # any mesh axis (MaxText-style). Logits over padded ids are live but
    # never targeted; samplers slice [:vocab_size].
    vocab_pad_to: int = 256
    # -- numerics / execution ---------------------------------------------------
    param_dtype: str = "float32"    # smoke tests; dry-run uses bfloat16
    activation_dtype: str = "float32"
    remat: bool = True              # activation checkpointing per layer
    # -- parallelism ---------------------------------------------------------------
    fsdp: bool = False              # shard params over the data axis too
    shard_seq: bool = False         # long-context: shard KV/seq on model
    # Sequence-parallel flash decode: attention over the seq-sharded KV
    # cache computed shard-locally (online-softmax partials) and merged
    # with tiny psums, instead of letting GSPMD all-gather the cache.
    # §Perf hillclimb lever for collective-bound decode cells.
    seq_parallel_decode: bool = False
    # int8 KV cache (GQA layers): rows stored int8 with per-(pos, head)
    # scales; exact-algebra dequant inside the attention einsums. Halves
    # the decode-cell cache residency vs bf16 — the remedy for the MHA
    # 32k-context cells that exceed one pod's HBM.
    kv_cache_quant: bool = False

    def __post_init__(self):
        if self.frontend not in ("none", "audio_frames", "vision_patches"):
            raise ValueError(f"bad frontend {self.frontend!r}")
        if not self.blocks:
            raise ValueError("need at least one block group")

    @property
    def n_layers(self) -> int:
        return sum(b.repeat for b in self.blocks)

    @property
    def padded_vocab(self) -> int:
        pad = self.vocab_pad_to
        return -(-self.vocab_size // pad) * pad

    # -- analytics (roofline / memory audits) ----------------------------------
    def param_count(self) -> int:
        """Exact parameter count (embeddings + blocks + heads)."""
        d = self.d_model
        total = self.padded_vocab * d  # embedding (padded for sharding)
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        if self.n_codebooks > 1:
            total += (self.n_codebooks - 1) * self.padded_vocab * d
        if self.frontend == "vision_patches":
            total += 1024 * d  # patch projection stub (from ViT dim 1024)
        for b in self.blocks:
            total += b.repeat * self._layer_params(b)
        total += d  # final norm
        if self.mtp_depth:
            mtp_block = self.blocks[-1]
            total += self.mtp_depth * (self._layer_params(mtp_block)
                                       + 2 * d * d)  # combine proj
        return total

    def _layer_params(self, b: BlockSpec) -> int:
        d = self.d_model
        has_ffn = not (b.ffn.kind == "dense" and b.ffn.d_ff == 0)
        n = 2 * d if has_ffn else d  # pre-mixer (+ pre-ffn) rmsnorms
        if b.mixer in ("attn", "hybrid"):
            a = b.attn
            if a.kind == "gqa":
                qkv = d * a.n_heads * a.head_dim \
                    + 2 * d * a.n_kv_heads * a.head_dim
                if a.qkv_bias:
                    qkv += (a.n_heads + 2 * a.n_kv_heads) * a.head_dim
                n += qkv + a.n_heads * a.head_dim * d
            else:  # mla
                qk_dim = a.qk_nope_dim + a.qk_rope_dim
                if a.q_lora_rank:
                    n += d * a.q_lora_rank \
                        + a.q_lora_rank * a.n_heads * qk_dim
                else:
                    n += d * a.n_heads * qk_dim
                n += d * (a.kv_lora_rank + a.qk_rope_dim)
                n += a.kv_lora_rank * a.n_heads * (a.qk_nope_dim
                                                   + a.v_head_dim)
                n += a.n_heads * a.v_head_dim * d
        if b.mixer in ("ssm", "hybrid"):
            s = b.ssm
            d_in = s.expand * d
            n_heads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            n += d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads)
            n += conv_dim * s.conv_width
            n += 2 * n_heads          # A_log, D
            n += n_heads              # dt_bias
            n += d_in * d             # out proj
            n += d_in                 # gate norm
        if b.cross_attn:
            a = b.attn
            n += d  # extra norm
            n += 2 * d * a.n_heads * a.head_dim \
                + a.n_heads * a.head_dim * d + d * a.n_heads * a.head_dim
        f = b.ffn
        if f.kind == "dense":
            mult = 3 if f.activation.endswith("_glu") else 2
            n += mult * d * f.d_ff
        else:
            mult = 3  # deepseek experts are glu
            n += d * f.n_experts  # router
            n += f.n_experts * mult * d * f.d_ff_expert
            n += f.n_shared * mult * d * f.d_ff_expert
            if f.router == "sigmoid":
                n += f.n_experts  # aux-free bias
        return n

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top_k + shared."""
        d = self.d_model
        total = self.padded_vocab * d + d
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        for b in self.blocks:
            full = self._layer_params(b)
            f = b.ffn
            if f.kind == "moe":
                mult = 3
                routed_all = f.n_experts * mult * d * f.d_ff_expert
                routed_active = f.top_k * mult * d * f.d_ff_expert
                full = full - routed_all + routed_active
            total += b.repeat * full
        return total
