"""Logical-to-physical sharding rules (MaxText-style).

Layers annotate parameters and activations with *logical* axis names;
a ``ShardingRules`` context maps those to physical mesh axes. No rules
active (unit tests, single device) -> every annotation is a no-op.

Physical axes: ("pod", "data", "model") on the multi-pod mesh,
("data", "model") single-pod. "pod" is folded into the batch/fsdp axes
when present.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis names to physical mesh axes."""

    mesh: Mesh
    fsdp: bool = False          # shard big param dims over the data axes
    shard_seq: bool = False     # long-context: activations' seq on model
    # Extra/overriding logical->physical entries (hillclimb knob).
    overrides: Optional[Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...]] \
        = None

    def table(self) -> Dict[str, Optional[Tuple[str, ...]]]:
        b = _batch_axes(self.mesh)
        t: Dict[str, Optional[Tuple[str, ...]]] = {
            # activations
            "batch": b,
            "seq": ("model",) if self.shard_seq else None,
            "kv_seq": ("model",) if self.shard_seq else None,
            "act_embed": None,
            "act_heads": ("model",),
            "act_mlp": ("model",),
            "act_vocab": ("model",),
            "act_experts": ("model",),
            # parameters
            "vocab": ("model",),
            "embed": b if self.fsdp else None,
            "heads": ("model",),
            "kv_heads": ("model",),
            "head_dim": None,
            "mlp": ("model",),
            "experts": ("model",),
            "expert_mlp": None,
            "lora": None,
            "conv": None,
            "ssm_inner": ("model",),
            "ssm_state": None,
            None: None,
        }
        if self.overrides:
            t.update(dict(self.overrides))
        return t

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """Resolve logical names to a PartitionSpec.

        When ``shape`` is provided, mesh axes that do not divide the
        corresponding dimension are dropped (graceful fallback to
        replication — e.g. hymba's 25 heads or qwen's 40 heads cannot
        split 16 ways; their TP lives on the FFN instead). Divisibility
        is required by GSPMD; padding the model dims is a per-arch
        hillclimb option, not a baseline default.
        """
        t = self.table()
        parts = []
        used: set = set()
        for i, name in enumerate(logical):
            ax = t.get(name)
            if ax is None:
                parts.append(None)
                continue
            ax = tuple(a for a in ax if a in self.mesh.axis_names
                       and a not in used)
            if shape is not None and ax:
                dim = shape[i]
                keep = []
                prod = 1
                for a in ax:
                    if dim % (prod * self.mesh.shape[a]) == 0:
                        keep.append(a)
                        prod *= self.mesh.shape[a]
                ax = tuple(keep)
            used.update(ax)
            parts.append(ax if len(ax) > 1 else (ax[0] if ax else None))
        return P(*parts)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


def shard_act(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with a logical sharding (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(logical, x.shape))


def param_sharding_tree(axes_tree, rules: Optional[ShardingRules],
                        params_tree=None):
    """Map a tree of logical-axis tuples to NamedShardings (or None).

    ``params_tree`` (arrays or ShapeDtypeStructs, same structure) enables
    divisibility-aware fallback per leaf.
    """
    is_axes_leaf = lambda x: isinstance(x, tuple)  # noqa: E731
    if rules is None:
        return jax.tree.map(lambda _: None, axes_tree, is_leaf=is_axes_leaf)
    if params_tree is None:
        return jax.tree.map(lambda ax: rules.sharding(ax), axes_tree,
                            is_leaf=is_axes_leaf)
    flat_axes, treedef = jax.tree.flatten(axes_tree, is_leaf=is_axes_leaf)
    flat_params = treedef.flatten_up_to(params_tree)
    shardings = [rules.sharding(ax, p.shape)
                 for ax, p in zip(flat_axes, flat_params)]
    return treedef.unflatten(shardings)
