"""Transformer / SSM layer implementations (pure functions over pytrees).

Every ``init_*`` returns ``(params, axes)`` where ``axes`` mirrors the
param tree with per-dimension *logical* axis names — the sharding layer
(models/sharding.py) resolves those to mesh PartitionSpecs. Every forward
helper is shape-polymorphic over batch and works in any dtype.

Attention comes in three executions:
  * ``attention_full``    — chunked online-softmax (flash-style) causal
                            attention; O(S * chunk) live memory.
  * ``attention_local``   — sliding-window attention computed per query
                            block against a static KV neighbourhood;
                            O(S * window) FLOPs, the 5:1 gemma3 pattern's
                            cheap path.
  * ``attention_decode``  — one-token query against a KV cache.

MoE uses sort-based dropping dispatch (argsort by expert, capacity clamp,
batched expert einsum, scatter-add combine) — the standard TPU-friendly
formulation that shards experts over the "model" axis (EP).

Mamba-2 is the chunked SSD algorithm (arXiv:2405.21060) with a
constant-memory decode step.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map as _shard_map
from repro.models.config import AttnSpec, FfnSpec, SsmSpec
from repro.models.sharding import shard_act

Array = jax.Array
Params = Dict[str, Array]
Axes = Dict[str, tuple]

# ---------------------------------------------------------------------------
# Abstract-init mode: the dry-run needs parameter *shapes* for 340B/671B
# models without allocating a byte. Inside ``abstract_init()`` every
# parameter constructor returns a ShapeDtypeStruct instead of an array;
# the logical-axes trees (static strings) are built identically.
# ---------------------------------------------------------------------------

import contextlib as _contextlib
import threading as _threading

_abstract_state = _threading.local()


@_contextlib.contextmanager
def abstract_init():
    prev = getattr(_abstract_state, "on", False)
    _abstract_state.on = True
    try:
        yield
    finally:
        _abstract_state.on = prev


def is_abstract() -> bool:
    return getattr(_abstract_state, "on", False)


def _maybe_sds(make, shape, dtype):
    if is_abstract():
        return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
    return make()


def _zeros(shape, dtype) -> Array:
    return _maybe_sds(lambda: jnp.zeros(shape, dtype), shape, dtype)


# ---------------------------------------------------------------------------
# Basics
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(
        jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype) -> Tuple[Array, tuple]:
    return _zeros((d,), dtype), ("embed",)


def _dense_init(key: Array, shape, dtype, in_axis: int = 0) -> Array:
    def make():
        fan_in = shape[in_axis]
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, shape) * std).astype(dtype)

    return _maybe_sds(make, shape, dtype)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(scores: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_gqa(key: Array, d_model: int, spec: AttnSpec, dtype,
             ) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 4)
    h, kv, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim
    p: Params = {
        "wq": _dense_init(ks[0], (d_model, h, dh), dtype),
        "wk": _dense_init(ks[1], (d_model, kv, dh), dtype),
        "wv": _dense_init(ks[2], (d_model, kv, dh), dtype),
        "wo": _dense_init(ks[3], (h, dh, d_model), dtype, in_axis=0),
    }
    a: Axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if spec.qkv_bias:
        p["bq"] = _zeros((h, dh), dtype)
        p["bk"] = _zeros((kv, dh), dtype)
        p["bv"] = _zeros((kv, dh), dtype)
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    return p, a


def _qkv(p: Params, spec: AttnSpec, x: Array, positions: Array,
         ) -> Tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if spec.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = rope(q, positions, spec.rope_theta)
    k = rope(k, positions, spec.rope_theta)
    return q, k, v


def _repeat_kv(k: Array, groups: int) -> Array:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attention_full(q: Array, k: Array, v: Array, *, q_offset: int = 0,
                   softcap: Optional[float] = None,
                   chunk: int = 1024) -> Array:
    """Chunked causal attention with online softmax.

    q: (B, Sq, H, Dh); k, v: (B, Skv, H, Dh) (kv already head-repeated).
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill
    continuation); causal mask is (q_offset + i) >= j.
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]  # may differ from dh (MLA)
    scale = 1.0 / math.sqrt(dh)
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(b, n_chunks, chunk, h, dh)
    vc = vp.reshape(b, n_chunks, chunk, h, dv)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        kb, vb, cidx = inputs
        k_pos = cidx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb) * scale
        s = _softcap(s, softcap)
        mask = (q_pos[:, None] >= k_pos[None, :]) & (
            k_pos[None, :] < skv)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # Guard fully-masked rows (exp(-inf - -inf)).
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(m), corr, 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)  # f32 accumulator
    # Remat the chunk body: the backward pass recomputes each chunk's
    # (Sq, chunk) score/prob block instead of keeping all of them live —
    # the flash-attention memory contract, expressed at the JAX level.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0),
        (jnp.swapaxes(kc, 0, 1), jnp.swapaxes(vc, 0, 1),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return jnp.einsum("bhqd->bqhd", out).astype(q.dtype)


def attention_local(q: Array, k: Array, v: Array, window: int,
                    *, softcap: Optional[float] = None,
                    block: int = 512) -> Array:
    """Sliding-window causal attention (training/prefill path).

    Query block i attends keys [i*block - window, i*block + block): a
    static-size neighbourhood, so total FLOPs are O(S * (window + block))
    rather than O(S^2).
    """
    b, s, h, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    block = min(block, s)
    n_blocks = -(-s // block)
    pad_q = n_blocks * block - s
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    # KV padded on the left by `window` so every block's neighbourhood is
    # in-range, and on the right to the padded q length.
    kp = jnp.pad(k, ((0, 0), (window, pad_q), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, pad_q), (0, 0), (0, 0)))
    span = window + block

    def one_block(i):
        qb = jax.lax.dynamic_slice_in_dim(qp, i * block, block, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(kp, i * block, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, i * block, span, axis=1)
        sc = jnp.einsum("bqhd,bkhd->bhqk", qb, kb) * scale
        sc = _softcap(sc, softcap)
        q_pos = i * block + jnp.arange(block)          # absolute
        k_pos = i * block - window + jnp.arange(span)  # absolute
        # Window semantics: attend to the last `window` keys *including*
        # self (diff in [0, window)) — matches the decode ring buffer.
        mask = ((q_pos[:, None] >= k_pos[None, :])
                & (q_pos[:, None] - k_pos[None, :] < window)
                & (k_pos[None, :] >= 0) & (q_pos[:, None] < s)
                & (k_pos[None, :] < s))
        sc = jnp.where(mask[None, None], sc, -jnp.inf)
        m = sc.max(axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(sc - m)
        p = jnp.where(jnp.isfinite(sc), p, 0.0)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vb.dtype), vb)
        denom = p.sum(axis=-1).transpose(0, 2, 1)[..., None]
        return o / jnp.maximum(denom, 1e-20).astype(o.dtype)

    # Remat per block: backward recomputes each block's score window.
    outs = jax.lax.map(jax.checkpoint(one_block),
                       jnp.arange(n_blocks))  # (nb, B, block, H, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n_blocks * block, h, dh)
    return out[:, :s]


def attention_decode(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array, *,
                     softcap: Optional[float] = None) -> Array:
    """Single-position decode: q (B, 1, H, Dh) vs cache (B, S, H, Dh).

    ``cache_len``: (B,) or scalar count of valid cache entries (the new
    token's k/v must already be written at cache_len - 1).
    """
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache) * scale
    s = _softcap(s, softcap)
    k_pos = jnp.arange(k_cache.shape[1])
    valid = k_pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v_cache)
    return out


def gqa_forward(p: Params, spec: AttnSpec, x: Array, positions: Array,
                ) -> Array:
    """Training/prefill GQA attention over hidden states x: (B, S, D)."""
    q, k, v = _qkv(p, spec, x, positions)
    q = shard_act(q, ("batch", "seq", "act_heads", None))
    groups = spec.n_heads // spec.n_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    if spec.window is not None and x.shape[1] > spec.window:
        out = attention_local(q, k, v, spec.window,
                              softcap=spec.logit_softcap)
    else:
        out = attention_full(q, k, v, softcap=spec.logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode_seqpar(q: Array, k_cache: Array, v_cache: Array,
                            k_new: Array, v_new: Array, slot: Array,
                            cache_len: Array, rules, *,
                            softcap: Optional[float] = None,
                            ) -> Tuple[Array, Array, Array]:
    """Sequence-parallel flash decode over a seq-sharded KV cache.

    The caches are sharded on their seq dim over "model". Instead of
    letting GSPMD all-gather the (possibly 500k-token) cache to every
    chip, each shard computes online-softmax partials (m, l, acc) over
    its local slice and the merge is three tiny psums — the flash-decode
    pattern. The new token's (k, v) is scattered into whichever shard
    owns ``slot``.

    Args:
      q: (B, 1, H, Dh) replicated query (kv already head-repeated
        upstream is NOT required — pass kv-head tensors and repeat
        inside to keep wire small).
      k_cache/v_cache: (B, S, KV, Dh), S sharded over "model".
      k_new/v_new: (B, KV, Dh) this step's entries.
      slot: (B,) global cache slot to write.
      cache_len: (B,) valid entries after the write.

    Returns:
      (out, new_k_cache, new_v_cache): out (B, 1, H, Dh).
    """
    mesh = rules.mesh
    b, _, h, dh = q.shape
    s_global = k_cache.shape[1]
    kv = k_cache.shape[2]
    groups = h // kv
    m_size = mesh.shape["model"]
    s_local = s_global // m_size
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    from jax.sharding import PartitionSpec as P

    def bspec(*rest):
        lead = ba if b % _axes_size(mesh, ba) == 0 else None
        return P(lead, *rest)

    def local(q_l, kc, vc, kn, vn, slot_l, len_l):
        # kc/vc: (B, s_local, KV, Dh) local slice; offset from rank.
        rank = jax.lax.axis_index("model")
        offset = rank * s_local
        local_slot = slot_l - offset
        in_range = (local_slot >= 0) & (local_slot < s_local)
        li = jnp.clip(local_slot, 0, s_local - 1)
        bidx = jnp.arange(kc.shape[0])
        kc = kc.at[bidx, li].set(
            jnp.where(in_range[:, None, None], kn, kc[bidx, li]))
        vc = vc.at[bidx, li].set(
            jnp.where(in_range[:, None, None], vn, vc[bidx, li]))

        kk = _repeat_kv(kc, groups)
        vv = _repeat_kv(vc, groups)
        scale = 1.0 / math.sqrt(dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_l, kk) * scale
        s = _softcap(s, softcap)
        k_pos = offset + jnp.arange(s_local)
        valid = k_pos[None, :] < len_l[:, None]
        s = jnp.where(valid[:, None, None, :], s.astype(jnp.float32),
                      -jnp.inf)
        m_l = jnp.max(s, axis=-1)                      # (B,H,1)
        m_g = jax.lax.pmax(m_l, "model")
        m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
        l_l = p.sum(axis=-1)
        acc_l = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vv.dtype), vv
                           ).astype(jnp.float32)
        l_g = jax.lax.psum(l_l, "model")
        acc_g = jax.lax.psum(acc_l, "model")
        out = (acc_g / jnp.maximum(l_g[..., None], 1e-20)).astype(q_l.dtype)
        return jnp.einsum("bhqd->bqhd", out), kc, vc

    out, new_k, new_v = _shard_map(
        local, mesh=mesh,
        in_specs=(bspec(None, None, None), bspec("model", None, None),
                  bspec("model", None, None), bspec(None, None),
                  bspec(None, None), bspec(), bspec()),
        out_specs=(bspec(None, None, None), bspec("model", None, None),
                   bspec("model", None, None)),
    )(q, k_cache, v_cache, k_new, v_new, slot, cache_len)
    return out, new_k, new_v


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def gqa_decode(p: Params, spec: AttnSpec, x: Array, cache: Dict[str, Array],
               *, seq_parallel: bool = False,
               ) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode. x: (B, 1, D); cache: {k, v, len}.

    cache["k"/"v"]: (B, S_cache, KV, Dh) — ring buffer when the layer is
    windowed (S_cache == window), linear otherwise. With ``seq_parallel``
    (and active sharding rules with seq-sharded caches) the attention
    runs shard-locally with psum merges (flash decode).
    """
    b = x.shape[0]
    pos = cache["len"]  # (B,) absolute position of the new token
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q, pos[:, None], spec.rope_theta)
    k = rope(k, pos[:, None], spec.rope_theta)

    s_cache = cache["k"].shape[1]
    slot = (pos % s_cache if spec.window is not None else pos)  # (B,)
    valid = jnp.minimum(pos + 1, s_cache)

    from repro.models import sharding as sh_mod
    rules = sh_mod.current_rules()
    use_seqpar = (seq_parallel and rules is not None
                  and rules.shard_seq and "model" in rules.mesh.axis_names
                  and s_cache % rules.mesh.shape["model"] == 0
                  and spec.window is None)
    if use_seqpar:
        out, k_cache, v_cache = attention_decode_seqpar(
            q, cache["k"], cache["v"], k[:, 0], v[:, 0], slot, valid,
            rules, softcap=spec.logit_softcap)
    else:
        bidx = jnp.arange(b)
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
        groups = spec.n_heads // spec.n_kv_heads
        kk = _repeat_kv(k_cache, groups)
        vv = _repeat_kv(v_cache, groups)
        out = attention_decode(q, kk, vv, valid,
                               softcap=spec.logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k_cache, "v": v_cache, "len": pos + 1}


def init_gqa_cache(spec: AttnSpec, batch: int, max_len: int, dtype,
                   quant: bool = False) -> Dict[str, Array]:
    s = min(max_len, spec.window) if spec.window is not None else max_len
    shape = (batch, s, spec.n_kv_heads, spec.head_dim)
    if quant:
        # int8 rows + per-(batch, pos, kv-head) float16 scales: ~1.03
        # bytes/element vs 2 for bf16.
        return {
            "k_q": jnp.zeros(shape, jnp.int8),
            "v_q": jnp.zeros(shape, jnp.int8),
            "k_s": jnp.zeros(shape[:3], jnp.float16),
            "v_s": jnp.zeros(shape[:3], jnp.float16),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def _quant_rows(x: Array) -> Tuple[Array, Array]:
    """Per-(..., head) symmetric int8 quantization over head_dim."""
    scale = jnp.max(jnp.abs(x), axis=-1) / 127.0 + 1e-8  # (..., H)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def gqa_decode_quant(p: Params, spec: AttnSpec, x: Array,
                     cache: Dict[str, Array],
                     ) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode against an int8 KV cache.

    Exact-algebra dequant: scores = (q . k_int8) * k_scale (the per-row
    scale factors out of the head_dim dot), and the value product applies
    v_scale to the attention probabilities before the int8 PV einsum —
    no materialized dequantized cache.
    """
    b = x.shape[0]
    pos = cache["len"]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q, pos[:, None], spec.rope_theta)
    k = rope(k, pos[:, None], spec.rope_theta)

    s_cache = cache["k_q"].shape[1]
    slot = (pos % s_cache if spec.window is not None else pos)
    bidx = jnp.arange(b)
    k_new_q, k_new_s = _quant_rows(k[:, 0])
    v_new_q, v_new_s = _quant_rows(v[:, 0])
    k_q = cache["k_q"].at[bidx, slot].set(k_new_q)
    v_q = cache["v_q"].at[bidx, slot].set(v_new_q)
    k_s = cache["k_s"].at[bidx, slot].set(k_new_s)
    v_s = cache["v_s"].at[bidx, slot].set(v_new_s)

    groups = spec.n_heads // spec.n_kv_heads
    kk = _repeat_kv(k_q, groups)                      # int8 (B,S,H,D)
    kk_s = _repeat_kv(k_s[..., None], groups)[..., 0]  # (B,S,H)
    vv = _repeat_kv(v_q, groups)
    vv_s = _repeat_kv(v_s[..., None], groups)[..., 0]

    scale = 1.0 / math.sqrt(spec.head_dim)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32))
    s = s * jnp.moveaxis(kk_s.astype(jnp.float32), -1, 1)[:, :, None, :]
    s = _softcap(s * scale, spec.logit_softcap)
    k_pos = jnp.arange(s_cache)
    valid = jnp.minimum(pos + 1, s_cache)
    mask = k_pos[None, :] < valid[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    pw = jax.nn.softmax(s, axis=-1)
    pw = pw * jnp.moveaxis(vv_s.astype(jnp.float32), -1, 1)[:, :, None, :]
    out = jnp.einsum("bhqk,bkhd->bqhd", pw, vv.astype(jnp.float32))
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, {"k_q": k_q, "v_q": v_q, "k_s": k_s, "v_s": v_s,
               "len": pos + 1}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek V2/V3)
# ---------------------------------------------------------------------------

def init_mla(key: Array, d_model: int, spec: AttnSpec, dtype,
             ) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 8)
    h = spec.n_heads
    qk = spec.qk_nope_dim + spec.qk_rope_dim
    p: Params = {}
    a: Axes = {}
    if spec.q_lora_rank:
        p["wq_a"] = _dense_init(ks[0], (d_model, spec.q_lora_rank), dtype)
        p["q_norm"] = _zeros((spec.q_lora_rank,), dtype)
        p["wq_b"] = _dense_init(ks[1], (spec.q_lora_rank, h, qk), dtype)
        a["wq_a"] = ("embed", "lora")
        a["q_norm"] = ("lora",)
        a["wq_b"] = ("lora", "heads", "head_dim")
    else:
        p["wq"] = _dense_init(ks[0], (d_model, h, qk), dtype)
        a["wq"] = ("embed", "heads", "head_dim")
    # Joint compressed KV + decoupled rope key.
    p["wkv_a"] = _dense_init(
        ks[2], (d_model, spec.kv_lora_rank + spec.qk_rope_dim), dtype)
    p["kv_norm"] = _zeros((spec.kv_lora_rank,), dtype)
    p["wk_b"] = _dense_init(
        ks[3], (spec.kv_lora_rank, h, spec.qk_nope_dim), dtype)
    p["wv_b"] = _dense_init(
        ks[4], (spec.kv_lora_rank, h, spec.v_head_dim), dtype)
    p["wo"] = _dense_init(ks[5], (h, spec.v_head_dim, d_model), dtype)
    a.update({
        "wkv_a": ("embed", "lora"),
        "kv_norm": ("lora",),
        "wk_b": ("lora", "heads", "head_dim"),
        "wv_b": ("lora", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    })
    return p, a


def _mla_q(p: Params, spec: AttnSpec, x: Array, positions: Array,
           eps: float) -> Tuple[Array, Array]:
    """Returns (q_nope, q_rope): (B,S,H,nope), (B,S,H,rope)."""
    if spec.q_lora_rank:
        ql = x @ p["wq_a"]
        ql = rms_norm(ql, p["q_norm"], eps)
        q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope = q[..., : spec.qk_nope_dim]
    q_rope = rope(q[..., spec.qk_nope_dim:], positions, spec.rope_theta)
    return q_nope, q_rope


def mla_forward(p: Params, spec: AttnSpec, x: Array, positions: Array,
                eps: float = 1e-5) -> Array:
    """Prefill/training MLA: materialize per-head K/V from the latent."""
    q_nope, q_rope = _mla_q(p, spec, x, positions, eps)
    kv = x @ p["wkv_a"]  # (B, S, lora + rope)
    c_kv = rms_norm(kv[..., : spec.kv_lora_rank], p["kv_norm"], eps)
    k_rope = rope(kv[..., spec.kv_lora_rank:][:, :, None, :], positions,
                  spec.rope_theta)  # (B, S, 1, rope)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
    h = spec.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1]
                                  + (spec.qk_rope_dim,))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention_full(q, k, v)  # v head dim differs from qk dim — ok
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_decode(p: Params, spec: AttnSpec, x: Array, cache: Dict[str, Array],
               eps: float = 1e-5) -> Tuple[Array, Dict[str, Array]]:
    """Absorbed-form MLA decode against the compressed latent cache.

    cache["ckv"]: (B, S, kv_lora); cache["krope"]: (B, S, rope).
    Scores = q_nope @ W_UK^T @ c_kv + q_rope @ k_rope  (W_UK absorbed into
    the query), so per-token cache is kv_lora + rope floats — the whole
    point of MLA.
    """
    b = x.shape[0]
    pos = cache["len"]
    q_nope, q_rope = _mla_q(p, spec, x, pos[:, None], eps)
    kv = x @ p["wkv_a"]
    c_new = rms_norm(kv[..., : spec.kv_lora_rank], p["kv_norm"], eps)
    kr_new = rope(kv[..., spec.kv_lora_rank:][:, :, None, :], pos[:, None],
                  spec.rope_theta)[:, :, 0, :]

    bidx = jnp.arange(b)
    ckv = cache["ckv"].at[bidx, pos].set(c_new[:, 0])
    krope = cache["krope"].at[bidx, pos].set(kr_new[:, 0])

    # Absorb W_UK into q: (B,1,H,nope) x (lora,H,nope) -> (B,1,H,lora)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])
    scale = 1.0 / math.sqrt(spec.qk_nope_dim + spec.qk_rope_dim)
    s = (jnp.einsum("bshr,btr->bhst", q_abs, ckv)
         + jnp.einsum("bshk,btk->bhst", q_rope, krope)) * scale
    k_pos = jnp.arange(ckv.shape[1])
    valid = k_pos[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    pw = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", pw, ckv)  # (B,1,H,lora)
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["wv_b"])  # absorb W_UV
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"ckv": ckv, "krope": krope, "len": pos + 1}


def init_mla_cache(spec: AttnSpec, batch: int, max_len: int, dtype,
                   ) -> Dict[str, Array]:
    return {
        "ckv": jnp.zeros((batch, max_len, spec.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, spec.qk_rope_dim), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Cross attention (musicgen conditioning)
# ---------------------------------------------------------------------------

def init_cross_attn(key: Array, d_model: int, spec: AttnSpec, dtype,
                    ) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 4)
    h, dh = spec.n_heads, spec.head_dim
    p = {
        "wq": _dense_init(ks[0], (d_model, h, dh), dtype),
        "wk": _dense_init(ks[1], (d_model, h, dh), dtype),
        "wv": _dense_init(ks[2], (d_model, h, dh), dtype),
        "wo": _dense_init(ks[3], (h, dh, d_model), dtype),
    }
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "heads", "head_dim"),
        "wv": ("embed", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    return p, a


def cross_attn_forward(p: Params, spec: AttnSpec, x: Array, cond: Array,
                       ) -> Array:
    """x: (B, S, D) attends over cond: (B, T, D) (no mask, no rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", cond, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", cond, p["wv"])
    s = jnp.einsum("bshk,bthk->bhst", q, k) / math.sqrt(spec.head_dim)
    pw = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthk->bshk", pw, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# FFN: dense + MoE
# ---------------------------------------------------------------------------

def _act(name: str, gate: Array, up: Optional[Array]) -> Array:
    if name == "silu_glu":
        return jax.nn.silu(gate) * up
    if name == "gelu_glu":
        return jax.nn.gelu(gate) * up
    if name == "gelu":
        return jax.nn.gelu(gate)
    if name == "squared_relu":
        r = jax.nn.relu(gate)
        return r * r
    raise ValueError(name)


def init_dense_ffn(key: Array, d_model: int, spec: FfnSpec, dtype,
                   ) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 3)
    glu = spec.activation.endswith("_glu")
    p: Params = {"w_in": _dense_init(ks[0], (d_model, spec.d_ff), dtype),
                 "w_out": _dense_init(ks[1], (spec.d_ff, d_model), dtype)}
    a: Axes = {"w_in": ("embed", "mlp"), "w_out": ("mlp", "embed")}
    if glu:
        p["w_up"] = _dense_init(ks[2], (d_model, spec.d_ff), dtype)
        a["w_up"] = ("embed", "mlp")
    return p, a


def dense_ffn(p: Params, spec: FfnSpec, x: Array) -> Array:
    gate = x @ p["w_in"]
    gate = shard_act(gate, ("batch", "seq", "act_mlp"))
    up = x @ p["w_up"] if "w_up" in p else None
    h = _act(spec.activation, gate, up)
    return h @ p["w_out"]


def init_moe_ffn(key: Array, d_model: int, spec: FfnSpec, dtype,
                 ) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 7)
    e, f = spec.n_experts, spec.d_ff_expert
    p: Params = {
        "router": _dense_init(ks[0], (d_model, e), jnp.float32),
        "w_gate": _dense_init(ks[1], (e, d_model, f), dtype),
        "w_up": _dense_init(ks[2], (e, d_model, f), dtype),
        "w_down": _dense_init(ks[3], (e, f, d_model), dtype),
    }
    a: Axes = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    if spec.router == "sigmoid":
        p["router_bias"] = _zeros((e,), jnp.float32)
        a["router_bias"] = (None,)
    if spec.n_shared:
        fs = spec.n_shared * f
        p["ws_gate"] = _dense_init(ks[4], (d_model, fs), dtype)
        p["ws_up"] = _dense_init(ks[5], (d_model, fs), dtype)
        p["ws_down"] = _dense_init(ks[6], (fs, d_model), dtype)
        a["ws_gate"] = ("embed", "mlp")
        a["ws_up"] = ("embed", "mlp")
        a["ws_down"] = ("mlp", "embed")
    return p, a


def moe_ffn(p: Params, spec: FfnSpec, x: Array,
            ) -> Tuple[Array, Dict[str, Array]]:
    """Top-k MoE dispatcher. x: (B, S, D) -> (y, aux).

    Two executions:
      * sharded (production): when sharding rules with a "model" axis are
        active, dispatch runs under shard_map with an explicit
        all-to-all over the expert axis — the only formulation GSPMD
        maps efficiently at E=256 (the pure-scatter version degenerates
        into full-buffer all-reduces; see EXPERIMENTS.md §Perf).
      * local: single-device sort-based dispatch (tests, smoke configs).

    aux carries the load-balance loss (softmax router) or the per-expert
    token counts (sigmoid router — the train loop applies DeepSeek-V3's
    aux-free bias update with them).
    """
    from repro.models import sharding as sh_mod
    rules = sh_mod.current_rules()
    if rules is not None and "model" in rules.mesh.axis_names:
        return _moe_ffn_sharded(p, spec, x, rules)
    return _moe_ffn_local(p, spec, x)


def _moe_ffn_local(p: Params, spec: FfnSpec, x: Array,
                   ) -> Tuple[Array, Dict[str, Array]]:
    """Single-device sort-based top-k dispatch (the reference semantics)."""
    b, s, d = x.shape
    e, k = spec.n_experts, spec.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])  # (T, E)
    if spec.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel_scores = scores + p["router_bias"]  # bias only affects choice
        _, top_i = jax.lax.top_k(sel_scores, k)
        top_w = jnp.take_along_axis(scores, top_i, axis=1)
        top_w = top_w / (top_w.sum(axis=-1, keepdims=True) + 1e-20)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(scores, k)
        top_w = top_w / (top_w.sum(axis=-1, keepdims=True) + 1e-20)

    # ---- sort-based dispatch ------------------------------------------------
    # Small token counts (decode steps, smoke tests) get worst-case
    # capacity == t: exact dropless routing for the serving path. At
    # training scale the capacity-factor formula bounds the buffer.
    if t * k <= 4096:
        cap = t
    else:
        cap = max(1, int(math.ceil(t * k / e * spec.capacity_factor)))
    flat_e = top_i.reshape(-1)                      # (T*k,)
    order = jnp.argsort(flat_e)                     # stable
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_in_seg = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_seg < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_seg, e * cap)

    tok_idx = order // k                            # source token per slot
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(xt[tok_idx])
    buf = shard_act(buf[: e * cap].reshape(e, cap, d),
                    ("act_experts", None, None))

    # ---- expert computation (batched einsum; experts shard over model) -----
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(gate) * up
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    # ---- combine -----------------------------------------------------------------
    y_flat = y_e.reshape(e * cap, d)
    y_slots = jnp.where(keep[:, None],
                        y_flat[jnp.minimum(dest, e * cap - 1)], 0.0)
    w_slots = top_w.reshape(-1)[order][:, None].astype(y_slots.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok_idx].add(y_slots * w_slots)

    # ---- shared experts ---------------------------------------------------------
    if spec.n_shared:
        sh = jax.nn.silu(xt @ p["ws_gate"]) * (xt @ p["ws_up"])
        y = y + sh @ p["ws_down"]

    # ---- aux --------------------------------------------------------------------
    counts = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0)
    if spec.router == "sigmoid":
        aux = {"expert_counts": counts}
    else:
        # Switch-style load-balance loss.
        frac_tokens = counts / (t * k)
        frac_probs = scores.mean(axis=0)
        aux = {"lb_loss": e * jnp.sum(frac_tokens * frac_probs),
               "expert_counts": counts}
    return y.reshape(b, s, d), aux


def _route(logits: Array, spec: FfnSpec, router_bias: Optional[Array],
           ) -> Tuple[Array, Array, Array]:
    """(scores, top_w, top_i) for either router flavour."""
    if spec.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + (router_bias if router_bias is not None else 0.0)
        _, top_i = jax.lax.top_k(sel, spec.top_k)
        top_w = jnp.take_along_axis(scores, top_i, axis=1)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(scores, spec.top_k)
    top_w = top_w / (top_w.sum(axis=-1, keepdims=True) + 1e-20)
    return scores, top_w, top_i


def _moe_ffn_sharded(p: Params, spec: FfnSpec, x: Array, rules,
                     ) -> Tuple[Array, Dict[str, Array]]:
    """Expert-parallel MoE: shard_map + all-to-all over the "model" axis.

    Tokens are flattened to (T, d) and sharded over *all* mesh axes;
    experts are sharded over "model". Each device routes its local
    tokens, packs per-(source, expert) capacity buffers, all-to-alls
    them to the expert owners along "model", runs its local experts as
    one batched einsum, and all-to-alls results back. Wire cost per
    layer is O(T_local * k * cf * d) — independent of E — instead of the
    O(E * cap * d) full-buffer reductions GSPMD generates for scattered
    dispatch.
    """
    mesh = rules.mesh
    all_axes = tuple(mesh.axis_names)
    e, k = spec.n_experts, spec.top_k
    b, s, d = x.shape
    t = b * s
    n_dev = mesh.devices.size
    # Expert-parallel axes come from the rules table ("experts" entry):
    # ("model",) by default; ("model", "data") gives full EP (one expert
    # per chip at E == n_devices) with no FSDP gathers on expert weights
    # — §Perf iteration D4.
    exp_axes = tuple(a for a in (rules.table().get("experts") or ("model",))
                     if a in mesh.axis_names)
    m_size = 1
    for a in exp_axes:
        m_size *= mesh.shape[a]
    if e % m_size:  # fall back to the largest dividing prefix
        exp_axes = ("model",)
        m_size = mesh.shape["model"]
    e_local = e // m_size
    assert e % m_size == 0, (e, m_size)
    a2a_axis = exp_axes if len(exp_axes) > 1 else exp_axes[0]

    pad_t = -t % n_dev
    xt = x.reshape(t, d)
    if pad_t:
        xt = jnp.concatenate(
            [xt, jnp.zeros((pad_t, d), x.dtype)], axis=0)
    t_pad = t + pad_t
    t_local = t_pad // n_dev
    # Per-(source-device, expert) capacity.
    cap = max(1, int(math.ceil(t_local * k / e * spec.capacity_factor)))

    router_bias = p.get("router_bias")
    from jax.sharding import PartitionSpec as P

    def local_fn(xt_l, router, bias, wg, wu, wd):
        # xt_l: (t_local, d); wg/wu/wd: (e_local, ..., ...)
        logits = xt_l.astype(jnp.float32) @ router
        scores, top_w, top_i = _route(
            logits, spec, bias[0] if bias is not None else None)

        flat_e = top_i.reshape(-1)                      # (t_local*k,)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
        pos = jnp.arange(t_local * k) - seg_start[sorted_e]
        keep = pos < cap
        dest = jnp.where(keep, sorted_e * cap + pos, e * cap)
        tok = order // k

        buf = jnp.zeros((e * cap + 1, d), xt_l.dtype
                        ).at[dest].set(xt_l[tok])[:-1]
        # (e, cap, d) -> regroup by destination model-rank and exchange.
        buf = buf.reshape(m_size, e_local * cap, d)
        recv = jax.lax.all_to_all(buf, a2a_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        # recv: (m_size * e_local * cap, d) grouped as (src, e_local, cap).
        hbuf = recv.reshape(m_size, e_local, cap, d)
        hbuf = jnp.moveaxis(hbuf, 1, 0).reshape(e_local, m_size * cap, d)

        gate = jnp.einsum("ecd,edf->ecf", hbuf, wg)
        up = jnp.einsum("ecd,edf->ecf", hbuf, wu)
        h = jax.nn.silu(gate) * up
        y_e = jnp.einsum("ecf,efd->ecd", h, wd)

        # Route results back to their source devices.
        y_e = y_e.reshape(e_local, m_size, cap, d)
        y_e = jnp.moveaxis(y_e, 1, 0).reshape(m_size, e_local * cap, d)
        back = jax.lax.all_to_all(y_e, a2a_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        y_buf = back.reshape(e * cap, d)
        y_slots = jnp.where(keep[:, None],
                            y_buf[jnp.minimum(dest, e * cap - 1)], 0.0)
        w_slots = top_w.reshape(-1)[order][:, None].astype(y_slots.dtype)
        y_l = jnp.zeros((t_local, d), x.dtype).at[tok].add(
            y_slots * w_slots)

        counts_l = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0)
        counts = jax.lax.psum(counts_l, all_axes)
        probs_mean = jax.lax.pmean(scores.mean(axis=0), all_axes)
        return y_l, counts, probs_mean

    bias_in = (router_bias[None] if router_bias is not None
               else jnp.zeros((1, e), jnp.float32))
    y_flat, counts, probs_mean = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(all_axes, None), P(), P(), P(exp_axes),
                  P(exp_axes), P(exp_axes)),
        out_specs=(P(all_axes, None), P(), P()),
    )(xt, p["router"], bias_in, p["w_gate"], p["w_up"], p["w_down"])

    y = y_flat[:t].reshape(b, s, d)

    if spec.n_shared:
        xt2 = x.reshape(t, d)
        sh = jax.nn.silu(xt2 @ p["ws_gate"]) * (xt2 @ p["ws_up"])
        y = y + (sh @ p["ws_down"]).reshape(b, s, d)

    if spec.router == "sigmoid":
        aux = {"expert_counts": counts}
    else:
        frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
        aux = {"lb_loss": e * jnp.sum(frac_tokens * probs_mean),
               "expert_counts": counts}
    return y, aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------

def init_ssm(key: Array, d_model: int, spec: SsmSpec, dtype,
             ) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 5)
    d_in = spec.expand * d_model
    n_heads = d_in // spec.head_dim
    conv_dim = d_in + 2 * spec.n_groups * spec.d_state
    # in_proj emits [z (gate), x, B, C, dt].
    d_proj = 2 * d_in + 2 * spec.n_groups * spec.d_state + n_heads
    p: Params = {
        "w_in": _dense_init(ks[0], (d_model, d_proj), dtype),
        "conv_w": _dense_init(ks[1], (spec.conv_width, conv_dim), dtype),
        "conv_b": _zeros((conv_dim,), dtype),
        "a_log": _maybe_sds(
            lambda: jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
            (n_heads,), dtype),
        "d_skip": _maybe_sds(lambda: jnp.ones((n_heads,), dtype),
                             (n_heads,), dtype),
        "dt_bias": _maybe_sds(
            lambda: jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
                ks[2], (n_heads,),
                minval=math.log(spec.dt_min),
                maxval=math.log(spec.dt_max))))).astype(dtype),
            (n_heads,), dtype),
        "gate_norm": _zeros((d_in,), dtype),
        "w_out": _dense_init(ks[3], (d_in, d_model), dtype),
    }
    a: Axes = {
        "w_in": ("embed", "ssm_inner"),
        "conv_w": ("conv", "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "a_log": (None,),
        "d_skip": (None,),
        "dt_bias": (None,),
        "gate_norm": ("ssm_inner",),
        "w_out": ("ssm_inner", "embed"),
    }
    return p, a


def _ssm_split(p: Params, spec: SsmSpec, d_model: int, proj: Array):
    d_in = spec.expand * d_model
    gn = spec.n_groups * spec.d_state
    n_heads = d_in // spec.head_dim
    z = proj[..., :d_in]
    xbc = proj[..., d_in: d_in + d_in + 2 * gn]
    dt = proj[..., -n_heads:]
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv, width W. xbc: (B, S, C)."""
    width = w.shape[0]
    xp = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + xbc.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def ssd_forward(p: Params, spec: SsmSpec, d_model: int, x: Array) -> Array:
    """Chunked SSD (Mamba-2). x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    d_in = spec.expand * d_model
    n_heads = d_in // spec.head_dim
    g, n, ph = spec.n_groups, spec.d_state, spec.head_dim

    proj = x @ p["w_in"]
    z, xbc, dt = _ssm_split(p, spec, d_model, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in].reshape(b, s, n_heads, ph)
    bmat = xbc[..., d_in: d_in + g * n].reshape(b, s, g, n)
    cmat = xbc[..., d_in + g * n:].reshape(b, s, g, n)
    heads_per_g = n_heads // g
    bmat = jnp.repeat(bmat, heads_per_g, axis=2)  # (B,S,H,N)
    cmat = jnp.repeat(cmat, heads_per_g, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    da = dt * a  # (B,S,H) log-decay per step

    q = min(spec.chunk, s)
    n_chunks = -(-s // q)
    pad = n_chunks * q - s

    def pad_t(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    xs_c = pad_t(xs).reshape(b, n_chunks, q, n_heads, ph)
    b_c = pad_t(bmat).reshape(b, n_chunks, q, n_heads, n)
    c_c = pad_t(cmat).reshape(b, n_chunks, q, n_heads, n)
    dt_c = pad_t(dt).reshape(b, n_chunks, q, n_heads)
    da_c = pad_t(da).reshape(b, n_chunks, q, n_heads)

    # ONE fused scan over chunks: intra-chunk attention, inter-chunk
    # state carry, and output — the (Q, Q) decay matrix exists for a
    # single chunk at a time (materializing it for all chunks at once is
    # O(S*Q) memory and was the dominant HBM term in the first dry-run
    # baseline; see EXPERIMENTS.md §Perf). State-path math stays float32
    # (long decay products underflow bf16). The body is remat'd so the
    # backward pass re-derives each chunk's decay instead of storing it.
    mask = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]

    def chunk_body(s_prev, inputs):
        # s_prev: (B,H,N,P) f32 state entering this chunk.
        xs_k, b_k, c_k, dt_k, da_k = inputs  # (B,Q,H,*) per-chunk slices
        cum = jnp.cumsum(da_k, axis=1)       # (B,Q,H)
        seg_total = cum[:, -1]               # (B,H)
        xdt = xs_k.astype(jnp.float32) * dt_k[..., None]
        b32 = b_k.astype(jnp.float32)
        c32 = c_k.astype(jnp.float32)

        # Intra-chunk: L[i,j] = exp(cum_i - cum_j), i >= j.
        decay = jnp.where(
            mask, jnp.exp(cum[:, :, None, :] - cum[:, None, :, :]), 0.0)
        cb = jnp.einsum("bqhn,bkhn->bqkh", c32, b32)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", cb * decay, xdt)

        # Inter-chunk: contribution of the carried state.
        in_decay = jnp.exp(cum)  # (B,Q,H)
        y_inter = jnp.einsum("bqhn,bhnp->bqhp",
                             c32 * in_decay[..., None], s_prev)

        # Next state: S' = exp(seg_total) * S + sum_j exp(total-cum_j) B_j xdt_j^T
        state_decay = jnp.exp(seg_total[:, None, :] - cum)  # (B,Q,H)
        bx = jnp.einsum("bqhn,bqhp->bhnp",
                        b32 * state_decay[..., None], xdt)
        s_new = s_prev * jnp.exp(seg_total)[..., None, None] + bx
        return s_new, (y_intra + y_inter).astype(xs.dtype)

    def to_scan(t):  # (B,Cn,Q,...) -> (Cn,B,Q,...)
        return jnp.moveaxis(t, 1, 0)

    s0 = jnp.zeros((b, n_heads, n, ph), jnp.float32)
    _, y_chunks = jax.lax.scan(
        jax.checkpoint(chunk_body),
        s0, (to_scan(xs_c), to_scan(b_c), to_scan(c_c), to_scan(dt_c),
             to_scan(da_c)))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(
        b, n_chunks * q, n_heads, ph)[:, :s]
    y = y.astype(xs.dtype) + xs * p["d_skip"].astype(
        xs.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    return (y @ p["w_out"]).astype(x.dtype)


def ssd_decode(p: Params, spec: SsmSpec, d_model: int, x: Array,
               cache: Dict[str, Array]) -> Tuple[Array, Dict[str, Array]]:
    """O(1) per-token SSD decode. x: (B, 1, D).

    cache: {"state": (B,H,N,P), "conv": (B,W-1,convdim), "len": (B,)}.
    """
    b = x.shape[0]
    d_in = spec.expand * d_model
    n_heads = d_in // spec.head_dim
    g, n, ph = spec.n_groups, spec.d_state, spec.head_dim

    proj = x @ p["w_in"]  # (B,1,dproj)
    z, xbc, dt = _ssm_split(p, spec, d_model, proj)
    # Causal conv against the rolling window.
    width = p["conv_w"].shape[0]
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,W,conv)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    new_conv = window[:, 1:]

    xs = xbc1[..., :d_in].reshape(b, n_heads, ph)
    bmat = xbc1[..., d_in: d_in + g * n].reshape(b, g, n)
    cmat = xbc1[..., d_in + g * n:].reshape(b, g, n)
    heads_per_g = n_heads // g
    bmat = jnp.repeat(bmat, heads_per_g, axis=1)  # (B,H,N)
    cmat = jnp.repeat(cmat, heads_per_g, axis=1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    gate = jnp.exp(dt1 * a)  # (B,H)

    state32 = (cache["state"].astype(jnp.float32)
               * gate[..., None, None]
               + jnp.einsum("bhn,bhp->bhnp", bmat.astype(jnp.float32),
                            xs.astype(jnp.float32) * dt1[..., None]))
    state = state32.astype(cache["state"].dtype)
    y = jnp.einsum("bhn,bhnp->bhp", cmat.astype(jnp.float32), state32)
    y = y.astype(xs.dtype) + xs * p["d_skip"].astype(xs.dtype)[None, :, None]
    y = y.reshape(b, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    return (y @ p["w_out"]).astype(x.dtype), {
        "state": state, "conv": new_conv, "len": cache["len"] + 1}


def init_ssm_cache(spec: SsmSpec, d_model: int, batch: int, dtype,
                   ) -> Dict[str, Array]:
    d_in = spec.expand * d_model
    n_heads = d_in // spec.head_dim
    conv_dim = d_in + 2 * spec.n_groups * spec.d_state
    return {
        "state": jnp.zeros((batch, n_heads, spec.d_state, spec.head_dim),
                           dtype),
        "conv": jnp.zeros((batch, spec.conv_width - 1, conv_dim), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
