"""InternVL2-2B [arXiv:2404.16821].

InternLM2-1.8B language backbone: 24L, d_model=2048, 16 heads GQA kv=8
(head_dim=128), d_ff=8192 SwiGLU, vocab=92553 (tied). The InternViT
vision tower is a STUB per the assignment: ``input_specs`` provides
precomputed patch features (B, 256, 1024) which a learned projection
maps into the token stream ahead of the text.
"""
from repro.models.config import AttnSpec, BlockSpec, FfnSpec, ModelConfig

_ATTN = AttnSpec(kind="gqa", n_heads=16, n_kv_heads=8, head_dim=128,
                 rope_theta=1_000_000.0)
_FFN = FfnSpec(kind="dense", d_ff=8_192, activation="silu_glu")


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        d_model=2_048,
        vocab_size=92_553,
        blocks=(BlockSpec(repeat=24, mixer="attn", attn=_ATTN, ffn=_FFN),),
        frontend="vision_patches",
        n_patches=256,
        tie_embeddings=True,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-smoke",
        d_model=128,
        vocab_size=512,
        blocks=(BlockSpec(
            repeat=2, mixer="attn",
            attn=AttnSpec(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=32),
            ffn=FfnSpec(kind="dense", d_ff=256, activation="silu_glu")),),
        frontend="vision_patches",
        n_patches=16,
        tie_embeddings=True,
        remat=False,
    )
