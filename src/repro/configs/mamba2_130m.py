"""Mamba2-130M [arXiv:2405.21060].

Attention-free SSD (state-space duality) stack: 24L, d_model=768,
d_inner=1536 (expand 2, 24 SSD heads of P=64), d_state N=128, 1 B/C
group, conv width 4, vocab=50280, tied embeddings.
"""
from repro.models.config import BlockSpec, FfnSpec, ModelConfig, SsmSpec

_SSM = SsmSpec(d_state=128, head_dim=64, expand=2, n_groups=1,
               conv_width=4, chunk=256)


def config() -> ModelConfig:
    # Mamba blocks have no separate FFN: the SSM mixer is the layer.
    # d_ff=0 in the assignment table; we honour it with a pass-through
    # dense FFN of zero cost? No — mamba literally has no FFN, so the
    # block uses mixer-only layout: the FfnSpec below is never applied
    # (see transformer._layer_forward: mamba arch uses ffn d_ff == 0
    # marker -> identity). Cleanest encoding: two SSD mixers per "layer
    # pair" is NOT mamba2; instead mark kind="dense", d_ff=0.
    ffn = FfnSpec(kind="dense", d_ff=0, activation="silu_glu")
    return ModelConfig(
        name="mamba2-130m",
        d_model=768,
        vocab_size=50_280,
        blocks=(BlockSpec(repeat=24, mixer="ssm", ssm=_SSM, ffn=ffn),),
        tie_embeddings=True,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    ssm = SsmSpec(d_state=32, head_dim=16, expand=2, n_groups=1,
                  conv_width=4, chunk=32)
    return ModelConfig(
        name="mamba2-130m-smoke",
        d_model=64,
        vocab_size=512,
        blocks=(BlockSpec(repeat=2, mixer="ssm", ssm=ssm,
                          ffn=FfnSpec(kind="dense", d_ff=0,
                                      activation="silu_glu")),),
        tie_embeddings=True,
        remat=False,
    )
