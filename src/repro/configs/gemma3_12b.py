"""Gemma-3-12B [hf:google/gemma-3-12b family].

Dense decoder with the 5:1 local:global attention pattern: 48 layers as
8 repetitions of [5x sliding-window-1024 local + 1x global]; GQA 16H/8KV
head_dim=256 (d_model=3840), d_ff=15360 GeGLU, vocab=262144 (tied),
rope_theta 10k local / 1M global, 128k context.
"""
from repro.models.config import AttnSpec, BlockSpec, FfnSpec, ModelConfig

_LOCAL = AttnSpec(kind="gqa", n_heads=16, n_kv_heads=8, head_dim=256,
                  rope_theta=10_000.0, window=1024)
_GLOBAL = AttnSpec(kind="gqa", n_heads=16, n_kv_heads=8, head_dim=256,
                   rope_theta=1_000_000.0)
_FFN = FfnSpec(kind="dense", d_ff=15_360, activation="gelu_glu")


def config() -> ModelConfig:
    pattern = []
    for _ in range(8):  # 8 x (5 local + 1 global) = 48 layers
        pattern.append(BlockSpec(repeat=5, mixer="attn", attn=_LOCAL,
                                 ffn=_FFN))
        pattern.append(BlockSpec(repeat=1, mixer="attn", attn=_GLOBAL,
                                 ffn=_FFN))
    return ModelConfig(
        name="gemma3-12b",
        d_model=3_840,
        vocab_size=262_144,
        blocks=tuple(pattern),
        tie_embeddings=True,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    local = AttnSpec(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=32,
                     rope_theta=10_000.0, window=64)
    glob = AttnSpec(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=32,
                    rope_theta=1_000_000.0)
    ffn = FfnSpec(kind="dense", d_ff=256, activation="gelu_glu")
    return ModelConfig(
        name="gemma3-12b-smoke",
        d_model=128,
        vocab_size=512,
        blocks=(
            BlockSpec(repeat=2, mixer="attn", attn=local, ffn=ffn),
            BlockSpec(repeat=1, mixer="attn", attn=glob, ffn=ffn),
        ),
        tie_embeddings=True,
        remat=False,
    )
