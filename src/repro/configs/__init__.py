from repro.configs.registry import (  # noqa: F401
    ARCHS, SHAPES, get_config, get_smoke_config, list_archs, shape_spec,
    cells, cell_applicable,
)
