"""Architecture & input-shape registry — the 40 dry-run cells.

Each architecture module registers a full config (the exact published
numbers) and a reduced smoke config (same family, CPU-runnable). Shapes
are the four assigned input geometries; ``cell_applicable`` encodes the
skip rules (long_500k only for sub-quadratic stacks — see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

from repro.models.config import ModelConfig

ARCHS: Tuple[str, ...] = (
    "hymba-1.5b",
    "qwen1.5-32b",
    "nemotron-4-340b",
    "gemma3-12b",
    "granite-20b",
    "musicgen-medium",
    "deepseek-v2-lite-16b",
    "deepseek-v3-671b",
    "internvl2-2b",
    "mamba2-130m",
)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "train"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs whose stack is sub-quadratic enough for the 500k-decode cell:
# SSM, hybrid, and the 5:1-local gemma3 (8/48 global layers hold the long
# KV; every decode step is linear in S). Pure full-attention stacks skip.
_SUBQUADRATIC = {"mamba2-130m", "hymba-1.5b", "gemma3-12b"}


def cell_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in _SUBQUADRATIC
    return True


def cells(include_skipped: bool = False):
    """Yield (arch, shape) cells; skipped ones only if requested."""
    for arch in ARCHS:
        for shape in SHAPES:
            if include_skipped or cell_applicable(arch, shape):
                yield arch, shape


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, **overrides) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    cfg = _module(name).config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    cfg = _module(name).smoke_config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def shape_spec(name: str) -> ShapeSpec:
    return SHAPES[name]


def list_archs() -> Tuple[str, ...]:
    return ARCHS
