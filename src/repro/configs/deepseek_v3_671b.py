"""DeepSeek-V3 (671B total / 37B active) [arXiv:2412.19437].

MLA (q_lora=1536, kv_lora=512, qk 128 nope + 64 rope, v=128), 61 layers,
d_model=7168, 128 heads. First 3 layers dense (d_ff=18432); 58 MoE layers
with 256 routed experts (top-8, sigmoid router + aux-free bias balancing)
+ 1 shared expert, expert d_ff=2048. vocab=129280. One-depth MTP head.
"""
from repro.models.config import AttnSpec, BlockSpec, FfnSpec, ModelConfig

_MLA = AttnSpec(kind="mla", n_heads=128, head_dim=192, q_lora_rank=1_536,
                kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                v_head_dim=128, rope_theta=10_000.0, n_kv_heads=128)
_DENSE = FfnSpec(kind="dense", d_ff=18_432, activation="silu_glu")
_MOE = FfnSpec(kind="moe", d_ff=18_432, activation="silu_glu",
               n_experts=256, n_shared=1, top_k=8, d_ff_expert=2_048,
               capacity_factor=1.25, router="sigmoid")


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        d_model=7_168,
        vocab_size=129_280,
        blocks=(
            BlockSpec(repeat=3, mixer="attn", attn=_MLA, ffn=_DENSE),
            BlockSpec(repeat=58, mixer="attn", attn=_MLA, ffn=_MOE),
        ),
        tie_embeddings=False,
        mtp_depth=1,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    mla = AttnSpec(kind="mla", n_heads=4, head_dim=48, q_lora_rank=48,
                   kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
                   v_head_dim=32, n_kv_heads=4)
    dense = FfnSpec(kind="dense", d_ff=256, activation="silu_glu")
    moe = FfnSpec(kind="moe", d_ff=256, activation="silu_glu",
                  n_experts=8, n_shared=1, top_k=2, d_ff_expert=64,
                  router="sigmoid")
    return ModelConfig(
        name="deepseek-v3-smoke",
        d_model=128,
        vocab_size=512,
        blocks=(
            BlockSpec(repeat=1, mixer="attn", attn=mla, ffn=dense),
            BlockSpec(repeat=2, mixer="attn", attn=mla, ffn=moe),
        ),
        tie_embeddings=False,
        mtp_depth=1,
        remat=False,
    )
