"""MusicGen-medium [arXiv:2306.05284].

Decoder-only over EnCodec tokens: 48L, d_model=1536, 24 heads MHA
(head_dim=64), d_ff=6144 (non-gated GELU, fairseq lineage), vocab=2048
per codebook with 4 codebooks (delay pattern), cross-attention to text
conditioning every layer. The EnCodec/T5 frontends are STUBS per the
assignment: ``input_specs`` provides precomputed frame embeddings
(B, S, d_model) and conditioning embeddings (B, 64, d_model).
"""
from repro.models.config import AttnSpec, BlockSpec, FfnSpec, ModelConfig

_ATTN = AttnSpec(kind="gqa", n_heads=24, n_kv_heads=24, head_dim=64,
                 rope_theta=10_000.0)
_FFN = FfnSpec(kind="dense", d_ff=6_144, activation="gelu")


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        d_model=1_536,
        vocab_size=2_048,
        blocks=(BlockSpec(repeat=48, mixer="attn", attn=_ATTN, ffn=_FFN,
                          cross_attn=True),),
        frontend="audio_frames",
        n_codebooks=4,
        n_cond_tokens=64,
        tie_embeddings=False,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        d_model=96,
        vocab_size=256,
        blocks=(BlockSpec(
            repeat=2, mixer="attn",
            attn=AttnSpec(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=24),
            ffn=FfnSpec(kind="dense", d_ff=256, activation="gelu"),
            cross_attn=True),),
        frontend="audio_frames",
        n_codebooks=4,
        n_cond_tokens=8,
        tie_embeddings=False,
        remat=False,
    )
