"""Hymba-1.5B [arXiv:2411.13676].

Hybrid-head decoder: every layer runs attention and a Mamba(-2 style) SSM
head *in parallel* on the same input and fuses (mean) their outputs.
32L, d_model=1600, 25 heads GQA kv=5 (head_dim=64), d_ff=5504 (SwiGLU),
vocab=32001, ssm_state=16. Sliding-window 1024 attention everywhere
except three full-attention layers (first / middle / last) — Hymba's
published global-layer placement.
"""
from repro.models.config import (
    AttnSpec, BlockSpec, FfnSpec, ModelConfig, SsmSpec,
)

_SWA = AttnSpec(kind="gqa", n_heads=25, n_kv_heads=5, head_dim=64,
                rope_theta=10_000.0, window=1024)
_GLOBAL = AttnSpec(kind="gqa", n_heads=25, n_kv_heads=5, head_dim=64,
                   rope_theta=10_000.0)
_SSM = SsmSpec(d_state=16, head_dim=64, expand=2, n_groups=1,
               conv_width=4, chunk=256)
_FFN = FfnSpec(kind="dense", d_ff=5_504, activation="silu_glu")


def _block(repeat: int, attn: AttnSpec) -> BlockSpec:
    return BlockSpec(repeat=repeat, mixer="hybrid", attn=attn, ssm=_SSM,
                     ffn=_FFN)


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        d_model=1_600,
        vocab_size=32_001,
        blocks=(
            _block(1, _GLOBAL),   # layer 0
            _block(14, _SWA),
            _block(1, _GLOBAL),   # middle
            _block(15, _SWA),
            _block(1, _GLOBAL),   # last
        ),
        tie_embeddings=True,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    swa = AttnSpec(kind="gqa", n_heads=5, n_kv_heads=1, head_dim=16,
                   window=32)
    glob = AttnSpec(kind="gqa", n_heads=5, n_kv_heads=1, head_dim=16)
    ssm = SsmSpec(d_state=16, head_dim=16, expand=2, n_groups=1,
                  conv_width=4, chunk=32)
    ffn = FfnSpec(kind="dense", d_ff=160, activation="silu_glu")
    return ModelConfig(
        name="hymba-1.5b-smoke",
        d_model=80,
        vocab_size=512,
        blocks=(
            BlockSpec(repeat=1, mixer="hybrid", attn=glob, ssm=ssm, ffn=ffn),
            BlockSpec(repeat=2, mixer="hybrid", attn=swa, ssm=ssm, ffn=ffn),
        ),
        tie_embeddings=True,
        remat=False,
    )
