"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

MLA attention (kv_lora_rank=512, no q-lora at Lite scale, qk 128 nope +
64 rope, v=128) over 27 layers, d_model=2048, 16 heads. FFN: layer 0 is
dense (d_ff=10944); layers 1..26 are MoE with 64 routed experts (top-6)
+ 2 shared, expert d_ff=1408, softmax router with load-balance loss.
vocab=102400.
"""
from repro.models.config import AttnSpec, BlockSpec, FfnSpec, ModelConfig

_MLA = AttnSpec(kind="mla", n_heads=16, head_dim=192, q_lora_rank=None,
                kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                v_head_dim=128, rope_theta=10_000.0, n_kv_heads=16)
_DENSE = FfnSpec(kind="dense", d_ff=10_944, activation="silu_glu")
_MOE = FfnSpec(kind="moe", d_ff=10_944, activation="silu_glu",
               n_experts=64, n_shared=2, top_k=6, d_ff_expert=1_408,
               capacity_factor=1.25, router="softmax")


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        d_model=2_048,
        vocab_size=102_400,
        blocks=(
            BlockSpec(repeat=1, mixer="attn", attn=_MLA, ffn=_DENSE),
            BlockSpec(repeat=26, mixer="attn", attn=_MLA, ffn=_MOE),
        ),
        tie_embeddings=False,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    mla = AttnSpec(kind="mla", n_heads=4, head_dim=48, q_lora_rank=None,
                   kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
                   v_head_dim=32, n_kv_heads=4)
    dense = FfnSpec(kind="dense", d_ff=256, activation="silu_glu")
    moe = FfnSpec(kind="moe", d_ff=256, activation="silu_glu",
                  n_experts=8, n_shared=2, top_k=2, d_ff_expert=64,
                  router="softmax")
    return ModelConfig(
        name="deepseek-v2-lite-smoke",
        d_model=128,
        vocab_size=512,
        blocks=(
            BlockSpec(repeat=1, mixer="attn", attn=mla, ffn=dense),
            BlockSpec(repeat=2, mixer="attn", attn=mla, ffn=moe),
        ),
        tie_embeddings=False,
        remat=False,
    )
