"""Qwen1.5-32B [hf:Qwen/Qwen1.5-32B family].

Dense llama-style decoder with QKV bias (the Qwen signature): 64L,
d_model=5120, 40 heads (MHA: kv=40, head_dim=128), d_ff=27392 (SwiGLU),
vocab=152064. Untied embeddings at this scale.
"""
from repro.models.config import AttnSpec, BlockSpec, FfnSpec, ModelConfig

_ATTN = AttnSpec(kind="gqa", n_heads=40, n_kv_heads=40, head_dim=128,
                 qkv_bias=True, rope_theta=1_000_000.0)
_FFN = FfnSpec(kind="dense", d_ff=27_392, activation="silu_glu")


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        d_model=5_120,
        vocab_size=152_064,
        blocks=(BlockSpec(repeat=64, mixer="attn", attn=_ATTN, ffn=_FFN),),
        tie_embeddings=False,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-smoke",
        d_model=128,
        vocab_size=512,
        blocks=(BlockSpec(
            repeat=2, mixer="attn",
            attn=AttnSpec(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=32,
                          qkv_bias=True, rope_theta=1_000_000.0),
            ffn=FfnSpec(kind="dense", d_ff=384, activation="silu_glu")),),
        tie_embeddings=False,
        remat=False,
    )
