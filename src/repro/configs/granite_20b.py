"""Granite-20B (code) [arXiv:2405.04324].

Decoder with extreme KV sharing: 52L, d_model=6144, 48 heads with a
single KV head (MQA, kv=1, head_dim=128), d_ff=24576 (4x, non-gated GELU
— the GPT-BigCode lineage of the Granite code models), vocab=49152.
"""
from repro.models.config import AttnSpec, BlockSpec, FfnSpec, ModelConfig

_ATTN = AttnSpec(kind="gqa", n_heads=48, n_kv_heads=1, head_dim=128,
                 rope_theta=10_000.0)
_FFN = FfnSpec(kind="dense", d_ff=24_576, activation="gelu")


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        d_model=6_144,
        vocab_size=49_152,
        blocks=(BlockSpec(repeat=52, mixer="attn", attn=_ATTN, ffn=_FFN),),
        tie_embeddings=True,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke",
        d_model=128,
        vocab_size=512,
        blocks=(BlockSpec(
            repeat=2, mixer="attn",
            attn=AttnSpec(kind="gqa", n_heads=4, n_kv_heads=1, head_dim=32),
            ffn=FfnSpec(kind="dense", d_ff=512, activation="gelu")),),
        tie_embeddings=True,
        remat=False,
    )
