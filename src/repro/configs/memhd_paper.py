"""The paper's own MEMHD operating points, as named configs.

These are the geometries the paper evaluates (Figs. 3–7, Table II):
square DxC grids for MNIST/FMNIST, fixed 128 columns for ISOLET, and
the flagship deployment points used in Table II / Fig. 7.

    from repro.configs.memhd_paper import paper_config
    enc_cfg, am_cfg = paper_config("mnist", "128x128")
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.types import EncoderConfig, MemhdConfig, dataset_spec

# Geometry grids straight from the paper's figures.
GRIDS: Dict[str, Tuple[str, ...]] = {
    "mnist": ("64x64", "128x128", "256x256", "512x512", "1024x1024"),
    "fmnist": ("64x64", "128x128", "256x256", "512x512", "1024x1024"),
    "isolet": ("128x128", "256x128", "512x128", "1024x128"),
}

# Table II / Fig. 7 flagship deployment points.
FLAGSHIP = {
    "mnist": "128x128",
    "fmnist": "128x128",
    "isolet": "512x128",
}

# Fig.-6 guidance: R ≈ 0.8–0.9 for tight column budgets; 1.0 for ISOLET.
DEFAULT_R = {"mnist": 0.8, "fmnist": 0.8, "isolet": 1.0}
# §III-C: lower lr for harder datasets / smaller D.
DEFAULT_LR = {"mnist": 0.02, "fmnist": 0.02, "isolet": 0.015}


def paper_config(dataset: str, geometry: str | None = None,
                 **overrides) -> Tuple[EncoderConfig, MemhdConfig]:
    """(EncoderConfig, MemhdConfig) for a paper operating point."""
    spec = dataset_spec(dataset)
    geometry = geometry or FLAGSHIP[dataset]
    if geometry not in GRIDS[dataset]:
        raise KeyError(
            f"{geometry!r} not a paper geometry for {dataset}: "
            f"{GRIDS[dataset]}")
    d, c = (int(x) for x in geometry.split("x"))
    enc = EncoderConfig(kind="projection", features=spec.features, dim=d)
    am_kwargs = dict(
        dim=d, columns=c, classes=spec.classes,
        init_ratio=DEFAULT_R[dataset], lr=DEFAULT_LR[dataset],
        epochs=100,  # paper: "trained for 100 epochs following init"
    )
    am_kwargs.update(overrides)
    return enc, MemhdConfig(**am_kwargs)


def list_paper_points():
    for ds, grid in GRIDS.items():
        for g in grid:
            yield ds, g
