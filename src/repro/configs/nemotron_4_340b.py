"""Nemotron-4-340B [arXiv:2402.16819].

Dense decoder: 96L, d_model=18432, 96 heads GQA kv=8 (head_dim=192),
d_ff=73728 with squared-ReLU (no gating), vocab=256000, untied.
"""
from repro.models.config import AttnSpec, BlockSpec, FfnSpec, ModelConfig

_ATTN = AttnSpec(kind="gqa", n_heads=96, n_kv_heads=8, head_dim=192,
                 rope_theta=10_000.0)
_FFN = FfnSpec(kind="dense", d_ff=73_728, activation="squared_relu")


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        d_model=18_432,
        vocab_size=256_000,
        blocks=(BlockSpec(repeat=96, mixer="attn", attn=_ATTN, ffn=_FFN),),
        tie_embeddings=False,
        param_dtype="bfloat16",
        activation_dtype="bfloat16",
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b-smoke",
        d_model=192,
        vocab_size=512,
        blocks=(BlockSpec(
            repeat=2, mixer="attn",
            attn=AttnSpec(kind="gqa", n_heads=6, n_kv_heads=2, head_dim=32),
            ffn=FfnSpec(kind="dense", d_ff=768,
                        activation="squared_relu")),),
        tie_embeddings=False,
        remat=False,
    )
