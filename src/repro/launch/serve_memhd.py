"""Batched MEMHD serving driver: the packed-AM classification workload.

``launch/serve.py`` serves LM decode; this driver serves the paper's
actual deployment scenario — a stream of classification requests of raw
feature rows against the resident AM of ANY registered deployment
backend (``--target packed | unpacked | imc``). Requests of ragged
sizes are greedily packed into batches (a request never splits), each
batch is zero-padded up to the next tile multiple so every launch hits
the same compiled kernel shapes, and batches are served through a
double-buffered pipeline: the host prepares/pads batch k+1 while batch
k is in flight on the device (``--depth`` controls how many batches may
be in flight; 1 recovers the fully synchronous loop).

``--devices N`` shards every batch over a data-parallel mesh of the
first N local devices (``repro.deploy.ShardedArtifact``: AM replicated,
batch rows sharded) — bit-exact with single-device serving. ``--fused``
serves each batch through ``predict_features`` — the single-dispatch
chain of the fused encode/sign/bitpack kernel into the packed search
(no float hypervector in HBM); the default serves the staged
encode -> binarize -> pack -> search path. Predictions are bit-exact
between the two modes.

The report mirrors serve.py's JSON contract: wall time, per-batch
latency percentiles, queries/s, per-device throughput, plus the
backend label and residence accounting of the served artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_memhd --smoke --fused \
      --requests 64 --max-batch 256
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve_memhd --smoke --devices 8
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import math
import time
from collections import deque
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

# Shared tile-padding helpers (re-exported here for existing callers).
from repro.deploy.padding import pad_to_multiple, round_up  # noqa: F401

log = logging.getLogger("serve_memhd")

TILE_B = 8  # batch padding granularity (float32 sublane tile)


@dataclasses.dataclass(frozen=True)
class Request:
    """One classification request: a block of feature rows."""

    rid: int
    feats: np.ndarray  # (n, f)

    @property
    def size(self) -> int:
        return self.feats.shape[0]


def make_batches(requests: Sequence[Request], max_batch: int,
                 ) -> List[List[Request]]:
    """Greedy first-fit batching: fill up to ``max_batch`` rows per batch.

    Requests are taken in arrival order and never split; a request larger
    than ``max_batch`` gets a batch of its own (it still pads to a tile
    multiple, it just can't share).
    """
    batches: List[List[Request]] = []
    cur: List[Request] = []
    cur_rows = 0
    for req in requests:
        if cur and cur_rows + req.size > max_batch:
            batches.append(cur)
            cur, cur_rows = [], 0
        cur.append(req)
        cur_rows += req.size
    if cur:
        batches.append(cur)
    return batches


def serve_batches(deployed, requests: Sequence[Request],
                  max_batch: int = 256, tile: int = TILE_B,
                  warmup: bool = True, fused: bool = False,
                  depth: int = 1, topk: int = 0,
                  ) -> Tuple[Dict[int, np.ndarray], Dict]:
    """Run the request stream through the deployed model.

    ``warmup=True`` pre-compiles every distinct padded batch shape the
    stream will hit (tile padding keeps that set small) so the reported
    latencies measure serving, not jit compilation. ``fused=True``
    serves each batch through ``predict_features`` (the single-dispatch
    fused pipeline) instead of the staged ``predict``; predictions are
    bit-exact between the two.

    ``depth`` is the double-buffer depth: up to ``depth`` batches may be
    in flight on the device while the host concatenates and pads the
    next one (jax dispatch is async; the host only blocks when the
    pipeline is full). The default ``depth=1`` is the synchronous loop,
    and its ``lat_ms_*`` stats are pure per-batch service latency —
    comparable across releases. With ``depth > 1`` latency is measured
    dispatch -> result ready and so INCLUDES pipeline queue wait; the
    ``depth`` stat field tags every report with which semantics apply.

    ``topk >= 1`` serves through the backend's ``predict_topk`` — the
    fused streaming top-k kernel epilogue — and each response row widens
    to the request's k best classes.

    Returns (responses, stats): responses maps rid -> (n,) predicted
    classes ((n, topk) when ``topk >= 1``); stats holds per-batch
    latencies and padding accounting.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    if topk and fused:
        raise ValueError("topk serving and the fused feature pipeline "
                         "are mutually exclusive")
    # Sharded artifacts need every batch to split evenly across devices.
    tile = math.lcm(tile, getattr(deployed, "row_multiple", 1))
    if topk:
        # (B, k) classes out of the streaming top-k epilogue; the ids
        # and sims of the triple stay available via predict_topk itself.
        predict = lambda x: deployed.predict_topk(x, topk)[0]  # noqa: E731
    else:
        predict = (deployed.predict_features if fused
                   else deployed.predict)
    batches = make_batches(requests, max_batch)
    if warmup:
        n_feats = requests[0].feats.shape[1] if requests else 0
        shapes = {round_up(sum(r.size for r in b), tile) for b in batches}
        for rows in sorted(shapes):
            jax.block_until_ready(predict(
                np.zeros((rows, n_feats), np.float32)))
    responses: Dict[int, np.ndarray] = {}
    lat_ms: List[float] = []
    rows_real = rows_padded = 0
    inflight: deque = deque()  # (batch, n_valid, pending result, t0)

    def _drain_one():
        batch, n_valid, fut, t0 = inflight.popleft()
        jax.block_until_ready(fut)
        lat_ms.append((time.perf_counter() - t0) * 1e3)
        pred = np.asarray(fut)[:n_valid]
        ofs = 0
        for r in batch:
            responses[r.rid] = pred[ofs:ofs + r.size]
            ofs += r.size

    for batch in batches:
        # Host-side prep of batch k+1 overlaps device work on batch k.
        feats = np.concatenate([r.feats for r in batch])
        padded, n_valid = pad_to_multiple(feats, tile)
        rows_real += n_valid
        rows_padded += padded.shape[0]
        t0 = time.perf_counter()
        inflight.append((batch, n_valid, predict(padded), t0))
        while len(inflight) >= depth:
            _drain_one()
    while inflight:
        _drain_one()
    lat = np.asarray(lat_ms) if lat_ms else np.zeros((1,))
    stats = {
        "depth": depth,
        "batches": len(batches),
        "rows_real": rows_real,
        "rows_padded": rows_padded,
        "pad_overhead": (round(rows_padded / rows_real - 1, 3)
                         if rows_real else 0.0),
        "lat_ms_min": round(float(lat.min()), 2),
        "lat_ms_p50": round(float(np.percentile(lat, 50)), 2),
        "lat_ms_p95": round(float(np.percentile(lat, 95)), 2),
        "lat_ms_p99": round(float(np.percentile(lat, 99)), 2),
        "lat_ms_total": round(float(lat.sum()), 2),
    }
    return responses, stats


def synthetic_requests(feats: np.ndarray, n_requests: int,
                       max_size: int, seed: int = 0) -> List[Request]:
    """Ragged request stream sampled from a feature pool."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_requests):
        n = int(rng.integers(1, max_size + 1))
        rows = rng.integers(0, feats.shape[0], size=n)
        reqs.append(Request(rid=rid, feats=feats[rows]))
    return reqs


def build_report(deployed, requests: Sequence[Request], stats: Dict,
                 wall_s: float, fused: bool = False, topk: int = 0,
                 ) -> Dict:
    """Assemble the serving JSON report — the driver's output contract.

    Key set and value types are stable (asserted in
    tests/test_serving.py); downstream dashboards parse this. Works for
    any ``DeployedArtifact`` backend (and its sharded wrapper): the
    ``backend`` / ``devices`` fields make reports from different
    substrates and device counts comparable.
    """
    n_rows = sum(r.size for r in requests)
    devices = int(getattr(deployed, "n_devices", 1))
    rows_per_s = round(n_rows / wall_s, 1) if wall_s else 0.0
    return {
        "workload": "memhd_classify",
        "backend": deployed.backend,
        "devices": devices,
        "packed": bool(getattr(deployed, "packed", False)),
        "mode": deployed.serving_mode,
        "pipeline": "fused" if fused else "staged",
        "topk": int(topk),  # 0 = argmax serving; k >= 1 = top-k epilogue
        "geometry": f"{deployed.am_cfg.dim}x{deployed.am_cfg.columns}",
        "requests": len(requests),
        "rows": n_rows,
        "wall_s": round(wall_s, 3),
        "qps": round(len(requests) / wall_s, 1) if wall_s else 0.0,
        "rows_per_s": rows_per_s,
        "rows_per_s_per_device": round(rows_per_s / devices, 1),
        "resident_am_bytes": deployed.resident_am_bytes,
        "am_memory_ratio": round(deployed.am_memory_ratio, 2),
        **stats,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny training budget (CI-sized)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-size", type=int, default=32,
                    help="max rows per request")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--target", default=None,
                    choices=["packed", "unpacked", "imc", "hierarchical"],
                    help="deployment backend (registry target)")
    ap.add_argument("--mode", default="popcount",
                    choices=["popcount", "unpack"])
    ap.add_argument("--topk", type=int, default=0,
                    help="serve k candidates per row through the fused "
                         "streaming top-k epilogue (hierarchical "
                         "backend); 0 = argmax serving")
    ap.add_argument("--groups", type=int, default=None,
                    help="hierarchical: G super-centroids "
                         "(default ~sqrt(C))")
    ap.add_argument("--shortlist", type=int, default=None,
                    help="hierarchical: S clusters searched per query "
                         "(default G — exact)")
    ap.add_argument("--unpacked", action="store_true",
                    help="legacy alias for --target unpacked")
    ap.add_argument("--fused", action="store_true",
                    help="serve raw features through the single-dispatch "
                         "fused encode->pack->search pipeline")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard every batch over the first N local "
                         "devices (data-parallel serving)")
    ap.add_argument("--depth", type=int, default=2,
                    help="double-buffer depth (batches in flight)")
    ap.add_argument("--record-dir", default=None,
                    help="also persist the report as a schema-versioned "
                         "BENCH_serve_memhd.json (benchmarks.record) in "
                         "this directory — the perf-trajectory sink")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    if args.target and args.unpacked:
        ap.error("--unpacked is the legacy alias; drop it with --target")
    target = args.target or ("unpacked" if args.unpacked else "packed")
    if args.fused and target != "packed":
        ap.error("--fused needs the packed backend (--target packed)")
    if args.topk and target != "hierarchical":
        ap.error("--topk needs the top-k backend "
                 "(--target hierarchical)")
    if (args.groups or args.shortlist) and target != "hierarchical":
        ap.error("--groups/--shortlist only apply to "
                 "--target hierarchical")

    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    from repro.data import load_dataset
    from repro.deploy import ShardedArtifact

    per_class = 80 if args.smoke else 400
    epochs = 2 if args.smoke else 20
    ds = load_dataset("mnist", train_per_class=per_class,
                      test_per_class=40)
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=128, classes=ds.classes,
                      epochs=epochs, kmeans_iters=5)
    model = MemhdModel.create(jax.random.key(0), enc, amc)
    model, _ = model.fit(jax.random.key(1), ds.train_x, ds.train_y)
    if target in ("packed", "unpacked"):
        deployed = model.deploy(target=target, mode=args.mode)
    elif target == "hierarchical":
        deployed = model.deploy(target=target, groups=args.groups,
                                shortlist=args.shortlist)
    else:
        deployed = model.deploy(target=target)
    if args.devices > 1:
        deployed = ShardedArtifact(deployed, devices=args.devices)
        log.info("sharded serving over %d devices", args.devices)

    reqs = synthetic_requests(np.asarray(ds.test_x), args.requests,
                              args.max_size)
    # Warmup pass compiles every padded batch shape; the timed pass then
    # measures pure serving.
    serve_batches(deployed, reqs, args.max_batch, fused=args.fused,
                  depth=args.depth, topk=args.topk)
    t0 = time.time()
    responses, stats = serve_batches(deployed, reqs, args.max_batch,
                                     warmup=False, fused=args.fused,
                                     depth=args.depth, topk=args.topk)
    wall = time.time() - t0
    report = build_report(deployed, reqs, stats, wall, fused=args.fused,
                          topk=args.topk)
    print(json.dumps(report, indent=1))
    assert len(responses) == len(reqs)
    if args.record_dir:
        # benchmarks/ lives at the repo root, not under src/ — recording
        # therefore needs the repo root on sys.path (python -m from the
        # checkout has it). Fail loudly, never silently skip the record.
        try:
            from benchmarks import record
        except ImportError as e:
            raise SystemExit(
                f"--record-dir needs the benchmarks package importable "
                f"(run from the repo root): {e}")
        path = record.from_report("serve_memhd", report,
                                  out_dir=args.record_dir)
        log.info("recorded -> %s", path)


if __name__ == "__main__":
    main()
