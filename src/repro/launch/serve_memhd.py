"""Batched MEMHD serving driver: the packed-AM classification workload.

``launch/serve.py`` serves LM decode; this driver serves the paper's
actual deployment scenario — a stream of classification requests of raw
feature rows against the resident AM of ANY registered deployment
backend (``--target packed | unpacked | imc | hierarchical |
multibit``). Requests of ragged
sizes are greedily packed into batches (a request never splits), each
batch is zero-padded up to the next tile multiple so every launch hits
the same compiled kernel shapes, and batches are served through a
double-buffered pipeline: the host prepares/pads batch k+1 while batch
k is in flight on the device (``--depth`` controls how many batches may
be in flight; 1 recovers the fully synchronous loop).

``--devices N`` shards every batch over a data-parallel mesh of the
first N local devices (``repro.deploy.ShardedArtifact``: AM replicated,
batch rows sharded) — bit-exact with single-device serving. ``--fused``
serves each batch through ``predict_features`` — the single-dispatch
chain of the fused encode/sign/bitpack kernel into the packed search
(no float hypervector in HBM); the default serves the staged
encode -> binarize -> pack -> search path. Predictions are bit-exact
between the two modes.

The report mirrors serve.py's JSON contract: wall time, per-batch
latency percentiles, queries/s, per-device throughput, plus the
backend label and residence accounting of the served artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_memhd --smoke --fused \
      --requests 64 --max-batch 256
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve_memhd --smoke --devices 8
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import math
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import obs

# Shared tile-padding helpers (re-exported here for existing callers).
from repro.deploy.padding import pad_to_multiple, round_up  # noqa: F401
from repro.obs import span

log = logging.getLogger("serve_memhd")

TILE_B = 8  # batch padding granularity (float32 sublane tile)


@dataclasses.dataclass(frozen=True)
class Request:
    """One classification request: a block of feature rows."""

    rid: int
    feats: np.ndarray  # (n, f)

    @property
    def size(self) -> int:
        return self.feats.shape[0]


def make_batches(requests: Sequence[Request], max_batch: int,
                 ) -> List[List[Request]]:
    """Greedy first-fit batching: fill up to ``max_batch`` rows per batch.

    Requests are taken in arrival order and never split; a request larger
    than ``max_batch`` gets a batch of its own (it still pads to a tile
    multiple, it just can't share).
    """
    batches: List[List[Request]] = []
    cur: List[Request] = []
    cur_rows = 0
    for req in requests:
        if cur and cur_rows + req.size > max_batch:
            batches.append(cur)
            cur, cur_rows = [], 0
        cur.append(req)
        cur_rows += req.size
    if cur:
        batches.append(cur)
    return batches


def serve_batches(deployed, requests: Sequence[Request],
                  max_batch: int = 256, tile: int = TILE_B,
                  warmup: bool = True, fused: bool = False,
                  depth: int = 1, topk: int = 0,
                  ) -> Tuple[Dict[int, np.ndarray], Dict]:
    """Run the request stream through the deployed model.

    ``warmup=True`` pre-compiles every distinct padded batch shape the
    stream will hit (tile padding keeps that set small) so the reported
    latencies measure serving, not jit compilation. ``fused=True``
    serves each batch through ``predict_features`` (the single-dispatch
    fused pipeline) instead of the staged ``predict``; predictions are
    bit-exact between the two.

    ``depth`` is the double-buffer depth: up to ``depth`` batches may be
    in flight on the device while the host concatenates and pads the
    next one (jax dispatch is async; the host only blocks when the
    pipeline is full). The default ``depth=1`` is the synchronous loop.

    Latency is reported DECOMPOSED, at any depth: ``lat_ms_*`` is the
    total dispatch -> result-ready time per batch, split into
    ``queue_ms_*`` (time the batch spent waiting behind earlier
    in-flight batches — the pipeline queue wait that used to be
    silently folded into ``lat_ms_*`` whenever ``depth > 1``) and
    ``service_ms_*`` (the batch's own device time once the queue ahead
    of it drained). Per batch ``queue + service == lat`` exactly; at
    ``depth=1`` queue wait is identically zero. The decomposition
    assumes in-order device execution (one stream), which is how a jax
    device dispatch queue drains.

    An empty request stream reports ``batches: 0`` and ``None`` for
    every latency field (JSON ``null``) — no fabricated zero rows.

    Each batch also emits host spans (``host_prep`` / ``pad`` /
    ``dispatch`` / ``device_wait``, exportable as a Chrome trace via
    ``repro.obs``) and feeds the ``serve_batch_ms`` histogram /
    ``serve_rows_total`` counters of the default metrics registry.

    ``topk >= 1`` serves through the backend's ``predict_topk`` — the
    fused streaming top-k kernel epilogue — and each response row widens
    to the request's k best classes.

    Returns (responses, stats): responses maps rid -> (n,) predicted
    classes ((n, topk) when ``topk >= 1``); stats holds per-batch
    latencies and padding accounting.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    if topk and fused:
        raise ValueError("topk serving and the fused feature pipeline "
                         "are mutually exclusive")
    # Sharded artifacts need every batch to split evenly across devices.
    tile = math.lcm(tile, getattr(deployed, "row_multiple", 1))
    if topk:
        # (B, k) classes out of the streaming top-k epilogue; the ids
        # and sims of the triple stay available via predict_topk itself.
        predict = lambda x: deployed.predict_topk(x, topk)[0]  # noqa: E731
    else:
        predict = (deployed.predict_features if fused
                   else deployed.predict)
    batches = make_batches(requests, max_batch)
    if warmup and requests:
        # The warmup batches must hit the SAME jit signatures the stream
        # will: shape AND dtype (a non-f32 stream warmed with f32 zeros
        # would silently recompile every steady-state shape).
        n_feats = requests[0].feats.shape[1]
        dtype = requests[0].feats.dtype
        shapes = {round_up(sum(r.size for r in b), tile) for b in batches}
        for rows in sorted(shapes):
            jax.block_until_ready(predict(
                np.zeros((rows, n_feats), dtype)))
    responses: Dict[int, np.ndarray] = {}
    lat_ms: List[float] = []
    queue_ms: List[float] = []
    service_ms: List[float] = []
    rows_real = rows_padded = 0
    inflight: deque = deque()  # (idx, batch, n_valid, result, t_disp)
    last_ready = [float("-inf")]  # when the device finished batch k-1
    hist = obs.histogram(
        "serve_batch_ms", "per-batch serving latency by stage")
    served_rows = obs.counter("serve_rows_total",
                              "feature rows served (pre-padding)")
    served_reqs = obs.counter("serve_requests_total",
                              "classification requests served")

    def _drain_one():
        idx, batch, n_valid, fut, t_disp = inflight.popleft()
        with span("device_wait", batch=idx):
            jax.block_until_ready(fut)
        t_ready = time.perf_counter()
        # The batch could only start once everything dispatched before
        # it had drained (in-order device queue): time up to the
        # previous batch's completion is queue wait, the rest is this
        # batch's own service time.
        lat = t_ready - t_disp
        queue = min(lat, max(0.0, last_ready[0] - t_disp))
        last_ready[0] = t_ready
        lat_ms.append(lat * 1e3)
        queue_ms.append(queue * 1e3)
        service_ms.append((lat - queue) * 1e3)
        hist.observe(lat * 1e3, stage="total")
        hist.observe(queue * 1e3, stage="queue")
        hist.observe((lat - queue) * 1e3, stage="service")
        pred = np.asarray(fut)[:n_valid]
        ofs = 0
        for r in batch:
            responses[r.rid] = pred[ofs:ofs + r.size]
            ofs += r.size

    for i, batch in enumerate(batches):
        # Host-side prep of batch k+1 overlaps device work on batch k.
        with span("host_prep", batch=i, requests=len(batch)):
            feats = np.concatenate([r.feats for r in batch])
            with span("pad", batch=i):
                padded, n_valid = pad_to_multiple(feats, tile)
        rows_real += n_valid
        rows_padded += padded.shape[0]
        t0 = time.perf_counter()
        with span("dispatch", batch=i, rows=padded.shape[0]):
            fut = predict(padded)
        inflight.append((i, batch, n_valid, fut, t0))
        while len(inflight) >= depth:
            _drain_one()
    while inflight:
        _drain_one()
    served_rows.inc(rows_real)
    served_reqs.inc(len(requests))
    stats = {
        "depth": depth,
        "batches": len(batches),
        "rows_real": rows_real,
        "rows_padded": rows_padded,
        "pad_overhead": (round(rows_padded / rows_real - 1, 3)
                         if rows_real else None),
        **_lat_fields("lat_ms", lat_ms),
        **_lat_fields("service_ms", service_ms),
        **_lat_fields("queue_ms", queue_ms),
    }
    return responses, stats


def _lat_fields(prefix: str, vals: List[float],
                ) -> Dict[str, Optional[float]]:
    """min/p50/p95/p99/total fields for one latency series; all None
    (JSON null) when the stream produced no batches."""
    if not vals:
        return {f"{prefix}_{s}": None
                for s in ("min", "p50", "p95", "p99", "total")}
    a = np.asarray(vals)
    return {
        f"{prefix}_min": round(float(a.min()), 3),
        f"{prefix}_p50": round(float(np.percentile(a, 50)), 3),
        f"{prefix}_p95": round(float(np.percentile(a, 95)), 3),
        f"{prefix}_p99": round(float(np.percentile(a, 99)), 3),
        f"{prefix}_total": round(float(a.sum()), 3),
    }


def synthetic_requests(feats: np.ndarray, n_requests: int,
                       max_size: int, seed: int = 0) -> List[Request]:
    """Ragged request stream sampled from a feature pool."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_requests):
        n = int(rng.integers(1, max_size + 1))
        rows = rng.integers(0, feats.shape[0], size=n)
        reqs.append(Request(rid=rid, feats=feats[rows]))
    return reqs


def metrics_summary(recompiles_steady_state: Optional[int] = None,
                    ) -> Dict:
    """The report's ``metrics`` section: runtime facts wall clocks
    can't show — total XLA compiles, compiles observed in the
    steady-state (post-warmup) serving window, and the per-kernel
    dispatch-tier breakdown (which execution tier actually served each
    kernel — a silent fallback to the oracle path is visible here)."""
    from repro.kernels import ops
    out = {
        "compiles_total": obs.jaxmon.compiles(),
        "dispatch_tiers": ops.dispatch_breakdown(),
    }
    if recompiles_steady_state is not None:
        out["recompiles_steady_state"] = int(recompiles_steady_state)
    return out


def build_report(deployed, requests: Sequence[Request], stats: Dict,
                 wall_s: float, fused: bool = False, topk: int = 0,
                 metrics: Optional[Dict] = None) -> Dict:
    """Assemble the serving JSON report — the driver's output contract.

    Key set and value types are stable (asserted in
    tests/test_serving.py); downstream dashboards parse this. Works for
    any ``DeployedArtifact`` backend (and its sharded wrapper): the
    ``backend`` / ``devices`` fields make reports from different
    substrates and device counts comparable. ``metrics`` is the
    runtime-introspection section (``metrics_summary()``); it defaults
    to a fresh summary with no steady-state window.
    """
    n_rows = sum(r.size for r in requests)
    devices = int(getattr(deployed, "n_devices", 1))
    rows_per_s = round(n_rows / wall_s, 1) if wall_s else 0.0
    return {
        "workload": "memhd_classify",
        "backend": deployed.backend,
        "devices": devices,
        "packed": bool(getattr(deployed, "packed", False)),
        "mode": deployed.serving_mode,
        "pipeline": "fused" if fused else "staged",
        "topk": int(topk),  # 0 = argmax serving; k >= 1 = top-k epilogue
        "geometry": f"{deployed.am_cfg.dim}x{deployed.am_cfg.columns}",
        "requests": len(requests),
        "rows": n_rows,
        "wall_s": round(wall_s, 3),
        "qps": round(len(requests) / wall_s, 1) if wall_s else 0.0,
        "rows_per_s": rows_per_s,
        "rows_per_s_per_device": round(rows_per_s / devices, 1),
        "resident_am_bytes": deployed.resident_am_bytes,
        "am_memory_ratio": round(deployed.am_memory_ratio, 2),
        "metrics": metrics if metrics is not None else metrics_summary(),
        **stats,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny training budget (CI-sized)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-size", type=int, default=32,
                    help="max rows per request")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--target", default=None,
                    choices=["packed", "unpacked", "imc", "hierarchical",
                             "multibit"],
                    help="deployment backend (registry target)")
    ap.add_argument("--cell-bits", type=int, default=4,
                    help="multibit: bits per resident AM cell (2-8)")
    ap.add_argument("--mode", default="popcount",
                    choices=["popcount", "unpack"])
    ap.add_argument("--topk", type=int, default=0,
                    help="serve k candidates per row through the fused "
                         "streaming top-k epilogue (hierarchical "
                         "backend); 0 = argmax serving")
    ap.add_argument("--groups", type=int, default=None,
                    help="hierarchical: G super-centroids "
                         "(default ~sqrt(C))")
    ap.add_argument("--shortlist", type=int, default=None,
                    help="hierarchical: S clusters searched per query "
                         "(default G — exact)")
    ap.add_argument("--unpacked", action="store_true",
                    help="legacy alias for --target unpacked")
    ap.add_argument("--fused", action="store_true",
                    help="serve raw features through the single-dispatch "
                         "fused encode->pack->search pipeline")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard every batch over the first N local "
                         "devices (data-parallel serving)")
    ap.add_argument("--depth", type=int, default=2,
                    help="double-buffer depth (batches in flight)")
    ap.add_argument("--record-dir", default=None,
                    help="also persist the report as a schema-versioned "
                         "BENCH_serve_memhd.json (benchmarks.record) in "
                         "this directory — the perf-trajectory sink")
    ap.add_argument("--metrics-out", default=None,
                    help="write the full obs metrics-registry snapshot "
                         "(counters/gauges/histograms) as JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="write the host-span Chrome trace-event JSON "
                         "here (open in Perfetto / chrome://tracing)")
    ap.add_argument("--log-json", action="store_true",
                    help="structured one-JSON-per-line logging")
    args = ap.parse_args()
    obs.setup_logging(json_mode=args.log_json)
    obs.install()  # count XLA compiles from the very first trace

    if args.target and args.unpacked:
        ap.error("--unpacked is the legacy alias; drop it with --target")
    target = args.target or ("unpacked" if args.unpacked else "packed")
    if args.fused and target != "packed":
        ap.error("--fused needs the packed backend (--target packed)")
    if args.topk and target != "hierarchical":
        ap.error("--topk needs the top-k backend "
                 "(--target hierarchical)")
    if (args.groups or args.shortlist) and target != "hierarchical":
        ap.error("--groups/--shortlist only apply to "
                 "--target hierarchical")

    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    from repro.data import load_dataset
    from repro.deploy import ShardedArtifact

    per_class = 80 if args.smoke else 400
    epochs = 2 if args.smoke else 20
    ds = load_dataset("mnist", train_per_class=per_class,
                      test_per_class=40)
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=128, classes=ds.classes,
                      epochs=epochs, kmeans_iters=5)
    model = MemhdModel.create(jax.random.key(0), enc, amc)
    model, _ = model.fit(jax.random.key(1), ds.train_x, ds.train_y)
    if target in ("packed", "unpacked"):
        deployed = model.deploy(target=target, mode=args.mode)
    elif target == "hierarchical":
        deployed = model.deploy(target=target, groups=args.groups,
                                shortlist=args.shortlist)
    elif target == "multibit":
        deployed = model.deploy(target=target, cell_bits=args.cell_bits)
    else:
        deployed = model.deploy(target=target)
    if args.devices > 1:
        deployed = ShardedArtifact(deployed, devices=args.devices)
        log.info("sharded serving over %d devices", args.devices)

    reqs = synthetic_requests(np.asarray(ds.test_x), args.requests,
                              args.max_size)
    # Warmup pass compiles every padded batch shape; the timed pass then
    # measures pure serving — and must not compile ANYTHING new
    # (``recompiles_steady_state`` in the report's metrics section
    # stays 0 unless the padding contract regressed).
    with span("warmup"):
        serve_batches(deployed, reqs, args.max_batch, fused=args.fused,
                      depth=args.depth, topk=args.topk)
    with obs.count_compiles() as steady_compiles:
        t0 = time.time()
        with span("serve", requests=len(reqs), depth=args.depth):
            responses, stats = serve_batches(
                deployed, reqs, args.max_batch, warmup=False,
                fused=args.fused, depth=args.depth, topk=args.topk)
        wall = time.time() - t0
    obs.update_memory_gauges()
    report = build_report(
        deployed, reqs, stats, wall, fused=args.fused, topk=args.topk,
        metrics=metrics_summary(
            recompiles_steady_state=steady_compiles()))
    print(json.dumps(report, indent=1))
    assert len(responses) == len(reqs)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(obs.snapshot(), f, indent=1)
        log.info("metrics snapshot -> %s", args.metrics_out)
    if args.trace_out:
        obs.export_chrome_trace(args.trace_out)
        log.info("chrome trace -> %s", args.trace_out)
    if args.record_dir:
        # benchmarks/ lives at the repo root, not under src/ — recording
        # therefore needs the repo root on sys.path (python -m from the
        # checkout has it). Fail loudly, never silently skip the record.
        try:
            from benchmarks import record
        except ImportError as e:
            raise SystemExit(
                f"--record-dir needs the benchmarks package importable "
                f"(run from the repo root): {e}")
        path = record.from_report("serve_memhd", report,
                                  out_dir=args.record_dir)
        log.info("recorded -> %s", path)


if __name__ == "__main__":
    main()
