"""Input ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

``input_specs(arch, shape)`` builds the exact abstract inputs a cell's
step function consumes — weak-type-correct, shardable, zero allocation.
Train cells feed {tokens, targets, ...}; decode cells feed a one-token
batch plus the fully-grown KV/state caches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, shape_spec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingRules

Sds = jax.ShapeDtypeStruct


def _batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_config_for_cell(arch: str, shape: str) -> ModelConfig:
    cfg = get_config(arch)
    spec = shape_spec(shape)
    if spec.step == "decode":
        # Decode caches dominate memory at 32k+ contexts: shard the KV /
        # latent seq dimension over "model" (sequence parallelism for
        # the cache; attention reduces over it with a psum XLA inserts).
        cfg = dataclasses.replace(cfg, shard_seq=True)
    return cfg


def train_input_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                      ) -> Dict[str, Sds]:
    b, s = global_batch, seq_len
    i32 = jnp.int32
    if cfg.frontend == "audio_frames":
        specs = {
            "frame_embeds": Sds((b, s, cfg.d_model), jnp.bfloat16),
            "targets": Sds((b, s, cfg.n_codebooks), i32),
        }
        if cfg.n_cond_tokens:
            specs["cond_embeds"] = Sds((b, cfg.n_cond_tokens, cfg.d_model),
                                       jnp.bfloat16)
        return specs
    if cfg.frontend == "vision_patches":
        s_text = s - cfg.n_patches
        return {
            "tokens": Sds((b, s_text), i32),
            "patch_feats": Sds((b, cfg.n_patches, T.VIT_DIM), jnp.bfloat16),
            "targets": Sds((b, s_text), i32),
        }
    return {"tokens": Sds((b, s), i32), "targets": Sds((b, s), i32)}


def decode_input_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                       ) -> Tuple[Dict[str, Sds], Any]:
    """(one-token batch, caches) abstract specs for a decode cell."""
    b = global_batch
    if cfg.frontend == "audio_frames":
        batch = {"frame_embeds": Sds((b, 1, cfg.d_model), jnp.bfloat16)}
        if cfg.n_cond_tokens:
            batch["cond_embeds"] = Sds((b, cfg.n_cond_tokens, cfg.d_model),
                                       jnp.bfloat16)
    else:
        batch = {"tokens": Sds((b, 1), jnp.int32)}
    caches = jax.eval_shape(
        lambda: T.init_cache(cfg, b, seq_len, "bfloat16"))
    return batch, caches


def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """Public entry: abstract inputs for the (arch, shape) cell."""
    cfg = model_config_for_cell(arch, shape)
    sp = shape_spec(shape)
    if sp.step == "train":
        return {"batch": train_input_specs(cfg, sp.seq_len, sp.global_batch)}
    batch, caches = decode_input_specs(cfg, sp.seq_len, sp.global_batch)
    return {"batch": batch, "caches": caches}


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def batch_shardings(mesh, batch_specs: Dict[str, Sds],
                    ) -> Dict[str, NamedSharding]:
    """Batch dim over the data axes; everything else replicated.

    Batches smaller than the data axes (long_500k: batch 1) stay
    replicated — their parallelism lives on the model axis instead.
    """
    ba = _batch_axes(mesh)
    out = {}
    for k, v in batch_specs.items():
        lead = ba if _divisible(v.shape[0], ba, mesh) else None
        rest = (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, P(lead, *rest))
    return out


def _divisible(n: int, axes: tuple, mesh) -> bool:
    size = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        size *= mesh.shape[a]
    return n % size == 0


def cache_shardings(mesh, caches, rules: ShardingRules):
    """Per-leaf cache shardings.

    Rank-based heuristics over the known cache layouts (leaves carry the
    group's ``repeat`` as leading axis L):
      (L, B, S, H, D) k/v       -> (None, batch, seq?, None, None)
      (L, B, S, R)    ckv/krope -> (None, batch, seq?, None)
      (L, B, H, N, P) ssm state -> (None, batch, None, None, None)
      (L, B, W, C)    conv      -> (None, batch, None, None)
      (L, B)          len       -> (None, batch)
    The seq dim is sharded over "model" only when rules.shard_seq and the
    length divides the axis (ring-buffered window caches usually don't —
    they stay local).
    """
    ba = _batch_axes(mesh)

    def leaf_spec(x) -> NamedSharding:
        shape = x.shape
        rank = len(shape)
        parts: list = [None] * rank
        if rank >= 2:
            if _divisible(shape[1], ba, mesh):
                parts[1] = ba
        if rank >= 4 and rules.shard_seq:
            # dim 2 is the seq dim for k/v/ckv caches
            if _divisible(shape[2], ("model",), mesh) and shape[2] > 1024:
                parts[2] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(leaf_spec, caches)
