"""Fault-tolerant training driver.

Runs a real (CPU-scale here, pod-scale by construction) training loop
with the full production substrate:

  * deterministic checkpointable data pipeline (position in manifest)
  * atomic checkpoints + auto-resume from the newest *valid* one
  * a per-step wall-clock watchdog (straggler/hang mitigation: the step
    deadline triggers an emergency checkpoint + non-zero exit so the
    cluster manager can reschedule — the standard TPU-pod pattern)
  * optional simulated failure injection (--fail-at-step) used by the
    fault-tolerance tests to prove bit-exact resume.

Two trainer families run under the same driver:

  * the LM archs from ``repro.configs`` (per-step AdamW training), and
  * ``--arch memhd`` — the paper's QAIL trainer: one "step" is one
    scan-compiled device-resident epoch (``qail.qail_epoch_scan``), the
    checkpointed state is a ``MemhdTrainState``, and resume is bit-exact
    (asserted by tests/test_train_loop.py via the final AM digest).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --smoke --steps 50 --ckpt-dir /tmp/run1
  PYTHONPATH=src python -m repro.launch.train --arch memhd \
      --smoke --steps 10 --ckpt-dir /tmp/memhd_run
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import os
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

log = logging.getLogger("train")


def _event_log(cfg: "TrainRunConfig") -> obs.EventLog:
    """The run's JSONL event stream, next to the checkpoints: epoch /
    step stats, checkpoint write durations, watchdog fires, resumes —
    the machine-readable run history a dashboard tails live."""
    return obs.EventLog(os.path.join(cfg.ckpt_dir, "events.jsonl"))


@dataclasses.dataclass
class TrainRunConfig:
    arch: str = "mamba2-130m"
    smoke: bool = True
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    keep: int = 3
    lr: float = 3e-4
    warmup: int = 20
    log_every: int = 10
    step_deadline_s: float = 300.0
    fail_at_step: int = -1  # fault-injection for tests
    seed: int = 0
    log_json: bool = False  # structured one-JSON-per-line logging


class StepWatchdog:
    """SIGALRM-based per-step deadline (single-host stand-in for the
    pod-level heartbeat/reschedule machinery)."""

    def __init__(self, deadline_s: float, on_timeout):
        self.deadline = deadline_s
        self.on_timeout = on_timeout

    def __enter__(self):
        def handler(signum, frame):
            self.on_timeout()
            raise TimeoutError("train step exceeded deadline")

        self._prev = signal.signal(signal.SIGALRM, handler)
        signal.setitimer(signal.ITIMER_REAL, self.deadline)
        return self

    def __exit__(self, *exc):
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, self._prev)
        return False


def run_memhd(cfg: TrainRunConfig) -> dict:
    """QAIL training under the fault-tolerant driver.

    One driver "step" == one scan-compiled QAIL epoch (a single device
    dispatch; the per-epoch ``float(miss)`` is the only host sync). The
    dataset, encoder and clustering init are deterministic in
    ``cfg.seed``, so a restore of the newest ``MemhdTrainState``
    continues the run bit-exactly — the returned ``am_digest`` (sha256
    of the binary AM) is identical with and without a mid-run crash.
    """
    import hashlib

    from repro.checkpoint import CheckpointConfig, CheckpointManager
    from repro.core import (
        EncoderConfig, MemhdConfig, MemhdModel, encoding, qail,
    )
    from repro.core.memhd import MemhdTrainState
    from repro.data import load_dataset

    if cfg.smoke:
        ds = load_dataset("mnist", train_per_class=120, test_per_class=30,
                          seed=cfg.seed)
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=256)
        amc = MemhdConfig(dim=256, columns=64, classes=ds.classes,
                          kmeans_iters=8, lr=0.02, batch_size=256,
                          seed=cfg.seed)
    else:
        ds = load_dataset("mnist", train_per_class=1000,
                          test_per_class=200, seed=cfg.seed)
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=512)
        amc = MemhdConfig(dim=512, columns=128, classes=ds.classes,
                          kmeans_iters=25, lr=0.02, batch_size=256,
                          seed=cfg.seed)

    model = MemhdModel.create(jax.random.key(cfg.seed), enc, amc)
    h = model.encode(ds.train_x)
    q = encoding.binarize_query(h)
    n = h.shape[0]
    epochs = cfg.steps

    ckpt = CheckpointManager(CheckpointConfig(cfg.ckpt_dir, keep=cfg.keep))
    events = _event_log(cfg)

    def timed_save(step, tree, extra):
        t0 = time.perf_counter()
        ckpt.save(step, tree, extra=extra)
        events.emit("checkpoint", step=step,
                    dur_s=round(time.perf_counter() - t0, 4),
                    emergency=bool(extra.get("emergency", False)))

    template = MemhdTrainState.create(model.am_state)
    restored_epoch, tree, extra = ckpt.restore(template)
    miss_hist = []
    if restored_epoch is not None:
        state = jax.tree.map(jnp.asarray, tree.am_state)
        start_epoch = restored_epoch
        miss_hist = list(extra.get("miss", []))
        log.info("resumed memhd from epoch %d", start_epoch)
        events.emit("resume", step=start_epoch)
    else:
        m_init, _ = model.initialize_am(jax.random.key(cfg.seed + 1),
                                        ds.train_x, ds.train_y, h=h, q=q)
        state = m_init.am_state
        start_epoch = 0
        timed_save(0, MemhdTrainState.create(state, 0),
                   extra={"miss": miss_hist})

    hb, qb, yb, mask = qail.prebatch(h, q, ds.train_y, amc.batch_size)
    # Emergency-checkpoint source: a HOST (numpy) snapshot of the last
    # completed epoch. The device state is donated into the in-flight
    # scan on accelerator backends, so a live reference would be a dead
    # buffer exactly when the watchdog needs it. The AM is a few KB —
    # the per-epoch snapshot cost is noise next to the epoch itself.
    last_state = [jax.tree.map(np.asarray, state)]

    def emergency_ckpt():
        log.error("watchdog fired: writing emergency memhd checkpoint")
        events.emit("watchdog", step=last_epoch[0],
                    deadline_s=cfg.step_deadline_s)
        timed_save(last_epoch[0],
                   MemhdTrainState.create(last_state[0], last_epoch[0]),
                   extra={"miss": miss_hist, "emergency": True})

    last_epoch = [start_epoch]
    t_start = time.time()
    for ep in range(start_epoch, epochs):
        t_ep = time.perf_counter()
        with StepWatchdog(cfg.step_deadline_s, emergency_ckpt):
            with obs.span("qail_epoch", epoch=ep):
                state, n_miss = qail.qail_epoch_scan(state, amc, hb, qb,
                                                     yb, mask)
        miss_rate = float(n_miss) / n  # the one host sync this epoch
        dur_s = time.perf_counter() - t_ep
        miss_hist.append(miss_rate)
        last_state[0] = jax.tree.map(np.asarray, state)
        last_epoch[0] = ep + 1
        events.emit("epoch", step=ep + 1, miss=round(miss_rate, 6),
                    dur_s=round(dur_s, 4),
                    samples_per_sec=round(n / dur_s, 1) if dur_s else None)
        if (ep + 1) % cfg.log_every == 0:
            log.info("epoch %d miss %.4f (%.2f s/epoch)", ep + 1,
                     miss_rate,
                     (time.time() - t_start) / (ep + 1 - start_epoch))
        if (ep + 1) % cfg.ckpt_every == 0 or ep + 1 == epochs:
            timed_save(ep + 1, MemhdTrainState.create(state, ep + 1),
                       extra={"miss": miss_hist})
        if cfg.fail_at_step == ep + 1:
            log.error("injected failure at epoch %d", ep + 1)
            events.emit("injected_failure", step=ep + 1)
            os._exit(42)  # simulate a hard node death

    trained = dataclasses.replace(model, am_state=state)
    eval_acc = trained.score(ds.test_x, ds.test_y)
    digest = hashlib.sha256(
        np.asarray(state["binary"]).tobytes()).hexdigest()
    dt = time.time() - t_start
    events.emit("run_end", steps_run=epochs - start_epoch,
                resumed_from=start_epoch, eval_acc=eval_acc,
                wall_s=round(dt, 3), compiles=obs.jaxmon.compiles())
    events.close()
    return {
        "first_miss": miss_hist[0] if miss_hist else None,
        "last_miss": miss_hist[-1] if miss_hist else None,
        "steps_run": epochs - start_epoch,
        "resumed_from": start_epoch,
        "eval_acc": eval_acc,
        "am_digest": digest,
        "samples_per_sec": (n * (epochs - start_epoch) / dt
                            if dt > 0 and epochs > start_epoch else None),
    }


# Non-LM trainers that run under the same fault-tolerant driver.
TRAINERS = {"memhd": run_memhd}


def run(cfg: TrainRunConfig) -> dict:
    if cfg.arch in TRAINERS:
        return TRAINERS[cfg.arch](cfg)

    from repro.checkpoint import CheckpointConfig, CheckpointManager
    from repro.configs import get_config, get_smoke_config
    from repro.data.lm import LmDataConfig, PipelineState, next_batch
    from repro.distributed.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig, ScheduleConfig, make_schedule

    mcfg = (get_smoke_config(cfg.arch) if cfg.smoke
            else get_config(cfg.arch))
    if mcfg.frontend != "none":
        raise SystemExit(
            f"{cfg.arch} needs modality inputs; use examples/ drivers")

    opt_cfg = AdamWConfig(lr=cfg.lr)
    sched = make_schedule(ScheduleConfig(
        warmup_steps=cfg.warmup, total_steps=cfg.steps))
    dcfg = LmDataConfig(vocab_size=mcfg.vocab_size, seq_len=cfg.seq_len,
                        global_batch=cfg.global_batch)

    params, opt_state, _axes = init_train_state(
        jax.random.key(cfg.seed), mcfg, opt_cfg)
    pipe = PipelineState(seed=cfg.seed)
    start_step = 0

    ckpt = CheckpointManager(CheckpointConfig(cfg.ckpt_dir, keep=cfg.keep))
    events = _event_log(cfg)

    def timed_save(step, tree, extra):
        t0 = time.perf_counter()
        ckpt.save(step, tree, extra=extra)
        events.emit("checkpoint", step=step,
                    dur_s=round(time.perf_counter() - t0, 4),
                    emergency=bool(extra.get("emergency", False)))

    restored_step, tree, extra = ckpt.restore(
        {"params": params, "opt": opt_state})
    if restored_step is not None:
        params, opt_state = tree["params"], tree["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        pipe = PipelineState.from_json(extra["pipeline"])
        start_step = restored_step
        log.info("resumed from step %d", start_step)
        events.emit("resume", step=start_step)

    step_fn = jax.jit(make_train_step(mcfg, opt_cfg, sched))

    def emergency_ckpt():
        log.error("watchdog fired: writing emergency checkpoint")
        events.emit("watchdog", step=last_step[0],
                    deadline_s=cfg.step_deadline_s)
        timed_save(last_step[0], {"params": params, "opt": opt_state},
                   extra={"pipeline": pipe.to_json(), "emergency": True})

    last_step = [start_step]
    losses = []
    t_start = time.time()
    for step in range(start_step, cfg.steps):
        t_step = time.perf_counter()
        batch_np, pipe = next_batch(dcfg, pipe)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        with StepWatchdog(cfg.step_deadline_s, emergency_ckpt):
            with obs.span("train_step", step=step):
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch,
                    jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        last_step[0] = step + 1
        if not np.isfinite(loss):
            events.emit("diverged", step=step, loss=loss)
            raise FloatingPointError(f"loss diverged at step {step}")
        if (step + 1) % cfg.log_every == 0:
            dt_step = time.perf_counter() - t_step
            log.info("step %d loss %.4f (%.2f s/step)", step + 1, loss,
                     (time.time() - t_start) / (step + 1 - start_step))
            events.emit("step", step=step + 1, loss=round(loss, 6),
                        dur_s=round(dt_step, 4),
                        tokens_per_sec=round(
                            cfg.global_batch * cfg.seq_len / dt_step, 1)
                        if dt_step else None)
        if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.steps:
            timed_save(step + 1, {"params": params, "opt": opt_state},
                       extra={"pipeline": pipe.to_json()})
        if cfg.fail_at_step == step + 1:
            log.error("injected failure at step %d", step + 1)
            events.emit("injected_failure", step=step + 1)
            os._exit(42)  # simulate a hard node death

    events.emit("run_end", steps_run=len(losses),
                resumed_from=start_step,
                wall_s=round(time.time() - t_start, 3),
                compiles=obs.jaxmon.compiles())
    events.close()
    return {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps_run": len(losses),
        "resumed_from": start_step,
    }


def main():
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainRunConfig):
        name = "--" + f.name.replace("_", "-")
        if f.type == "bool" or isinstance(f.default, bool):
            ap.add_argument(name, action="store_true", default=f.default)
        else:
            ap.add_argument(name, type=type(f.default), default=f.default)
    args = ap.parse_args()
    cfg = TrainRunConfig(**{f.name: getattr(args, f.name)
                            for f in dataclasses.fields(TrainRunConfig)})
    obs.setup_logging(json_mode=cfg.log_json)
    obs.install()  # jit compile counters for the run_end event
    out = run(cfg)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
