"""Fault-tolerant training driver.

Runs a real (CPU-scale here, pod-scale by construction) training loop
with the full production substrate:

  * deterministic checkpointable data pipeline (position in manifest)
  * atomic checkpoints + auto-resume from the newest *valid* one
  * a per-step wall-clock watchdog (straggler/hang mitigation: the step
    deadline triggers an emergency checkpoint + non-zero exit so the
    cluster manager can reschedule — the standard TPU-pod pattern)
  * optional simulated failure injection (--fail-at-step) used by the
    fault-tolerance tests to prove bit-exact resume.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --smoke --steps 50 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import os
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger("train")


@dataclasses.dataclass
class TrainRunConfig:
    arch: str = "mamba2-130m"
    smoke: bool = True
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    keep: int = 3
    lr: float = 3e-4
    warmup: int = 20
    log_every: int = 10
    step_deadline_s: float = 300.0
    fail_at_step: int = -1  # fault-injection for tests
    seed: int = 0


class StepWatchdog:
    """SIGALRM-based per-step deadline (single-host stand-in for the
    pod-level heartbeat/reschedule machinery)."""

    def __init__(self, deadline_s: float, on_timeout):
        self.deadline = deadline_s
        self.on_timeout = on_timeout

    def __enter__(self):
        def handler(signum, frame):
            self.on_timeout()
            raise TimeoutError("train step exceeded deadline")

        self._prev = signal.signal(signal.SIGALRM, handler)
        signal.setitimer(signal.ITIMER_REAL, self.deadline)
        return self

    def __exit__(self, *exc):
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, self._prev)
        return False


def run(cfg: TrainRunConfig) -> dict:
    from repro.checkpoint import CheckpointConfig, CheckpointManager
    from repro.configs import get_config, get_smoke_config
    from repro.data.lm import LmDataConfig, PipelineState, next_batch
    from repro.distributed.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig, ScheduleConfig, make_schedule

    mcfg = (get_smoke_config(cfg.arch) if cfg.smoke
            else get_config(cfg.arch))
    if mcfg.frontend != "none":
        raise SystemExit(
            f"{cfg.arch} needs modality inputs; use examples/ drivers")

    opt_cfg = AdamWConfig(lr=cfg.lr)
    sched = make_schedule(ScheduleConfig(
        warmup_steps=cfg.warmup, total_steps=cfg.steps))
    dcfg = LmDataConfig(vocab_size=mcfg.vocab_size, seq_len=cfg.seq_len,
                        global_batch=cfg.global_batch)

    params, opt_state, _axes = init_train_state(
        jax.random.key(cfg.seed), mcfg, opt_cfg)
    pipe = PipelineState(seed=cfg.seed)
    start_step = 0

    ckpt = CheckpointManager(CheckpointConfig(cfg.ckpt_dir, keep=cfg.keep))
    restored_step, tree, extra = ckpt.restore(
        {"params": params, "opt": opt_state})
    if restored_step is not None:
        params, opt_state = tree["params"], tree["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        pipe = PipelineState.from_json(extra["pipeline"])
        start_step = restored_step
        log.info("resumed from step %d", start_step)

    step_fn = jax.jit(make_train_step(mcfg, opt_cfg, sched))

    def emergency_ckpt():
        log.error("watchdog fired: writing emergency checkpoint")
        ckpt.save(last_step[0], {"params": params, "opt": opt_state},
                  extra={"pipeline": pipe.to_json(), "emergency": True})

    last_step = [start_step]
    losses = []
    t_start = time.time()
    for step in range(start_step, cfg.steps):
        batch_np, pipe = next_batch(dcfg, pipe)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        with StepWatchdog(cfg.step_deadline_s, emergency_ckpt):
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        last_step[0] = step + 1
        if not np.isfinite(loss):
            raise FloatingPointError(f"loss diverged at step {step}")
        if (step + 1) % cfg.log_every == 0:
            log.info("step %d loss %.4f (%.2f s/step)", step + 1, loss,
                     (time.time() - t_start) / (step + 1 - start_step))
        if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.steps:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      extra={"pipeline": pipe.to_json()})
        if cfg.fail_at_step == step + 1:
            log.error("injected failure at step %d", step + 1)
            os._exit(42)  # simulate a hard node death

    return {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps_run": len(losses),
        "resumed_from": start_step,
    }


def main():
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainRunConfig):
        name = "--" + f.name.replace("_", "-")
        if f.type == "bool" or isinstance(f.default, bool):
            ap.add_argument(name, action="store_true", default=f.default)
        else:
            ap.add_argument(name, type=type(f.default), default=f.default)
    args = ap.parse_args()
    cfg = TrainRunConfig(**{f.name: getattr(args, f.name)
                            for f in dataclasses.fields(TrainRunConfig)})
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    out = run(cfg)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
