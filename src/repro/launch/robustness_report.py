"""Accuracy-vs-fidelity JSON report for a deployed MEMHD model.

Trains (or smoke-trains) the flagship MEMHD geometry, deploys it onto
simulated analog arrays across the fidelity grid (ADC bits, conductance
noise sigma, stuck-at fault rate), runs the noise-aware QAIL recovery
experiment at the headline noisy point, and emits everything as one
JSON document — the deployment-qualification artifact for a model about
to be burned onto real arrays.

Usage:
  PYTHONPATH=src python -m repro.launch.robustness_report --smoke
  PYTHONPATH=src python -m repro.launch.robustness_report \
      --noise-sigma 0.5 --adc-bits 16,8,6,4 --finetune-epochs 10
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import time

import jax

from repro import obs

log = logging.getLogger("robustness_report")


def _floats(s: str):
    return [float(x) for x in s.split(",") if x]


def _ints(s: str):
    return [int(x) for x in s.split(",") if x]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny training budget (CI-sized)")
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--columns", type=int, default=128)
    ap.add_argument("--adc-bits", type=_ints, default=[16, 8, 6, 4, 3])
    ap.add_argument("--noise-sigmas", type=_floats,
                    default=[0.0, 0.25, 0.5, 1.0])
    ap.add_argument("--fault-rates", type=_floats,
                    default=[0.0, 0.02, 0.05, 0.1])
    ap.add_argument("--noise-sigma", type=float, default=0.5,
                    help="headline noisy point for the recovery run")
    ap.add_argument("--device-seed", type=int, default=7)
    ap.add_argument("--finetune-epochs", type=int, default=10)
    ap.add_argument("--out", default=None,
                    help="write the JSON here instead of stdout")
    ap.add_argument("--log-json", action="store_true",
                    help="structured one-JSON-per-line logging")
    args = ap.parse_args()
    obs.setup_logging(json_mode=args.log_json)

    from repro.core import (
        EncoderConfig, ImcSimConfig, MemhdConfig, MemhdModel,
    )
    from repro.data import load_dataset
    from repro.imcsim import recovery_experiment, robustness_report

    per_class = 120 if args.smoke else 400
    epochs = 4 if args.smoke else 20
    ds = load_dataset(args.dataset, train_per_class=per_class,
                      test_per_class=40)
    enc = EncoderConfig(kind="projection", features=ds.features,
                        dim=args.dim)
    amc = MemhdConfig(dim=args.dim, columns=args.columns,
                      classes=ds.classes, epochs=epochs,
                      kmeans_iters=5 if args.smoke else 25)
    t0 = time.time()
    model = MemhdModel.create(jax.random.key(0), enc, amc)
    model, _ = model.fit(jax.random.key(1), ds.train_x, ds.train_y)
    log.info("trained %sx%s model in %.1fs", args.dim, args.columns,
             time.time() - t0)

    base = ImcSimConfig(seed=args.device_seed)
    report = robustness_report(
        model, ds.test_x, ds.test_y, base=base, adc_bits=args.adc_bits,
        noise_sigmas=args.noise_sigmas, fault_rates=args.fault_rates)

    noisy = dataclasses.replace(base, noise_sigma=args.noise_sigma)
    report["recovery"] = dict(
        recovery_experiment(
            model, jax.random.key(2), ds.train_x, ds.train_y,
            ds.test_x, ds.test_y, noisy, epochs=args.finetune_epochs),
        noise_sigma=args.noise_sigma, device_seed=args.device_seed)
    report["dataset"] = ds.name
    report["wall_s"] = round(time.time() - t0, 2)

    text = json.dumps(report, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        log.info("wrote %s", args.out)
    else:
        print(text)


if __name__ == "__main__":
    main()
