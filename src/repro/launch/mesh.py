"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and smoke tests must keep seeing 1 device.

Mesh geometry (TPU v5e pods of 256 chips):
  single-pod:  (16, 16)       axes ("data", "model")
  multi-pod:   (2, 16, 16)    axes ("pod", "data", "model")

The "pod" axis is an outer data-parallel axis whose collectives cross the
pod-to-pod (DCI) links — the axis the int8 error-feedback gradient
compression targets. "model" carries TP / EP / long-context sequence
sharding; "data" carries DP + FSDP.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.models.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 4),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CI on the 8-device fake backend."""
    return jax.make_mesh(shape, axes)


def make_rules(mesh, *, fsdp: bool = False, shard_seq: bool = False,
               overrides: Optional[tuple] = None) -> ShardingRules:
    return ShardingRules(mesh=mesh, fsdp=fsdp, shard_seq=shard_seq,
                         overrides=overrides)


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
