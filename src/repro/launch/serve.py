"""Batched serving driver: prefill + decode with KV/state caches.

CPU-scale demonstration of the production decode path: a batch of
requests is prefilled token-by-token into per-layer caches (attention
ring buffers / MLA latents / SSM states) and then decoded with greedy or
temperature sampling. The same ``decode_step`` is what the decode_32k and
long_500k dry-run cells lower at pod scale.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --smoke --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import json
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger("serve")


def generate(mcfg, params, prompts: jax.Array, gen_len: int,
             temperature: float = 0.0, seed: int = 0,
             ) -> jax.Array:
    """prompts: (B, P) int32 -> (B, P + gen_len) tokens."""
    from repro.models import transformer as T

    b, p = prompts.shape
    max_len = p + gen_len
    caches = T.init_cache(mcfg, b, max_len)
    step = jax.jit(lambda pr, bt, c: T.decode_step(pr, mcfg, bt, c))

    # Prefill token-by-token (prefill-as-decode keeps one compiled step;
    # a chunked prefill path is the obvious next optimization).
    logits = None
    for t in range(p):
        logits, caches = step(params, {"tokens": prompts[:, t:t + 1]},
                              caches)
    out = [prompts]
    key = jax.random.key(seed)
    cur = None
    for t in range(gen_len):
        if cur is None:
            lg = logits
        else:
            lg, caches = step(params, {"tokens": cur}, caches)
        lg = lg[..., : mcfg.vocab_size]  # drop padded-vocab logits
        if temperature > 0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(
                sub, lg / temperature, axis=-1)[:, None]
        else:
            cur = jnp.argmax(lg, axis=-1)[:, None]
        cur = cur.astype(jnp.int32)
        out.append(cur)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    from repro.obs import setup_logging
    setup_logging()

    from repro.configs import get_config, get_smoke_config
    from repro.models import transformer as T

    mcfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    if mcfg.frontend != "none":
        raise SystemExit("modality archs: see examples/ drivers")
    params, _ = T.init_params(jax.random.key(0), mcfg)
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0,
        mcfg.vocab_size, dtype=jnp.int32)

    t0 = time.time()
    out = generate(mcfg, params, prompts, args.gen,
                   temperature=args.temperature)
    dt = time.time() - t0
    toks = args.batch * (args.prompt_len + args.gen)
    print(json.dumps({
        "arch": mcfg.name,
        "batch": args.batch,
        "tokens_total": int(toks),
        "wall_s": round(dt, 2),
        "tok_per_s": round(toks / dt, 1),
        "sample_row": np.asarray(out[0, :16]).tolist(),
    }, indent=1))


if __name__ == "__main__":
    main()
