"""Online serving driver: a timed request stream with live updates.

Stages the full online-deployment story end-to-end and prints one JSON
report (the CI smoke parses it):

  phase A  — Poisson arrivals over the trained classes;
  fold 1   — labeled *drifted* feedback arrives mid-stream and folds
             through QAIL (``--drift``): same geometry, so the artifact
             swap is shape-stable and costs zero steady recompiles;
  phase B  — drifted arrivals served by generation 1;
  fold 2   — feedback labeled with a never-seen class
             (``--append-class``): the AM grows (D,C)->(D,C+1), the
             artifact re-packs through the deploy registry, the engine
             re-warms its bucket grid once (an excluded compile
             window);
  phase C  — arrivals including the appended class.

The engine's report is extended with per-phase accuracy and latency
(requests carry ground-truth labels for scoring only — the engine
itself is label-blind). ``recompiles_steady_state`` must print 0: every
compile belongs to the warmup / fold / rewarm windows.

Examples:

    python -m repro.launch.serve_online --smoke
    python -m repro.launch.serve_online --smoke --append-class \
        --devices 8 --target hierarchical
"""
from __future__ import annotations

import argparse
import json
import logging
from typing import Dict, List, Optional

import jax
import numpy as np

from repro import obs

log = logging.getLogger("serve_online")

# rid blocks per phase — keeps phase membership recoverable from the
# engine's flat response map.
RID_BLOCK = 100_000
PHASES = ("A", "B", "C")


def phase_stats(phase_idx: int, arrivals, engine) -> Dict:
    """Per-phase accuracy + latency summary from the engine's maps."""
    reqs = [a.request for a in arrivals]
    lats = [engine.request_lat_ms[r.rid] for r in reqs
            if r.rid in engine.request_lat_ms]
    hits = total = 0
    for r in reqs:
        pred = engine.responses.get(r.rid)
        if pred is None or r.labels is None:
            continue
        hits += int((np.asarray(pred) == np.asarray(r.labels)).sum())
        total += r.size
    misses = sum(
        1 for r in reqs
        if r.deadline_ms is not None and r.rid in engine.request_lat_ms
        and engine.request_lat_ms[r.rid] > r.deadline_ms)
    with_deadline = sum(1 for r in reqs if r.deadline_ms is not None
                        and r.rid in engine.request_lat_ms)
    return {
        "requests": len(reqs),
        "rows": sum(r.size for r in reqs),
        "accuracy": round(hits / total, 4) if total else None,
        "lat_ms_p50": (round(float(np.percentile(lats, 50)), 3)
                       if lats else None),
        "lat_ms_p99": (round(float(np.percentile(lats, 99)), 3)
                       if lats else None),
        "deadline_miss_rate": (round(misses / with_deadline, 4)
                               if with_deadline else None),
    }


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny training budget + short stream (CI-sized)")
    ap.add_argument("--requests", type=int, default=80,
                    help="requests per phase")
    ap.add_argument("--rate", type=float, default=300.0,
                    help="Poisson arrival rate (QPS)")
    ap.add_argument("--max-size", type=int, default=8,
                    help="max rows per request")
    ap.add_argument("--max-batch", type=int, default=128)
    ap.add_argument("--deadline-ms", type=float, default=250.0,
                    help="per-request latency budget (0 = best-effort)")
    ap.add_argument("--max-wait-ms", type=float, default=20.0,
                    help="batching policy's bounded-staleness cap")
    ap.add_argument("--target", default="packed",
                    choices=["packed", "unpacked", "imc", "hierarchical"])
    ap.add_argument("--fused", action="store_true",
                    help="serve through the fused feature pipeline")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard serving over the first N local devices")
    ap.add_argument("--depth", type=int, default=2,
                    help="double-buffer depth (batches in flight)")
    ap.add_argument("--fold-epochs", type=int, default=2,
                    help="QAIL epochs per feedback fold")
    ap.add_argument("--drift", type=float, default=0.35,
                    help="covariate-drift strength for fold 1 "
                         "(0 disables the drift phase)")
    ap.add_argument("--append-class", action="store_true",
                    help="hold out the last class at training time and "
                         "append it live via mid-stream feedback")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--events-out", default=None,
                    help="append-only JSONL event log (generation "
                         "swaps, serve start/end)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the obs metrics-registry snapshot here")
    ap.add_argument("--record-dir", default=None,
                    help="persist the report as BENCH_serve_online.json "
                         "(benchmarks.record) in this directory")
    ap.add_argument("--log-json", action="store_true")
    args = ap.parse_args(argv)
    obs.setup_logging(json_mode=args.log_json)
    obs.install()

    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    from repro.data import load_dataset
    from repro.deploy import ShardedArtifact
    from repro.serve import (
        OnlineEngine, StreamingUpdater, apply_drift, feedback_burst,
        merge_events, poisson_arrivals,
    )

    if args.smoke:
        args.requests = min(args.requests, 40)
    per_class = 80 if args.smoke else 300
    epochs = 2 if args.smoke else 10
    ds = load_dataset("mnist", train_per_class=per_class,
                      test_per_class=40)
    known = ds.classes - 1 if args.append_class else ds.classes
    tr_x, tr_y = np.asarray(ds.train_x), np.asarray(ds.train_y)
    te_x, te_y = np.asarray(ds.test_x), np.asarray(ds.test_y)
    mask = tr_y < known
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=4 * known, classes=known,
                      epochs=epochs, kmeans_iters=5)
    model = MemhdModel.create(jax.random.key(args.seed), enc, amc)
    model, _ = model.fit(jax.random.key(args.seed + 1),
                         tr_x[mask], tr_y[mask])
    log.info("trained on %d/%d classes (C=%d, D=%d)", known, ds.classes,
             amc.columns, amc.dim)

    deployed = model.deploy(target=args.target)
    if args.devices > 1:
        deployed = ShardedArtifact(deployed, devices=args.devices)
        log.info("sharded serving over %d devices", args.devices)

    events_log = obs.EventLog(args.events_out)
    updater = StreamingUpdater(model, deployed,
                               fold_epochs=args.fold_epochs,
                               events=events_log)
    engine = OnlineEngine(updater, max_batch=args.max_batch,
                          depth=args.depth, fused=args.fused,
                          max_wait_ms=args.max_wait_ms,
                          events=events_log)

    deadline = args.deadline_ms or None
    kw = dict(rate_qps=args.rate, max_size=args.max_size,
              deadline_ms=deadline, labels_pool=te_y)
    drift = args.drift if args.drift > 0 else 0.0
    phases: Dict[str, List] = {}
    streams: List[List] = []

    # Phase A: clean arrivals over the trained classes.
    phases["A"] = poisson_arrivals(te_x, n_requests=args.requests,
                                   classes=range(known),
                                   seed=args.seed + 10, **kw)
    t = phases["A"][-1].t + 1e-3
    streams.append(phases["A"])

    # Fold 1: labeled drifted feedback -> shape-stable generation swap.
    if drift:
        streams.append(feedback_burst(
            apply_drift(tr_x[mask], drift), tr_y[mask], t=t, fold=True))
    pool_b = apply_drift(te_x, drift) if drift else te_x
    phases["B"] = poisson_arrivals(pool_b, n_requests=args.requests,
                                   classes=range(known), start=t,
                                   rid_base=RID_BLOCK,
                                   seed=args.seed + 11, **kw)
    t = phases["B"][-1].t + 1e-3
    streams.append(phases["B"])

    # Fold 2: feedback for a never-seen class -> grow + re-pack swap.
    if args.append_class:
        new = tr_y == known
        streams.append(feedback_burst(tr_x[new], tr_y[new], t=t,
                                      fold=True))
        phases["C"] = (
            poisson_arrivals(pool_b, n_requests=args.requests // 2,
                             classes=range(known), start=t,
                             rid_base=2 * RID_BLOCK,
                             seed=args.seed + 12, **kw)
            + poisson_arrivals(te_x, n_requests=args.requests // 2,
                               classes=[known], start=t,
                               rid_base=3 * RID_BLOCK,
                               seed=args.seed + 13, **kw))
        streams.append(phases["C"])

    report = engine.serve(merge_events(*streams))
    obs.update_memory_gauges()
    report = {
        "workload": "memhd_online_serve",
        "backend": deployed.backend,
        "devices": int(getattr(deployed, "n_devices", 1)),
        "pipeline": "fused" if args.fused else "staged",
        "geometry": (f"{updater.model.am_cfg.dim}"
                     f"x{updater.model.am_cfg.columns}"),
        "classes": updater.model.am_cfg.classes,
        "scenario": {
            "drift": drift, "append_class": bool(args.append_class),
            "rate_qps": args.rate, "deadline_ms": deadline,
            "requests_per_phase": args.requests,
        },
        **report,
        "phases": {name: phase_stats(i, arr, engine)
                   for i, (name, arr) in enumerate(phases.items())},
    }
    print(json.dumps(report, indent=1))

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(obs.snapshot(), f, indent=1)
        log.info("metrics snapshot -> %s", args.metrics_out)
    if args.record_dir:
        try:
            from benchmarks import record
        except ImportError as e:
            raise SystemExit(
                f"--record-dir needs the benchmarks package importable "
                f"(run from the repo root): {e}")
        path = record.from_report("serve_online", report,
                                  out_dir=args.record_dir)
        log.info("recorded -> %s", path)
    return report


if __name__ == "__main__":
    main()
