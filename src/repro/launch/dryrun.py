"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each
cell we build abstract train/serve state, jit with the production mesh's
in/out shardings, ``.lower().compile()``, and record

  * memory_analysis()  — per-chip bytes (does it fit 16 GB v5e HBM?)
  * cost_analysis()    — per-chip FLOPs / bytes for §Roofline
  * collective inventory (parsed from the post-SPMD HLO)

Artifacts land in reports/dryrun/<arch>__<shape>__<mesh>.json; the
roofline benchmark and EXPERIMENTS.md tables read from there.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m \
      --shape train_4k --mesh single           # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
# The fake-device flag MUST precede any jax import (jax locks the device
# count at first init) — keep these the first two lines of the module.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import logging       # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

log = logging.getLogger("dryrun")

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             overrides: dict | None = None,
             report_dir: str = REPORT_DIR) -> dict:
    """Lower+compile one cell; returns (and writes) the report dict."""
    from repro.configs import shape_spec
    from repro.distributed import collective_bytes, roofline
    from repro.distributed.steps import (
        abstract_train_state, make_serve_step, make_train_step,
    )
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh, make_rules, mesh_name
    from repro.models.sharding import param_sharding_tree
    from repro.optim import AdamWConfig, ScheduleConfig, make_schedule
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    sp = shape_spec(shape)
    cfg = S.model_config_for_cell(arch, shape)
    overrides = dict(overrides or {})
    # Step-level knobs (not ModelConfig fields).
    forced_accum = overrides.pop("grad_accum", None)
    rule_overrides = overrides.pop("rule_overrides", None)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    rules = make_rules(mesh, fsdp=cfg.fsdp, shard_seq=cfg.shard_seq,
                       overrides=rule_overrides)

    opt_cfg = AdamWConfig(state_dtype="bf16" if cfg.param_dtype ==
                          "bfloat16" else "fp32")
    chips = mesh.devices.size
    report = {
        "arch": arch, "shape": shape, "mesh": mesh_name(mesh),
        "chips": chips, "step": sp.step, "status": "error",
        "fsdp": cfg.fsdp, "shard_seq": cfg.shard_seq,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
    }

    with mesh:
        if sp.step == "train":
            params_sds, opt_sds, axes = abstract_train_state(cfg, opt_cfg)
            p_sh = param_sharding_tree(axes, rules, params_sds)
            o_sh = {
                "m": p_sh, "v": p_sh,
                "step": NamedSharding(mesh, P()),
            }
            if "ef_err" in opt_sds:
                o_sh["ef_err"] = p_sh
            batch_sds = S.train_input_specs(cfg, sp.seq_len, sp.global_batch)
            b_sh = S.batch_shardings(mesh, batch_sds)
            sched = make_schedule(ScheduleConfig())
            # Auto microbatching: keep live per-chip activations bounded
            # (~4k tokens per chip per microbatch); recorded in the
            # report so §Perf can iterate on it.
            data_shards = chips // mesh.shape["model"]
            tokens_local = sp.seq_len * sp.global_batch // data_shards
            if forced_accum is not None:
                grad_accum = int(forced_accum)
                report["overrides"]["grad_accum"] = grad_accum
            else:
                # Microbatches must stay shardable over the data axes:
                # accum <= global_batch / data_shards.
                max_accum = max(1, sp.global_batch // data_shards)
                grad_accum = 1
                while (tokens_local // grad_accum > 4096
                       and grad_accum * 2 <= max_accum):
                    grad_accum *= 2
            report["grad_accum"] = grad_accum
            step_fn = make_train_step(cfg, opt_cfg, sched, rules,
                                      grad_accum=grad_accum)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, o_sh, b_sh, NamedSharding(mesh, P())),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            args = (params_sds, opt_sds, batch_sds,
                    jax.ShapeDtypeStruct((), jnp.int32))
            tokens = sp.seq_len * sp.global_batch
            mf = roofline.__module__  # silence linters
            del mf
            model_flops = 6.0 * cfg.active_param_count() * tokens
        else:
            from repro.distributed.steps import abstract_train_state as _ats
            from repro.models import layers as L
            from repro.models import transformer as T
            with L.abstract_init():
                params_sds, axes = T.init_params(jax.random.key(0), cfg)
            p_sh = param_sharding_tree(axes, rules, params_sds)
            batch_sds, cache_sds = S.decode_input_specs(
                cfg, sp.seq_len, sp.global_batch)
            b_sh = S.batch_shardings(mesh, batch_sds)
            c_sh = S.cache_shardings(mesh, cache_sds, rules)
            step_fn = make_serve_step(cfg, rules)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, b_sh, c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            )
            args = (params_sds, batch_sds, cache_sds)
            # decode: one token per sequence in the batch, fwd only
            model_flops = 2.0 * cfg.active_param_count() * sp.global_batch

        try:
            t_lower = time.time()
            lowered = jitted.lower(*args)
            t_compile = time.time()
            compiled = lowered.compile()
            t_done = time.time()

            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            # Loop-corrected accounting (cost_analysis counts while bodies
            # once — useless for scanned-layer stacks; see hlo_cost.py).
            from repro.distributed import hlo_cost
            totals = hlo_cost.analyze(hlo, chips)

            from repro.distributed.roofline import roofline as mk_roofline
            rep = mk_roofline(
                arch=arch, shape=shape, mesh_name=mesh_name(mesh),
                chips=chips,
                flops_per_dev=totals.flops,
                bytes_per_dev=totals.hbm_bytes,
                wire_by_kind=totals.wire_by_kind,
                model_flops_global=model_flops,
                argument_bytes=float(getattr(ma, "argument_size_in_bytes",
                                             0) or 0),
                temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0) or 0),
                output_bytes=float(getattr(ma, "output_size_in_bytes", 0)
                                   or 0),
            )
            report.update(
                status="ok",
                lower_s=round(t_compile - t_lower, 2),
                compile_s=round(t_done - t_compile, 2),
                roofline=rep.to_json(),
                raw_cost_analysis={
                    "flops_loop_naive": float(ca.get("flops", 0.0)),
                    "bytes_loop_naive": float(
                        ca.get("bytes accessed", 0.0)),
                },
                memory={
                    "argument_bytes": int(ma.argument_size_in_bytes),
                    "temp_bytes": int(ma.temp_size_in_bytes),
                    "output_bytes": int(ma.output_size_in_bytes),
                    "alias_bytes": int(ma.alias_size_in_bytes),
                    "fits_16GB": bool(
                        ma.argument_size_in_bytes + ma.temp_size_in_bytes
                        - ma.alias_size_in_bytes < 16e9),
                },
                n_collectives=len([1 for line in hlo.splitlines()
                                   if "all-" in line or "collective-" in
                                   line]),
            )
        except Exception as e:  # noqa: BLE001 — report & continue
            report.update(status="error", error=f"{type(e).__name__}: {e}",
                          traceback=traceback.format_exc()[-2000:])

    report["total_s"] = round(time.time() - t0, 2)
    os.makedirs(report_dir, exist_ok=True)
    import hashlib
    tag = "_".join(f"{k}-{v}" for k, v in report["overrides"].items())
    if len(tag) > 48:  # long structured overrides: stable short hash
        tag = hashlib.md5(tag.encode()).hexdigest()[:10]
    fn = os.path.join(
        report_dir,
        f"{arch}__{shape}__{report['mesh']}" + (f"__{tag}" if tag else "")
        + ".json")
    with open(fn, "w") as f:
        json.dump(report, f, indent=1, default=str)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-skipped", action="store_true",
                    help="also attempt cells marked SKIP (full-attn 500k)")
    args = ap.parse_args()

    from repro.obs import setup_logging
    setup_logging()

    from repro.configs import cell_applicable, cells

    if args.all:
        todo = list(cells(include_skipped=args.include_skipped))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        todo = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch, shape in todo:
        if not cell_applicable(arch, shape) and not args.include_skipped:
            log.info("SKIP %s x %s (inapplicable)", arch, shape)
            continue
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            log.info("dry-run %s ...", tag)
            rep = run_cell(arch, shape, multi_pod=mp)
            ok = rep["status"] == "ok"
            extra = ""
            if ok:
                r = rep["roofline"]
                extra = (f" dominant={r['dominant']}"
                         f" bound={r['bound_seconds']:.4f}s"
                         f" fits={rep['memory']['fits_16GB']}")
            log.info("%s -> %s (%.1fs)%s", tag, rep["status"],
                     rep["total_s"], extra)
            if not ok:
                log.error("  error: %s", rep.get("error"))
            results.append(rep)

    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\n=== dry-run: {n_ok}/{len(results)} cells OK ===")
    for r in results:
        if r["status"] != "ok":
            print(f"FAILED {r['arch']} x {r['shape']} x {r['mesh']}: "
                  f"{r.get('error')}")


if __name__ == "__main__":
    main()
