"""1-bit pack/unpack Pallas kernels — binary AM storage.

The paper's memory-efficiency claims (Table I, Fig. 3) count the AM and
projection matrix at 1 bit per cell. These kernels realize that storage
format on TPU: bipolar (+-1) tiles are packed 8 cells/byte (LSB-first)
for HBM residence and unpacked tile-by-tile into VMEM for compute.

Both kernels are purely element-wise over (R, C) tiles, so blocks are
(block_r, 1024) lanes — VPU work, no MXU involvement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.deploy.padding import pad_tiles

Array = jax.Array

LANES = 1024  # unpacked cells per block column; packed cols = LANES // 8


def _pack_kernel(x_ref, o_ref):
    x = x_ref[...]  # (bR, LANES)
    br = x.shape[0]
    bits = (x > 0).astype(jnp.int32).reshape(br, LANES // 8, 8)
    weights = (2 ** jnp.arange(8, dtype=jnp.int32))
    o_ref[...] = jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def _unpack_kernel(p_ref, o_ref):
    p = p_ref[...].astype(jnp.int32)  # (bR, LANES // 8)
    br = p.shape[0]
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (p[:, :, None] >> shifts) & 1  # (bR, LANES//8, 8)
    o_ref[...] = (bits.reshape(br, LANES).astype(jnp.float32) * 2 - 1)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def pack_bits(x: Array, *, block_r: int = 256,
              interpret: bool | None = None) -> Array:
    """(R, C) bipolar -> (R, C // 8) uint8, C % 8 == 0 (pad upstream)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    r, c = x.shape
    if c % 8:
        raise ValueError(f"C={c} must be a multiple of 8")
    br = min(block_r, max(r, 1))
    xp = pad_tiles(x.astype(jnp.float32), br, LANES, value=-1.0)
    gr, gc = xp.shape[0] // br, xp.shape[1] // LANES

    out = pl.pallas_call(
        _pack_kernel,
        grid=(gr, gc),
        in_specs=[pl.BlockSpec((br, LANES), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, LANES // 8), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], xp.shape[1] // 8),
                                       jnp.uint8),
        interpret=interpret,
    )(xp)
    return out[:r, : c // 8]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def unpack_bits(packed: Array, *, block_r: int = 256,
                interpret: bool | None = None) -> Array:
    """(R, C//8) uint8 -> (R, C) bipolar float32 {-1, +1}."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    r, cb = packed.shape
    br = min(block_r, max(r, 1))
    pp = pad_tiles(packed, br, LANES // 8)
    gr, gc = pp.shape[0] // br, pp.shape[1] // (LANES // 8)

    out = pl.pallas_call(
        _unpack_kernel,
        grid=(gr, gc),
        in_specs=[pl.BlockSpec((br, LANES // 8), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, LANES), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pp.shape[0], pp.shape[1] * 8),
                                       jnp.float32),
        interpret=interpret,
    )(pp)
    return out[:r, : cb * 8]
