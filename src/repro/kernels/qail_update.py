"""Fused QAIL inner-step Pallas kernel: sims MVM + Eq.-(4)/(5) + Eq.-(6).

The training hot loop of the paper (§III-C): each minibatch computes the
similarity of its binarized queries against the binary AM, selects the
push-away (Eq. 4, global argmax) and pull-toward (Eq. 5, true-class
argmax) centroids for every mispredicted sample, and emits the Eq.-(6)
delta for the float shadow AM. Unfused, that is a matmul, two argmax
reductions, two gathers, and a scatter — five HBM round-trips of (B, C)
similarities and (C, D) deltas per batch.

Here the whole step is ONE VMEM-resident pass: the grid walks query
blocks only, with the transposed binary AM, the update payload and the
(C, D) delta accumulator resident in VMEM across steps. Scatter-free by
construction — target selection becomes a one-hot selection matrix W
(B, C) with W[i] = lr*mis_i*(onehot(true) - onehot(pred)), and the delta
is the MXU matmul W^T @ upd accumulated over query blocks. The miss
count rides along in a (1, 1) accumulator, so training needs no second
pass to know its error rate.

Padded columns are masked to -inf before both argmaxes (they can never
be selected); padded rows carry mask 0 and label -1 (their W row is
zero); padded D columns contribute zero delta. Ties resolve first-wins,
matching ``jnp.argmax`` and ``kernels.ref.qail_update_delta``, the
bit-exact oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.deploy.padding import pad_tiles, pad_vec

Array = jax.Array

TILE = 128

# Batch-tile height of the query-block grid walk: the free tiling knob
# (the AM, payload and (C, D) delta stay VMEM-resident regardless).
# ``kernels.autotune`` searches TUNE_BLOCK_B per geometry and ops.py
# applies the cached winner; DEFAULT_BLOCK_B is the fallback.
DEFAULT_BLOCK_B = 256
TUNE_BLOCK_B = (64, 128, 256, 512, 1024)


def _make_kernel(n_valid_cols: int, lr: float):
    """Bind the static valid-column count and learning rate."""

    def kernel(q_ref, upd_ref, am_ref, own_ref, y_ref, mask_ref,
               delta_ref, miss_ref):
        b, nb = pl.program_id(0), pl.num_programs(0)

        @pl.when(b == 0)
        def _init():
            delta_ref[...] = jnp.zeros_like(delta_ref)
            miss_ref[...] = jnp.zeros_like(miss_ref)

        sims = jnp.dot(q_ref[...].astype(jnp.float32),
                       am_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)  # (bB, C)
        col = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 1)
        valid = col < n_valid_cols
        neg = jnp.finfo(jnp.float32).min
        sims = jnp.where(valid, sims, neg)

        owners = own_ref[...]          # (1, C) int32, padded cols = -1
        labels = y_ref[...]            # (bB, 1) int32, padded rows = -1

        # Eq. (4): global argmax -> push-away target, one-hot on C.
        pred_t = jnp.argmax(sims, axis=1)  # (bB,)
        pred_hot = col == pred_t[:, None]  # (bB, C)
        pred_class = jnp.sum(jnp.where(pred_hot, owners, 0), axis=1)

        # Eq. (5): argmax within the true class -> pull-toward target.
        own_mask = (owners == labels) & valid  # (bB, C)
        true_t = jnp.argmax(jnp.where(own_mask, sims, neg), axis=1)
        true_hot = col == true_t[:, None]

        mis = ((pred_class != labels[:, 0]).astype(jnp.float32)
               * mask_ref[...][:, 0])  # (bB,)

        # Eq. (6) as a selection matmul: delta += W^T @ upd on the MXU.
        w = (lr * mis)[:, None] * (true_hot.astype(jnp.float32)
                                   - pred_hot.astype(jnp.float32))
        delta_ref[...] += jnp.dot(w.T, upd_ref[...].astype(jnp.float32),
                                  preferred_element_type=jnp.float32)
        miss_ref[0, 0] += jnp.sum(mis)
        del nb

    return kernel


@functools.partial(jax.jit, static_argnames=("lr", "block_b", "interpret"))
def qail_update(q: Array, upd: Array, am_t: Array, centroid_class: Array,
                labels: Array, mask: Array, *, lr: float,
                block_b: int = DEFAULT_BLOCK_B,
                interpret: bool | None = None) -> tuple[Array, Array]:
    """Fused QAIL inner step for one minibatch.

    Args:
      q: (B, D) binarized queries H^b.
      upd: (B, D) Eq.-(6) update payload (encoded H or H^b).
      am_t: (D, C) transposed binary AM (column c = centroid c).
      centroid_class: (C,) int centroid ownership.
      labels: (B,) int true labels (-1 marks padded rows).
      mask: (B,) float {0, 1} sample validity.
      lr: iterative-learning rate alpha (static).
      block_b: query-block tile height (grid walks B only; AM, payload
        and the (C, D) delta stay VMEM-resident across blocks).
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (delta, n_miss): (C, D) float32 Eq.-(6) AM increment and the
      scalar float32 count of mispredicted (masked) samples. Bit-exact
      vs ``kernels.ref.qail_update_delta``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, dd = q.shape
    dd2, c = am_t.shape
    assert dd == dd2, (q.shape, am_t.shape)
    assert upd.shape == q.shape, (upd.shape, q.shape)

    bb = min(block_b, max(b, 1))
    qp = pad_tiles(q.astype(jnp.float32), bb, TILE)
    up = pad_tiles(upd.astype(jnp.float32), bb, TILE)
    ap = pad_tiles(am_t.astype(jnp.float32), TILE, TILE)
    pb, pd = qp.shape[0] - b, qp.shape[1] - dd
    pc = ap.shape[1] - c
    ownp = pad_vec(centroid_class.astype(jnp.int32), c + pc,
                   value=-1)[None, :]
    yp = pad_vec(labels.astype(jnp.int32), b + pb, value=-1)[:, None]
    mp = pad_vec(mask.astype(jnp.float32), b + pb)[:, None]
    gb = qp.shape[0] // bb

    delta, miss = pl.pallas_call(
        _make_kernel(c, lr),
        grid=(gb,),
        in_specs=[
            pl.BlockSpec((bb, dd + pd), lambda i: (i, 0)),
            pl.BlockSpec((bb, dd + pd), lambda i: (i, 0)),
            pl.BlockSpec((dd + pd, c + pc), lambda i: (0, 0)),
            pl.BlockSpec((1, c + pc), lambda i: (0, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c + pc, dd + pd), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c + pc, dd + pd), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, up, ap, ownp, yp, mp)
    return delta[:c, :dd], miss[0, 0]
