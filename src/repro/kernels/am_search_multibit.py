"""Bit-sliced multi-bit associative search: packed int2/int4 MVM + ADC.

The 1-bit deployment paths bound the accuracy/memory frontier from one
side (``am_search_packed``: 1 bit/cell, binary accuracy) and the float
path from the other (``am_search``: 32 bits/cell, float accuracy). This
kernel opens the region between them: the resident AM is a symmetric
``cell_bits``-bit quantization of the *float* AM shadow, stored as bit
planes packed 8 cells/byte along D (``ref.pack_planes``), and the search
runs on the ``am_search_imc`` tiling/grid contract — one (C, D) grid
step is one physical array pass over multi-level cells.

Bit-sliced MVM, per tile, entirely in VMEM:

    codes are stored as offset codes  u = code + Qmax  in  [0, 2^b - 2]
    (Qmax = 2^(b-1) - 1), one packed bit plane per bit of u.  Each plane
    is unpacked to a {0, 1} float slab and fed to the MXU; the per-plane
    partial sums combine with shifted weights and the offset is removed
    with a single rowsum correction:

        part = sum_p 2^p * (q_tile @ U_p)  -  Qmax * rowsum(q_tile)
             = q_tile @ (u - Qmax)  =  q_tile @ codes        (exact)

    then the ``am_search_imc`` epilogue: per-tile readout drift offset,
    symmetric mid-tread ADC, digital accumulation, and the first-wins
    running-winner fold.

Everything inside the kernel lives in the integer *code* domain: with
bipolar queries every partial sum is an integer bounded by
``Qmax * tile_rows`` (~1024 at b=4, A=128), far below 2^24, so float32
arithmetic is exact and the kernel is bit-for-bit equal to the
``ref.am_search_multibit`` oracle — the same fidelity-parity contract
``am_search_imc`` has. The default ADC clip (``ref.multibit_adc_clip``:
next power of two >= Qmax * tile_rows) keeps the mid-tread step a power
of two, so any ADC with step <= 1 reproduces the un-quantized search
exactly. Dequantized similarities are the caller's job: multiply by the
quantizer scale outside the kernel (argmax is scale-invariant).

Padding semantics: packed D-tail bits are 0, i.e. offset code u = 0 and
effective code -Qmax — harmless because the matching query rows are
zero-padded (the rowsum correction has the same property). Padded C
columns are masked to -inf before the winner update, as everywhere.

Memory: C * D * cell_bits resident bits — 16x (b=2) / 8x (b=4) below
the 32-bit unpacked float AM, while reading out against the float
shadow's accuracy rather than the binarized AM's.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.deploy.padding import pad_tiles
from repro.kernels.ref import multibit_adc_clip

Array = jax.Array

# Batch-tile height knob, same ladder as the other search kernels; the
# VMEM ceiling is the per-plane unpacked (tile_rows, tile_cols) slab
# plus the (bb, tile_cols) accumulator.
DEFAULT_BLOCK_B = 256
TUNE_BLOCK_B = (64, 128, 256, 512, 1024)


def _make_kernel(n_valid_cols: int, cell_bits: int, adc_bits: int,
                 adc_clip: float, tile_rows: int, tile_cols: int):
    """Bind static geometry + quantizer + ADC transfer into the body."""
    step = 2.0 * adc_clip / (2 ** adc_bits)
    qmax = float(2 ** (cell_bits - 1) - 1)

    def kernel(q_ref, am_ref, off_ref, idx_ref, sim_ref,
               acc_ref, best_sim_ref, best_idx_ref):
        c, d = pl.program_id(1), pl.program_id(2)
        nc, nd = pl.num_programs(1), pl.num_programs(2)

        @pl.when(d == 0)
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[...].astype(jnp.float32)      # (bB, tile_rows)
        slabs = am_ref[...].astype(jnp.int32)   # (bits, tile_rows/8, tc)
        shifts = jnp.arange(8, dtype=jnp.int32)
        # Bit-sliced analog pass: one {0,1} plane per stored bit through
        # the MXU, partial sums combined with shifted weights...
        part = jnp.zeros((q.shape[0], tile_cols), jnp.float32)
        for p in range(cell_bits):
            bits = (slabs[p][:, None, :] >> shifts[:, None]) & 1
            plane = bits.reshape(tile_rows, tile_cols).astype(jnp.float32)
            part += (2.0 ** p) * jnp.dot(
                q, plane, preferred_element_type=jnp.float32)
        # ...minus the offset-code recentering (u = code + Qmax).
        part -= qmax * jnp.sum(q, axis=1, keepdims=True)
        # Readout drift + ADC, then digital accumulation — identical
        # epilogue to am_search_imc, in the code domain.
        part = part + off_ref[0, 0]
        part = jnp.clip(part, -adc_clip, adc_clip)
        part = jnp.round(part / step) * step
        acc_ref[...] += part

        @pl.when(d == nd - 1)
        def _fold_winner():
            sims = acc_ref[...]  # (bB, tile_cols)
            col = c * tile_cols + jax.lax.broadcasted_iota(
                jnp.int32, sims.shape, 1)
            neg = jnp.finfo(jnp.float32).min
            sims = jnp.where(col < n_valid_cols, sims, neg)
            blk_best = jnp.max(sims, axis=1)  # (bB,)
            blk_arg = (c * tile_cols
                       + jnp.argmax(sims, axis=1).astype(jnp.int32))

            @pl.when(c == 0)
            def _first():
                best_sim_ref[...] = blk_best
                best_idx_ref[...] = blk_arg

            @pl.when(c > 0)
            def _update():
                prev_sim = best_sim_ref[...]
                prev_idx = best_idx_ref[...]
                take = blk_best > prev_sim  # strict: first-wins on ties
                best_sim_ref[...] = jnp.where(take, blk_best, prev_sim)
                best_idx_ref[...] = jnp.where(take, blk_arg, prev_idx)

            @pl.when(c == nc - 1)
            def _emit():
                idx_ref[...] = best_idx_ref[...][:, None]
                sim_ref[...] = best_sim_ref[...][:, None]

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "cell_bits", "tile_rows", "tile_cols", "adc_bits", "adc_clip",
    "block_b", "interpret"))
def am_search_multibit(q: Array, am_planes_t: Array,
                       offsets: Array | None = None, *,
                       cell_bits: int, tile_rows: int = 128,
                       tile_cols: int = 128, adc_bits: int = 16,
                       adc_clip: float | None = None,
                       block_b: int = DEFAULT_BLOCK_B,
                       interpret: bool | None = None,
                       ) -> tuple[Array, Array]:
    """Bit-sliced associative search over the multi-bit packed AM.

    Args:
      q: (B, D) bipolar query hypervectors.
      am_planes_t: (cell_bits, ceil(D/8), C) uint8 offset-code bit
        planes — ``ref.pack_planes(codes + Qmax, cell_bits)`` for a
        (C, D) code matrix from ``repro.core.am.quantize_am``.
      offsets: (ceil(D/tile_rows), ceil(C/tile_cols)) per-tile
        code-domain readout offsets, or None for drift-free readout.
      cell_bits: bits per memory cell (2..8).
      tile_rows / tile_cols: physical array geometry (ImcArrayConfig).
      adc_bits / adc_clip: ADC resolution and full-scale range; clip
        defaults to ``ref.multibit_adc_clip(cell_bits, tile_rows)``.
      block_b: query-batch tile height.
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (best_idx, best_sim): (B,) int32 winning centroid per query and
      (B,) float32 its code-domain ADC-quantized similarity (multiply
      by the quantizer scale for the dequantized value).
    """
    if not 2 <= cell_bits <= 8:
        raise ValueError(f"cell_bits={cell_bits} outside [2, 8]")
    if tile_rows % 8:
        raise ValueError(f"tile_rows={tile_rows} not a byte multiple")
    if adc_clip is None:
        adc_clip = multibit_adc_clip(cell_bits, tile_rows)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, dd = q.shape
    n_planes, dp, c = am_planes_t.shape
    if n_planes != cell_bits:
        raise ValueError(
            f"{n_planes} planes for cell_bits={cell_bits}")
    if not dp * 8 >= dd > (dp - 1) * 8:
        raise ValueError(f"D={dd} inconsistent with Dp={dp}")

    bb = min(block_b, max(b, 1))
    tr_p = tile_rows // 8
    qp = pad_tiles(q.astype(jnp.float32), bb, tile_rows)
    gb = qp.shape[0] // bb
    gd = qp.shape[1] // tile_rows
    gc = -(-c // tile_cols)
    # Zero-pad planes: padded cells hold offset code 0; the matching
    # query rows are zero so the recentering stays exact, and padded
    # columns are masked in the winner fold.
    ap = jnp.pad(am_planes_t, ((0, 0), (0, gd * tr_p - dp),
                               (0, gc * tile_cols - c)))
    if offsets is None:
        offsets = jnp.zeros((gd, gc), jnp.float32)
    if offsets.shape != (gd, gc):
        raise ValueError(
            f"offsets shape {offsets.shape} != tile grid {(gd, gc)}")

    idx, sim = pl.pallas_call(
        _make_kernel(c, cell_bits, adc_bits, float(adc_clip),
                     tile_rows, tile_cols),
        grid=(gb, gc, gd),
        in_specs=[
            pl.BlockSpec((bb, tile_rows), lambda i, cc, d: (i, d)),
            pl.BlockSpec((n_planes, tr_p, tile_cols),
                         lambda i, cc, d: (0, d, cc)),
            pl.BlockSpec((1, 1), lambda i, cc, d: (d, cc)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, cc, d: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, cc, d: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, tile_cols), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.int32),
        ],
        interpret=interpret,
    )(qp, ap, offsets.astype(jnp.float32))
    return idx[:b, 0], sim[:b, 0]


def imc_cycles_for(am_planes_t_shape: tuple, tile_rows: int = 128,
                   tile_cols: int = 128) -> int:
    """ceil(D/Ar) * ceil(C/Ac) grid steps per batch tile — multi-level
    cells hold the full code, so the cycle count matches the 1-bit
    ``am_search_imc`` grid for the same logical (D, C) geometry."""
    _, dp, c = am_planes_t_shape
    return (-(-dp * 8 // tile_rows)) * (-(-c // tile_cols))
