"""Search-over-tilings autotuner for the MEMHD hot-path kernels.

``am_search_packed``, ``encode_pack`` (the fused encoder),
``qail_update``, and the two hierarchical-search kernels
(``am_shortlist``, ``am_search_sparse``) ship with a fixed
batch-tile height (``block_b``)
chosen for the paper's flagship 128x128 geometry. The lane/sublane tile
(``TILE = 128``) is NOT searchable — it IS the IMC-array contract
(kernel grid == ``repro.core.imc`` cycle count, asserted in tests) —
but ``block_b`` is a free VMEM-residency knob: it sets how many query
rows each grid step holds resident (scratch accumulators, the XOR
broadcast of the popcount path, the one-hot selection matmul of the
QAIL step), trading fewer grid steps against a larger VMEM footprint.
MIMHD-style frontier work (PAPERS.md) shows the efficiency frontier is
tiling-sensitive; this module searches it instead of hardcoding it.

For each kernel the tuner:

  1. builds deterministic inputs for the requested geometry,
  2. walks the kernel's ``TUNE_BLOCK_B`` candidate list, skipping any
     candidate whose estimated per-step VMEM footprint exceeds the
     budget (``--vmem-budget-mb``, default 8 MB of the ~16 MB/core),
  3. parity-checks every candidate bit-exactly against the ``ref.py``
     oracle BEFORE timing it (a tiling that changes results is a bug,
     never a win — ``block_b`` only re-tiles the batch axis, so outputs
     must be identical),
  4. times the real dispatch path (Pallas; interpret mode off-TPU,
     where per-grid-step overhead still orders block sizes the same
     way: fewer batch steps = fewer dispatched tiles) and caches the
     winner per (kernel, backend, geometry) in a JSON config cache.

``ops.py`` dispatch consults the cache (``tuned_block_b``) whenever the
caller doesn't pin ``block_b`` explicitly, falling back to the kernel's
``DEFAULT_BLOCK_B``; the committed cache ships tuned entries for the
paper geometries. Re-tune after changing a kernel or geometry with:

    PYTHONPATH=src python -m repro.kernels.autotune --kernel all

The cache lives next to this file (``autotune_cache.json``); point
``$MEMHD_AUTOTUNE_CACHE`` elsewhere to experiment without touching the
committed configs. Tuned-vs-default bit-exactness and the cache
round-trip are covered in tests/test_bench_harness.py; the recorded
tuned-vs-default microbench lives in benchmarks/kernel_bench.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import am_search_multibit as _amb
from repro.kernels import am_search_packed as _asp
from repro.kernels import am_search_sparse as _ass
from repro.kernels import am_shortlist as _shl
from repro.kernels import encode_fused as _ef
from repro.kernels import qail_update as _qu
from repro.kernels import ref

SCHEMA_VERSION = 1
CACHE_ENV = "MEMHD_AUTOTUNE_CACHE"
DEFAULT_CACHE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "autotune_cache.json")
DEFAULT_VMEM_BUDGET_MB = 8.0
TILE = 128
TILE_P = TILE // 8


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One tunable kernel: geometry key dims, candidates, runners."""

    name: str
    key_dims: Tuple[str, ...]          # geometry dims identifying a config
    default_block_b: int
    candidates: Tuple[int, ...]
    make_inputs: Callable             # (rng, batch, dims) -> args tuple
    run: Callable                     # (block_b, *args) -> outputs
    run_ref: Callable                 # (*args) -> oracle outputs
    vmem_bytes: Callable              # (block_b, dims) -> int estimate


def _asp_inputs(rng, batch, dims):
    d, c = dims["D"], dims["C"]
    q = jnp.asarray(rng.choice([-1.0, 1.0], size=(batch, d))
                    .astype(np.float32))
    am = jnp.asarray(rng.choice([-1.0, 1.0], size=(c, d))
                     .astype(np.float32))
    return ref.pack_rows(q), ref.pack_rows(am).T, d


def _asp_vmem(bb, dims):
    # Dominant term: the (bb, TILE_P, TILE) int32 XOR broadcast of the
    # popcount path; plus the f32 accumulator and winner scratch.
    return bb * TILE_P * TILE * 4 + bb * TILE * 4 + bb * 8


def _ef_inputs(rng, batch, dims):
    f, d = dims["f"], dims["D"]
    feats = jnp.asarray(rng.random((batch, f)).astype(np.float32))
    proj = jnp.asarray(rng.choice([-1.0, 1.0], size=(f, d))
                       .astype(np.float32))
    return feats, proj


def _ef_vmem(bb, dims):
    # x block + w block + f32 accumulator + packed out block.
    return bb * TILE * 4 * 2 + TILE * TILE * 4 + bb * TILE_P


def _qu_inputs(rng, batch, dims):
    d, c = dims["D"], dims["C"]
    q = jnp.asarray(rng.choice([-1.0, 1.0], size=(batch, d))
                    .astype(np.float32))
    upd = jnp.asarray(rng.choice([-1.0, 1.0], size=(batch, d))
                      .astype(np.float32))
    am_t = jnp.asarray(rng.choice([-1.0, 1.0], size=(d, c))
                       .astype(np.float32))
    own = jnp.asarray(rng.integers(0, max(dims.get("classes", 10), 1),
                                   size=(c,)).astype(np.int32))
    labels = jnp.asarray(rng.integers(
        0, max(dims.get("classes", 10), 1), size=(batch,))
        .astype(np.int32))
    mask = jnp.ones((batch,), jnp.float32)
    return q, upd, am_t, own, labels, mask


def _qu_vmem(bb, dims):
    d = -(-dims["D"] // TILE) * TILE
    c = -(-dims["C"] // TILE) * TILE
    # q + upd blocks, resident AM, resident (C, D) delta, (bb, C) sims/W.
    return 2 * bb * d * 4 + d * c * 4 + c * d * 4 + 2 * bb * c * 4


def _amb_inputs(rng, batch, dims):
    # A quantized float AM packed into offset-code bit planes (inline
    # quantizer — keeps this module kernels-only, no repro.core import).
    d, c, bits = dims["D"], dims["C"], dims["bits"]
    qmax = 2 ** (bits - 1) - 1
    fp = rng.normal(size=(c, d)).astype(np.float32)
    scale = np.abs(fp).max() / qmax
    codes = np.clip(np.round(fp / scale), -qmax, qmax).astype(np.int32)
    planes = ref.pack_planes(jnp.asarray(codes + qmax), bits)
    q = jnp.asarray(rng.choice([-1.0, 1.0], size=(batch, d))
                    .astype(np.float32))
    return q, planes, bits


def _amb_vmem(bb, dims):
    # q block + the per-plane unpacked {0,1} slab + int32 bit broadcast
    # + partial/accumulator blocks and winner scratch.
    return (bb * TILE * 4 + TILE * TILE * 4 + TILE_P * 8 * TILE * 4
            + 2 * bb * TILE * 4 + bb * 8)


def _shl_inputs(rng, batch, dims):
    d, g, s = dims["D"], dims["G"], dims["S"]
    q = jnp.asarray(rng.choice([-1.0, 1.0], size=(batch, d))
                    .astype(np.float32))
    am = jnp.asarray(rng.choice([-1.0, 1.0], size=(g, d))
                     .astype(np.float32))
    return ref.pack_rows(q), ref.pack_rows(am).T, d, s


def _shl_vmem(bb, dims):
    # XOR broadcast + accumulator + the (bb, S + TILE) top-S merge pair.
    s = dims["S"]
    return (bb * TILE_P * TILE * 4 + bb * TILE * 4
            + 2 * bb * (s + TILE) * 8)


def _ass_inputs(rng, batch, dims):
    # Tunes the Pallas half (the gathered-tiles scan): inputs mimic the
    # XLA gather's output — per-query tile slabs with unique original
    # ids and an invalid (id -1) padding run, shared across the batch.
    d, t, k = dims["D"], dims["T"], dims["K"]
    tc = t * TILE
    cols = jnp.asarray(rng.choice([-1.0, 1.0], size=(tc, d))
                       .astype(np.float32))
    q = jnp.asarray(rng.choice([-1.0, 1.0], size=(batch, d))
                    .astype(np.float32))
    ids = rng.permutation(4 * tc)[:tc].astype(np.int32)
    ids[tc - TILE // 2:] = -1
    qp = ref.pack_rows(q)
    tiles = jnp.broadcast_to(ref.pack_rows(cols).T[None, :, :],
                             (batch, qp.shape[1], tc))
    ids_b = jnp.broadcast_to(jnp.asarray(ids)[None, :], (batch, tc))
    return qp, tiles, ids_b, d, k


def _ass_vmem(bb, dims):
    # Per-query uint8 tile block + its int32 XOR broadcast + accumulator
    # + the (bb, K + TILE) top-k merge pair.
    k = dims["K"]
    return (bb * TILE_P * TILE * 5 + bb * TILE * 4
            + 2 * bb * (k + TILE) * 8)


KERNELS: Dict[str, KernelSpec] = {
    "am_search_multibit": KernelSpec(
        name="am_search_multibit",
        key_dims=("D", "C", "bits"),
        default_block_b=_amb.DEFAULT_BLOCK_B,
        candidates=_amb.TUNE_BLOCK_B,
        make_inputs=_amb_inputs,
        run=lambda bb, q, planes, bits: _amb.am_search_multibit(
            q, planes, cell_bits=bits, block_b=bb),
        run_ref=lambda q, planes, bits: ref.am_search_multibit(
            q, planes, cell_bits=bits),
        vmem_bytes=_amb_vmem,
    ),
    "am_search_packed": KernelSpec(
        name="am_search_packed",
        key_dims=("D", "C"),
        default_block_b=_asp.DEFAULT_BLOCK_B,
        candidates=_asp.TUNE_BLOCK_B,
        make_inputs=_asp_inputs,
        run=lambda bb, qp, apt, d: _asp.am_search_packed(
            qp, apt, n_dims=d, block_b=bb),
        run_ref=lambda qp, apt, d: ref.am_search_packed(qp, apt, d),
        vmem_bytes=_asp_vmem,
    ),
    "am_shortlist": KernelSpec(
        name="am_shortlist",
        key_dims=("D", "G", "S"),
        default_block_b=_shl.DEFAULT_BLOCK_B,
        candidates=_shl.TUNE_BLOCK_B,
        make_inputs=_shl_inputs,
        run=lambda bb, qp, spt, d, s: _shl.am_shortlist(
            qp, spt, n_dims=d, s=s, block_b=bb),
        run_ref=lambda qp, spt, d, s: ref.am_shortlist(qp, spt, d, s),
        vmem_bytes=_shl_vmem,
    ),
    "am_search_sparse": KernelSpec(
        name="am_search_sparse",
        key_dims=("D", "T", "K"),
        default_block_b=_ass.DEFAULT_BLOCK_B,
        candidates=_ass.TUNE_BLOCK_B,
        make_inputs=_ass_inputs,
        run=lambda bb, qp, tiles, ids, d, k: _ass.am_search_sparse_gathered(
            qp, tiles, ids, n_dims=d, k=k, block_b=bb),
        run_ref=lambda qp, tiles, ids, d, k: ref.am_search_sparse(
            qp, tiles, ids, d, k),
        vmem_bytes=_ass_vmem,
    ),
    "encode_pack": KernelSpec(
        name="encode_pack",
        key_dims=("f", "D"),
        default_block_b=_ef.DEFAULT_BLOCK_B,
        candidates=_ef.TUNE_BLOCK_B,
        make_inputs=_ef_inputs,
        run=lambda bb, feats, proj: _ef.encode_pack(
            feats, proj, block_b=bb),
        run_ref=lambda feats, proj: ref.encode_pack(feats, proj),
        vmem_bytes=_ef_vmem,
    ),
    "qail_update": KernelSpec(
        name="qail_update",
        key_dims=("D", "C"),
        default_block_b=_qu.DEFAULT_BLOCK_B,
        candidates=_qu.TUNE_BLOCK_B,
        make_inputs=_qu_inputs,
        # Dyadic lr: every Eq.-(6) delta term is +-2^-4 on +-1 payloads,
        # so partial sums are exact in f32 and the per-B-block
        # accumulation a block_b retiling introduces is order-exact —
        # bit-exactness vs the whole-batch oracle holds for EVERY
        # candidate. (A non-dyadic lr differs in the last ulp once
        # batch > block_b; the training engine itself never tiles —
        # its minibatches fit one block.)
        run=lambda bb, q, upd, am_t, own, y, m: _qu.qail_update(
            q, upd, am_t, own, y, m, lr=0.0625, block_b=bb),
        run_ref=lambda q, upd, am_t, own, y, m: ref.qail_update_delta(
            q, upd, am_t, own, y, m, 0.0625),
        vmem_bytes=_qu_vmem,
    ),
}

# Paper geometries tuned by default (and shipped in the committed cache).
DEFAULT_GEOMETRIES: Dict[str, Tuple[Dict[str, int], ...]] = {
    "am_search_multibit": ({"D": 128, "C": 128, "bits": 2},
                           {"D": 128, "C": 128, "bits": 4}),
    "am_search_packed": ({"D": 128, "C": 128}, {"D": 256, "C": 256}),
    # Hierarchical search: one serving-scale geometry (the 128x128
    # flagship model under the default G ~ 1.4*sqrt(C)) and one
    # huge-label geometry matching the C=100k serving recommendation of
    # the benchmarks/hierarchical_search.py sweep (G=448, S=8, balanced
    # layout max_tiles=2 -> T = S*max_tiles = 16).
    "am_shortlist": ({"D": 128, "G": 16, "S": 8},
                     {"D": 1024, "G": 448, "S": 8}),
    "am_search_sparse": ({"D": 128, "T": 8, "K": 1},
                         {"D": 1024, "T": 16, "K": 1}),
    "encode_pack": ({"f": 784, "D": 128}, {"f": 617, "D": 512}),
    "qail_update": ({"D": 128, "C": 128}, {"D": 256, "C": 64}),
}


def geometry_key(kernel: str, **dims) -> str:
    """Canonical geometry key, batch-agnostic: block_b clamps to the
    batch at dispatch, so one entry serves every batch size."""
    spec = KERNELS[kernel]
    missing = [k for k in spec.key_dims if k not in dims]
    if missing:
        raise KeyError(f"{kernel} geometry needs dims {spec.key_dims}, "
                       f"missing {missing}")
    return "_".join(f"{k}{int(dims[k])}" for k in spec.key_dims)


def cache_path() -> str:
    return os.environ.get(CACHE_ENV) or DEFAULT_CACHE


_LOAD_MEMO: Dict[Tuple[str, int], Dict] = {}


def load_cache(path: Optional[str] = None) -> Dict[str, Dict]:
    """The cache's entries dict; memoized per (path, mtime) so the jit
    trace-time lookups in ops.py never re-read an unchanged file."""
    path = path or cache_path()
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return {}
    memo_key = (os.path.abspath(path), mtime)
    if memo_key not in _LOAD_MEMO:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
        entries = data.get("entries", {})
        if data.get("schema_version") != SCHEMA_VERSION:
            entries = {}
        if len(_LOAD_MEMO) > 16:
            _LOAD_MEMO.clear()
        _LOAD_MEMO[memo_key] = entries
    return _LOAD_MEMO[memo_key]


def save_entry(entry: Dict, path: Optional[str] = None) -> str:
    path = path or cache_path()
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        data = {}
    if data.get("schema_version") != SCHEMA_VERSION:
        data = {"schema_version": SCHEMA_VERSION, "entries": {}}
    key = f"{entry['kernel']}|{entry['backend']}|{entry['geometry']}"
    data["entries"][key] = entry
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def lookup(kernel: str, geometry: str, backend: Optional[str] = None,
           ) -> Optional[Dict]:
    backend = backend or jax.default_backend()
    return load_cache().get(f"{kernel}|{backend}|{geometry}")


def tuned_block_b(kernel: str, **dims) -> int:
    """The block_b ops.py dispatch uses: cached winner, else default."""
    spec = KERNELS[kernel]
    entry = lookup(kernel, geometry_key(kernel, **dims))
    if entry is not None:
        return int(entry["block_b"])
    return spec.default_block_b


def _time_call(fn, *args, iters: int = 3) -> float:
    """Min wall time per call in us (min is the stable tuning statistic)."""
    jax.block_until_ready(fn(*args))  # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _assert_parity(got, want, label: str) -> None:
    got = jax.tree.leaves(got)
    want = jax.tree.leaves(want)
    assert len(got) == len(want), label
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=label)


def autotune_kernel(kernel: str, dims: Dict[str, int], *,
                    batch: int = 512, iters: int = 3, seed: int = 0,
                    vmem_budget_mb: float = DEFAULT_VMEM_BUDGET_MB,
                    save: bool = True,
                    cache: Optional[str] = None) -> Dict:
    """Tune one kernel at one geometry; returns (and caches) the entry.

    Every candidate is parity-checked bit-exactly against the ref.py
    oracle before timing — the search can only ever trade speed, never
    results.
    """
    spec = KERNELS[kernel]
    rng = np.random.default_rng(seed)
    args = spec.make_inputs(rng, batch, dims)
    want = spec.run_ref(*args)

    budget = int(vmem_budget_mb * 1024 * 1024)
    timings: Dict[str, float] = {}
    skipped: Dict[str, int] = {}
    seen_clamped = set()
    best_bb, best_us = None, float("inf")
    for bb in spec.candidates:
        clamped = min(bb, batch)
        if clamped in seen_clamped:
            continue  # same effective tile as a smaller candidate
        seen_clamped.add(clamped)
        est = int(spec.vmem_bytes(clamped, dims))
        if est > budget:
            skipped[str(bb)] = est
            continue
        _assert_parity(spec.run(bb, *args), want,
                       f"{kernel} block_b={bb} diverged from ref oracle")
        us = _time_call(lambda *a: spec.run(bb, *a), *args, iters=iters)
        timings[str(bb)] = round(us, 1)
        if us < best_us:
            best_bb, best_us = bb, us
    if best_bb is None:
        raise RuntimeError(
            f"{kernel}: every candidate in {spec.candidates} exceeded "
            f"the {vmem_budget_mb} MB VMEM budget")

    default_us = timings.get(str(min(spec.default_block_b, batch)))
    if default_us is None:
        default_us = _time_call(
            lambda *a: spec.run(spec.default_block_b, *a), *args,
            iters=iters)
    entry = {
        "kernel": kernel,
        "backend": jax.default_backend(),
        "geometry": geometry_key(kernel, **dims),
        "dims": {k: int(v) for k, v in dims.items()},
        "block_b": int(best_bb),
        "default_block_b": spec.default_block_b,
        "tuned_batch": int(batch),
        "best_us": round(best_us, 1),
        "default_us": round(float(default_us), 1),
        "speedup_vs_default": round(float(default_us) / best_us, 3),
        "candidates_us": timings,
        "skipped_vmem": skipped,
        "vmem_budget_mb": vmem_budget_mb,
        "vmem_bytes_est": int(spec.vmem_bytes(min(best_bb, batch), dims)),
        "created_unix": int(time.time()),
    }
    if save:
        save_entry(entry, path=cache)
    return entry


def autotune_all(kernels=None, *, batch: int = 512, iters: int = 3,
                 vmem_budget_mb: float = DEFAULT_VMEM_BUDGET_MB,
                 cache: Optional[str] = None, verbose: bool = True):
    entries = []
    for kernel in kernels or KERNELS:
        for dims in DEFAULT_GEOMETRIES[kernel]:
            entry = autotune_kernel(
                kernel, dims, batch=batch, iters=iters,
                vmem_budget_mb=vmem_budget_mb, cache=cache)
            entries.append(entry)
            if verbose:
                print(f"autotune: {kernel} {entry['geometry']} -> "
                      f"block_b={entry['block_b']} "
                      f"({entry['best_us']}us, default "
                      f"block_b={entry['default_block_b']} "
                      f"{entry['default_us']}us, "
                      f"{entry['speedup_vs_default']}x)", flush=True)
    return entries


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernel", default="all",
                    choices=["all"] + sorted(KERNELS),
                    help="which kernel to tune")
    ap.add_argument("--batch", type=int, default=512,
                    help="query batch the candidates are timed at")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--vmem-budget-mb", type=float,
                    default=DEFAULT_VMEM_BUDGET_MB)
    ap.add_argument("--cache", default=None,
                    help=f"cache file (default {DEFAULT_CACHE}, or "
                         f"${CACHE_ENV})")
    args = ap.parse_args(argv)
    kernels = list(KERNELS) if args.kernel == "all" else [args.kernel]
    autotune_all(kernels, batch=args.batch, iters=args.iters,
                 vmem_budget_mb=args.vmem_budget_mb, cache=args.cache)
    print(f"autotune: cache -> {args.cache or cache_path()}")


if __name__ == "__main__":
    main()
