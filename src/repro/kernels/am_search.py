"""Fused associative-search Pallas kernel: MVM + running arg-max.

The deployment hot loop of the paper (§III-D): similarity of a query batch
against the (D x C) multi-centroid AM followed by arg-max. On the IMC
array this is one analog MVM + a winner-take-all; on TPU we fuse the
arg-max into the MVM's epilogue so similarities never round-trip to HBM:

    grid = (B/bB, C/128, D/128)    # D innermost: similarity accumulation
    scratch: acc (bB x 128) VMEM   — partial sims of the current C block
             best_sim / best_idx   — running winner across C blocks

One (C, D) grid step == one IMC array cycle (asserted against
``repro.core.imc`` in tests), and for the paper's flagship 128x128 AM the
whole search is a single step — the "one-shot associative search" claim,
literally.

C and D may be ragged: padded columns are masked to -inf before the winner
update so they can never win; padded D rows contribute zeros (query and AM
are zero-padded). Ties resolve first-wins, matching ``jnp.argmax``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.deploy.padding import pad_tiles

Array = jax.Array

TILE = 128


def _make_kernel(n_valid_cols: int):
    """Bind the static valid-column count into the kernel body."""

    def kernel(q_ref, am_ref, idx_ref, sim_ref,
               acc_ref, best_sim_ref, best_idx_ref):
        c, d = pl.program_id(1), pl.program_id(2)
        nc, nd = pl.num_programs(1), pl.num_programs(2)

        @pl.when(d == 0)
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(
            q_ref[...].astype(jnp.float32),
            am_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

        @pl.when(d == nd - 1)
        def _fold_winner():
            sims = acc_ref[...]  # (bB, TILE)
            col = c * TILE + jax.lax.broadcasted_iota(
                jnp.int32, sims.shape, 1)
            neg = jnp.finfo(jnp.float32).min
            sims = jnp.where(col < n_valid_cols, sims, neg)
            blk_best = jnp.max(sims, axis=1)  # (bB,)
            blk_arg = (c * TILE
                       + jnp.argmax(sims, axis=1).astype(jnp.int32))

            @pl.when(c == 0)
            def _first():
                best_sim_ref[...] = blk_best
                best_idx_ref[...] = blk_arg

            @pl.when(c > 0)
            def _update():
                prev_sim = best_sim_ref[...]
                prev_idx = best_idx_ref[...]
                take = blk_best > prev_sim  # strict: first-wins on ties
                best_sim_ref[...] = jnp.where(take, blk_best, prev_sim)
                best_idx_ref[...] = jnp.where(take, blk_arg, prev_idx)

            @pl.when(c == nc - 1)
            def _emit():
                idx_ref[...] = best_idx_ref[...][:, None]
                sim_ref[...] = best_sim_ref[...][:, None]

    return kernel


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def am_search(q: Array, am_t: Array, *, block_b: int = 256,
              interpret: bool | None = None) -> tuple[Array, Array]:
    """Fused associative search over the multi-centroid AM.

    Args:
      q: (B, D) query hypervectors.
      am_t: (D, C) transposed AM (column c = centroid c), bipolar.
      block_b: query-batch tile height.
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (best_idx, best_sim): (B,) int32 winning centroid per query and
      (B,) float32 its dot similarity.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, dd = q.shape
    dd2, c = am_t.shape
    assert dd == dd2, (q.shape, am_t.shape)

    bb = min(block_b, max(b, 1))
    qp = pad_tiles(q.astype(jnp.float32), bb, TILE)
    ap = pad_tiles(am_t.astype(jnp.float32), TILE, TILE)
    gb = qp.shape[0] // bb
    gc = ap.shape[1] // TILE
    gd = qp.shape[1] // TILE

    idx, sim = pl.pallas_call(
        _make_kernel(c),
        grid=(gb, gc, gd),
        in_specs=[
            pl.BlockSpec((bb, TILE), lambda i, cc, d: (i, d)),
            pl.BlockSpec((TILE, TILE), lambda i, cc, d: (d, cc)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, cc, d: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, cc, d: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, TILE), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.int32),
        ],
        interpret=interpret,
    )(qp, ap)
    return idx[:b, 0], sim[:b, 0]


def imc_cycles_for(am_t_shape: tuple) -> int:
    """(C/128)*(D/128) grid steps per batch tile — must equal
    ``repro.core.imc.map_memhd(D, C).cycles``."""
    d, c = am_t_shape
    return (-(-d // TILE)) * (-(-c // TILE))
