"""Pallas SSD chunk kernel — the Mamba-2 hot loop, one chunk per pass.

The fused-scan SSD (models/layers.py::ssd_forward) is the dominant cost
of the mamba2/hymba cells; its per-chunk body is a natural TPU kernel:
everything for one (chunk Q, head) pair — the (Q, Q) decay matrix, the
intra-chunk attention-like product, the inter-chunk state contribution,
and the state update — lives comfortably in VMEM, and the (Q,Q)@(Q,P)
and (Q,N)@(N,P) contractions are MXU work.

    grid = (B, H)          # one (batch row, head) per pass
    in:  x (Q,P), b/c (Q,N), dt/da (Q,), state (N,P)
    out: y (Q,P), new_state (N,P)

The chunk-to-chunk dependency (state) stays in the caller's scan —
kernels keep the per-chunk math, the framework keeps the recurrence.
``ref_ssd_chunk`` is the pure-jnp oracle (mirrors ssd_forward's body).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def ref_ssd_chunk(x: Array, b: Array, c: Array, dt: Array, da: Array,
                  state: Array):
    """Oracle. x: (B,Q,H,P), b/c: (B,Q,H,N), dt/da: (B,Q,H),
    state: (B,H,N,P) -> (y (B,Q,H,P), new_state (B,H,N,P))."""
    q = x.shape[1]
    cum = jnp.cumsum(da, axis=1)                       # (B,Q,H)
    seg_total = cum[:, -1]                             # (B,H)
    xdt = x.astype(jnp.float32) * dt[..., None]
    b32 = b.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
    decay = jnp.where(mask,
                      jnp.exp(cum[:, :, None, :] - cum[:, None, :, :]), 0.0)
    cb = jnp.einsum("bqhn,bkhn->bqkh", c32, b32)
    y_intra = jnp.einsum("bqkh,bkhp->bqhp", cb * decay, xdt)
    in_decay = jnp.exp(cum)
    y_inter = jnp.einsum("bqhn,bhnp->bqhp", c32 * in_decay[..., None],
                         state.astype(jnp.float32))
    state_decay = jnp.exp(seg_total[:, None, :] - cum)
    bx = jnp.einsum("bqhn,bqhp->bhnp", b32 * state_decay[..., None], xdt)
    new_state = state.astype(jnp.float32) \
        * jnp.exp(seg_total)[..., None, None] + bx
    return (y_intra + y_inter).astype(x.dtype), new_state


def _kernel(x_ref, b_ref, c_ref, dt_ref, da_ref, s_ref,
            y_ref, snew_ref):
    x = x_ref[0, :, 0, :].astype(jnp.float32)     # (Q, P)
    b = b_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    da = da_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    s = s_ref[0, 0].astype(jnp.float32)           # (N, P)
    q = x.shape[0]

    cum = jnp.cumsum(da)                          # (Q,)
    seg_total = cum[-1]
    xdt = x * dt[:, None]

    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(row >= col, jnp.exp(cum[:, None] - cum[None, :]),
                      0.0)                         # (Q, Q)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Q, Q)
    y_intra = jnp.dot(cb * decay, xdt,
                      preferred_element_type=jnp.float32)     # (Q, P)
    y_inter = jnp.dot(c * jnp.exp(cum)[:, None], s,
                      preferred_element_type=jnp.float32)     # (Q, P)
    bx = jnp.dot((b * jnp.exp(seg_total - cum)[:, None]).T, xdt,
                 preferred_element_type=jnp.float32)          # (N, P)
    s_new = s * jnp.exp(seg_total) + bx

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)
    snew_ref[0, 0] = s_new.astype(snew_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x: Array, b: Array, c: Array, dt: Array, da: Array,
              state: Array, *, interpret: bool | None = None):
    """One SSD chunk for all (batch, head) pairs.

    Shapes as in ``ref_ssd_chunk``. Returns (y, new_state).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, q, h, p = x.shape
    n = b.shape[-1]

    y, s_new = pl.pallas_call(
        _kernel,
        grid=(bsz, h),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(state.shape, jnp.float32),
        ],
        interpret=interpret,
    )(x, b, c, dt, da, state)
    return y, s_new
