"""Pallas TPU kernels for the paper's compute hot spots.

The paper's whole deployment story is "the model IS the array": encoding
and associative search are MVMs streamed through 128x128 IMC tiles. The
TPU analogue keeps the exact geometry (MXU tile == IMC array), so each
kernel's grid size *is* the paper's cycle count (asserted in tests).

  binary_mvm       — tiled bipolar projection encoding (the EM)
  encode_fused     — encoding MVM + sign + bitpack in one pass, chained
                     into the packed search for a single-dispatch
                     feature->prediction pipeline (no float H in HBM)
  am_search        — fused similarity + running arg-max (the AM, one-shot)
  am_search_packed — the same search over the uint8-packed 1-bit AM via
                     XOR + popcount (the deployed Table-I residence)
  pack_bits        — 1-bit storage format for binary AM / projection
  flash_decode — one-token GQA attention streaming a KV cache (the
                 serving hot loop of the decode dry-run cells)
  ssd_chunk    — the Mamba-2 SSD per-chunk body (decay + intra/inter
                 products + state update) for the ssm/hybrid archs

``ops`` is the public jit'd surface; ``ref`` holds pure-jnp oracles.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.flash_decode import flash_decode  # noqa: F401
from repro.kernels.ssd_chunk import ssd_chunk  # noqa: F401
