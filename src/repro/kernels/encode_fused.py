"""Fused feature->packed-query encoding: projection MVM + sign + bitpack.

The serving path used to stage the encoder: float einsum H = F @ M,
round-trip the (B, D) float hypervector through HBM, binarize it, pack
it, and only then dispatch the XOR+popcount search. But the only thing
the search ever reads is one *bit* per dimension (sign(H) >= 0), so the
float H is pure HBM traffic. This kernel closes that gap: it tiles the
bipolar projection MVM over 128x128 blocks exactly like
``binary_mvm.py`` (grid == the IMC cycle count of the encoder mapping),
keeps the accumulator in VMEM across K slabs, and on the last K step
emits the sign-binarized, uint8-packed query row directly — no float H
ever touches HBM.

    grid = (B/bB, D/128, f/128)      # f innermost: accumulation
    out block per (i, j): (bB, 16) uint8 — one packed 128-dim slab

Bit semantics are exactly the staged chain's
``encode_query -> pack_rows``: a bit is 1 iff the accumulated H >= 0
(``binarize_query`` maps sign(0) -> +1 and ``pack_bits`` packs +1 as
bit 1), bits are LSB-first along D, and columns >= n_dims (the padded
D tail) pack as 0 so they XOR-cancel against the identically padded AM.
Validated bit-for-bit against ``ref.encode_pack`` in
tests/test_kernel_parity.py.

Parity caveat: for f > 128 the kernel sums the MVM in 128-wide K slabs
while the staged einsum may reduce in a different order, so for
*non-integer* features the two H values can differ by float rounding —
a bit flips only when the true H sits within that rounding error of 0.
Bipolar/integer features are exact (integer accumulation); float
features agree for every tested geometry and seed, but "bit-exact" is
a structural guarantee only where H is integer-valued.

``search_from_features`` / ``predict_from_features`` chain this kernel
straight into ``am_search_packed`` under ONE jit — the whole
feature->prediction pipeline is a single host dispatch with only the
(B, ceil(D/8)) packed rows materialized between the two kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.deploy.padding import pad_tiles

from repro.kernels.am_search_packed import am_search_packed

Array = jax.Array

TILE = 128          # IMC array dim == MXU tile dim
TILE_P = TILE // 8  # packed bytes per 128-dim slab

# Batch-tile height: the free tiling knob (TILE is the IMC-geometry /
# MXU contract). ``kernels.autotune`` searches TUNE_BLOCK_B and ops.py
# dispatch applies the cached winner; DEFAULT_BLOCK_B is the fallback.
DEFAULT_BLOCK_B = 128
TUNE_BLOCK_B = (32, 64, 128, 256, 512)


def _make_kernel(n_valid_dims: int):
    """Bind the static valid-dimension count into the kernel body."""

    def kernel(x_ref, w_ref, o_ref, acc_ref):
        j, k = pl.program_id(1), pl.program_id(2)
        nk = pl.num_programs(2)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(
            x_ref[...].astype(jnp.float32),
            w_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

        @pl.when(k == nk - 1)
        def _sign_and_pack():
            h = acc_ref[...]  # (bB, TILE)
            col = j * TILE + jax.lax.broadcasted_iota(
                jnp.int32, h.shape, 1)
            # bit 1 iff H >= 0 (binarize_query: sign(0) -> +1, and
            # pack_bits packs +1 as 1); padded D columns pack as 0.
            bits = ((h >= 0) & (col < n_valid_dims)).astype(jnp.int32)
            bits = bits.reshape(h.shape[0], TILE_P, 8)
            weights = (2 ** jnp.arange(8, dtype=jnp.int32))
            o_ref[...] = jnp.sum(bits * weights, axis=-1).astype(
                jnp.uint8)

    return kernel


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def encode_pack(feats: Array, projection: Array, *,
                block_b: int = DEFAULT_BLOCK_B,
                interpret: bool | None = None) -> Array:
    """Fused encode + sign + bitpack: (B, f) features -> (B, Dp) uint8.

    Args:
      feats: (B, f) float features.
      projection: (f, D) bipolar projection matrix M.
      block_b: batch tile height.
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (B, ceil(D/8)) uint8 packed queries, LSB-first along D with tail
      bits 0 — bit-identical to
      ``pack_rows(binarize_query(feats @ projection))``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, f = feats.shape
    f2, d = projection.shape
    assert f == f2, (feats.shape, projection.shape)

    bb = min(block_b, max(b, 1))
    xp = pad_tiles(feats.astype(jnp.float32), bb, TILE)
    wp = pad_tiles(projection.astype(jnp.float32), TILE, TILE)
    gb, gf, gd = (xp.shape[0] // bb, xp.shape[1] // TILE,
                  wp.shape[1] // TILE)

    out = pl.pallas_call(
        _make_kernel(d),
        grid=(gb, gd, gf),
        in_specs=[
            pl.BlockSpec((bb, TILE), lambda i, j, k: (i, k)),
            pl.BlockSpec((TILE, TILE), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, TILE_P), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], gd * TILE_P),
                                       jnp.uint8),
        scratch_shapes=[pltpu.VMEM((bb, TILE), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:b, : -(-d // 8)]


@functools.partial(jax.jit, static_argnames=(
    "mode", "block_b", "interpret"))
def search_from_features(feats: Array, projection: Array,
                         am_packed_t: Array, *, mode: str = "popcount",
                         block_b: int = DEFAULT_BLOCK_B,
                         interpret: bool | None = None,
                         ) -> tuple[Array, Array]:
    """Single-dispatch feature->search chain: encode_pack |> am_search_packed.

    Both Pallas kernels run inside one jit; the only intermediate is the
    (B, Dp) packed query matrix — the float H never exists.

    Args:
      feats: (B, f) float features.
      projection: (f, D) bipolar projection matrix.
      am_packed_t: (Dp, C) uint8 packed transposed AM (``pack_am``).
      mode: packed-search compute mode ("popcount" | "unpack").

    Returns:
      (best_idx, best_sim) as ``am_search_packed`` — bit-exact with the
      staged encode_query -> pack_rows -> am_search_packed chain.
    """
    n_dims = projection.shape[1]
    qp = encode_pack(feats, projection, block_b=block_b,
                     interpret=interpret)
    return am_search_packed(qp, am_packed_t, n_dims=n_dims, mode=mode,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "mode", "block_b", "interpret"))
def predict_from_features(feats: Array, projection: Array,
                          am_packed_t: Array, centroid_class: Array, *,
                          mode: str = "popcount",
                          block_b: int = DEFAULT_BLOCK_B,
                          interpret: bool | None = None) -> Array:
    """Single-dispatch feature->class pipeline (§III-D end to end).

    encode_pack |> am_search_packed |> ownership gather, one jit.
    Returns (B,) int32 predicted classes.
    """
    idx, _ = search_from_features(feats, projection, am_packed_t,
                                  mode=mode, block_b=block_b,
                                  interpret=interpret)
    return centroid_class[idx]


def imc_cycles_for(feats_shape: tuple, projection_shape: tuple) -> int:
    """Grid size of the f x D tiling — identical to ``binary_mvm``'s,
    so the fused encoder keeps the encoder-mapping cycle count of
    ``repro.core.imc.map_basic(f, D)`` (the pack epilogue rides the last
    accumulation step for free)."""
    f, d = projection_shape
    return (-(-f // TILE)) * (-(-d // TILE))
