"""Tiled bipolar MVM Pallas kernel — the TPU realization of an IMC array.

The paper's encoder (and every IMC mapping it compares against) is a
matrix-vector multiply streamed through 128x128 crossbar tiles. The MXU is
*also* a 128x128 systolic tile, so the natural TPU adaptation is a Pallas
kernel whose BlockSpec grid reproduces the IMC tiling exactly:

    grid = (B/bB, N/128, K/128)       # K innermost: accumulation
    one grid step == one array "cycle" of the paper's cost model
      (asserted against repro.core.imc in tests/test_kernels.py)

VMEM working set per step: bB*128 (x tile) + 128*128 (w tile) + bB*128
(accumulator) floats — comfortably inside the ~16 MB/core VMEM for
bB <= 512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.deploy.padding import pad_tiles

Array = jax.Array

TILE = 128  # IMC array dim == MXU tile dim


def _mvm_kernel(x_ref, w_ref, o_ref):
    """One (bB, bK) x (bK, bN) tile pass with K-accumulation in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def binary_mvm(x: Array, w: Array, *, block_b: int = 128,
               interpret: bool | None = None) -> Array:
    """H = x @ w via 128x128 IMC-geometry tiles.

    Args:
      x: (B, K) float input (features / queries).
      w: (K, N) bipolar weights (projection matrix or AM).
      block_b: batch tile height.
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (B, N) float32.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    bb = min(block_b, max(b, 1))
    xp = pad_tiles(x.astype(jnp.float32), bb, TILE)
    wp = pad_tiles(w.astype(jnp.float32), TILE, TILE)
    gb, gk, gn = (xp.shape[0] // bb, xp.shape[1] // TILE,
                  wp.shape[1] // TILE)

    out = pl.pallas_call(
        _mvm_kernel,
        grid=(gb, gn, gk),
        in_specs=[
            pl.BlockSpec((bb, TILE), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bb, TILE), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:b, :n]


def imc_cycles_for(x_shape: tuple, w_shape: tuple) -> int:
    """Grid size of the K x N tiling — equals the IMC cycle count of
    ``repro.core.imc.map_basic(K, N)`` (batch tiles reuse resident
    weights, so the per-sample cycle count ignores the batch axis)."""
    k, n = w_shape
    return (-(-k // TILE)) * (-(-n // TILE))
