"""Device-fidelity associative search: tiled analog MVM + per-tile ADC.

``am_search.py`` computes the deployment search exactly — the digital
semantics. A real IMC deployment computes the same search through
physics: the (D x C) AM is sliced into (A x A) physical arrays, each
array produces an *analog* partial sum for its slice, that current is
digitized by a finite-resolution ADC, and only the digitized per-tile
outputs are accumulated and compared. This kernel executes exactly that
pipeline, so the fidelity knobs of ``ImcSimConfig`` become executable
hardware semantics instead of closed-form accounting:

    grid = (B/bB, C/Ac, D/Ar)        # one (C, D) step == ONE physical
                                     # array pass == one IMC cycle
    per step:  part = q_tile @ am_tile          # analog MVM of one array
               part += offset[d, c]             # per-tile readout drift
               part  = ADC(part)                # clip + mid-tread round
               acc  += part                     # digital accumulation
    at d == nd-1: same running-winner argmax epilogue as am_search.py

The grid is the cost model made literal: ``math.prod(grid[1:]) ==
repro.core.imc.map_memhd(D, C, arr).cycles`` (asserted in
tests/test_imcsim.py), and for the paper's flagship 128x128 AM on a
128x128 array the whole search is one step — the one-shot claim, now
with device physics inside the step.

ADC semantics (shared verbatim with ``ref.adc_quantize``): symmetric
mid-tread quantizer, 2^bits + 1 codes over [-clip, +clip], step =
2*clip / 2^bits, jnp.round tie-to-even. With the default power-of-two
clip (the array row count), bipolar partial sums are integers and the
step is a power of two, so any ``adc_bits`` with step <= 1 (b >= 8 at
A=128; b >= 16 trivially) reproduces the exact digital search bit for
bit — similarities AND first-wins tie-breaks. That is the
fidelity-parity contract.

Conductance noise and stuck-at faults are *storage* perturbations: they
are applied to the resident AM before it reaches this kernel (see
``repro.imcsim.device``); the kernel models the readout path (tiling,
drift offsets, ADC).

Non-default array geometries (``arr.rows``/``arr.cols`` not multiples
of the TPU 128-lane tile) are simulation-only territory: they run in
interpret mode, which is where the robustness sweeps live anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.deploy.padding import pad_tiles

Array = jax.Array


def _make_kernel(n_valid_cols: int, adc_bits: int, adc_clip: float,
                 tile_cols: int):
    """Bind static valid-column count + ADC transfer into the body."""
    step = 2.0 * adc_clip / (2 ** adc_bits)

    def kernel(q_ref, am_ref, off_ref, idx_ref, sim_ref,
               acc_ref, best_sim_ref, best_idx_ref):
        c, d = pl.program_id(1), pl.program_id(2)
        nc, nd = pl.num_programs(1), pl.num_programs(2)

        @pl.when(d == 0)
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # One physical array's analog MVM pass...
        part = jnp.dot(
            q_ref[...].astype(jnp.float32),
            am_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        # ...its readout offset, and its ADC. Digital accumulation only
        # ever sees the quantized tile outputs.
        part = part + off_ref[0, 0]
        part = jnp.clip(part, -adc_clip, adc_clip)
        part = jnp.round(part / step) * step
        acc_ref[...] += part

        @pl.when(d == nd - 1)
        def _fold_winner():
            sims = acc_ref[...]  # (bB, tile_cols)
            col = c * tile_cols + jax.lax.broadcasted_iota(
                jnp.int32, sims.shape, 1)
            neg = jnp.finfo(jnp.float32).min
            sims = jnp.where(col < n_valid_cols, sims, neg)
            blk_best = jnp.max(sims, axis=1)  # (bB,)
            blk_arg = (c * tile_cols
                       + jnp.argmax(sims, axis=1).astype(jnp.int32))

            @pl.when(c == 0)
            def _first():
                best_sim_ref[...] = blk_best
                best_idx_ref[...] = blk_arg

            @pl.when(c > 0)
            def _update():
                prev_sim = best_sim_ref[...]
                prev_idx = best_idx_ref[...]
                take = blk_best > prev_sim  # strict: first-wins on ties
                best_sim_ref[...] = jnp.where(take, blk_best, prev_sim)
                best_idx_ref[...] = jnp.where(take, blk_arg, prev_idx)

            @pl.when(c == nc - 1)
            def _emit():
                idx_ref[...] = best_idx_ref[...][:, None]
                sim_ref[...] = best_sim_ref[...][:, None]

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "tile_rows", "tile_cols", "adc_bits", "adc_clip", "block_b",
    "interpret"))
def am_search_imc(q: Array, am_t: Array, offsets: Array | None = None, *,
                  tile_rows: int = 128, tile_cols: int = 128,
                  adc_bits: int = 16, adc_clip: float = 128.0,
                  block_b: int = 256, interpret: bool | None = None,
                  ) -> tuple[Array, Array]:
    """Associative search as the tiled analog arrays would compute it.

    Args:
      q: (B, D) query hypervectors.
      am_t: (D, C) transposed resident AM — typically the *perturbed*
        bipolar AM from ``repro.imcsim.device.perturb_am``.
      offsets: (ceil(D/tile_rows), ceil(C/tile_cols)) per-tile readout
        offsets, or None for drift-free readout.
      tile_rows / tile_cols: physical array geometry (ImcArrayConfig).
      adc_bits / adc_clip: ADC resolution and full-scale range.
      block_b: query-batch tile height.
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (best_idx, best_sim): (B,) int32 winning centroid per query and
      (B,) float32 its ADC-quantized accumulated similarity.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, dd = q.shape
    dd2, c = am_t.shape
    assert dd == dd2, (q.shape, am_t.shape)

    bb = min(block_b, max(b, 1))
    qp = pad_tiles(q.astype(jnp.float32), bb, tile_rows)
    ap = pad_tiles(am_t.astype(jnp.float32), tile_rows, tile_cols)
    gb = qp.shape[0] // bb
    gc = ap.shape[1] // tile_cols
    gd = qp.shape[1] // tile_rows
    if offsets is None:
        offsets = jnp.zeros((gd, gc), jnp.float32)
    if offsets.shape != (gd, gc):
        raise ValueError(
            f"offsets shape {offsets.shape} != tile grid {(gd, gc)}")

    idx, sim = pl.pallas_call(
        _make_kernel(c, adc_bits, float(adc_clip), tile_cols),
        grid=(gb, gc, gd),
        in_specs=[
            pl.BlockSpec((bb, tile_rows), lambda i, cc, d: (i, d)),
            pl.BlockSpec((tile_rows, tile_cols), lambda i, cc, d: (d, cc)),
            pl.BlockSpec((1, 1), lambda i, cc, d: (d, cc)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, cc, d: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, cc, d: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, tile_cols), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.int32),
        ],
        interpret=interpret,
    )(qp, ap, offsets.astype(jnp.float32))
    return idx[:b, 0], sim[:b, 0]


def imc_cycles_for(am_t_shape: tuple, tile_rows: int = 128,
                   tile_cols: int = 128) -> int:
    """ceil(D/Ar) * ceil(C/Ac) grid steps per batch tile — must equal
    ``repro.core.imc.map_memhd(D, C, arr).cycles`` for the matching
    array geometry (the hardware-model == kernel-geometry contract)."""
    d, c = am_t_shape
    return (-(-d // tile_rows)) * (-(-c // tile_cols))
