"""Fused associative search over the *packed* 1-bit AM: XOR + popcount.

``am_search.py`` deploys the AM as ±1 float32 — 32 bits per cell, 32x the
paper's Table-I accounting. This kernel is the deployment path that makes
the 1-bit claim literal: the resident AM is the uint8-packed output of
``pack_bits`` (8 cells/byte, LSB-first along D) and queries arrive packed
the same way. Similarity is computed in the bit domain via the Hamming
identity for bipolar vectors

    dot(q, a) = D_valid - 2 * popcount(bits(q) XOR bits(a)),

so the kernel XORs packed bytes, popcounts them with a 3-step SWAR
reduction on the VPU, accumulates Hamming distance across D slabs, and
folds the same running-winner epilogue as ``am_search.py`` — the emitted
(idx, sim) pair is bit-exact with the unpacked kernel (similarities are
integer-valued, exact in float32).

Geometry contract (same as ``am_search.py``): the grid is

    (B/bB, C/128, Dp/16)      # 16 packed bytes == one 128-dim slab

so one (C, D) grid step still equals one IMC array cycle and the paper's
flagship 128x128 AM is searched in a single step — the packed kernel
inherits the "one-shot associative search" claim (asserted against
``repro.core.imc.cycles`` in tests/test_packed.py).

Padding semantics, all bit-exact with the unpacked path:
* D tail bits / padded D slabs are packed as 0 in both query and AM, so
  they XOR to 0 and never touch the Hamming count; ``sim`` uses the true
  (static) valid-dim count, matching the zero-padded float kernel.
* Padded C columns are masked to -inf before the winner update.
* Ties resolve first-wins via the strict ``>`` running compare.

``mode="popcount"`` is the bit-domain path described above (pure VPU).
``mode="unpack"`` is the fallback: each packed AM slab is unpacked to
±1 float in VMEM and fed to the MXU exactly like ``am_search.py`` — same
outputs, useful where int ops are slow or for cross-checking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.deploy.padding import pad_tiles

from repro.kernels.pack_bits import pack_bits

Array = jax.Array

TILE = 128          # unpacked dims / centroid columns per grid step
TILE_P = TILE // 8  # packed bytes per 128-dim slab

# Batch-tile height: the one free tiling knob (TILE is the IMC-array
# contract). DEFAULT_BLOCK_B is the untuned fallback; TUNE_BLOCK_B is
# the candidate ladder ``kernels.autotune`` searches, bounded above by
# the VMEM footprint of the (bb, TILE_P, TILE) popcount XOR broadcast.
DEFAULT_BLOCK_B = 256
TUNE_BLOCK_B = (64, 128, 256, 512, 1024)


def _popcount8(v: Array) -> Array:
    """Population count of a byte held in int32, 3-step SWAR."""
    v = v - ((v >> 1) & 0x55)
    v = (v & 0x33) + ((v >> 2) & 0x33)
    return (v + (v >> 4)) & 0x0F


def _unpack_slab(packed: Array, n_valid_rows: int, row0: Array) -> Array:
    """(TILE_P, TILE) packed bytes -> (TILE, TILE) float in {-1, 0, +1}.

    Rows at global dim index >= n_valid_rows unpack to 0 (not -1) so the
    MXU dot reproduces the zero-padded float kernel exactly.
    """
    p = packed.astype(jnp.int32)  # (TILE_P, TILE)
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (p[:, None, :] >> shifts[:, None]) & 1  # (TILE_P, 8, TILE)
    vals = bits.reshape(TILE, TILE).astype(jnp.float32) * 2.0 - 1.0
    row = row0 + jax.lax.broadcasted_iota(jnp.int32, (TILE, TILE), 0)
    return jnp.where(row < n_valid_rows, vals, 0.0)


def _make_kernel(n_valid_cols: int, n_valid_dims: int, mode: str):
    """Bind static valid counts + compute mode into the kernel body."""

    def kernel(q_ref, am_ref, idx_ref, sim_ref,
               acc_ref, best_sim_ref, best_idx_ref):
        c, d = pl.program_id(1), pl.program_id(2)
        nc, nd = pl.num_programs(1), pl.num_programs(2)

        @pl.when(d == 0)
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        if mode == "popcount":
            # Hamming accumulation in the bit domain (VPU only).
            q = q_ref[...].astype(jnp.int32)   # (bB, TILE_P)
            a = am_ref[...].astype(jnp.int32)  # (TILE_P, TILE)
            x = jax.lax.bitwise_xor(q[:, :, None], a[None, :, :])
            acc_ref[...] += jnp.sum(_popcount8(x), axis=1).astype(
                jnp.float32)
        else:
            # Unpack-in-VMEM fallback: ±1 slab through the MXU.
            am = _unpack_slab(am_ref[...], n_valid_dims, d * TILE)
            qb = q_ref[...].astype(jnp.int32)  # (bB, TILE_P)
            shifts = jnp.arange(8, dtype=jnp.int32)
            qbits = (qb[:, :, None] >> shifts) & 1  # (bB, TILE_P, 8)
            qv = qbits.reshape(qb.shape[0], TILE).astype(jnp.float32)
            col = d * TILE + jax.lax.broadcasted_iota(
                jnp.int32, qv.shape, 1)
            qv = jnp.where(col < n_valid_dims, qv * 2.0 - 1.0, 0.0)
            acc_ref[...] += jnp.dot(
                qv, am, preferred_element_type=jnp.float32)

        @pl.when(d == nd - 1)
        def _fold_winner():
            if mode == "popcount":
                # dot = D_valid - 2 * hamming; integer-exact in float32.
                sims = n_valid_dims - 2.0 * acc_ref[...]
            else:
                sims = acc_ref[...]  # (bB, TILE)
            col = c * TILE + jax.lax.broadcasted_iota(
                jnp.int32, sims.shape, 1)
            neg = jnp.finfo(jnp.float32).min
            sims = jnp.where(col < n_valid_cols, sims, neg)
            blk_best = jnp.max(sims, axis=1)  # (bB,)
            blk_arg = (c * TILE
                       + jnp.argmax(sims, axis=1).astype(jnp.int32))

            @pl.when(c == 0)
            def _first():
                best_sim_ref[...] = blk_best
                best_idx_ref[...] = blk_arg

            @pl.when(c > 0)
            def _update():
                prev_sim = best_sim_ref[...]
                prev_idx = best_idx_ref[...]
                take = blk_best > prev_sim  # strict: first-wins on ties
                best_sim_ref[...] = jnp.where(take, blk_best, prev_sim)
                best_idx_ref[...] = jnp.where(take, blk_arg, prev_idx)

            @pl.when(c == nc - 1)
            def _emit():
                idx_ref[...] = best_idx_ref[...][:, None]
                sim_ref[...] = best_sim_ref[...][:, None]

    return kernel


def pack_rows(x: Array) -> Array:
    """(B, D) bipolar -> (B, ceil(D/8)) uint8, LSB-first; D-tail bits 0.

    The query-side packer: pads the trailing dimension to a byte boundary
    with -1 (bit 0) so tail bits XOR-cancel against the identically padded
    AM. Shares its bit layout with ``pack_bits`` / ``ref.pack_bits``.
    """
    x = pad_tiles(x.astype(jnp.float32), 1, 8, value=-1.0)
    return pack_bits(x)


@functools.partial(jax.jit, static_argnames=(
    "n_dims", "n_cols", "block_b", "mode", "interpret"))
def am_search_packed(q_packed: Array, am_packed_t: Array, *,
                     n_dims: int, n_cols: int | None = None,
                     block_b: int = DEFAULT_BLOCK_B,
                     mode: str = "popcount",
                     interpret: bool | None = None,
                     ) -> tuple[Array, Array]:
    """Fused associative search over the packed 1-bit AM.

    Args:
      q_packed: (B, Dp) uint8 queries, Dp = ceil(D/8), packed LSB-first
        along D (``pack_rows``); tail bits must be 0.
      am_packed_t: (Dp, C) uint8 transposed packed AM (column c =
        centroid c) — ``pack_rows(am).T`` for a (C, D) bipolar AM.
      n_dims: true (unpacked, unpadded) hypervector dimension D.
      n_cols: true centroid count; defaults to am_packed_t.shape[1].
      block_b: query-batch tile height.
      mode: "popcount" (XOR + SWAR popcount, VPU) or "unpack"
        (unpack-in-VMEM ±1 slabs through the MXU).
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (best_idx, best_sim): (B,) int32 winning centroid per query and
      (B,) float32 its ±1-domain dot similarity — bit-exact with
      ``am_search.am_search`` on the corresponding unpacked operands.
    """
    if mode not in ("popcount", "unpack"):
        raise ValueError(f"bad mode: {mode!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, dp = q_packed.shape
    dp2, c = am_packed_t.shape
    assert dp == dp2, (q_packed.shape, am_packed_t.shape)
    if n_cols is None:
        n_cols = c
    if not dp * 8 >= n_dims > (dp - 1) * 8:
        raise ValueError(f"n_dims={n_dims} inconsistent with Dp={dp}")

    bb = min(block_b, max(b, 1))
    # Zero pad bytes: padded dims XOR to 0 in both operands.
    qp = pad_tiles(q_packed, bb, TILE_P)
    ap = pad_tiles(am_packed_t, TILE_P, TILE)
    gb = qp.shape[0] // bb
    gc = ap.shape[1] // TILE
    gd = qp.shape[1] // TILE_P

    idx, sim = pl.pallas_call(
        _make_kernel(n_cols, n_dims, mode),
        grid=(gb, gc, gd),
        in_specs=[
            pl.BlockSpec((bb, TILE_P), lambda i, cc, d: (i, d)),
            pl.BlockSpec((TILE_P, TILE), lambda i, cc, d: (d, cc)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, cc, d: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, cc, d: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, TILE), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.int32),
        ],
        interpret=interpret,
    )(qp, ap)
    return idx[:b, 0], sim[:b, 0]


def imc_cycles_for(am_packed_t_shape: tuple) -> int:
    """(C/128)*(Dp/16) grid steps per batch tile. One 16-byte packed slab
    covers 128 unpacked dims, so this equals the unpacked kernel's
    (C/128)*(D/128) and must equal ``repro.core.imc.map_memhd(...).cycles``
    — the packed deployment keeps the paper's cycle accounting."""
    dp, c = am_packed_t_shape
    return (-(-dp // TILE_P)) * (-(-c // TILE))
