"""Fine pass of the hierarchical AM search: shortlisted tiles + top-k.

Second stage of the coarse-to-fine pipeline (first stage:
``am_shortlist``). The AM has been physically permuted offline so every
cluster owns a contiguous run of 128-column packed tiles inside one
``am_search_packed``-contract slab (``deploy/hierarchical.build_layout``).
A query therefore only needs the tiles of its S shortlisted clusters:

  1. ``expand_shortlist_tiles`` turns each query's (S,) cluster shortlist
     into a fixed-shape (S * max_tiles,) tile-index list, padding short
     clusters with the slab's trailing all-invalid *null tile*;
  2. ``gather_shortlist`` gathers those tiles (and their original
     centroid ids) out of the slab — a plain XLA take, fixed shapes, so
     the whole pipeline stays jittable;
  3. the Pallas kernel scans the gathered (B, Dp, T*128) slab with the
     same XOR + SWAR-popcount accumulation as ``am_search_packed`` and a
     fused *streaming top-k* epilogue (``topk_select`` merge per tile) —
     so serving can return k candidates, not just an argmax.

Cost per query is S * max_tiles tiles instead of C/128 — sublinear in C
once G ~ sqrt(C) — while keeping the flat kernel's batch tiling (the
gather runs in XLA, so ``block_b`` queries still share each grid step).

Ordering is (-similarity, ORIGINAL centroid id): the id gathered with
each column is the centroid's pre-permutation index, and ties resolve
toward the lower id — exactly the flat scan's first-wins compare over
the original column order. That is the degenerate contract: with S = G
the gathered set covers every centroid and (idx, sim) at k=1 is
bit-exact with ``am_search_packed``. Columns whose id is -1 (cluster
padding / null tile) are masked out; output slots with no candidate
left emit id -1 and sim float32-min, matching ``ref.am_search_sparse``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.deploy.padding import pad_tiles

from repro.kernels.am_search_packed import TILE, TILE_P, _popcount8
from repro.kernels.am_shortlist import topk_select

Array = jax.Array

DEFAULT_BLOCK_B = 256
TUNE_BLOCK_B = (64, 128, 256, 512, 1024)

_NEG = float(jnp.finfo(jnp.float32).min)
_SENT = int(jnp.iinfo(jnp.int32).max)


def expand_shortlist_tiles(shortlist: Array, tile_start: Array,
                           tile_count: Array, *, max_tiles: int,
                           null_tile: int) -> Array:
    """(B, S) cluster shortlist -> (B, S * max_tiles) slab tile indices.

    Every cluster contributes a fixed ``max_tiles`` slots (fixed shapes
    keep this jittable); slots past a cluster's real ``tile_count`` point
    at ``null_tile`` — the slab's trailing all-invalid tile, whose
    columns carry id -1 and are masked by the kernel.
    """
    j = jnp.arange(max_tiles, dtype=jnp.int32)
    ts = tile_start[shortlist]  # (B, S)
    tc = tile_count[shortlist]
    tiles = ts[:, :, None] + j[None, None, :]  # (B, S, max_tiles)
    tiles = jnp.where(j[None, None, :] < tc[:, :, None], tiles, null_tile)
    return tiles.reshape(shortlist.shape[0], -1)


def gather_shortlist(am_packed_t: Array, col_ids: Array, tiles: Array,
                     ) -> tuple[Array, Array]:
    """Gather per-query tiles (and their centroid ids) from the slab.

    am_packed_t: (Dp, Ctot) uint8 permuted packed slab; col_ids: (Ctot,)
    int32 original centroid id per slab column (-1 = padding); tiles:
    (B, T) int32 tile indices. Returns ((B, Dp, T*128) uint8 gathered
    tiles, (B, T*128) int32 gathered ids).
    """
    b, t = tiles.shape
    cols = (tiles[:, :, None] * TILE
            + jnp.arange(TILE, dtype=jnp.int32)).reshape(b, t * TILE)
    gathered = jnp.moveaxis(jnp.take(am_packed_t, cols, axis=1), 1, 0)
    return gathered, jnp.take(col_ids, cols, axis=0)


def _make_kernel(n_valid_dims: int, k: int):
    def kernel(q_ref, tiles_ref, ids_ref, idx_ref, sim_ref,
               acc_ref, best_sim_ref, best_idx_ref):
        t, d = pl.program_id(1), pl.program_id(2)
        nt, nd = pl.num_programs(1), pl.num_programs(2)

        @pl.when(d == 0)
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[...].astype(jnp.int32)       # (bB, TILE_P)
        a = tiles_ref[...].astype(jnp.int32)   # (bB, TILE_P, TILE)
        x = jax.lax.bitwise_xor(q[:, :, None], a)
        acc_ref[...] += jnp.sum(_popcount8(x), axis=1).astype(jnp.float32)

        @pl.when(d == nd - 1)
        def _fold_topk():
            ids = ids_ref[...]  # (bB, TILE) original centroid ids
            valid = ids >= 0
            sims = jnp.where(valid,
                             n_valid_dims - 2.0 * acc_ref[...], _NEG)
            sel = jnp.where(valid, ids, _SENT)
            blk_s, blk_i = topk_select(sims, sel, k)

            @pl.when(t == 0)
            def _first():
                best_sim_ref[...] = blk_s
                best_idx_ref[...] = blk_i

            @pl.when(t > 0)
            def _merge():
                ms, mi = topk_select(
                    jnp.concatenate([best_sim_ref[...], blk_s], axis=1),
                    jnp.concatenate([best_idx_ref[...], blk_i], axis=1),
                    k)
                best_sim_ref[...] = ms
                best_idx_ref[...] = mi

            @pl.when(t == nt - 1)
            def _emit():
                bs = best_sim_ref[...]
                bi = best_idx_ref[...]
                idx_ref[...] = jnp.where(bs > _NEG, bi, -1)
                sim_ref[...] = bs

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "n_dims", "k", "block_b", "interpret"))
def am_search_sparse_gathered(q_packed: Array, tiles_packed: Array,
                              tile_ids: Array, *, n_dims: int, k: int,
                              block_b: int = DEFAULT_BLOCK_B,
                              interpret: bool | None = None,
                              ) -> tuple[Array, Array]:
    """Streaming top-k search over pre-gathered per-query tiles.

    Args:
      q_packed: (B, Dp) uint8 packed queries, tail bits 0.
      tiles_packed: (B, Dp, T*128) uint8 gathered tiles
        (``gather_shortlist``); T*128 must be a multiple of 128.
      tile_ids: (B, T*128) int32 original centroid id per gathered
        column, -1 for invalid (padding / null-tile) columns.
      n_dims: true hypervector dimension D.
      k: number of candidates to return (static).
      block_b: query-batch tile height.
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (idx, sims): (B, k) int32 original centroid ids and (B, k) float32
      similarities, ordered by (-sim, id); exhausted slots are
      (-1, float32-min). Bit-exact with ``ref.am_search_sparse``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, dp = q_packed.shape
    b2, dp2, tc = tiles_packed.shape
    assert (b, dp) == (b2, dp2), (q_packed.shape, tiles_packed.shape)
    assert tile_ids.shape == (b, tc), (tile_ids.shape, tiles_packed.shape)
    if tc % TILE != 0:
        raise ValueError(f"gathered columns {tc} not a multiple of {TILE}")
    if not dp * 8 >= n_dims > (dp - 1) * 8:
        raise ValueError(f"n_dims={n_dims} inconsistent with Dp={dp}")
    if k < 1:
        raise ValueError(f"k={k} must be >= 1")

    bb = min(block_b, max(b, 1))
    qp = pad_tiles(q_packed, bb, TILE_P)
    bpad, dpad = qp.shape[0] - b, qp.shape[1] - dp
    # Zero pad bytes XOR-cancel; padded rows are sliced off; padded ids
    # are -1 so no padding column can ever enter a top-k.
    tp = jnp.pad(tiles_packed, ((0, bpad), (0, dpad), (0, 0)))
    ip = jnp.pad(tile_ids, ((0, bpad), (0, 0)), constant_values=-1)
    gb = qp.shape[0] // bb
    gt = tc // TILE
    gd = qp.shape[1] // TILE_P

    idx, sim = pl.pallas_call(
        _make_kernel(n_dims, k),
        grid=(gb, gt, gd),
        in_specs=[
            pl.BlockSpec((bb, TILE_P), lambda i, t, d: (i, d)),
            pl.BlockSpec((bb, TILE_P, TILE), lambda i, t, d: (i, d, t)),
            pl.BlockSpec((bb, TILE), lambda i, t, d: (i, t)),
        ],
        out_specs=[
            pl.BlockSpec((bb, k), lambda i, t, d: (i, 0)),
            pl.BlockSpec((bb, k), lambda i, t, d: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.int32),
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, TILE), jnp.float32),
            pltpu.VMEM((bb, k), jnp.float32),
            pltpu.VMEM((bb, k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, tp, ip)
    return idx[:b], sim[:b]


@functools.partial(jax.jit, static_argnames=(
    "n_dims", "k", "max_tiles", "block_b", "interpret"))
def am_search_sparse(q_packed: Array, am_packed_t: Array, col_ids: Array,
                     shortlist: Array, tile_start: Array,
                     tile_count: Array, *, n_dims: int, k: int,
                     max_tiles: int, block_b: int = DEFAULT_BLOCK_B,
                     interpret: bool | None = None) -> tuple[Array, Array]:
    """Expand + gather + kernel: the full fine pass on the layout slab.

    am_packed_t is the permuted padded slab whose LAST 128-column tile is
    the all-invalid null tile (``build_layout`` appends it); col_ids maps
    slab columns back to original centroid ids (-1 = padding).
    """
    null_tile = am_packed_t.shape[1] // TILE - 1
    tiles = expand_shortlist_tiles(
        shortlist, tile_start, tile_count,
        max_tiles=max_tiles, null_tile=null_tile)
    gathered, ids = gather_shortlist(am_packed_t, col_ids, tiles)
    return am_search_sparse_gathered(
        q_packed, gathered, ids, n_dims=n_dims, k=k,
        block_b=block_b, interpret=interpret)
