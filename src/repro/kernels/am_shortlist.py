"""Coarse pass of the hierarchical AM search: top-S cluster shortlist.

The flat packed scan (``am_search_packed``) is linear in centroid count C;
at C in the 10^5+ regime (per-user / per-entity label spaces) that is the
wrong algorithm. The hierarchical subsystem splits the query into

  1. this kernel — score the query against G packed *super-centroids*
     (one per kmeans cluster of the trained AM) and keep the S best
     clusters per query, and
  2. ``am_search_sparse`` — search only the packed tiles belonging to
     those S clusters, with a streaming top-k epilogue.

The Hamming accumulation is byte-for-byte the ``am_search_packed``
popcount path (XOR + 3-step SWAR on the VPU, same (bB, 128-col, 16-byte
slab) grid); the epilogue differs: instead of one running argmax the
kernel keeps a per-query streaming top-S scratch, merged block-by-block
with an iterated select-max-then-min-id reduction so results are ordered
by (-similarity, cluster id) — ties resolve toward the LOWER cluster id,
matching the stable argsort oracle ``ref.am_shortlist`` exactly.

Similarities are integer-valued (exact in float32), so the top-S set and
its order are bit-exact with the oracle, which is what lets the S = G
degenerate configuration of the full two-stage pipeline reproduce the
flat scan bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.deploy.padding import pad_tiles

from repro.kernels.am_search_packed import TILE, TILE_P, _popcount8

Array = jax.Array

DEFAULT_BLOCK_B = 256
TUNE_BLOCK_B = (64, 128, 256, 512, 1024)

_NEG = float(jnp.finfo(jnp.float32).min)
_SENT = int(jnp.iinfo(jnp.int32).max)


def topk_select(sims: Array, ids: Array, k: int) -> tuple[Array, Array]:
    """Row-wise top-k of (sims, ids) pairs ordered by (-sim, id).

    sims: (B, N) float32, ids: (B, N) int32. Returns ((B, k) sims,
    (B, k) ids), best first. k is a static Python int — the selection is
    k unrolled max-then-min-id steps, which keeps the epilogue fusable
    inside a Pallas kernel body (no sort primitive needed) and encodes
    the tie-break exactly: among equal similarities the LOWEST id wins.
    Exhausted slots decay to (float32-min, int32-max) sentinels.

    Composite float/int sort keys are deliberately avoided: an int32
    (sim, id) pack overflows once D * C grows past 2^31 and float keys
    lose id bits to the mantissa; the iterated select is exact at any
    geometry.
    """
    out_s, out_i = [], []
    for _ in range(k):
        m = jnp.max(sims, axis=1, keepdims=True)  # (B, 1)
        pick = jnp.min(jnp.where(sims == m, ids, _SENT), axis=1,
                       keepdims=True)
        out_s.append(m)
        out_i.append(pick)
        drop = (sims == m) & (ids == pick)
        sims = jnp.where(drop, _NEG, sims)
        ids = jnp.where(drop, _SENT, ids)
    return jnp.concatenate(out_s, axis=1), jnp.concatenate(out_i, axis=1)


def _make_kernel(n_valid_cols: int, n_valid_dims: int, s: int):
    def kernel(q_ref, am_ref, idx_ref, sim_ref,
               acc_ref, best_sim_ref, best_idx_ref):
        c, d = pl.program_id(1), pl.program_id(2)
        nc, nd = pl.num_programs(1), pl.num_programs(2)

        @pl.when(d == 0)
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[...].astype(jnp.int32)   # (bB, TILE_P)
        a = am_ref[...].astype(jnp.int32)  # (TILE_P, TILE)
        x = jax.lax.bitwise_xor(q[:, :, None], a[None, :, :])
        acc_ref[...] += jnp.sum(_popcount8(x), axis=1).astype(jnp.float32)

        @pl.when(d == nd - 1)
        def _fold_topk():
            sims = n_valid_dims - 2.0 * acc_ref[...]  # (bB, TILE)
            col = c * TILE + jax.lax.broadcasted_iota(
                jnp.int32, sims.shape, 1)
            valid = col < n_valid_cols
            sims = jnp.where(valid, sims, _NEG)
            ids = jnp.where(valid, col, _SENT)
            blk_s, blk_i = topk_select(sims, ids, s)

            @pl.when(c == 0)
            def _first():
                best_sim_ref[...] = blk_s
                best_idx_ref[...] = blk_i

            @pl.when(c > 0)
            def _merge():
                ms, mi = topk_select(
                    jnp.concatenate([best_sim_ref[...], blk_s], axis=1),
                    jnp.concatenate([best_idx_ref[...], blk_i], axis=1),
                    s)
                best_sim_ref[...] = ms
                best_idx_ref[...] = mi

            @pl.when(c == nc - 1)
            def _emit():
                bs = best_sim_ref[...]
                bi = best_idx_ref[...]
                idx_ref[...] = jnp.where(bs > _NEG, bi, -1)
                sim_ref[...] = bs

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "n_dims", "s", "n_cols", "block_b", "interpret"))
def am_shortlist(q_packed: Array, super_packed_t: Array, *,
                 n_dims: int, s: int, n_cols: int | None = None,
                 block_b: int = DEFAULT_BLOCK_B,
                 interpret: bool | None = None) -> tuple[Array, Array]:
    """Score packed queries against G packed super-centroids, keep top S.

    Args:
      q_packed: (B, Dp) uint8 packed queries (``pack_rows``), tail bits 0.
      super_packed_t: (Dp, G) uint8 transposed packed super-centroids —
        ``pack_rows(super_am).T`` for a (G, D) bipolar super-AM.
      n_dims: true hypervector dimension D.
      s: shortlist length, 1 <= s <= G (static).
      n_cols: true cluster count G; defaults to super_packed_t.shape[1].
      block_b: query-batch tile height.
      interpret: force Pallas interpret mode (defaults to True off-TPU).

    Returns:
      (cluster_idx, cluster_sims): (B, s) int32 and (B, s) float32,
      best-first, ties toward the lower cluster id — bit-exact with
      ``ref.am_shortlist``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, dp = q_packed.shape
    dp2, g = super_packed_t.shape
    assert dp == dp2, (q_packed.shape, super_packed_t.shape)
    if n_cols is None:
        n_cols = g
    if not 1 <= s <= n_cols:
        raise ValueError(f"shortlist s={s} outside [1, {n_cols}]")
    if not dp * 8 >= n_dims > (dp - 1) * 8:
        raise ValueError(f"n_dims={n_dims} inconsistent with Dp={dp}")

    bb = min(block_b, max(b, 1))
    qp = pad_tiles(q_packed, bb, TILE_P)
    ap = pad_tiles(super_packed_t, TILE_P, TILE)
    gb = qp.shape[0] // bb
    gc = ap.shape[1] // TILE
    gd = qp.shape[1] // TILE_P

    idx, sim = pl.pallas_call(
        _make_kernel(n_cols, n_dims, s),
        grid=(gb, gc, gd),
        in_specs=[
            pl.BlockSpec((bb, TILE_P), lambda i, cc, d: (i, d)),
            pl.BlockSpec((TILE_P, TILE), lambda i, cc, d: (d, cc)),
        ],
        out_specs=[
            pl.BlockSpec((bb, s), lambda i, cc, d: (i, 0)),
            pl.BlockSpec((bb, s), lambda i, cc, d: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], s), jnp.int32),
            jax.ShapeDtypeStruct((qp.shape[0], s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, TILE), jnp.float32),
            pltpu.VMEM((bb, s), jnp.float32),
            pltpu.VMEM((bb, s), jnp.int32),
        ],
        interpret=interpret,
    )(qp, ap)
    return idx[:b], sim[:b]
