"""Pallas flash-decode kernel: one-token GQA attention over a KV cache.

The serving hot loop of every attention arch's decode cell: a single
query position attends over a (possibly 32k–500k entry) cache. On TPU
the cache streams HBM→VMEM in (BLOCK, head_dim) tiles while (m, l, acc)
online-softmax state lives in VMEM scratch — the cache is read exactly
once and no (S,) score vector ever materializes in HBM.

    grid = (B, H, S/BLOCK)     # S innermost: streaming reduction
    scratch: m (1,), l (1,), acc (1, Dh)

Head-repeat for GQA (q heads / kv heads) happens through the kv
BlockSpec index_map (query head h reads kv head h // groups) — zero-copy
sharing of kv tiles across the q heads of a group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

BLOCK = 128


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    sblk = pl.program_id(2)
    nblk = pl.num_programs(2)

    @pl.when(sblk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bb = pl.program_id(0)
    qv = q_ref[0, 0, :].astype(jnp.float32)      # (Dh,)
    k = k_ref[0, 0].astype(jnp.float32)          # (BLOCK, Dh)
    v = v_ref[0, 0].astype(jnp.float32)          # (BLOCK, Dh)
    dh = qv.shape[-1]
    scale = 1.0 / (dh ** 0.5)
    s = jnp.dot(k, qv, preferred_element_type=jnp.float32) * scale

    pos = sblk * BLOCK + jax.lax.broadcasted_iota(jnp.int32, (BLOCK,), 0)
    valid = pos < len_ref[bb]
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)  # (BLOCK,)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_ref[0] * corr + jnp.sum(p)
    acc_new = acc_ref[...] * corr + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32)  # (1, Dh)
    m_ref[0] = m_new
    l_ref[0] = l_new
    acc_ref[...] = acc_new

    @pl.when(sblk == nblk - 1)
    def _emit():
        o_ref[0, 0, :] = (acc_ref[0]
                          / jnp.maximum(l_ref[0], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode(q: Array, k_cache: Array, v_cache: Array,
                 cache_len: Array, *,
                 interpret: bool | None = None) -> Array:
    """One-token attention over the cache.

    Args:
      q: (B, H, Dh) query for the current position.
      k_cache/v_cache: (B, S, KV, Dh); S is padded to a BLOCK multiple by
        this wrapper. H % KV == 0 (GQA groups).
      cache_len: (B,) valid entries per row (keys at index >= len are
        masked).

    Returns: (B, H, Dh) attention output.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    groups = h // kv
    pad = -s % BLOCK
    kp = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = (s + pad) // BLOCK
    # (B, S, KV, Dh) -> (B, KV, S, Dh): the streaming dim is block-major.
    kp = jnp.swapaxes(kp, 1, 2)
    vp = jnp.swapaxes(vp, 1, 2)

    return pl.pallas_call(
        _kernel,
        grid=(b, h, nblk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # cache_len (B,)
            pl.BlockSpec((1, 1, dh), lambda bb, hh, ss: (bb, hh, 0)),
            pl.BlockSpec((1, 1, BLOCK, dh),
                         lambda bb, hh, ss: (bb, hh // groups, ss, 0)),
            pl.BlockSpec((1, 1, BLOCK, dh),
                         lambda bb, hh, ss: (bb, hh // groups, ss, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda bb, hh, ss: (bb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, q, kp, vp)
