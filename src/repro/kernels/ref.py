"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantics* — the kernels must match them bit-for-bit (exact
integer-valued arithmetic) across the shape/dtype sweeps in
tests/test_kernels.py. Keep them boring and obviously correct.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def binary_mvm(x: Array, w: Array) -> Array:
    """H = x @ w with float32 accumulation.

    x: (B, K) features or queries; w: (K, N) bipolar projection/AM weights.
    """
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def am_search(q: Array, am_t: Array) -> tuple[Array, Array]:
    """Fused associative search.

    q: (B, D) queries; am_t: (D, C) transposed AM (column c = centroid c).

    Returns:
      (best_idx, best_sim): (B,) int32 argmax centroid (first-wins ties,
      matching the kernel's running-compare semantics) and (B,) float32
      max similarity.
    """
    sims = jnp.dot(q.astype(jnp.float32), am_t.astype(jnp.float32),
                   preferred_element_type=jnp.float32)  # (B, C)
    best_idx = jnp.argmax(sims, axis=-1).astype(jnp.int32)
    best_sim = jnp.max(sims, axis=-1)
    return best_idx, best_sim


def pack_bits(x: Array) -> Array:
    """Pack bipolar/binary values into uint8, 8 cells per byte, LSB-first.

    x: (R, C) with C % 8 == 0; a cell is "1" iff x > 0.

    Returns: (R, C // 8) uint8.
    """
    r, c = x.shape
    bits = (x > 0).astype(jnp.int32).reshape(r, c // 8, 8)
    weights = (2 ** jnp.arange(8, dtype=jnp.int32))
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def unpack_bits(packed: Array, dtype=jnp.float32) -> Array:
    """Inverse of pack_bits: (R, C//8) uint8 -> (R, C) bipolar {-1, +1}."""
    r, cb = packed.shape
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (packed.astype(jnp.int32)[:, :, None] >> shifts) & 1
    return (bits.reshape(r, cb * 8).astype(dtype) * 2 - 1)


def pack_rows(x: Array) -> Array:
    """(B, D) bipolar -> (B, ceil(D/8)) uint8; tail bits packed as 0."""
    d = x.shape[-1]
    pad = -d % 8
    if pad:
        x = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad)),
                    constant_values=-1.0)
    return pack_bits(x)


def hamming_distances(q_packed: Array, am_packed_t: Array) -> Array:
    """Popcount(XOR) distances over packed bits.

    q_packed: (B, Dp) uint8; am_packed_t: (Dp, C) uint8 -> (B, C) int32.
    """
    x = jax.lax.bitwise_xor(
        q_packed.astype(jnp.int32)[:, :, None],
        am_packed_t.astype(jnp.int32)[None, :, :])  # (B, Dp, C)
    v = x - ((x >> 1) & 0x55)
    v = (v & 0x33) + ((v >> 2) & 0x33)
    pc = (v + (v >> 4)) & 0x0F
    return jnp.sum(pc, axis=1)


def am_search_packed(q_packed: Array, am_packed_t: Array, n_dims: int,
                     ) -> tuple[Array, Array]:
    """Packed-domain associative search oracle.

    Uses the bipolar identity dot = D - 2*hamming (tail bits pack to 0 in
    both operands, so they cancel in the XOR). Returns the same
    (best_idx, best_sim) as ``am_search`` on the unpacked operands.
    """
    ham = hamming_distances(q_packed, am_packed_t)  # (B, C)
    sims = (n_dims - 2 * ham).astype(jnp.float32)
    best_idx = jnp.argmax(sims, axis=-1).astype(jnp.int32)
    best_sim = jnp.max(sims, axis=-1)
    return best_idx, best_sim


def _rank_by_sim_then_id(sims: Array, ids: Array) -> Array:
    """Column order sorting each row by (-sim, id): best similarity
    first, ties broken toward the LOWER id — exactly the flat kernel's
    first-wins running compare when ids are the original scan order.

    Implemented as a two-pass stable sort (sort by id, then stably by
    -sim), which is the lexicographic (-sim, id) order.
    """
    id_order = jnp.argsort(ids, axis=-1, stable=True)
    sims_by_id = jnp.take_along_axis(sims, id_order, axis=-1)
    sim_order = jnp.argsort(-sims_by_id, axis=-1, stable=True)
    return jnp.take_along_axis(id_order, sim_order, axis=-1)


def am_shortlist(q_packed: Array, super_packed_t: Array, n_dims: int,
                 s: int) -> tuple[Array, Array]:
    """Coarse pass of the hierarchical search: top-``s`` clusters.

    q_packed: (B, Dp) uint8 packed queries; super_packed_t: (Dp, G)
    uint8 packed super-centroids (one column per cluster of the full
    AM); n_dims: true D; s: shortlist length, 1 <= s <= G.

    Returns (cluster_idx, cluster_sims): (B, s) int32 cluster ids and
    (B, s) float32 super-centroid similarities, ordered best-first with
    ties broken toward the lower cluster id.
    """
    ham = hamming_distances(q_packed, super_packed_t)  # (B, G)
    sims = (n_dims - 2 * ham).astype(jnp.float32)
    g = sims.shape[-1]
    ids = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32), sims.shape)
    order = _rank_by_sim_then_id(sims, ids)[:, :s]
    return (order.astype(jnp.int32),
            jnp.take_along_axis(sims, order, axis=-1))


def am_search_topk(q_packed: Array, am_packed_t: Array, n_dims: int,
                   k: int) -> tuple[Array, Array]:
    """Exact flat top-k associative search (the recall reference).

    Same operands as ``am_search_packed``; returns (idx, sims), each
    (B, k), ordered by (-sim, centroid id). Row k=1 is bit-identical to
    ``am_search_packed`` (first-wins tie == lowest-id tie).
    """
    ham = hamming_distances(q_packed, am_packed_t)  # (B, C)
    sims = (n_dims - 2 * ham).astype(jnp.float32)
    c = sims.shape[-1]
    ids = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), sims.shape)
    order = _rank_by_sim_then_id(sims, ids)[:, :k]
    return (order.astype(jnp.int32),
            jnp.take_along_axis(sims, order, axis=-1))


def am_search_sparse(q_packed: Array, tiles_packed: Array,
                     tile_ids: Array, n_dims: int, k: int,
                     ) -> tuple[Array, Array]:
    """Fine pass of the hierarchical search, on pre-gathered tiles.

    q_packed: (B, Dp) uint8 packed queries; tiles_packed: (B, Dp, T*128)
    uint8 — each query's shortlisted AM tiles gathered side by side;
    tile_ids: (B, T*128) int32 ORIGINAL centroid id per gathered column
    (-1 for cluster-padding / null-tile columns).

    Returns (idx, sims): (B, k) int32 original centroid ids and (B, k)
    float32 similarities, ordered by (-sim, id); slots with no valid
    candidate left emit id -1 and sim float32-min. Tie-breaking on the
    ORIGINAL id makes the degenerate shortlist-everything configuration
    bit-exact with the flat packed scan.
    """
    # Stay in uint8 until the reduce: the (B, Dp, TC) intermediate is
    # the dominant cost of this path (it also serves as the CPU/GPU
    # serving path via ops' auto-dispatch, not just the test oracle),
    # and hardware popcount on uint8 is bit-identical to the SWAR form.
    x = jax.lax.bitwise_xor(q_packed[:, :, None], tiles_packed)
    ham = jnp.sum(jnp.bitwise_count(x), axis=1, dtype=jnp.int32)  # (B, TC)
    neg = jnp.finfo(jnp.float32).min
    valid = tile_ids >= 0
    sims = jnp.where(valid, (n_dims - 2 * ham).astype(jnp.float32), neg)
    sent = jnp.iinfo(jnp.int32).max
    ids = jnp.where(valid, tile_ids, sent)
    order = _rank_by_sim_then_id(sims, ids)[:, :k]
    top_sims = jnp.take_along_axis(sims, order, axis=-1)
    top_ids = jnp.take_along_axis(tile_ids, order, axis=-1)
    idx = jnp.where(top_sims > neg, top_ids, -1).astype(jnp.int32)
    if idx.shape[-1] < k:  # k > candidate columns: pad exhausted slots
        pad = ((0, 0), (0, k - idx.shape[-1]))
        idx = jnp.pad(idx, pad, constant_values=-1)
        top_sims = jnp.pad(top_sims, pad, constant_values=neg)
    return idx, top_sims


def encode_pack(feats: Array, projection: Array) -> Array:
    """Staged feature->packed-query chain: the ``encode_fused`` oracle.

    H = feats @ projection (float32 accumulation), binarized with the
    inference-path semantics (sign(0) -> +1, i.e. bit 1 iff H >= 0) and
    packed LSB-first along D with tail bits 0 (``pack_rows``).

    feats: (B, f); projection: (f, D) bipolar. Returns (B, ceil(D/8))
    uint8.
    """
    h = binary_mvm(feats, projection)
    q = jnp.where(h >= 0, 1.0, -1.0)
    return pack_rows(q)


def predict_from_features(feats: Array, projection: Array,
                          am_packed_t: Array, centroid_class: Array,
                          ) -> Array:
    """Staged feature->class pipeline oracle: encode_pack + packed search
    + ownership gather. Returns (B,) int32 predicted classes."""
    qp = encode_pack(feats, projection)
    idx, _ = am_search_packed(qp, am_packed_t, projection.shape[1])
    return centroid_class[idx]


def adc_quantize(x: Array, bits: int, clip: float) -> Array:
    """Symmetric mid-tread ADC transfer function.

    Clips to [-clip, +clip] and rounds to the nearest of the 2^bits + 1
    codes spaced ``step = 2*clip / 2**bits`` apart (jnp.round semantics:
    ties to even, matching the kernel bit-for-bit). With a power-of-two
    clip the step is a power of two, so any integer input with
    ``|x| <= clip`` is reproduced exactly once ``step <= 1``.
    """
    step = 2.0 * clip / (2 ** bits)
    x = jnp.clip(x, -clip, clip)
    return jnp.round(x / step) * step


def am_search_imc(q: Array, am_t: Array, *, tile_rows: int, tile_cols: int,
                  adc_bits: int, adc_clip: float,
                  offsets: Array | None = None) -> tuple[Array, Array]:
    """Tiled analog associative-search oracle (device-fidelity semantics).

    The AM is split into (tile_rows x tile_cols) physical arrays; each
    array contributes an analog partial sum that picks up its per-tile
    readout offset, goes through the ADC (``adc_quantize``), and only
    then is accumulated digitally across row-tiles. Argmax is first-wins
    over the quantized similarities.

    q: (B, D) queries; am_t: (D, C) transposed (possibly perturbed) AM;
    offsets: optional (ceil(D/tile_rows), ceil(C/tile_cols)) per-tile
    readout offsets. Returns (best_idx, best_sim) like ``am_search``.
    """
    b, d = q.shape
    d2, c = am_t.shape
    assert d == d2, (q.shape, am_t.shape)
    gd = -(-d // tile_rows)
    gc = -(-c // tile_cols)
    qp = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, gd * tile_rows - d)))
    ap = jnp.pad(am_t.astype(jnp.float32),
                 ((0, gd * tile_rows - d), (0, gc * tile_cols - c)))
    qr = qp.reshape(b, gd, tile_rows)
    ar = ap.reshape(gd, tile_rows, gc, tile_cols)
    # One (g, h) slot == one physical array's analog MVM output.
    part = jnp.einsum("bgr,grhc->bghc", qr, ar,
                      preferred_element_type=jnp.float32)
    if offsets is not None:
        part = part + offsets[None, :, :, None]
    part = adc_quantize(part, adc_bits, adc_clip)
    sims = jnp.sum(part, axis=1).reshape(b, gc * tile_cols)[:, :c]
    best_idx = jnp.argmax(sims, axis=-1).astype(jnp.int32)
    best_sim = jnp.max(sims, axis=-1)
    return best_idx, best_sim


def multibit_adc_clip(cell_bits: int, tile_rows: int = 128) -> float:
    """Default ADC full-scale range for bit-sliced multi-bit readout.

    A (tile_rows)-row analog pass over ``cell_bits``-bit cells produces
    code-domain partial sums bounded by ``Qmax * tile_rows`` with
    ``Qmax = 2**(cell_bits-1) - 1``; the default clip is the next power
    of two at or above that bound, so (as with the 1-bit kernel's
    ``clip = rows`` default) the mid-tread step is a power of two and
    integer partial sums reproduce exactly whenever ``step <= 1``.
    """
    qmax = 2 ** (cell_bits - 1) - 1
    bound = max(qmax * tile_rows, 1)
    return float(2 ** (bound - 1).bit_length())


def pack_planes(u: Array, n_planes: int) -> Array:
    """(C, D) unsigned integer codes -> (n_planes, ceil(D/8), C) uint8.

    Bit plane p holds bit p of every code, packed 8 cells/byte LSB-first
    along D (the ``pack_bits`` layout) and transposed to the kernels'
    column-major centroid placement. D-tail bits pack as 0, i.e. code 0.
    """
    c, d = u.shape
    pad = -d % 8
    u = jnp.pad(u.astype(jnp.int32), ((0, 0), (0, pad)))
    dp = u.shape[1] // 8
    weights = 2 ** jnp.arange(8, dtype=jnp.int32)
    planes = []
    for p in range(n_planes):
        bits = ((u >> p) & 1).reshape(c, dp, 8)
        planes.append(jnp.sum(bits * weights, axis=-1).astype(jnp.uint8).T)
    return jnp.stack(planes)


def unpack_planes(planes: Array) -> Array:
    """Inverse of ``pack_planes``: (P, Dp, C) uint8 -> (Dp*8, C) int32
    offset codes (D-tail rows unpack to 0)."""
    n_planes, dp, c = planes.shape
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (planes.astype(jnp.int32)[:, :, None, :]
            >> shifts[None, None, :, None]) & 1       # (P, Dp, 8, C)
    weights = 2 ** jnp.arange(n_planes, dtype=jnp.int32)
    return jnp.sum(bits.reshape(n_planes, dp * 8, c)
                   * weights[:, None, None], axis=0)


def am_search_multibit(q: Array, am_planes_t: Array, *, cell_bits: int,
                       tile_rows: int = 128, tile_cols: int = 128,
                       adc_bits: int = 16,
                       adc_clip: float | None = None,
                       offsets: Array | None = None,
                       ) -> tuple[Array, Array]:
    """Bit-sliced multi-bit associative-search oracle (code domain).

    The resident AM is ``cell_bits``-bit symmetric codes stored as
    offset codes ``u = code + Qmax`` in ``pack_planes`` bit planes;
    the search unpacks them, recenters (``code = u - Qmax``), and runs
    the same tiled analog-partial-sum + ADC + first-wins pipeline as
    ``am_search_imc`` — in the integer code domain, so every similarity
    is integer-valued and the kernel must match bit for bit. Callers
    wanting dequantized similarities multiply by the AM scale.

    q: (B, D) bipolar queries; am_planes_t: (cell_bits, ceil(D/8), C)
    uint8 bit planes; offsets: optional (ceil(D/tile_rows),
    ceil(C/tile_cols)) per-tile code-domain readout offsets.
    Returns (best_idx, best_sim) like ``am_search``.
    """
    if adc_clip is None:
        adc_clip = multibit_adc_clip(cell_bits, tile_rows)
    qmax = 2 ** (cell_bits - 1) - 1
    b, d = q.shape
    n_planes, dp, c = am_planes_t.shape
    assert n_planes == cell_bits, (am_planes_t.shape, cell_bits)
    assert dp * 8 >= d > (dp - 1) * 8, (q.shape, am_planes_t.shape)
    # Recentered codes; D-tail cells read -Qmax, but the matching query
    # rows are zero-padded so they contribute nothing (the kernel's
    # rowsum correction has the same property).
    codes_t = (unpack_planes(am_planes_t) - qmax).astype(jnp.float32)
    gd = -(-dp * 8 // tile_rows)
    gc = -(-c // tile_cols)
    qp = jnp.pad(q.astype(jnp.float32),
                 ((0, 0), (0, gd * tile_rows - d)))
    ap = jnp.pad(codes_t, ((0, gd * tile_rows - dp * 8),
                           (0, gc * tile_cols - c)))
    qr = qp.reshape(b, gd, tile_rows)
    ar = ap.reshape(gd, tile_rows, gc, tile_cols)
    part = jnp.einsum("bgr,grhc->bghc", qr, ar,
                      preferred_element_type=jnp.float32)
    if offsets is not None:
        part = part + offsets[None, :, :, None]
    part = adc_quantize(part, adc_bits, adc_clip)
    sims = jnp.sum(part, axis=1).reshape(b, gc * tile_cols)[:, :c]
    best_idx = jnp.argmax(sims, axis=-1).astype(jnp.int32)
    best_sim = jnp.max(sims, axis=-1)
    return best_idx, best_sim


def qail_update_delta(q: Array, upd: Array, am_t: Array,
                      centroid_class: Array, labels: Array, mask: Array,
                      lr: float) -> tuple[Array, Array]:
    """Fused QAIL inner step (§III-C steps 1-3) for one minibatch.

    q: (B, D) binarized queries; upd: (B, D) Eq.-(6) update payload;
    am_t: (D, C) transposed binary AM; centroid_class: (C,) ownership;
    labels: (B,) int labels (-1 for padded rows); mask: (B,) {0,1}.

    Returns (delta, n_miss): delta is the (C, D) float32 Eq.-(6) AM
    increment, expressed as the one-hot selection matmul
    ``W^T @ upd`` with W[i] = lr*mis_i*(onehot(true_t_i)-onehot(pred_t_i))
    — the formulation the Pallas kernel computes on the MXU, so kernel
    and oracle share bit-identical arithmetic.
    """
    c = am_t.shape[1]
    sims = jnp.dot(q.astype(jnp.float32), am_t.astype(jnp.float32),
                   preferred_element_type=jnp.float32)  # (B, C)
    pred_t = jnp.argmax(sims, axis=-1)  # Eq. (4)
    pred_class = centroid_class[pred_t]
    mis = (pred_class != labels).astype(jnp.float32) * mask

    neg = jnp.finfo(sims.dtype).min
    own = centroid_class[None, :] == labels[:, None]
    true_t = jnp.argmax(jnp.where(own, sims, neg), axis=-1)  # Eq. (5)

    w = (lr * mis)[:, None] * (
        jax.nn.one_hot(true_t, c, dtype=jnp.float32)
        - jax.nn.one_hot(pred_t, c, dtype=jnp.float32))  # (B, C)
    delta = jnp.dot(w.T, upd.astype(jnp.float32),
                    preferred_element_type=jnp.float32)  # (C, D)
    return delta, mis.sum()
