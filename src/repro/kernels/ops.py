"""Public jit'd surface of the kernel package.

Higher layers call these; each dispatches to the Pallas kernel (TPU, or
interpret mode elsewhere) and is validated against ``repro.kernels.ref``
across shape/dtype sweeps in tests/test_kernels.py.

The three hot-path kernels (``am_search_packed``, ``encode_pack`` and
its fused chains, ``qail_update``) accept ``block_b=None`` (the
default), meaning: consult the ``repro.kernels.autotune`` config cache
for the best batch-tile height tuned for this (kernel, backend,
geometry) and fall back to the kernel's fixed default when no tuned
entry exists. Tuned tilings only re-tile the batch axis, so every
config is bit-exact with the ``ref.py`` oracle (parity-checked at tune
time and again in tests); pass an explicit ``block_b`` to pin a tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.obs import metrics as _obs_metrics
from repro.kernels.am_search import am_search as _am_search
from repro.kernels.am_search import imc_cycles_for as search_cycles
from repro.kernels.am_search_imc import am_search_imc as _am_search_imc
from repro.kernels.am_search_imc import imc_cycles_for as imc_search_cycles
from repro.kernels.am_search_multibit import (
    am_search_multibit as _am_search_multibit,
)
from repro.kernels.am_search_multibit import (
    imc_cycles_for as multibit_search_cycles,
)
from repro.kernels.am_search_packed import am_search_packed as _am_search_packed
from repro.kernels.am_search_packed import imc_cycles_for as packed_search_cycles
from repro.kernels.am_search_packed import pack_rows as _pack_rows
from repro.kernels.am_search_sparse import am_search_sparse as _am_search_sparse
from repro.kernels.am_search_sparse import (
    am_search_sparse_gathered as _am_search_sparse_gathered,
)
from repro.kernels.am_search_sparse import (
    expand_shortlist_tiles as _expand_shortlist_tiles,
)
from repro.kernels.am_search_sparse import gather_shortlist as _gather_shortlist
from repro.kernels.am_shortlist import am_shortlist as _am_shortlist
from repro.kernels.binary_mvm import binary_mvm as _binary_mvm
from repro.kernels.binary_mvm import imc_cycles_for as mvm_cycles
from repro.kernels.encode_fused import encode_pack as _encode_pack
from repro.kernels.encode_fused import imc_cycles_for as encode_pack_cycles
from repro.kernels.encode_fused import (
    predict_from_features as _predict_from_features,
)
from repro.kernels.encode_fused import (
    search_from_features as _search_from_features,
)
from repro.kernels.pack_bits import pack_bits as _pack_bits
from repro.kernels.pack_bits import unpack_bits as _unpack_bits
from repro.kernels.qail_update import qail_update as _qail_update

Array = jax.Array

# Every public dispatch below counts itself here, labeled with which
# execution tier actually served it:
#   pallas     — the Pallas kernel (interpret-mode emulation off-TPU)
#   xla-oracle — the bit-exact XLA fallback the auto-dispatch kernels
#                (am_shortlist / am_search_sparse) serve through off-TPU
#   ref        — the pure-jnp ref.py oracle, requested explicitly
# plus the static geometry, so a kernel silently falling off its fast
# path (or a caller churning through padded shapes) shows up in any
# metrics snapshot instead of only as latency noise. Counts increment
# when the Python dispatch runs: once per trace for jitted callers
# (i.e. per compiled specialization), per call in eager mode.
_DISPATCH = _obs_metrics.counter(
    "kernel_dispatch_total",
    "kernel dispatches by (kernel, tier, geometry)")


def _count(kernel: str, tier: str, **dims) -> None:
    geometry = ",".join(f"{k}={v}" for k, v in sorted(dims.items()))
    _DISPATCH.inc(kernel=kernel, tier=tier, geometry=geometry)


def _tier(use_kernel: bool) -> str:
    return "pallas" if use_kernel else "ref"


def dispatch_breakdown() -> dict[str, dict[str, int]]:
    """{kernel: {tier: count}} summed over geometries — the serving
    report's and bench recorder's dispatch-tier table."""
    out: dict[str, dict[str, int]] = {}
    for labels, val in _DISPATCH.series():
        k, t = labels.get("kernel", "?"), labels.get("tier", "?")
        out.setdefault(k, {})
        out[k][t] = out[k].get(t, 0) + int(val)
    return out


def tuned_block_b(kernel: str, block_b: int | None, **dims) -> int:
    """Resolve the batch tile for a dispatch: explicit arg wins, then
    the autotune cache, then the kernel's DEFAULT_BLOCK_B. Runs at
    trace time (the cache read is memoized on file mtime)."""
    if block_b is not None:
        return block_b
    from repro.kernels import autotune  # deferred: package-init cycle
    return autotune.tuned_block_b(kernel, **dims)


__all__ = [
    "encode_mvm", "encode_pack", "am_search", "am_search_imc",
    "am_search_multibit", "am_search_packed", "am_shortlist",
    "am_search_sparse",
    "search_from_features", "predict_from_features",
    "pack_bits", "unpack_bits", "pack_rows", "qail_update",
    "predict_classes", "predict_packed", "predict_imc",
    "predict_multibit",
    "search_cycles", "imc_search_cycles", "packed_search_cycles",
    "multibit_search_cycles",
    "mvm_cycles", "encode_pack_cycles", "ref", "tuned_block_b",
    "dispatch_breakdown",
]


def encode_mvm(feats: Array, projection: Array, *, use_kernel: bool = True,
               ) -> Array:
    """Projection encoding H = F @ M through the IMC-geometry kernel.

    feats: (B, f); projection: (f, D) bipolar. Returns (B, D) float32.
    """
    _count("binary_mvm", _tier(use_kernel), B=feats.shape[0],
           f=projection.shape[0], D=projection.shape[1])
    if not use_kernel:
        return ref.binary_mvm(feats, projection)
    return _binary_mvm(feats, projection)


def encode_pack(feats: Array, projection: Array, *, use_kernel: bool = True,
                block_b: int | None = None) -> Array:
    """Fused encode + sign + bitpack: (B, f) -> (B, ceil(D/8)) uint8.

    One kernel pass: the projection MVM accumulates in VMEM and emits
    sign-binarized packed query rows directly — the float hypervector
    never reaches HBM. Bit-identical to
    ``pack_rows(binarize_query(feats @ projection))``.
    """
    _count("encode_pack", _tier(use_kernel), B=feats.shape[0],
           f=projection.shape[0], D=projection.shape[1])
    if not use_kernel:
        return ref.encode_pack(feats, projection)
    bb = tuned_block_b("encode_pack", block_b,
                       f=projection.shape[0], D=projection.shape[1])
    return _encode_pack(feats, projection, block_b=bb)


def search_from_features(feats: Array, projection: Array,
                         am_packed_t: Array, *, mode: str = "popcount",
                         use_kernel: bool = True,
                         block_b: int | None = None,
                         ) -> tuple[Array, Array]:
    """Single-dispatch feature->search chain over the packed AM.

    feats: (B, f); projection: (f, D) bipolar; am_packed_t: (Dp, C)
    uint8 (``pack_am``). Returns (best_idx, best_sim) bit-exact with
    the staged encode_query -> pack_rows -> am_search_packed chain.
    """
    _count("search_from_features", _tier(use_kernel), B=feats.shape[0],
           D=projection.shape[1], C=am_packed_t.shape[1])
    if not use_kernel:
        qp = ref.encode_pack(feats, projection)
        return ref.am_search_packed(qp, am_packed_t, projection.shape[1])
    bb = tuned_block_b("encode_pack", block_b,
                       f=projection.shape[0], D=projection.shape[1])
    return _search_from_features(feats, projection, am_packed_t,
                                 mode=mode, block_b=bb)


def predict_from_features(feats: Array, projection: Array,
                          am_packed_t: Array, centroid_class: Array, *,
                          mode: str = "popcount", use_kernel: bool = True,
                          block_b: int | None = None) -> Array:
    """End-to-end §III-D prediction from raw features, one dispatch:
    fused encode/pack -> packed search -> ownership gather."""
    _count("predict_from_features", _tier(use_kernel), B=feats.shape[0],
           D=projection.shape[1], C=am_packed_t.shape[1])
    if not use_kernel:
        return ref.predict_from_features(feats, projection, am_packed_t,
                                         centroid_class)
    bb = tuned_block_b("encode_pack", block_b,
                       f=projection.shape[0], D=projection.shape[1])
    return _predict_from_features(feats, projection, am_packed_t,
                                  centroid_class, mode=mode, block_b=bb)


def am_search(queries: Array, am: Array, *, use_kernel: bool = True,
              ) -> tuple[Array, Array]:
    """Fused associative search.

    queries: (B, D); am: (C, D) bipolar centroid rows (the (D, C)
    transpose is formed here once — resident layout matches the IMC
    array's column-major centroid placement).

    Returns (best_idx, best_sim): (B,) int32, (B,) float32.
    """
    _count("am_search", _tier(use_kernel), B=queries.shape[0],
           D=queries.shape[1], C=am.shape[0])
    am_t = am.T
    if not use_kernel:
        return ref.am_search(queries, am_t)
    return _am_search(queries, am_t)


def am_search_imc(queries: Array, am: Array, *, sim, offsets: Array = None,
                  use_kernel: bool = True) -> tuple[Array, Array]:
    """Device-fidelity associative search (tiled analog MVM + ADC).

    queries: (B, D); am: (C, D) resident centroid rows — typically the
    perturbed output of ``repro.imcsim.device.perturb_am``; sim: an
    ``ImcSimConfig`` (array geometry + ADC transfer); offsets: optional
    per-tile readout drift grid.

    With an ideal sim (>=8-bit ADC at the default 128-row array, no
    perturbations) the result is bit-exact with ``am_search``.

    Returns (best_idx, best_sim): (B,) int32, (B,) float32.
    """
    _count("am_search_imc", _tier(use_kernel), B=queries.shape[0],
           D=queries.shape[1], C=am.shape[0])
    am_t = am.T
    if not use_kernel:
        return ref.am_search_imc(
            queries, am_t, tile_rows=sim.arr.rows, tile_cols=sim.arr.cols,
            adc_bits=sim.adc_bits, adc_clip=sim.clip, offsets=offsets)
    return _am_search_imc(
        queries, am_t, offsets, tile_rows=sim.arr.rows,
        tile_cols=sim.arr.cols, adc_bits=sim.adc_bits, adc_clip=sim.clip)


def am_search_multibit(queries: Array, am_planes_t: Array, *, sim=None,
                       scale: Array | None = None,
                       offsets: Array | None = None,
                       use_kernel: bool = True,
                       block_b: int | None = None) -> tuple[Array, Array]:
    """Bit-sliced associative search over the multi-bit packed AM.

    queries: (B, D) bipolar; am_planes_t: (cell_bits, Dp, C) uint8
    offset-code bit planes (``repro.core.am.pack_am_planes``); sim: an
    optional ``ImcSimConfig`` supplying array geometry + ADC transfer
    (defaults: 128x128 array, 16-bit ADC, ``ref.multibit_adc_clip``
    full scale); scale: optional quantizer scale — when given, the
    returned similarities are dequantized (idx is scale-invariant);
    offsets: optional per-tile code-domain readout drift grid.

    Returns (best_idx, best_sim): (B,) int32, (B,) float32 — the idx
    bit-exact with ``ref.am_search_multibit`` on the same operands.
    """
    cell_bits = int(am_planes_t.shape[0])
    tile_rows = sim.arr.rows if sim is not None else 128
    tile_cols = sim.arr.cols if sim is not None else 128
    adc_bits = sim.adc_bits if sim is not None else 16
    # Not sim.clip: that property defaults to the 1-bit bound (the row
    # count); multi-bit partial sums need the Qmax-scaled full scale.
    adc_clip = (sim.adc_clip
                if sim is not None and sim.adc_clip is not None
                else ref.multibit_adc_clip(cell_bits, tile_rows))
    _count("am_search_multibit", _tier(use_kernel), B=queries.shape[0],
           D=queries.shape[1], C=am_planes_t.shape[2], bits=cell_bits)
    if not use_kernel:
        idx, s = ref.am_search_multibit(
            queries, am_planes_t, cell_bits=cell_bits,
            tile_rows=tile_rows, tile_cols=tile_cols, adc_bits=adc_bits,
            adc_clip=adc_clip, offsets=offsets)
    else:
        bb = tuned_block_b("am_search_multibit", block_b,
                           D=queries.shape[1], C=am_planes_t.shape[2],
                           bits=cell_bits)
        idx, s = _am_search_multibit(
            queries, am_planes_t, offsets, cell_bits=cell_bits,
            tile_rows=tile_rows, tile_cols=tile_cols, adc_bits=adc_bits,
            adc_clip=float(adc_clip), block_b=bb)
    if scale is not None:
        s = s * jnp.asarray(scale, jnp.float32)
    return idx, s


def am_search_packed(q_packed: Array, am_packed_t: Array, *, n_dims: int,
                     mode: str = "popcount", use_kernel: bool = True,
                     block_b: int | None = None) -> tuple[Array, Array]:
    """Fused associative search over the packed 1-bit AM.

    q_packed: (B, Dp) uint8 packed queries (``pack_rows``);
    am_packed_t: (Dp, C) uint8 resident packed AM (``pack_rows(am).T``);
    n_dims: true hypervector dimension D.

    Returns (best_idx, best_sim) bit-exact with ``am_search`` on the
    corresponding unpacked operands.
    """
    _count("am_search_packed", _tier(use_kernel), B=q_packed.shape[0],
           D=n_dims, C=am_packed_t.shape[1])
    if not use_kernel:
        return ref.am_search_packed(q_packed, am_packed_t, n_dims)
    bb = tuned_block_b("am_search_packed", block_b, D=n_dims,
                       C=am_packed_t.shape[1])
    return _am_search_packed(q_packed, am_packed_t, n_dims=n_dims,
                             mode=mode, block_b=bb)


def am_shortlist(q_packed: Array, super_packed_t: Array, *, n_dims: int,
                 s: int, use_kernel: bool | None = None,
                 block_b: int | None = None) -> tuple[Array, Array]:
    """Coarse pass of the hierarchical search: top-``s`` clusters.

    q_packed: (B, Dp) uint8 packed queries; super_packed_t: (Dp, G)
    uint8 packed super-centroids. Returns ((B, s) cluster ids, (B, s)
    super similarities), best-first, ties toward the lower cluster id —
    bit-exact with ``ref.am_shortlist``. ``use_kernel=None`` (default)
    auto-dispatches like ``am_search_sparse``: Pallas on TPU, the
    bit-exact oracle elsewhere.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    _count("am_shortlist", "pallas" if use_kernel else "xla-oracle",
           B=q_packed.shape[0], D=n_dims, G=super_packed_t.shape[1],
           S=s)
    if not use_kernel:
        return ref.am_shortlist(q_packed, super_packed_t, n_dims, s)
    bb = tuned_block_b("am_shortlist", block_b, D=n_dims,
                       G=super_packed_t.shape[1], S=s)
    return _am_shortlist(q_packed, super_packed_t, n_dims=n_dims, s=s,
                         block_b=bb)


def am_search_sparse(q_packed: Array, am_slab_t: Array, col_ids: Array,
                     shortlist: Array, tile_start: Array,
                     tile_count: Array, *, n_dims: int, k: int,
                     max_tiles: int, use_kernel: bool | None = None,
                     block_b: int | None = None) -> tuple[Array, Array]:
    """Fine pass of the hierarchical search: shortlisted tiles + top-k.

    am_slab_t/col_ids/tile_start/tile_count describe the permuted
    cluster-contiguous slab (``deploy.hierarchical.build_layout``);
    shortlist: (B, S) cluster ids from ``am_shortlist``. Returns
    ((B, k) original centroid ids, (B, k) sims) ordered by (-sim, id);
    exhausted slots are (-1, float32-min). Bit-exact with
    ``ref.am_search_sparse`` on the gathered operands, and with S = G
    the k=1 column reproduces ``am_search_packed`` bit-for-bit.

    ``use_kernel=None`` (default) auto-dispatches: the Pallas kernel on
    TPU, the bit-exact XLA gather+oracle path elsewhere. Unlike the
    other kernels — whose inputs are shared across the grid — the
    sparse kernel's gathered operand is per-query, so interpret-mode
    emulation (which re-copies the full input every grid step) costs
    O(steps x B*S*max_tiles) and is pathologically slow off-TPU; the
    two paths are parity-tested bit-exact.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    _count("am_search_sparse", "pallas" if use_kernel else "xla-oracle",
           B=q_packed.shape[0], D=n_dims, S=shortlist.shape[1], K=k)
    if not use_kernel:
        null_tile = am_slab_t.shape[1] // 128 - 1
        tiles = _expand_shortlist_tiles(
            shortlist, tile_start, tile_count,
            max_tiles=max_tiles, null_tile=null_tile)
        gathered, ids = _gather_shortlist(am_slab_t, col_ids, tiles)
        return ref.am_search_sparse(q_packed, gathered, ids, n_dims, k)
    bb = tuned_block_b("am_search_sparse", block_b, D=n_dims,
                       T=shortlist.shape[1] * max_tiles, K=k)
    return _am_search_sparse(q_packed, am_slab_t, col_ids, shortlist,
                             tile_start, tile_count, n_dims=n_dims, k=k,
                             max_tiles=max_tiles, block_b=bb)


def pack_rows(x: Array, *, use_kernel: bool = True) -> Array:
    """(B, D) bipolar -> (B, ceil(D/8)) uint8, any D (tail bits 0)."""
    _count("pack_rows", _tier(use_kernel), B=x.shape[0], D=x.shape[1])
    if not use_kernel:
        return ref.pack_rows(x)
    return _pack_rows(x)


def pack_bits(x: Array, *, use_kernel: bool = True) -> Array:
    if not use_kernel:
        return ref.pack_bits(x)
    return _pack_bits(x)


def unpack_bits(p: Array, *, use_kernel: bool = True) -> Array:
    if not use_kernel:
        return ref.unpack_bits(p)
    return _unpack_bits(p)


def qail_update(q: Array, upd: Array, am_t: Array, centroid_class: Array,
                labels: Array, mask: Array, *, lr: float,
                use_kernel: bool = True,
                block_b: int | None = None) -> tuple[Array, Array]:
    """Fused QAIL inner step (§III-C): sims MVM + Eq. 4/5 + Eq.-(6) delta.

    q/upd: (B, D); am_t: (D, C) transposed binary AM; labels/mask: (B,).
    Returns (delta (C, D) float32, n_miss float32) — the Eq.-(6) shadow-AM
    increment for one minibatch, bit-exact between kernel and oracle.
    """
    _count("qail_update", _tier(use_kernel), B=q.shape[0],
           D=am_t.shape[0], C=am_t.shape[1])
    if not use_kernel:
        return ref.qail_update_delta(q, upd, am_t, centroid_class,
                                     labels, mask, lr)
    bb = tuned_block_b("qail_update", block_b, D=am_t.shape[0],
                       C=am_t.shape[1])
    return _qail_update(q, upd, am_t, centroid_class, labels, mask,
                        lr=lr, block_b=bb)


def predict_classes(queries: Array, am: Array, centroid_class: Array,
                    *, use_kernel: bool = True) -> Array:
    """End-to-end §III-D prediction: search + ownership lookup."""
    idx, _ = am_search(queries, am, use_kernel=use_kernel)
    return centroid_class[idx]


def predict_packed(queries: Array, am_packed_t: Array,
                   centroid_class: Array, *, n_dims: int,
                   mode: str = "popcount", use_kernel: bool = True,
                   ) -> Array:
    """§III-D prediction over the packed residence: pack the bipolar
    queries, fused XOR+popcount search, ownership lookup."""
    qp = pack_rows(queries, use_kernel=use_kernel)
    idx, _ = am_search_packed(qp, am_packed_t, n_dims=n_dims, mode=mode,
                              use_kernel=use_kernel)
    return centroid_class[idx]


def predict_imc(queries: Array, am: Array, centroid_class: Array, *,
                sim, offsets: Array = None, use_kernel: bool = True,
                ) -> Array:
    """§III-D prediction through the simulated analog readout:
    tiled analog search + ADC + ownership lookup."""
    idx, _ = am_search_imc(queries, am, sim=sim, offsets=offsets,
                           use_kernel=use_kernel)
    return centroid_class[idx]


def predict_multibit(queries: Array, am_planes_t: Array,
                     centroid_class: Array, *, sim=None,
                     offsets: Array = None, use_kernel: bool = True,
                     ) -> Array:
    """§III-D prediction over the multi-bit residence: bit-sliced
    code-domain search + ownership lookup (argmax is scale-invariant,
    so the quantizer scale never enters)."""
    idx, _ = am_search_multibit(queries, am_planes_t, sim=sim,
                                offsets=offsets, use_kernel=use_kernel)
    return centroid_class[idx]
