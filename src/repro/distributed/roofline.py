"""Roofline-term computation from compiled dry-run artifacts.

Three terms, per EXPERIMENTS.md §Roofline:

  compute    = HLO_FLOPs_global / (chips * peak_flops)
  memory     = HLO_bytes_global / (chips * hbm_bw)
  collective = wire_bytes_per_chip / link_bw
             (== collective_bytes_global / (chips * link_bw))

``cost_analysis()`` on a GSPMD-partitioned module reports *per-device*
flops/bytes (verified in tests/test_hlo.py), so global = per_device *
chips. The dominant term approximates step latency under perfect overlap;
its max() lower-bounds the step time, and MODEL_FLOPS / HLO_FLOPs exposes
remat/dispatch overhead (how much compiled compute is "useful").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops: float        # per chip, bf16
    hbm_bw: float            # B/s per chip
    link_bw: float           # B/s per ICI link
    hbm_bytes: float         # per chip


V5E = HwSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
             link_bw=50e9, hbm_bytes=16e9)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw measurements (per device unless noted)
    flops_per_dev: float
    bytes_per_dev: float
    wire_bytes_per_dev: float
    wire_by_kind: Dict[str, float]
    model_flops_global: float          # 6*N*D (or 6*N_active*D)
    argument_bytes_per_dev: float
    temp_bytes_per_dev: float
    output_bytes_per_dev: float
    # derived terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_seconds(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_dev * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization if the step ran at the roofline bound."""
        denom = self.bound_seconds * self.chips
        if denom <= 0:
            return 0.0
        return self.model_flops_global / denom / V5E.peak_flops

    @property
    def hbm_per_dev(self) -> float:
        return self.argument_bytes_per_dev + self.temp_bytes_per_dev \
            + self.output_bytes_per_dev

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, bound_seconds=self.bound_seconds,
                 useful_flops_ratio=self.useful_flops_ratio,
                 mfu_bound=self.mfu_bound, hbm_per_dev=self.hbm_per_dev)
        return d


def roofline(*, arch: str, shape: str, mesh_name: str, chips: int,
             flops_per_dev: float, bytes_per_dev: float,
             wire_by_kind: Dict[str, float], model_flops_global: float,
             argument_bytes: float = 0.0, temp_bytes: float = 0.0,
             output_bytes: float = 0.0,
             hw: HwSpec = V5E) -> RooflineReport:
    wire_total = wire_by_kind.get("total", 0.0)
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_dev=flops_per_dev, bytes_per_dev=bytes_per_dev,
        wire_bytes_per_dev=wire_total, wire_by_kind=dict(wire_by_kind),
        model_flops_global=model_flops_global,
        argument_bytes_per_dev=argument_bytes,
        temp_bytes_per_dev=temp_bytes,
        output_bytes_per_dev=output_bytes,
    )
    rep.t_compute = flops_per_dev / hw.peak_flops
    rep.t_memory = bytes_per_dev / hw.hbm_bw
    rep.t_collective = wire_total / hw.link_bw
    return rep


def model_flops(param_count_active: int, tokens: int,
                step: str = "train") -> float:
    """6*N*D for training; 2*N*D for a forward/decode pass."""
    mult = 6.0 if step == "train" else 2.0
    return mult * param_count_active * tokens
