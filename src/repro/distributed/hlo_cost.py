"""Loop-aware cost analysis over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE, which
makes scanned-layer models (all of ours) look ~L-times cheaper than they
are (verified in tests/test_hlo.py). This module re-derives the three
roofline inputs from the HLO text with loops expanded:

  * flops       — 2 * prod(result_dims) * K for every dot, times the
                  product of enclosing whiles' known_trip_counts;
  * hbm bytes   — Σ (result + operand bytes) over *materialized* ops
                  (top-level instructions only: fusion internals live in
                  registers/VMEM, so the fusion boundary is exactly the
                  HBM-traffic boundary), loop-corrected likewise;
  * wire bytes  — per-collective ring-model bytes (see hlo.py),
                  loop-corrected.

The analyzer builds the computation call graph (fusion `calls=`,
`to_apply=`, while `body=`/`condition=`, conditional branches) and
memoizes totals bottom-up. Trip counts come from the
``backend_config={"known_trip_count":{"n":...}}`` attribute XLA attaches
to compiled scan loops (fallback: constants in the condition).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

from repro.distributed.hlo import _DTYPE_BYTES, _wire_bytes

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_OP_KIND = re.compile(r"^(?:\(.*?\)|[a-z][a-z0-9]*\[[\d,]*\](?:\{[\d,]*\})?)\s+"
                      r"([\w\-]+)\(")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

# Ops that move no data (metadata / aliasing only).
_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "add-dependency", "opt-barrier", "partition-id",
    "replica-id", "iota",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _parse_shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        nb = _DTYPE_BYTES.get(m.group(1))
        if nb is None:
            continue
        n = 1
        dims = m.group(2)
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * nb
    return total


def _first_shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE.search(text)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims.strip() else []


@dataclasses.dataclass
class Instr:
    name: str
    rhs: str
    kind: str
    result_bytes: int


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]  # instr/param name -> its full type text


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(1), [], {})
                # Parameter types from the signature.
                for pm in re.finditer(r"([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                      m.group(2)):
                    cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        km = _OP_KIND.match(rhs)
        kind = km.group(1) if km else "unknown"
        shape_prefix = rhs.split(kind + "(")[0] if km else rhs
        cur.shapes[name] = shape_prefix
        cur.instrs.append(Instr(
            name=name, rhs=rhs, kind=kind,
            result_bytes=_parse_shape_bytes(shape_prefix)))
    return comps


def _dot_flops(instr: Instr, comp: Computation) -> float:
    """2 * prod(result) * K for dot/dot_general."""
    result_dims = _first_shape_dims(instr.rhs.split(instr.kind + "(")[0])
    if result_dims is None:
        return 0.0
    out = 1
    for d in result_dims:
        out *= d
    cm = _CONTRACT.search(instr.rhs)
    k = 1
    if cm:
        # lhs operand: first operand name inside the call parens
        om = _OPERANDS.search(instr.rhs[instr.rhs.index(instr.kind + "("):])
        if om:
            lhs = _operand_names(om.group(1))
            lhs = lhs[0] if lhs else None
            lhs_type = comp.shapes.get(lhs, "")
            # Some HLO dumps print the operand's type inline
            # ("dot(f32[64,128]{1,0} %x, ...)") — use it directly when
            # the name isn't resolvable (e.g. cross-computation refs).
            if not _first_shape_dims(lhs_type):
                tm = _SHAPE.search(om.group(1))
                lhs_type = tm.group(0) if tm else ""
            lhs_dims = _first_shape_dims(lhs_type)
            if lhs_dims and cm.group(1).strip():
                for idx in cm.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
    return 2.0 * out * k


def _operand_names(operand_text: str) -> List[str]:
    """Operand instruction names from a call's paren contents.

    Handles both bare-name operands ("dot(x, y)") and typed operands
    whose layouts contain commas ("dot(f32[64,128]{1,0} %x, ...)") — a
    naive split(",") breaks on the latter, so prefer %-prefixed tokens.
    """
    pct = re.findall(r"%([\w.\-]+)", operand_text)
    if pct:
        return pct
    names = []
    for tok in operand_text.split(","):
        tok = tok.strip()
        if tok:
            names.append(tok.split()[-1].lstrip("%"))
    return names


def _operand_bytes(instr: Instr, comp: Computation) -> int:
    start = instr.rhs.find(instr.kind + "(")
    if start < 0:
        return 0
    om = _OPERANDS.search(instr.rhs[start:])
    if not om:
        return 0
    total = 0
    for name in _operand_names(om.group(1)):
        total += _parse_shape_bytes(comp.shapes.get(name, ""))
    return total


def _trip_count(instr: Instr, comps: Dict[str, Computation]) -> int:
    m = _TRIP.search(instr.rhs)
    if m:
        return int(m.group(1))
    # Fallback: largest integer constant in the condition computation.
    cm = _COND.search(instr.rhs)
    if cm and cm.group(1) in comps:
        best = 1
        for ins in comps[cm.group(1)].instrs:
            c = re.search(r"constant\((\d+)\)", ins.rhs)
            if c:
                best = max(best, int(c.group(1)))
        return best
    return 1


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "CostTotals":
        return CostTotals(self.flops * k, self.hbm_bytes * k,
                          self.wire_bytes * k,
                          {kk: v * k for kk, v in self.wire_by_kind.items()})

    def add(self, other: "CostTotals"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.wire_bytes += other.wire_bytes
        for kk, v in other.wire_by_kind.items():
            self.wire_by_kind[kk] = self.wire_by_kind.get(kk, 0.0) + v


def analyze(hlo: str, total_devices: int) -> CostTotals:
    """Loop-corrected per-device totals for the entry computation."""
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            m = _COMP_HEADER.match(ls)
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back to the last computation
        entry = list(comps)[-1] if comps else ""

    memo: Dict[str, CostTotals] = {}
    visiting: set = set()

    def comp_cost(name: str, materialized: bool) -> CostTotals:
        """materialized=False -> inside a fusion: no HBM traffic."""
        key = f"{name}|{materialized}"
        if key in memo:
            return memo[key]
        if name not in comps or name in visiting:
            return CostTotals()
        visiting.add(name)
        comp = comps[name]
        total = CostTotals()
        for ins in comp.instrs:
            sub = CostTotals()
            if ins.kind in ("dot", "convolution"):
                sub.flops += _dot_flops(ins, comp)
            if ins.kind == "while":
                calls = _CALLS.search(ins.rhs)
                trips = _trip_count(ins, comps)
                if calls:
                    sub.add(comp_cost(calls.group(1), materialized)
                            .scaled(trips))
                cond = _COND.search(ins.rhs)
                if cond:
                    sub.add(comp_cost(cond.group(1), False).scaled(trips))
            elif ins.kind == "conditional":
                bm = _BRANCHES.search(ins.rhs)
                if bm:
                    branches = [b.strip().lstrip("%")
                                for b in bm.group(1).split(",")]
                    subs = [comp_cost(b, materialized) for b in branches]
                    if subs:  # conservative: most expensive branch
                        sub.add(max(subs, key=lambda c: c.flops))
            elif ins.kind in ("fusion",):
                calls = _CALLS.search(ins.rhs)
                if calls:
                    sub.add(comp_cost(calls.group(1), False))
            elif ins.kind in ("call", "custom-call", "reduce", "sort",
                              "reduce-window", "scatter", "select-and-scatter",
                              "map", "all-reduce"):
                calls = _CALLS.search(ins.rhs)
                if calls:
                    sub.add(comp_cost(calls.group(1), False))
            base_kind = ins.kind.replace("-start", "").replace("-done", "")
            if base_kind in _COLLECTIVES and not ins.rhs.endswith("-done"):
                if not ins.kind.endswith("-done"):
                    from repro.distributed.hlo import _group_size
                    g = _group_size(ins.rhs, total_devices)
                    wb = _wire_bytes(base_kind, ins.result_bytes
                                     if base_kind != "reduce-scatter"
                                     else ins.result_bytes, g)
                    sub.wire_bytes += wb
                    sub.wire_by_kind[base_kind] = \
                        sub.wire_by_kind.get(base_kind, 0.0) + wb
            if materialized and ins.kind not in _FREE_OPS \
                    and not ins.kind.endswith("-done"):
                sub.hbm_bytes += ins.result_bytes + _operand_bytes(ins, comp)
            total.add(sub)
        visiting.discard(name)
        memo[key] = total
        return total

    result = comp_cost(entry, True)
    result.wire_by_kind["total"] = result.wire_bytes
    return result
