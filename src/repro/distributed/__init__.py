from repro.distributed.hlo import collective_bytes, parse_collectives  # noqa: F401
from repro.distributed.roofline import (  # noqa: F401
    HwSpec, RooflineReport, V5E, roofline,
)
