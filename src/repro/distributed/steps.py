"""Step-function builders: train_step and serve_step under pjit.

``make_train_step`` closes over (ModelConfig, AdamWConfig, schedule,
ShardingRules) and returns a pure (params, opt_state, batch, step) ->
(params, opt_state, metrics) function whose activations are annotated
with the rules' logical shardings. XLA GSPMD inserts every collective;
the dry-run inspects them.

Distributed-optimization features wired here:
  * FSDP / TP via the rules (params sharded at rest, gathered per layer).
  * DeepSeek-V3 aux-free router balancing: router biases are updated
    outside the gradient with the batch's expert counts.
  * Optional int8 error-feedback gradient compression across the "pod"
    axis (shard_map ring reduce-scatter; see optim/compression.py).
    With compression ON the gradient is averaged over pods *manually*,
    so the loss is computed with gradients stopped from crossing pods
    (per-pod mean), matching what the wire carries.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingRules, use_rules
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import _BLOCK, axis_size, \
    ef_int8_compress, ring_all_gather, ring_reduce_scatter_int8

Array = jax.Array
PyTree = Any

BIAS_UPDATE_RATE = 0.001  # DeepSeek-V3 gamma for aux-free balancing


def _apply_router_bias_update(params: PyTree, cfg: ModelConfig,
                              metrics: Dict[str, Array]) -> PyTree:
    """Aux-free load balancing: bias += gamma * sign(mean_load - load)."""
    groups = list(params["groups"])
    for gi, (b, gp) in enumerate(zip(cfg.blocks, groups)):
        key = f"expert_counts_g{gi}"
        if b.ffn.kind != "moe" or b.ffn.router != "sigmoid" \
                or key not in metrics:
            continue
        counts = metrics[key]
        err = jnp.mean(counts) - counts
        new_bias = gp["ffn"]["router_bias"] \
            + BIAS_UPDATE_RATE * jnp.sign(err)
        gp = dict(gp, ffn=dict(gp["ffn"], router_bias=new_bias))
        groups[gi] = gp
    return dict(params, groups=groups)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    schedule: Callable[[Array], Array],
                    rules: Optional[ShardingRules] = None,
                    grad_compression: str = "none",
                    grad_accum: int = 1,
                    ) -> Callable:
    """Build the train step (not yet jitted — callers own jit options).

    ``grad_accum`` > 1 splits the global batch into that many
    microbatches and accumulates gradients in an f32 buffer (scan) —
    the live-activation footprint shrinks by the same factor, which is
    what lets the 340B/671B train cells fit a 16 GB/chip pod.
    """

    def _grads(params, batch):
        return jax.value_and_grad(T.loss_fn, has_aux=True)(
            params, cfg, batch)

    def train_step(params, opt_state, batch, step):
        with use_rules(rules):
            if grad_accum == 1:
                (loss, metrics), grads = _grads(params, batch)
            else:
                micro = jax.tree.map(
                    lambda x: x.reshape((grad_accum,
                                         x.shape[0] // grad_accum)
                                        + x.shape[1:]), batch)

                def body(acc, mb):
                    (l, m), g = _grads(params, mb)
                    acc = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32) /
                        grad_accum, acc, g)
                    return acc, (l, m)

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, (losses, metricses) = jax.lax.scan(
                    body, g0, micro)
                loss = losses.mean()
                # Scalars average; expert counts sum over microbatches.
                metrics = {
                    k: (jnp.sum(v, axis=0)
                        if k.startswith("expert_counts")
                        else jnp.mean(v, axis=0))
                    for k, v in metricses.items()}
                metrics["loss"] = loss
            if grad_compression == "int8_ef":
                grads, opt_state = _compress_pod_grads(grads, opt_state)
            lr_scale = schedule(step)
            new_params, new_opt = adamw_update(
                params, grads, opt_state, opt_cfg, lr_scale)
            new_params = _apply_router_bias_update(new_params, cfg, metrics)
        metrics = {k: v for k, v in metrics.items()
                   if not k.startswith("expert_counts")}
        metrics["grad_step"] = step + 1
        return new_params, new_opt, metrics

    return train_step


def _compress_pod_grads(grads: PyTree, opt_state: PyTree,
                        ) -> Tuple[PyTree, PyTree]:
    """Int8 error-feedback all-reduce of grads across the "pod" axis.

    Requires running inside shard_map over "pod" — wired by
    make_compressed_train_step below. Error-feedback buffers live in
    opt_state["ef_err"] (same tree as grads).
    """
    err_tree = opt_state.get("ef_err")
    if err_tree is None:
        raise ValueError("opt_state lacks ef_err buffers; "
                         "init with init_ef_buffers()")
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out_g, out_e = [], []
    n = axis_size("pod")
    for g, e in zip(flat_g, flat_e):
        q, scale, new_err = ef_int8_compress(g, e)
        deq = q.astype(jnp.float32) * scale
        pad = -deq.shape[0] % n
        deq_p = jnp.pad(deq, ((0, pad), (0, 0)))
        red = ring_reduce_scatter_int8(deq_p, "pod")
        full = ring_all_gather(red, "pod")
        flat = full.reshape(-1)[: g.size] / n
        out_g.append(flat.reshape(g.shape).astype(g.dtype))
        out_e.append(new_err)
    return (treedef.unflatten(out_g),
            dict(opt_state, ef_err=treedef.unflatten(out_e)))


def init_ef_buffers(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_serve_step(cfg: ModelConfig,
                    rules: Optional[ShardingRules] = None) -> Callable:
    """One-token decode step: (params, batch, caches) -> (logits, caches)."""

    def serve_step(params, batch, caches):
        with use_rules(rules):
            return T.decode_step(params, cfg, batch, caches)

    return serve_step


def init_train_state(key, cfg: ModelConfig, opt_cfg: AdamWConfig,
                     ) -> Tuple[PyTree, PyTree, PyTree]:
    """(params, opt_state, logical_axes) — host-side init for real runs."""
    params, axes = T.init_params(key, cfg)
    opt_state = adamw_init(params, opt_cfg)
    return params, opt_state, axes


def abstract_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, seed: int = 0,
                         ) -> Tuple[PyTree, PyTree, PyTree]:
    """ShapeDtypeStruct versions for the dry-run (zero allocation)."""
    from repro.models import layers as L
    with L.abstract_init():
        params_shape, axes = T.init_params(jax.random.key(seed), cfg)
    opt_shape = jax.eval_shape(lambda p: adamw_init(p, opt_cfg),
                               params_shape)
    return params_shape, opt_shape, axes
