"""Post-SPMD HLO inspection: collective inventory and wire-byte model.

``compiled.as_text()`` is the per-device module after GSPMD partitioning —
shapes are shard shapes, so summed sizes are *per-device* quantities.
For each collective we estimate per-device wire bytes with the standard
ring models:

  all-gather(out B, group g)        : B * (g-1)/g          received
  reduce-scatter(out B, group g)    : B * (g-1)            sent+recv of shards
  all-reduce(B, group g)            : 2 * B * (g-1)/g      (RS + AG)
  all-to-all(B, group g)            : B * (g-1)/g
  collective-permute(B)             : B

These are the bytes that cross links per chip, the quantity the roofline's
collective term divides by link bandwidth.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s+\((.+?)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * nb


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    wire_bytes: float
    line: str


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _wire_bytes(kind: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return nbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return nbytes * (g - 1)
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind == "all-to-all":
        return nbytes * (g - 1) / g
    if kind == "collective-permute":
        return float(nbytes)
    return float(nbytes)


def parse_collectives(hlo_text: str, total_devices: int,
                      ) -> List[CollectiveOp]:
    """Inventory all collectives (deduplicating -start/-done pairs)."""
    ops: List[CollectiveOp] = []
    seen_started: set = set()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "-done(" in ls:
            continue  # its -start twin carries the same payload
        m = _COLL_RE.search(ls)
        result_bytes = 0
        kind = None
        if m:
            kind = m.group(3)
            result_bytes = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_COLL_RE.search(ls)
            if mt:
                kind = mt.group(2)
                for sm in _SHAPE_RE.finditer(mt.group(1)):
                    result_bytes += _shape_bytes(sm.group(1), sm.group(2))
        if kind is None:
            continue
        g = _group_size(ls, total_devices)
        ops.append(CollectiveOp(
            kind=kind, result_bytes=result_bytes, group_size=g,
            wire_bytes=_wire_bytes(kind, result_bytes, g), line=ls[:200]))
    del seen_started
    return ops


def collective_bytes(hlo_text: str, total_devices: int) -> Dict[str, float]:
    """Summed per-device wire bytes by collective kind (+ 'total')."""
    out: Dict[str, float] = {}
    for op in parse_collectives(hlo_text, total_devices):
        out[op.kind] = out.get(op.kind, 0.0) + op.wire_bytes
        out["total"] = out.get("total", 0.0) + op.wire_bytes
    return out
