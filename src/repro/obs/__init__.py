"""repro.obs — the unified observability layer.

Three pillars, one import:

  * ``repro.obs.metrics`` — thread-safe process-local registry of
    counters / gauges / log-bucket histograms; ``snapshot()`` (stable
    JSON dict) and Prometheus text exposition.
  * ``repro.obs.trace`` — nested host spans (``with span("pad"):``)
    exported as Chrome trace-event JSON (Perfetto-viewable), with an
    optional ``jax.profiler.TraceAnnotation`` bridge.
  * ``repro.obs.jaxmon`` — JAX runtime introspection: jit
    compile/recompile counters via ``jax.monitoring``, per-device
    memory gauges, and the ``assert_no_recompiles`` steady-state
    helper.

Plus the shared driver plumbing: ``setup_logging`` (one consistent
format for every launch driver, ``--log-json`` structured option) and
``EventLog`` (append-only JSONL run-event streams).

``metrics``/``trace``/``logs`` are stdlib-only; only ``jaxmon``
touches jax, and only lazily (safe to import repro.obs anywhere).
"""
from repro.obs import jaxmon, metrics, trace
from repro.obs.jaxmon import (
    RecompileError, assert_no_recompiles, count_compiles, install,
    update_memory_gauges,
)
from repro.obs.logs import EventLog, setup_logging
from repro.obs.metrics import (
    REGISTRY, counter, gauge, histogram, log_buckets, render_prometheus,
    snapshot, timed_ms,
)
from repro.obs.trace import TRACER, export_chrome_trace, span

__all__ = [
    "metrics", "trace", "jaxmon",
    "REGISTRY", "counter", "gauge", "histogram", "log_buckets",
    "snapshot", "render_prometheus", "timed_ms",
    "TRACER", "span", "export_chrome_trace",
    "install", "count_compiles", "assert_no_recompiles",
    "RecompileError", "update_memory_gauges",
    "setup_logging", "EventLog",
]
