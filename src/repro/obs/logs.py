"""Unified driver logging + JSONL event streams.

Every launch driver used to call ``logging.basicConfig`` with its own
(or no) format; ``setup_logging()`` is the one entry point now — a
consistent human-readable line by default, and ``json_mode=True``
(drivers expose it as ``--log-json``) switches the root handler to
one-JSON-object-per-line for log shippers.

``EventLog`` is the machine-readable sibling for *training*: an
append-only JSONL stream of structured run events (epoch stats,
checkpoint writes, watchdog fires, resumes) written next to the
checkpoints, so a run's history survives the terminal and a dashboard
can tail it live.
"""
from __future__ import annotations

import json
import logging
import os
import time
from typing import IO, Optional

HUMAN_FORMAT = "%(asctime)s %(levelname).1s %(name)s :: %(message)s"
HUMAN_DATEFMT = "%H:%M:%S"


class JsonFormatter(logging.Formatter):
    """One JSON object per log record (stable key set)."""

    def format(self, record: logging.LogRecord) -> str:
        out = {
            "ts": round(record.created, 3),
            "level": record.levelname,
            "logger": record.name,
            "msg": record.getMessage(),
        }
        if record.exc_info:
            out["exc"] = self.formatException(record.exc_info)
        return json.dumps(out)


def setup_logging(level: int = logging.INFO,
                  json_mode: bool = False) -> None:
    """Configure root logging for a driver process (idempotent: the
    last call wins — ``force=True`` replaces prior handlers, so a
    driver importing another driver can't end up double-logging)."""
    if json_mode:
        handler = logging.StreamHandler()
        handler.setFormatter(JsonFormatter())
        logging.basicConfig(level=level, handlers=[handler], force=True)
    else:
        logging.basicConfig(level=level, format=HUMAN_FORMAT,
                            datefmt=HUMAN_DATEFMT, force=True)


class EventLog:
    """Append-only JSONL event stream (one flush per event).

    Each line: ``{"ts": <unix seconds>, "event": <kind>, **fields}``.
    The file parent is created on first emit; a no-path EventLog is a
    no-op sink so call sites never branch on "is event logging on".
    """

    def __init__(self, path: Optional[str]):
        self.path = path
        self._fh: Optional[IO[str]] = None

    def emit(self, event: str, **fields) -> None:
        if self.path is None:
            return
        if self._fh is None:
            parent = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(parent, exist_ok=True)
            self._fh = open(self.path, "a")
        rec = {"ts": round(time.time(), 3), "event": event, **fields}
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
