"""Nested host-side span tracing with Chrome trace-event export.

``with span("pad"): ...`` records a complete event ("ph": "X") with
``perf_counter_ns`` timestamps; spans nest through a thread-local
stack, so every event carries its own ``span_id`` and its enclosing
``parent_id`` — the double-buffered serving loop's host-prep of batch
k+1 visibly overlaps batch k's device wait when the export is opened
in Perfetto (https://ui.perfetto.dev) or chrome://tracing.

``span(..., device=True)`` additionally wraps the body in
``jax.profiler.TraceAnnotation``, so when a device profile is being
captured the host span lines up with the XLA activity it caused; off
the profiler the annotation is a cheap no-op, and the bridge degrades
to nothing if the profiler API is unavailable.

The recorder is bounded (``max_events``, default 100k): a long-running
serving process must not grow a trace without limit, so past the cap
new events are counted in ``dropped`` instead of stored.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional


class SpanEvent:
    """One completed span (Chrome "X" event), times in ns."""

    __slots__ = ("name", "start_ns", "dur_ns", "span_id", "parent_id",
                 "tid", "args")

    def __init__(self, name, start_ns, dur_ns, span_id, parent_id, tid,
                 args):
        self.name = name
        self.start_ns = start_ns
        self.dur_ns = dur_ns
        self.span_id = span_id
        self.parent_id = parent_id
        self.tid = tid
        self.args = args


class Tracer:
    """Span recorder; one per process is plenty (module ``TRACER``)."""

    def __init__(self, max_events: int = 100_000):
        self.max_events = max_events
        self._lock = threading.Lock()
        self._events: List[SpanEvent] = []
        self._ids = itertools.count(1)
        self._local = threading.local()
        self.dropped = 0
        self.enabled = True

    # -- recording ----------------------------------------------------

    def _stack(self) -> List[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextmanager
    def span(self, name: str, device: bool = False, **args):
        """Record a nested span around the body.

        ``args`` become the event's Chrome-trace ``args`` (stringified
        lazily at export). ``device=True`` bridges to
        ``jax.profiler.TraceAnnotation(name)`` so host spans align with
        XLA device activity under an active profiler capture.
        """
        if not self.enabled:
            yield
            return
        stack = self._stack()
        span_id = next(self._ids)
        parent_id = stack[-1] if stack else 0
        stack.append(span_id)
        annotation = _device_annotation(name) if device else None
        start = time.perf_counter_ns()
        try:
            if annotation is not None:
                with annotation:
                    yield
            else:
                yield
        finally:
            dur = time.perf_counter_ns() - start
            stack.pop()
            ev = SpanEvent(name, start, dur, span_id, parent_id,
                           threading.get_ident(), args or None)
            with self._lock:
                if len(self._events) < self.max_events:
                    self._events.append(ev)
                else:
                    self.dropped += 1

    def current_span_id(self) -> int:
        """Id of the innermost open span on this thread (0 = none)."""
        stack = self._stack()
        return stack[-1] if stack else 0

    # -- export -------------------------------------------------------

    def events(self) -> List[SpanEvent]:
        with self._lock:
            return list(self._events)

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def to_chrome_trace(self) -> Dict:
        """The Chrome trace-event JSON object (trace-viewer / Perfetto).

        Timestamps and durations are microseconds (floats are legal);
        thread ids are compacted to small ints in first-seen order so
        the viewer's track names stay readable.
        """
        pid = os.getpid()
        tids: Dict[int, int] = {}
        trace_events: List[Dict] = []
        for ev in self.events():
            tid = tids.setdefault(ev.tid, len(tids))
            args = {"span_id": ev.span_id, "parent_id": ev.parent_id}
            if ev.args:
                args.update({k: _jsonable(v) for k, v in ev.args.items()})
            trace_events.append({
                "name": ev.name,
                "ph": "X",
                "ts": ev.start_ns / 1e3,
                "dur": ev.dur_ns / 1e3,
                "pid": pid,
                "tid": tid,
                "args": args,
            })
        meta = {"dropped_events": self.dropped}
        return {"traceEvents": trace_events, "displayTimeUnit": "ms",
                "otherData": meta}

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON; returns the path."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
            f.write("\n")
        return path


def _jsonable(v):
    return v if isinstance(v, (int, float, bool, str, type(None))) else str(v)


def _device_annotation(name: str):
    """``jax.profiler.TraceAnnotation`` when available, else None."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:  # profiler API absent/changed: degrade silently
        return None
    return TraceAnnotation(name)


# Process-default tracer; ``span`` is the one-liner call sites use.
TRACER = Tracer()
span = TRACER.span
export_chrome_trace = TRACER.export
