"""Process-local metrics registry: counters, gauges, histograms.

Dependency-free (stdlib only — deliberately importable before jax) and
thread-safe: the serving driver's double-buffered loop, the training
driver's watchdog handler, and ``jax.monitoring`` listeners all write
into the same default registry from whatever thread they run on.

Instruments are *families*: one name + help string, many labeled
series (``counter.inc(kernel="am_search_packed", tier="pallas")``).
Label values are stringified and the series key is canonical (sorted
label names), so ``snapshot()`` output is stable across call orders —
the schema contract tests/test_obs.py freezes.

Two export surfaces:

  * ``snapshot()`` — a plain-dict, JSON-serializable view (stable key
    set per instrument type); what ``--metrics-out`` writes and what
    ``benchmarks.record`` attaches to bench records.
  * ``render_prometheus()`` — Prometheus text exposition (v0.0.4) for
    scraping once the serving loop runs behind an HTTP handler.

Histograms use log-spaced buckets by default (``log_buckets``):
latency-shaped data spans decades, and linear buckets either crush the
fast tail or truncate the slow one.
"""
from __future__ import annotations

import math
import re
import threading
import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

LabelDict = Dict[str, str]

# Canonical series key: sorted (name, value) pairs rendered in
# Prometheus label syntax. "" is the unlabeled series.
def _series_key(labels: Dict[str, object]) -> str:
    if not labels:
        return ""
    return ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))


def _parse_series_key(key: str) -> LabelDict:
    """Inverse of ``_series_key`` for well-formed keys.

    Values may themselves contain commas and ``=`` (the dispatch
    counter's ``geometry="B=4,C=5,D=32"``), so split on the quoted
    structure rather than on raw commas."""
    if not key:
        return {}
    return {m.group(1): m.group(2)
            for m in re.finditer(r'([^=,]+)="([^"]*)"', key)}


def log_buckets(lo: float = 0.01, hi: float = 10_000.0,
                per_decade: int = 4) -> Tuple[float, ...]:
    """Log-spaced histogram bucket upper bounds covering [lo, hi].

    ``per_decade`` bounds per power of ten; the list always starts at
    ``lo`` and ends at (or one step past) ``hi``. A terminal +Inf
    bucket is implicit in every histogram.
    """
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    if per_decade < 1:
        raise ValueError(f"per_decade must be >= 1, got {per_decade}")
    step = 10.0 ** (1.0 / per_decade)
    out: List[float] = []
    b = lo
    while b < hi * (1 + 1e-12):
        out.append(round(b, 12))
        b *= step
    return tuple(out)


class _Instrument:
    """Shared family plumbing: name, help, per-series storage, lock."""

    kind = "abstract"

    def __init__(self, name: str, help: str, lock: threading.RLock):
        self.name = name
        self.help = help
        self._lock = lock
        self._series: Dict[str, object] = {}

    def series(self) -> Iterator[Tuple[LabelDict, object]]:
        """Iterate (labels, value) over the family's live series."""
        with self._lock:
            items = list(self._series.items())
        for key, val in items:
            yield _parse_series_key(key), val

    def clear(self) -> None:
        with self._lock:
            self._series.clear()


class Counter(_Instrument):
    """Monotonically increasing float per labeled series."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        key = _series_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_series_key(labels), 0.0))

    def total(self) -> float:
        """Sum over every labeled series of the family."""
        with self._lock:
            return float(sum(self._series.values()))


class Gauge(_Instrument):
    """Last-write-wins float per labeled series."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[_series_key(labels)] = float(value)

    def add(self, amount: float, **labels) -> None:
        key = _series_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_series_key(labels), 0.0))


class Histogram(_Instrument):
    """Cumulative-bucket histogram (Prometheus semantics).

    Per series: ``counts[i]`` observations <= ``buckets[i]`` (cumulative
    at export, per-bucket internally), plus an overflow slot, ``sum``
    and ``count``.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, lock: threading.RLock,
                 buckets: Optional[Sequence[float]] = None):
        super().__init__(name, help, lock)
        bs = tuple(float(b) for b in (buckets or log_buckets()))
        if list(bs) != sorted(set(bs)):
            raise ValueError(f"histogram {name}: buckets must be "
                             f"strictly increasing, got {bs}")
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        key = _series_key(labels)
        with self._lock:
            st = self._series.get(key)
            if st is None:
                st = {"counts": [0] * (len(self.buckets) + 1),
                      "sum": 0.0, "count": 0}
                self._series[key] = st
            # First bucket whose upper bound holds the value; the last
            # slot is +Inf.
            idx = len(self.buckets)
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    idx = i
                    break
            st["counts"][idx] += 1
            st["sum"] += float(value)
            st["count"] += 1


class Registry:
    """Named instrument families behind one lock.

    ``counter``/``gauge``/``histogram`` are idempotent getters-or-
    creators; re-registering a name as a different kind (or a histogram
    with different buckets) raises — a name collision is a bug, not a
    merge.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._families: Dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name: str, help: str, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if not isinstance(fam, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}, requested {cls.kind}")
                if (cls is Histogram and kw.get("buckets") is not None
                        and tuple(map(float, kw["buckets"])) != fam.buckets):
                    raise ValueError(
                        f"histogram {name!r} already registered with "
                        f"different buckets")
                return fam
            fam = cls(name, help, self._lock, **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Instrument]:
        with self._lock:
            return self._families.get(name)

    def reset(self) -> None:
        """Clear every family's series, keeping the families themselves
        (live references held by listeners/dispatch sites stay valid)."""
        with self._lock:
            for fam in self._families.values():
                fam.clear()

    def snapshot(self) -> Dict[str, Dict]:
        """Stable, JSON-serializable view of every family.

        Per family: ``{"type", "help", "values": {series_key: ...}}``;
        histograms add ``"buckets"`` (upper bounds) and their values are
        ``{"counts" (cumulative, +Inf last), "sum", "count"}``. Series
        keys are canonical sorted-label strings, so two snapshots of
        the same state are ``==``.
        """
        with self._lock:
            out: Dict[str, Dict] = {}
            for name in sorted(self._families):
                fam = self._families[name]
                entry: Dict[str, object] = {"type": fam.kind,
                                            "help": fam.help}
                if isinstance(fam, Histogram):
                    entry["buckets"] = list(fam.buckets)
                    entry["values"] = {
                        key: {"counts": _cumulative(st["counts"]),
                              "sum": st["sum"], "count": st["count"]}
                        for key, st in sorted(fam._series.items())}
                else:
                    entry["values"] = {key: val for key, val
                                       in sorted(fam._series.items())}
                out[name] = entry
            return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines: List[str] = []
        snap = self.snapshot()
        for name, fam in snap.items():
            if fam["help"]:
                lines.append(f"# HELP {name} {fam['help']}")
            lines.append(f"# TYPE {name} {fam['type']}")
            if fam["type"] != "histogram":
                for key, val in fam["values"].items():
                    lines.append(f"{name}{{{key}}} {_fmt(val)}" if key
                                 else f"{name} {_fmt(val)}")
                continue
            bounds = fam["buckets"]
            for key, st in fam["values"].items():
                base = key + "," if key else ""
                for ub, cum in zip(bounds + [math.inf], st["counts"]):
                    le = "+Inf" if math.isinf(ub) else _fmt(ub)
                    lines.append(
                        f'{name}_bucket{{{base}le="{le}"}} {cum}')
                suffix = f"{{{key}}}" if key else ""
                lines.append(f"{name}_sum{suffix} {_fmt(st['sum'])}")
                lines.append(f"{name}_count{suffix} {st['count']}")
        return "\n".join(lines) + "\n"


@contextmanager
def timed_ms(hist: Histogram, **labels):
    """Observe the body's wall time (milliseconds) into ``hist``.

        with timed_ms(obs.histogram("update_fold_ms"), backend="packed"):
            fold()

    Yields a zero-arg callable returning the elapsed ms so far — after
    the block it is the recorded value (callers that also report the
    duration don't need a second clock).
    """
    t0 = time.perf_counter()
    elapsed = lambda: (time.perf_counter() - t0) * 1e3  # noqa: E731
    try:
        yield elapsed
    finally:
        hist.observe(elapsed(), **labels)


def _cumulative(counts: Sequence[int]) -> List[int]:
    out, run = [], 0
    for c in counts:
        run += c
        out.append(run)
    return out


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


# The process-default registry: everything in-repo records here unless
# handed an explicit registry (tests isolate with their own instances).
REGISTRY = Registry()

counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
render_prometheus = REGISTRY.render_prometheus
reset = REGISTRY.reset
