"""JAX runtime introspection -> the obs metrics registry.

Three windows into the runtime the rest of the repo can't see from
wall clocks alone:

  * **Compile/recompile counting.** ``install()`` registers
    ``jax.monitoring`` listeners; every XLA backend compile increments
    ``jax_compiles_total`` (and feeds ``jax_compile_seconds``), every
    trace/lowering duration event lands in a labeled counter. A cached
    executable fires no event, so the counter's *delta* over a window
    is exactly the number of fresh compilations in that window — the
    basis of ``assert_no_recompiles`` and the serving driver's
    ``recompiles_steady_state`` report field (a steady-state serving
    loop that still compiles is mis-padded and will stutter under
    load).
  * **Device memory gauges.** ``update_memory_gauges()`` snapshots
    ``device.memory_stats()`` per device into
    ``jax_device_memory_bytes{device=..., stat=...}`` (CPU backends
    return None — skipped, not faked).
  * **Steady-state assertion helper.** ``assert_no_recompiles()`` is
    the context manager CI and tests wrap around a supposedly
    shape-stable region; it raises ``RecompileError`` with the compile
    delta when jit retraces inside.

``install()`` is idempotent and registers into the *default* registry;
``jax.monitoring`` has no per-listener removal (only a global clear),
so one process-lifetime registration is the contract.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional

from repro.obs import metrics as _metrics

# The duration event the XLA backend fires once per *actual* compile
# (cache hits are silent) — observed stable across jax 0.4.x.
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_COMPILE_BUCKETS = _metrics.log_buckets(1e-3, 1e3, per_decade=3)

_install_lock = threading.Lock()
_installed = False


class RecompileError(AssertionError):
    """A region that must be shape-stable recompiled anyway."""


def install() -> None:
    """Register the jax.monitoring listeners (once per process)."""
    global _installed
    with _install_lock:
        if _installed:
            return
        from jax import monitoring

        compiles = _metrics.counter(
            "jax_compiles_total",
            "XLA backend compilations (cache hits fire no event)")
        compile_secs = _metrics.histogram(
            "jax_compile_seconds", "XLA backend compile durations",
            buckets=_COMPILE_BUCKETS)
        durations = _metrics.counter(
            "jax_event_duration_seconds_total",
            "summed jax.monitoring duration events by event name")
        events = _metrics.counter(
            "jax_events_total", "jax.monitoring point events by name")

        def on_duration(name: str, dur: float, **kw) -> None:
            durations.inc(dur, event=name)
            if name == COMPILE_EVENT:
                compiles.inc()
                compile_secs.observe(dur)

        def on_event(name: str, **kw) -> None:
            events.inc(event=name)

        monitoring.register_event_duration_secs_listener(on_duration)
        monitoring.register_event_listener(on_event)
        _installed = True


def installed() -> bool:
    return _installed


def compiles() -> int:
    """Backend compiles observed since ``install()`` (0 before it)."""
    fam = _metrics.REGISTRY.get("jax_compiles_total")
    return int(fam.total()) if fam is not None else 0


@contextmanager
def count_compiles():
    """Yields a zero-arg callable returning the compile delta so far.

    Usable mid-region: ``with count_compiles() as n: ...; n()``.
    """
    install()
    before = compiles()
    yield lambda: compiles() - before


@contextmanager
def assert_no_recompiles(what: str = "steady-state region"):
    """Raise ``RecompileError`` if any XLA compile happens inside.

    Wrap the *post-warmup* body — the steady-state serving loop, the
    second epoch of a training run. A failure means some input shape or
    static argument escaped the padding contract.
    """
    install()
    before = compiles()
    yield
    delta = compiles() - before
    if delta:
        raise RecompileError(
            f"{what}: {delta} recompile(s) in a region that must be "
            f"shape-stable (jax_compiles_total {before} -> "
            f"{before + delta})")


def update_memory_gauges() -> Dict[str, Dict[str, float]]:
    """Per-device ``memory_stats()`` -> gauges; returns what it set.

    Backends without allocator stats (CPU) yield no gauges — absent is
    honest, zero would be a lie.
    """
    import jax

    gauge = _metrics.gauge(
        "jax_device_memory_bytes",
        "per-device allocator stats from device.memory_stats()")
    out: Dict[str, Dict[str, float]] = {}
    for dev in jax.devices():
        stats: Optional[Dict] = None
        if hasattr(dev, "memory_stats"):
            try:
                stats = dev.memory_stats()
            except Exception:
                stats = None
        if not stats:
            continue
        label = f"{dev.platform}:{dev.id}"
        kept = {k: float(v) for k, v in stats.items()
                if isinstance(v, (int, float))}
        for stat, val in kept.items():
            gauge.set(val, device=label, stat=stat)
        out[label] = kept
    return out
