"""Device imperfection models: what the analog arrays do to stored bits.

Three physical effects, each modeled as a perturbation of the resident
bipolar AM (or, for drift, of the readout path), all seeded and
jit-compatible so they can run inside the training scan as well as at
deploy time:

* **Stuck-at faults** — write-path defects: a stuck-at-0 cell reads bit
  0 (bipolar -1) and a stuck-at-1 cell reads bit 1 (bipolar +1)
  regardless of the value written. Applied first: they corrupt the
  *stored* bit.
* **Conductance variation** — i.i.d. Gaussian perturbation of each
  cell's effective weight around its (possibly fault-corrupted) stored
  value; the classic programming-variability model.
* **Per-tile readout drift** — one Gaussian offset per physical (A x A)
  array, added to that array's analog partial sum before the ADC (sense
  amplifier / reference drift). This one lives in the readout, so it is
  returned as an offset grid consumed by ``kernels/am_search_imc``.

The perturbed AM is what actually sits in the simulated arrays: the
same instance serves every query (deploy-time determinism comes from
``ImcSimConfig.seed``), while the noise-aware trainer draws a *fresh*
perturbation per minibatch to train against the distribution.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ImcSimConfig

Array = jax.Array


def tile_grid(dim: int, columns: int, sim: ImcSimConfig) -> Tuple[int, int]:
    """(row-tiles, col-tiles) the (C, D) AM maps onto: the offset-grid
    shape. Delegates to ``imc.sim_grid`` so the device models, the
    kernel grid, and the cost model share ONE tile decomposition."""
    from repro.core import imc
    return imc.sim_grid(dim, columns, sim.arr)


def conductance_noise(key: Array, am: Array, sigma: float) -> Array:
    """Gaussian conductance variation around each stored cell value."""
    if sigma == 0.0:
        return am
    return am + sigma * jax.random.normal(key, am.shape, am.dtype)


def stuck_at_faults(key: Array, am: Array, p0: float, p1: float) -> Array:
    """Stuck-at cell faults: disjoint SA0 (-> -1) / SA1 (-> +1) masks.

    Each cell is independently stuck-at-0 with probability p0 and
    stuck-at-1 with probability p1 (disjoint events carved out of one
    uniform draw, so a cell can't be both).
    """
    if p0 == 0.0 and p1 == 0.0:
        return am
    u = jax.random.uniform(key, am.shape)
    am = jnp.where(u < p0, jnp.asarray(-1.0, am.dtype), am)
    am = jnp.where((u >= p0) & (u < p0 + p1),
                   jnp.asarray(1.0, am.dtype), am)
    return am


def tile_drift(key: Array, grid: Tuple[int, int], sigma: float) -> Array:
    """(gd, gc) per-array readout offsets; zeros when sigma == 0."""
    if sigma == 0.0:
        return jnp.zeros(grid, jnp.float32)
    return sigma * jax.random.normal(key, grid, jnp.float32)


def perturb_binary(key: Array, binary_am: Array, sim: ImcSimConfig,
                   ) -> Array:
    """Storage-path perturbations only (faults, then conductance noise).

    This is the AM view the *training-time* sims MVM sees (the
    noise-aware QAIL hook): drift offsets belong to the tiled readout
    and are handled by the imc kernel, not here.
    """
    k_fault, k_noise = jax.random.split(key)
    am = stuck_at_faults(k_fault, binary_am, sim.fault_p0, sim.fault_p1)
    return conductance_noise(k_noise, am, sim.noise_sigma)


def device_instance_key(sim: ImcSimConfig) -> Array:
    """The cell-perturbation key of the deployed device instance.

    ``deploy_imc`` derives its fault/noise key as the first split of
    ``jax.random.key(sim.seed)``; chip-in-the-loop training
    (``noise_mode="fixed"``) must perturb with exactly this key so the
    training-time sims MVM sees the very device it will deploy onto.
    """
    k_cells, _ = jax.random.split(jax.random.key(sim.seed))
    return k_cells


def perturb_am(key: Array, binary_am: Array, sim: ImcSimConfig,
               ) -> Tuple[Array, Optional[Array]]:
    """Full device instance for a (C, D) binary AM.

    Returns ``(am_analog, offsets)``: the fault+noise perturbed AM and
    the (gd, gc) per-tile readout offset grid (None when drift is off).
    Deterministic in (key, sim): the same config always deploys the
    same simulated device.
    """
    k_cells, k_drift = jax.random.split(key)
    am = perturb_binary(k_cells, binary_am, sim)
    offsets = None
    if sim.drift_sigma > 0.0:
        c, d = binary_am.shape
        offsets = tile_drift(k_drift, tile_grid(d, c, sim),
                             sim.drift_sigma)
    return am, offsets
