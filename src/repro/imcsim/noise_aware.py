"""Noise-aware QAIL: train centroids that survive analog readout.

The paper's QAIL (§III-C) is quantization-aware: it evaluates
similarities against the *binary* AM so training sees the deployed
representation. This module extends the same idea one level further
down the stack — similarities during training are evaluated against a
*device-perturbed* view of the binary AM (fresh conductance noise and
stuck-at faults each minibatch, via the ``sim``/``noise_key`` hook of
``qail.qail_epoch_scan``), so the learned centroids acquire margins
that survive the analog readout instead of just the 1-bit
quantization.

Two regimes, selected by ``noise_mode``:

* ``"fixed"`` (default) — chip-in-the-loop: deployment burns ONE
  seeded device instance (``deploy_imc``), and training evaluates every
  sims MVM against exactly that instance
  (``device.device_instance_key``), so QAIL learns to compensate the
  specific faults and conductance offsets it will actually serve on.
  This is the hardware-aware-training recipe of the memristive HDC /
  analog-NN literature, and the regime the recovery acceptance test
  exercises.
* ``"fresh"`` — a new perturbation per minibatch: optimizes *expected*
  accuracy over the device distribution (no privileged instance); use
  it when the deployment device is unknown at training time.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.core.types import ImcSimConfig

Array = jax.Array


def noise_aware_finetune(model, key: Array, feats: Array, labels: Array,
                         sim: ImcSimConfig, *, epochs: int = 10,
                         noise_mode: str = "fixed",
                         **fit_kwargs) -> Tuple[object, Dict]:
    """Continue QAIL from the trained AM with device noise in the loop.

    Runs ``model.fit`` with ``init_method="keep"`` (no re-clustering —
    this is a fine-tune of the already-trained AM) and ``noise_sim=sim``
    so the training-time sims MVM sees the device-perturbed AM
    (the ``sim.seed`` instance when ``noise_mode="fixed"``, a fresh
    draw per batch when ``"fresh"``).

    Returns (model, history) like ``fit``.
    """
    return model.fit(key, feats, labels, init_method="keep",
                     epochs=epochs, noise_sim=sim, noise_mode=noise_mode,
                     **fit_kwargs)


def multibit_finetune(model, key: Array, feats: Array, labels: Array,
                      cell_bits: int, *, sim: Optional[ImcSimConfig] = None,
                      epochs: int = 10, noise_mode: str = "fixed",
                      **fit_kwargs) -> Tuple[object, Dict]:
    """Quantization-aware fine-tune for the multi-bit deployment.

    The same recipe as ``noise_aware_finetune``, one representation up:
    ``model.fit(init_method="keep", cell_bits=cell_bits)`` evaluates
    every training-time sims MVM against the ``cell_bits``-bit quantized
    view of the live float shadow (``qail.qail_epoch_scan``'s per-batch
    quantizer), so Eq.-(4)/(5) targets are selected against exactly the
    representation ``deploy(target="multibit", cell_bits=cell_bits)``
    serves. Pass a conductance-noise ``sim`` to additionally train
    against per-level-step readout noise on the code view.

    Returns (model, history) like ``fit``.
    """
    return model.fit(key, feats, labels, init_method="keep",
                     epochs=epochs, cell_bits=cell_bits, noise_sim=sim,
                     noise_mode=noise_mode, **fit_kwargs)


def recovery_experiment(model, key: Array, feats: Array, labels: Array,
                        test_feats: Array, test_labels: Array,
                        sim: ImcSimConfig, *, epochs: int = 10,
                        train_sim: Optional[ImcSimConfig] = None,
                        noise_mode: str = "fixed",
                        ) -> Dict:
    """Measure how much deployment accuracy noise-aware QAIL recovers.

    Protocol (the Fig.-robustness 'recovery' row):
      1. score the trained model digitally and on the ``sim`` device;
      2. fine-tune it noise-aware (against ``train_sim``, default =
         ``sim`` — with the default chip-in-the-loop mode that means
         the exact device instance of step 1) for ``epochs`` epochs;
      3. redeploy on the SAME device instance (same ``sim.seed``) and
         score again.

    Returns a dict with the three accuracies, the noise-induced loss,
    and ``recovered_frac`` = recovered / lost (the acceptance metric:
    >= 0.5 at the flagship point under the documented setting).
    """
    digital = model.score(test_feats, test_labels)
    from repro.imcsim.evaluate import imc_accuracy
    noisy_before = imc_accuracy(model, test_feats, test_labels, sim)

    tuned, _ = noise_aware_finetune(
        model, key, feats, labels, train_sim or sim, epochs=epochs,
        noise_mode=noise_mode)
    noisy_after = imc_accuracy(tuned, test_feats, test_labels, sim)

    lost = digital - noisy_before
    recovered = noisy_after - noisy_before
    return {
        "digital_accuracy": digital,
        "noisy_accuracy_before": noisy_before,
        "noisy_accuracy_after": noisy_after,
        "lost": lost,
        "recovered": recovered,
        "recovered_frac": (recovered / lost) if lost > 1e-9 else 1.0,
        "epochs": epochs,
    }
