"""Robustness evaluation: accuracy vs. device fidelity, swept.

Every point deploys the trained model onto one simulated device
instance (``deploy_imc``) and scores it through the shared padded
batched evaluator (``core/evaluate.batched_accuracy`` — the same
machinery every other accuracy loop in the repo uses, so ragged test
sets don't recompile here either). Sweeps vary ONE fidelity axis of a
base ``ImcSimConfig`` and report plain dict rows, JSON-able for the
``launch/robustness_report.py`` CLI and ``benchmarks/fig_robustness``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax

from repro.core.types import ImcSimConfig

Array = jax.Array

# Default sweep axes: chosen to span "indistinguishable from digital"
# to "readout dominated by device error" at the flagship 128x128 point.
ADC_BITS = (16, 8, 6, 5, 4, 3, 2)
NOISE_SIGMAS = (0.0, 0.25, 0.5, 1.0, 1.5, 2.0)
FAULT_RATES = (0.0, 0.01, 0.02, 0.05, 0.1, 0.2)


def _queries_of(model, feats: Array, queries: Optional[Array]) -> Array:
    """Encode once per sweep: every sweep point shares the same encoder,
    so the (f x D) encode of the test set is hoisted out of the loop and
    each point pays only for its AM search."""
    return model.encode_query(feats) if queries is None else queries


def _score_queries(model, q: Array, labels: Array, sim: ImcSimConfig,
                   batch: int = 4096) -> float:
    from repro.imcsim.deploy import deploy_imc
    return deploy_imc(model, sim).score_queries(q, labels, batch)


def imc_accuracy(model, feats: Array, labels: Array,
                 sim: Optional[ImcSimConfig] = None,
                 batch: int = 4096,
                 queries: Optional[Array] = None) -> float:
    """Accuracy of ``model`` deployed on one simulated device.

    Pass pre-encoded ``queries`` to reuse an existing encode of
    ``feats`` (the sweeps do).
    """
    return _score_queries(model, _queries_of(model, feats, queries),
                          labels, sim or ImcSimConfig(), batch)


def _sweep(model, feats, labels, base: ImcSimConfig, axis: str,
           values: Sequence, queries: Optional[Array] = None) -> List[Dict]:
    q = _queries_of(model, feats, queries)
    rows = []
    for v in values:
        sim = dataclasses.replace(base, **{axis: v})
        rows.append({axis: v,
                     "accuracy": _score_queries(model, q, labels, sim)})
    return rows


def sweep_adc_bits(model, feats: Array, labels: Array,
                   bits: Sequence[int] = ADC_BITS,
                   base: Optional[ImcSimConfig] = None,
                   queries: Optional[Array] = None) -> List[Dict]:
    """Accuracy vs. ADC resolution (other knobs from ``base``)."""
    return _sweep(model, feats, labels, base or ImcSimConfig(),
                  "adc_bits", list(bits), queries)


def sweep_noise_sigma(model, feats: Array, labels: Array,
                      sigmas: Sequence[float] = NOISE_SIGMAS,
                      base: Optional[ImcSimConfig] = None,
                      queries: Optional[Array] = None) -> List[Dict]:
    """Accuracy vs. conductance-variation sigma."""
    return _sweep(model, feats, labels, base or ImcSimConfig(),
                  "noise_sigma", list(sigmas), queries)


def sweep_fault_rate(model, feats: Array, labels: Array,
                     rates: Sequence[float] = FAULT_RATES,
                     base: Optional[ImcSimConfig] = None,
                     queries: Optional[Array] = None) -> List[Dict]:
    """Accuracy vs. stuck-at fault rate (split evenly SA0/SA1)."""
    base = base or ImcSimConfig()
    q = _queries_of(model, feats, queries)
    rows = []
    for r in rates:
        sim = dataclasses.replace(base, fault_p0=r / 2, fault_p1=r / 2)
        rows.append({"fault_rate": r,
                     "accuracy": _score_queries(model, q, labels, sim)})
    return rows


def robustness_report(model, feats: Array, labels: Array,
                      base: Optional[ImcSimConfig] = None,
                      adc_bits: Sequence[int] = ADC_BITS,
                      noise_sigmas: Sequence[float] = NOISE_SIGMAS,
                      fault_rates: Sequence[float] = FAULT_RATES,
                      ) -> Dict:
    """Full accuracy-vs-fidelity report for one trained model.

    Returns a JSON-able dict: the digital reference accuracy, the
    geometry/cost contract, and one sweep per fidelity axis (each axis
    swept with the other knobs at their ``base`` values).
    """
    base = base or ImcSimConfig()
    q = model.encode_query(feats)  # ONE encode serves every sweep point
    digital = model.score(feats, labels)
    ideal = imc_accuracy(model, feats, labels, base, queries=q)
    return {
        "geometry": f"{model.am_cfg.dim}x{model.am_cfg.columns}",
        "array": f"{base.arr.rows}x{base.arr.cols}",
        "cycles": model.imc_cost(base.arr).am.cycles,
        "digital_accuracy": digital,
        "base_sim_accuracy": ideal,
        "adc_sweep": sweep_adc_bits(model, feats, labels, adc_bits, base,
                                    queries=q),
        "noise_sweep": sweep_noise_sigma(model, feats, labels,
                                         noise_sigmas, base, queries=q),
        "fault_sweep": sweep_fault_rate(model, feats, labels,
                                        fault_rates, base, queries=q),
    }
