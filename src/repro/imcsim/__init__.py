"""Device-fidelity IMC simulation subsystem.

``repro.core.imc`` accounts for the IMC deployment in closed form
(cycles / arrays / energy); this package *executes* it. The pieces:

* ``device`` — seeded, jit-compatible device imperfection models:
  Gaussian conductance variation, stuck-at-0/1 cell faults, per-tile
  readout drift. All are expressed as perturbations of the resident
  bipolar AM (plus a per-tile offset grid for the readout path).
* ``kernels/am_search_imc`` (in the kernel package) — the tiled analog
  search itself: per-array partial sums, ADC quantization, digital
  accumulation, running argmax; grid == ``imc.cycles``.
* ``deploy`` — ``ImcDeployedMemhd``, the simulated-hardware serving
  artifact behind ``MemhdModel.deploy(target="imc", sim=...)``.
* ``evaluate`` — robustness sweeps (accuracy vs ADC bits / noise sigma
  / fault rate), routed through ``core/evaluate.py``'s padded batched
  evaluator.
* ``noise_aware`` — the noise-aware QAIL hook: fine-tune with device
  noise injected into the training-time sims MVM so centroids learn
  margins that survive analog readout.
"""
from repro.core.types import ImcSimConfig  # noqa: F401
from repro.imcsim.deploy import ImcDeployedMemhd, deploy_imc  # noqa: F401
from repro.imcsim.device import (  # noqa: F401
    conductance_noise, perturb_am, perturb_binary, stuck_at_faults,
    tile_drift, tile_grid,
)
from repro.imcsim.evaluate import (  # noqa: F401
    imc_accuracy, robustness_report, sweep_adc_bits, sweep_fault_rate,
    sweep_noise_sigma,
)
from repro.imcsim.noise_aware import (  # noqa: F401
    multibit_finetune, noise_aware_finetune, recovery_experiment,
)
