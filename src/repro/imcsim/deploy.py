"""Simulated-hardware serving artifact: MEMHD on imperfect analog arrays.

``MemhdModel.deploy(target="imc", sim=ImcSimConfig(...))`` freezes the
trained binary AM onto a *simulated device instance*: stuck-at faults
and conductance variation are burned into the resident analog AM once
(seeded by ``sim.seed`` — the same config always deploys the same
device), per-tile drift offsets are attached to the readout, and every
query then goes through the tiled analog search kernel
(``kernels/am_search_imc``): per-array partial sums, ADC quantization,
digital accumulation, argmax.

With an ideal sim (no perturbations, ADC step <= 1) the artifact's
predictions are bit-exact with the digital model — the fidelity-parity
contract proven in tests/test_imcsim.py. With a realistic sim it is the
thing the robustness sweeps (``imcsim.evaluate``) and the noise-aware
trainer (``imcsim.noise_aware``) measure against.

``ImcDeployedMemhd`` implements the shared ``DeployedArtifact``
protocol (``repro.deploy.base``) and registers as the ``"imc"``
deployment backend — the staged predict, padded-evaluator ``score``,
and pytree registration all come from the base class.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional, Tuple

import jax

from repro.core import imc as imc_lib
from repro.core.types import EncoderConfig, ImcSimConfig, MemhdConfig
from repro.deploy.base import DeployedArtifact, pytree_artifact
from repro.deploy.registry import register_backend
from repro.imcsim import device as device_lib

Array = jax.Array


@pytree_artifact
@dataclasses.dataclass
class ImcDeployedMemhd(DeployedArtifact):
    """Frozen MEMHD model resident on a simulated analog device.

    Immutable pytree (like ``DeployedMemhd``): the analog AM, the
    per-tile readout offsets and the encoder parameters are the leaves;
    the configs ride in aux. ``predict``/``score`` route through the
    tiled analog kernel; ``cycles`` exposes the kernel-grid ==
    ``imc.cycles`` contract for this geometry.
    """

    enc_params: Dict[str, Array]
    am_analog: Array               # (C, D) fault+noise perturbed AM
    tile_offsets: Optional[Array]  # (gd, gc) readout drift, or None
    centroid_class: Array          # (C,) int32
    enc_cfg: EncoderConfig
    am_cfg: MemhdConfig
    sim: ImcSimConfig

    _leaf_fields: ClassVar[Tuple[str, ...]] = (
        "enc_params", "am_analog", "tile_offsets", "centroid_class")
    _static_fields: ClassVar[Tuple[str, ...]] = (
        "enc_cfg", "am_cfg", "sim")

    # -- inference -------------------------------------------------------------
    def predict_query(self, q: Array) -> Array:
        """(B, D) bipolar queries -> (B,) predicted class, via the
        simulated analog readout."""
        from repro.kernels import ops
        return ops.predict_imc(q, self.am_analog, self.centroid_class,
                               sim=self.sim, offsets=self.tile_offsets)

    # -- live updates ----------------------------------------------------------
    def _deploy_opts(self) -> dict:
        # refresh() re-burns the updated binary AM onto the SAME
        # simulated device instance (sim carries the seed).
        return {"sim": self.sim}

    # -- reporting / accounting ------------------------------------------------
    @property
    def backend(self) -> str:
        return "imc"

    @property
    def serving_mode(self) -> str:
        return "analog"

    @property
    def resident_bytes(self) -> int:
        n = self.am_analog.size * self.am_analog.dtype.itemsize
        if self.tile_offsets is not None:
            n += self.tile_offsets.size * self.tile_offsets.dtype.itemsize
        return int(n)

    @property
    def cycles(self) -> int:
        """Array passes per query — the kernel grid, which equals
        ``imc.map_memhd(D, C, arr).cycles`` by construction."""
        from repro.kernels.am_search_imc import imc_cycles_for
        return imc_cycles_for((self.am_cfg.dim, self.am_cfg.columns),
                              self.sim.arr.rows, self.sim.arr.cols)

    def _cost_arr(self):
        return self.sim.arr


@register_backend("imc")
def deploy_imc(model, sim: Optional[ImcSimConfig] = None,
               ) -> ImcDeployedMemhd:
    """Burn ``model``'s binary AM onto a simulated device instance."""
    sim = sim or ImcSimConfig()
    imc_lib.assert_consistent_sim(model.am_cfg.dim, model.am_cfg.columns,
                                  sim.arr)
    key = jax.random.key(sim.seed)
    am_analog, offsets = device_lib.perturb_am(
        key, model.am_state["binary"], sim)
    return ImcDeployedMemhd(
        enc_params=model.enc_params,
        am_analog=am_analog,
        tile_offsets=offsets,
        centroid_class=model.am_state["centroid_class"],
        enc_cfg=model.enc_cfg, am_cfg=model.am_cfg, sim=sim,
    )
