"""Simulated-hardware serving artifact: MEMHD on imperfect analog arrays.

``MemhdModel.deploy(target="imc", sim=ImcSimConfig(...))`` freezes the
trained binary AM onto a *simulated device instance*: stuck-at faults
and conductance variation are burned into the resident analog AM once
(seeded by ``sim.seed`` — the same config always deploys the same
device), per-tile drift offsets are attached to the readout, and every
query then goes through the tiled analog search kernel
(``kernels/am_search_imc``): per-array partial sums, ADC quantization,
digital accumulation, argmax.

With an ideal sim (no perturbations, ADC step <= 1) the artifact's
predictions are bit-exact with the digital model — the fidelity-parity
contract proven in tests/test_imcsim.py. With a realistic sim it is the
thing the robustness sweeps (``imcsim.evaluate``) and the noise-aware
trainer (``imcsim.noise_aware``) measure against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from repro.core import encoding, evaluate as eval_lib
from repro.core import imc as imc_lib
from repro.core.types import EncoderConfig, ImcSimConfig, MemhdConfig
from repro.imcsim import device as device_lib

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ImcDeployedMemhd:
    """Frozen MEMHD model resident on a simulated analog device.

    Immutable pytree (like ``DeployedMemhd``): the analog AM, the
    per-tile readout offsets and the encoder parameters are the leaves;
    the configs ride in aux. ``predict``/``score`` route through the
    tiled analog kernel; ``cycles`` exposes the kernel-grid ==
    ``imc.cycles`` contract for this geometry.
    """

    enc_params: Dict[str, Array]
    am_analog: Array               # (C, D) fault+noise perturbed AM
    tile_offsets: Optional[Array]  # (gd, gc) readout drift, or None
    centroid_class: Array          # (C,) int32
    enc_cfg: EncoderConfig
    am_cfg: MemhdConfig
    sim: ImcSimConfig

    def tree_flatten(self):
        children = (self.enc_params, self.am_analog, self.tile_offsets,
                    self.centroid_class)
        aux = (self.enc_cfg, self.am_cfg, self.sim)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        enc_params, am_analog, tile_offsets, centroid_class = children
        enc_cfg, am_cfg, sim = aux
        return cls(enc_params, am_analog, tile_offsets, centroid_class,
                   enc_cfg, am_cfg, sim)

    # -- inference -------------------------------------------------------------
    def predict_query(self, q: Array) -> Array:
        """(B, D) bipolar queries -> (B,) predicted class, via the
        simulated analog readout."""
        from repro.kernels import ops
        idx, _ = ops.am_search_imc(q, self.am_analog, sim=self.sim,
                                   offsets=self.tile_offsets)
        return self.centroid_class[idx]

    def predict(self, feats: Array) -> Array:
        q = encoding.encode_query(self.enc_params, self.enc_cfg, feats)
        return self.predict_query(q)

    def score(self, feats: Array, labels: Array, batch: int = 4096,
              ) -> float:
        return eval_lib.batched_accuracy(self.predict, feats, labels, batch)

    # -- deployment accounting -------------------------------------------------
    @property
    def cycles(self) -> int:
        """Array passes per query — the kernel grid, which equals
        ``imc.map_memhd(D, C, arr).cycles`` by construction."""
        from repro.kernels.am_search_imc import imc_cycles_for
        return imc_cycles_for((self.am_cfg.dim, self.am_cfg.columns),
                              self.sim.arr.rows, self.sim.arr.cols)

    def imc_cost(self, arr=None):
        return imc_lib.memhd_pipeline(
            self.enc_cfg.features, self.am_cfg.dim, self.am_cfg.columns,
            arr or self.sim.arr)


def deploy_imc(model, sim: Optional[ImcSimConfig] = None,
               ) -> ImcDeployedMemhd:
    """Burn ``model``'s binary AM onto a simulated device instance."""
    sim = sim or ImcSimConfig()
    imc_lib.assert_consistent_sim(model.am_cfg.dim, model.am_cfg.columns,
                                  sim.arr)
    key = jax.random.key(sim.seed)
    am_analog, offsets = device_lib.perturb_am(
        key, model.am_state["binary"], sim)
    return ImcDeployedMemhd(
        enc_params=model.enc_params,
        am_analog=am_analog,
        tile_offsets=offsets,
        centroid_class=model.am_state["centroid_class"],
        enc_cfg=model.enc_cfg, am_cfg=model.am_cfg, sim=sim,
    )
