"""AdamW with optional quantized second moment — no external deps.

Why hand-rolled: the container ships no optax, and at the 340B/671B dry-run
scale the optimizer-state dtype is a first-order memory knob —
``state_dtype="bf16"`` / ``second_moment="int8"`` are what let DeepSeek-V3
fit a 256-chip v5e pod (see EXPERIMENTS.md §Dry-run), so the optimizer has
to expose them natively rather than through a wrapper.

State layout per parameter p:
  m: first moment, ``state_dtype``
  v: second moment, ``state_dtype`` or int8 block-quantized (128-blocks,
     per-block fp32 scale — an error-feedback-free quantization; v is a
     positive, slowly-moving average so block max-scaling loses <1% of
     resolution, validated in tests/test_optim.py)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

_STATE_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16}
_Q_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4              # peak lr; schedules multiply on top
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    state_dtype: str = "fp32"     # "fp32" | "bf16"
    second_moment: str = "dense"  # "dense" | "int8"

    def __post_init__(self):
        if self.state_dtype not in _STATE_DTYPES:
            raise ValueError(f"bad state_dtype {self.state_dtype!r}")
        if self.second_moment not in ("dense", "int8"):
            raise ValueError(f"bad second_moment {self.second_moment!r}")

    def state_bytes_per_param(self) -> float:
        """Optimizer bytes/param — used by the dry-run memory audit."""
        m = 4 if self.state_dtype == "fp32" else 2
        v = m if self.second_moment == "dense" else 1.04  # scale overhead
        return m + v


# -- int8 block quantization of v -------------------------------------------------

def _q_v(v: Array) -> Tuple[Array, Array]:
    flat = v.reshape(-1)
    pad = -flat.shape[0] % _Q_BLOCK
    fp = jnp.pad(flat, (0, pad))
    blocks = fp.reshape(-1, _Q_BLOCK)
    scale = jnp.max(blocks, axis=-1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(blocks / scale), 0, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq_v(q: Array, scale: Array, shape, size: int) -> Array:
    blocks = q.astype(jnp.float32) * scale
    return blocks.reshape(-1)[:size].reshape(shape)


def adamw_init(params: PyTree, cfg: AdamWConfig) -> Dict[str, PyTree]:
    dt = _STATE_DTYPES[cfg.state_dtype]
    m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dt), params)
    if cfg.second_moment == "int8":
        v = jax.tree.map(lambda p: _q_v(jnp.zeros(p.shape, jnp.float32)),
                         params)
    else:
        v = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dt), params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree: PyTree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


@partial(jax.jit, static_argnames=("cfg",))
def adamw_update(params: PyTree, grads: PyTree, state: Dict[str, PyTree],
                 cfg: AdamWConfig, lr_scale: Array | float = 1.0,
                 ) -> Tuple[PyTree, Dict[str, PyTree]]:
    """One AdamW step (with global-norm clipping and decoupled decay).

    ``lr_scale`` is the schedule multiplier (traced, so one compilation
    serves the whole run).
    """
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-12))
    dt = _STATE_DTYPES[cfg.state_dtype]

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32)
        new_m = cfg.b1 * m32 + (1 - cfg.b1) * g
        if cfg.second_moment == "int8":
            q, scale = v
            v32 = _dq_v(q, scale, p.shape, p.size)
        else:
            v32 = v.astype(jnp.float32)
        new_v = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        mhat = new_m / b1c
        vhat = new_v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        new_m = new_m.astype(dt)
        new_vs = _q_v(new_v) if cfg.second_moment == "int8" else \
            new_v.astype(dt)
        return new_p, new_m, new_vs

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    if cfg.second_moment == "int8":
        flat_v = jax.tree.flatten(state["v"],
                                  is_leaf=lambda x: isinstance(x, tuple))[0]
    else:
        flat_v = treedef.flatten_up_to(state["v"])

    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, {"m": new_m, "v": new_v, "step": step}
