"""Int8 error-feedback gradient compression for cross-pod reduction.

At 512+ chips the pod-to-pod (DCI) links are the thinnest pipe in the
data-parallel all-reduce. The classic remedy — int8 quantization with
*error feedback* (the quantization residual is added back into the next
step's gradient) — preserves convergence (Karimireddy et al., 2019) while
cutting cross-pod bytes 4x vs fp32 / 2x vs bf16.

This module provides the quantize/dequantize pair plus a shard_map ring
reduce-scatter/all-gather that moves int8 payloads over a named mesh axis
with ``jax.lax.ppermute``. ``repro/distributed/collectives.py`` wires it
into the train step when ``TrainConfig.grad_compression == "int8_ef"``.

Quantization: per-block (1024) symmetric max-scaling into int8.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size  # noqa: F401  (re-export for callers)

Array = jax.Array
PyTree = Any

_BLOCK = 1024


def ef_int8_compress(g: Array, err: Array) -> Tuple[Array, Array, Array]:
    """Quantize (g + err) to int8 blocks; return (q, scale, new_err).

    g, err: same shape, float. new_err is the residual to carry.
    """
    x = g.astype(jnp.float32) + err.astype(jnp.float32)
    flat = x.reshape(-1)
    pad = -flat.shape[0] % _BLOCK
    fp = jnp.pad(flat, (0, pad))
    blocks = fp.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[: flat.shape[0]]
    new_err = (flat - deq).reshape(g.shape)
    return q, scale.astype(jnp.float32), new_err


def ef_int8_decompress(q: Array, scale: Array, shape, size: int) -> Array:
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return deq.reshape(shape)


def _requantize(buf: Array) -> Tuple[Array, Array]:
    """Symmetric int8 wire format for a (chunk, _BLOCK) partial sum."""
    s = jnp.max(jnp.abs(buf), axis=-1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(buf / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def ring_reduce_scatter_int8(deq: Array, axis_name: str) -> Array:
    """Ring reduce-scatter over ``axis_name`` with int8 wire format.

    Standard n-1-hop ring: at hop t, member j sends its running partial
    for chunk (j - t) mod n and folds the incoming partial into its local
    copy of chunk (j - t - 1) mod n. Every hop's payload is re-quantized
    to int8 (+ fp32 per-block scales, 0.4 % overhead) — wire bytes are
    1/4 of an fp32 ring. Error feedback for the *initial* quantization
    happens upstream (``ef_int8_compress``); re-quantization noise along
    the ring is bounded by the per-hop block scaling.

    Args:
      deq: (nblocks, _BLOCK) fp32 shard-local gradient blocks; nblocks
        must be divisible by the axis size.
      axis_name: mesh axis to reduce over.

    Returns:
      (nblocks/n, _BLOCK) fp32 — this member's fully-reduced chunk
      ((me + 1) mod n in chunk order).
    """
    n = axis_size(axis_name)  # static: mesh sizes are known
    me = jax.lax.axis_index(axis_name)
    nb = deq.shape[0]
    if nb % n:
        raise ValueError(f"nblocks={nb} not divisible by axis size {n}")
    chunk = nb // n
    chunks = deq.reshape(n, chunk, _BLOCK)
    perm = [(j, (j + 1) % n) for j in range(n)]

    def local(idx):
        return jax.lax.dynamic_slice_in_dim(chunks, idx % n, 1, axis=0)[0]

    buf = local(me)  # hop 0 sends my own copy of chunk `me`
    for t in range(n - 1):  # unrolled: n is a small static mesh dim
        qw, s = _requantize(buf)
        qr = jax.lax.ppermute(qw, axis_name, perm)
        sr = jax.lax.ppermute(s, axis_name, perm)
        incoming = qr.astype(jnp.float32) * sr
        buf = incoming + local(me - t - 1)
    return buf  # fully reduced chunk (me + 1) mod n


def ring_all_gather(x: Array, axis_name: str) -> Array:
    """Ring all-gather of per-member chunks back to the full array.

    Inverse companion of ``ring_reduce_scatter_int8``: member j enters
    holding chunk (j + 1) mod n and leaves holding all n chunks in order,
    concatenated along axis 0. Payload stays fp32 (the reduced gradient
    must be exact); the *reduce* leg is where compression pays.
    """
    n = axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    perm = [(j, (j + 1) % n) for j in range(n)]

    out = jnp.zeros((n,) + x.shape, x.dtype)
    cur = x
    idx = (me + 1) % n
    out = jax.lax.dynamic_update_slice_in_dim(out, cur[None], idx, axis=0)
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        idx = (idx - 1) % n
        out = jax.lax.dynamic_update_slice_in_dim(
            out, cur[None], idx, axis=0)
    return out.reshape((n * x.shape[0],) + x.shape[1:])
