"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "cosine"        # "cosine" | "linear" | "constant"
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_ratio: float = 0.1      # floor as a fraction of peak lr

    def __post_init__(self):
        if self.kind not in ("cosine", "linear", "constant"):
            raise ValueError(f"bad schedule kind {self.kind!r}")
        if self.warmup_steps < 0 or self.total_steps <= 0:
            raise ValueError("bad schedule steps")


def make_schedule(cfg: ScheduleConfig):
    """Returns step -> lr multiplier in [min_ratio, 1]."""

    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, s / jnp.maximum(cfg.warmup_steps, 1))
        if cfg.kind == "constant":
            decay = 1.0
        else:
            frac = jnp.clip(
                (s - cfg.warmup_steps)
                / max(cfg.total_steps - cfg.warmup_steps, 1),
                0.0, 1.0)
            if cfg.kind == "cosine":
                decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
            else:  # linear
                decay = 1.0 - frac
        mult = cfg.min_ratio + (1 - cfg.min_ratio) * decay
        return warm * mult

    return fn
