from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, adamw_init, adamw_update,
)
from repro.optim.schedule import (  # noqa: F401
    ScheduleConfig, make_schedule,
)
from repro.optim.compression import (  # noqa: F401
    ef_int8_compress, ef_int8_decompress,
)
