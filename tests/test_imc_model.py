"""Table II of the paper, asserted verbatim — the strongest faithfulness
check available without the physical SRAM testbed (the table is
closed-form in the mapping geometry) — plus the Table-I memory-bit
accounting across models."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.baselines import BaselineModel
from repro.core.imc import (
    ImcArrayConfig, am_energy_ratio, assert_consistent, map_basic,
    map_memhd, map_partitioned, mxu_grid, table2,
)
from repro.core.types import BaselineConfig, EncoderConfig, MemhdConfig

ARR = ImcArrayConfig()  # 128x128, the paper's array


class TestTable2MnistFmnist:
    """Table II-(a): MNIST/FMNIST, baseline 10240D x 10 classes."""

    def setup_method(self):
        self.t = table2(ARR)["mnist_fmnist"]

    def test_basic(self):
        c = self.t["basic"]
        assert c.em.cycles == 560 and c.em.arrays == 560
        assert c.am.cycles == 80 and c.am.arrays == 80
        assert c.total_cycles == 640 and c.total_arrays == 640
        assert abs(c.am.utilization - 0.0781) < 1e-3

    def test_partition_p5(self):
        c = self.t["partition_p5"]
        assert c.am.cycles == 80          # partitioning never saves cycles
        assert c.am.arrays == 16          # ...but saves arrays
        assert abs(c.am.utilization - 0.3906) < 1e-3

    def test_partition_p10(self):
        c = self.t["partition_p10"]
        assert c.am.cycles == 80
        assert c.am.arrays == 8
        assert abs(c.am.utilization - 0.7813) < 1e-3

    def test_memhd(self):
        c = self.t["memhd"]
        assert c.em.cycles == 7 and c.am.cycles == 1   # one-shot search
        assert c.total_cycles == 8 and c.total_arrays == 8
        assert c.am.utilization == 1.0                 # fully utilized

    def test_improvements(self):
        base, memhd = self.t["basic"], self.t["memhd"]
        assert base.total_cycles // memhd.total_cycles == 80   # 80x
        assert base.total_arrays // memhd.total_arrays == 80
        # vs best partitioning (P=10): 568 arrays -> 71x fewer
        p10 = self.t["partition_p10"]
        assert (p10.total_arrays) // memhd.total_arrays == 71


class TestTable2Isolet:
    """Table II-(b): ISOLET, baseline 10240D x 26 classes."""

    def setup_method(self):
        self.t = table2(ARR)["isolet"]

    def test_basic(self):
        c = self.t["basic"]
        assert c.em.cycles == 400 and c.am.cycles == 80
        assert c.total_cycles == 480 and c.total_arrays == 480
        assert abs(c.am.utilization - 0.2031) < 1e-3

    def test_partitions(self):
        p2, p4 = self.t["partition_p2"], self.t["partition_p4"]
        assert p2.am.cycles == 80 and p2.am.arrays == 40
        assert abs(p2.am.utilization - 0.4063) < 1e-3
        assert p4.am.cycles == 80 and p4.am.arrays == 20
        assert abs(p4.am.utilization - 0.8125) < 1e-3

    def test_memhd(self):
        c = self.t["memhd"]
        assert c.em.cycles == 20 and c.am.cycles == 4
        assert c.total_cycles == 24 and c.total_arrays == 24
        assert c.am.utilization == 1.0

    def test_improvements(self):
        base, memhd = self.t["basic"], self.t["memhd"]
        assert base.total_cycles / memhd.total_cycles == 20.0   # 20x
        assert base.total_arrays / memhd.total_arrays == 20.0
        p4 = self.t["partition_p4"]
        assert (p4.total_arrays) / memhd.total_arrays == 17.5   # 17.5x


class TestEnergyModel:
    """Fig. 7 ratios: energy ~ sequential tile passes."""

    def test_basic_80x(self):
        assert am_energy_ratio(128, 128, 10240, 10) == 80.0

    def test_lehdc_4x(self):
        # LeHDC at 400D, 10 classes vs MEMHD 128x128
        assert am_energy_ratio(128, 128, 400, 10) == 4.0

    def test_partitioning_constant_energy(self):
        e_base = map_basic(10240, 10, ARR).energy_pj(ARR)
        for p in (5, 10):
            e_p = map_partitioned(10240, 10, p, ARR).energy_pj(ARR)
            assert e_p == e_base  # Fig. 7: partitioning never saves energy


class TestKernelConsistency:
    """The Pallas kernel's grid must equal the IMC cycle model."""

    @pytest.mark.parametrize("d,c", [(128, 128), (512, 128), (1024, 1024),
                                     (256, 64), (130, 257)])
    def test_grid_equals_cycles(self, d, c):
        assert_consistent(d, c, ARR)

    def test_grid_shape(self):
        assert mxu_grid(512, 128) == (4, 1)
        assert map_memhd(512, 128, ARR).cycles == 4


def _baseline(kind, dim, classes=10, n_models=64, features=784):
    """BaselineModel shell for accounting tests (arrays never touched)."""
    cfg = BaselineConfig(kind=kind, dim=dim, classes=classes,
                         n_models=n_models)
    enc_kind = "projection" if kind == "basic" else "id_level"
    enc = EncoderConfig(kind=enc_kind, features=features, dim=dim)
    m = classes * (n_models if kind == "searchd" else 1)
    return BaselineModel(cfg=cfg, enc_cfg=enc, enc_params={},
                         am=jnp.zeros((m, dim)),
                         owners=jnp.zeros((m,), jnp.int32))


class TestTable1MemoryAccounting:
    """Table I bit accounting: EM + AM bits per model family, and the
    equal-budget identity (same D*C cell budget => same AM bits,
    whichever model holds it)."""

    def test_memhd_model_bits(self):
        from repro.core import MemhdModel
        enc = EncoderConfig(kind="projection", features=784, dim=128)
        amc = MemhdConfig(dim=128, columns=160, classes=10)
        model = MemhdModel.create(jax.random.key(0), enc, amc)
        assert amc.am_memory_bits == 160 * 128
        assert model.memory_bits == 784 * 128 + 160 * 128
        assert model.memory_kb == model.memory_bits / 8 / 1024

    def test_baseline_bits_formulas(self):
        # BasicHDC: projection EM (f x D) + k class vectors.
        b = _baseline("basic", 2048)
        assert b.memory_bits == 784 * 2048 + 10 * 2048
        # QuantHD / LeHDC: id_level EM ((f+L) x D) + k class vectors.
        q = _baseline("quanthd", 2048)
        assert q.memory_bits == (784 + 256) * 2048 + 10 * 2048
        # SearcHD: id_level EM + k*N binary vectors.
        s = _baseline("searchd", 32, n_models=64)
        assert s.memory_bits == (784 + 256) * 32 + 10 * 64 * 32

    def test_equal_cell_budget_equal_am_bits(self):
        # One 20480-cell AM budget, four holders: MEMHD 128x160,
        # BasicHDC/QuantHD at D=2048 x 10 classes, SearcHD at
        # D=32 x 10 classes x N=64. Identical AM bits, per Table I.
        budget = 128 * 160
        memhd = MemhdConfig(dim=128, columns=160, classes=10)
        assert memhd.am_memory_bits == budget
        assert _baseline("basic", 2048).cfg.am_memory_bits() == budget
        assert _baseline("quanthd", 2048).cfg.am_memory_bits() == budget
        assert _baseline("searchd", 32,
                         n_models=64).cfg.am_memory_bits() == budget

    def test_paper_flagship_vs_10240d_baseline(self):
        # The headline Table-I comparison: MEMHD 128x128 holds 16Kb of
        # AM; the 10240-D binary baseline holds 100Kb for MNIST's 10
        # classes — 6.25x more (the "memory-efficient" in the title).
        memhd = MemhdConfig(dim=128, columns=128, classes=10)
        base = BaselineConfig(kind="basic", dim=10240, classes=10)
        assert memhd.am_memory_bits == 128 * 128
        assert base.am_memory_bits() / memhd.am_memory_bits == 6.25
