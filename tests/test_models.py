"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, output shapes + finiteness; decode==forward consistency for the
cache-bearing families; param-count sanity vs published sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import transformer as T

EXPECTED_PARAMS_B = {
    "hymba-1.5b": (1.0, 2.2),
    "qwen1.5-32b": (29.0, 38.0),
    "nemotron-4-340b": (320.0, 360.0),
    "gemma3-12b": (10.5, 13.5),
    "granite-20b": (18.0, 22.0),
    "musicgen-medium": (1.2, 2.2),
    "deepseek-v2-lite-16b": (14.0, 17.5),
    "deepseek-v3-671b": (650.0, 700.0),
    "internvl2-2b": (1.4, 2.2),
    "mamba2-130m": (0.10, 0.16),
}


def make_batch(cfg, B=2, S=64, seed=0):
    key = jax.random.key(seed)
    if cfg.frontend == "audio_frames":
        b = {"frame_embeds": jax.random.normal(key, (B, S, cfg.d_model)),
             "targets": jax.random.randint(
                 key, (B, S, cfg.n_codebooks), 0, cfg.vocab_size)}
        if cfg.n_cond_tokens:
            b["cond_embeds"] = jax.random.normal(
                key, (B, cfg.n_cond_tokens, cfg.d_model))
        return b
    if cfg.frontend == "vision_patches":
        s_text = S - cfg.n_patches
        return {
            "tokens": jax.random.randint(key, (B, s_text), 0,
                                         cfg.vocab_size),
            "patch_feats": jax.random.normal(key,
                                             (B, cfg.n_patches, T.VIT_DIM)),
            "targets": jax.random.randint(key, (B, s_text), 0,
                                          cfg.vocab_size),
        }
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "targets": toks}


class TestSmokeForward:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_forward_and_loss(self, arch):
        cfg = get_smoke_config(arch)
        params, axes = T.init_params(jax.random.key(0), cfg)
        batch = make_batch(cfg)
        logits, aux = jax.jit(
            lambda p, b: T.forward(p, cfg, b))(params, batch)
        b, s = 2, 64
        if cfg.frontend == "audio_frames":
            assert logits.shape == (b, s, cfg.n_codebooks,
                                    cfg.padded_vocab)
        elif cfg.frontend == "vision_patches":
            assert logits.shape == (b, s, cfg.padded_vocab)
        else:
            assert logits.shape == (b, s, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        loss, metrics = jax.jit(
            lambda p, bt: T.loss_fn(p, cfg, bt))(params, batch)
        assert bool(jnp.isfinite(loss))

    @pytest.mark.parametrize("arch", ARCHS)
    def test_train_step(self, arch):
        from repro.distributed.steps import make_train_step
        from repro.optim import AdamWConfig, ScheduleConfig, make_schedule

        cfg = get_smoke_config(arch)
        params, _ = T.init_params(jax.random.key(0), cfg)
        from repro.optim import adamw_init
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = adamw_init(params, opt_cfg)
        sched = make_schedule(ScheduleConfig(warmup_steps=0, total_steps=10))
        step = jax.jit(make_train_step(cfg, opt_cfg, sched))
        batch = make_batch(cfg, S=32)
        p2, o2, metrics = step(params, opt, batch,
                               jnp.asarray(1, jnp.int32))
        assert bool(jnp.isfinite(metrics["loss"]))
        # Params actually moved.
        delta = sum(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(params), jax.tree.leaves(p2)))
        assert delta > 0


class TestParamCounts:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_full_config_matches_published_size(self, arch):
        lo, hi = EXPECTED_PARAMS_B[arch]
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"

    def test_moe_active_counts(self):
        v3 = get_config("deepseek-v3-671b")
        active = v3.active_param_count() / 1e9
        assert 34.0 <= active <= 41.0  # published: 37B active

    def test_layer_counts(self):
        for arch, want in [("hymba-1.5b", 32), ("qwen1.5-32b", 64),
                           ("nemotron-4-340b", 96), ("gemma3-12b", 48),
                           ("granite-20b", 52), ("musicgen-medium", 48),
                           ("deepseek-v2-lite-16b", 27),
                           ("deepseek-v3-671b", 61), ("internvl2-2b", 24),
                           ("mamba2-130m", 24)]:
            assert get_config(arch).n_layers == want, arch


class TestDecodeConsistency:
    """decode_step with caches must reproduce the full forward pass."""

    @pytest.mark.parametrize("arch", [
        "mamba2-130m",        # SSD state decode
        "gemma3-12b",         # ring-buffer window + global mix
        "deepseek-v2-lite-16b",  # MLA absorbed decode + MoE
        "hymba-1.5b",         # hybrid: attn cache + SSM state
    ])
    def test_matches_forward(self, arch):
        cfg = get_smoke_config(arch)
        B, S = 2, 96
        params, _ = T.init_params(jax.random.key(0), cfg)
        tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                    cfg.vocab_size)
        logits, _ = jax.jit(lambda p, b: T.forward(p, cfg, b))(
            params, {"tokens": tokens, "targets": tokens})
        caches = T.init_cache(cfg, B, S)
        step = jax.jit(lambda p, b, c: T.decode_step(p, cfg, b, c))
        for t in range(S):
            lg, caches = step(params, {"tokens": tokens[:, t:t + 1]},
                              caches)
        diff = float(jnp.max(jnp.abs(lg - logits[:, -1])))
        scale = float(jnp.max(jnp.abs(logits[:, -1]))) + 1e-6
        assert diff < 2e-2 * scale, (arch, diff, scale)


class TestArchitectureFeatures:
    def test_qwen_has_qkv_bias(self):
        cfg = get_config("qwen1.5-32b")
        assert cfg.blocks[0].attn.qkv_bias

    def test_gemma_local_global_pattern(self):
        cfg = get_config("gemma3-12b")
        windows = []
        for b in cfg.blocks:
            windows.extend([b.attn.window] * b.repeat)
        assert len(windows) == 48
        assert windows.count(None) == 8          # 8 global layers
        assert windows.count(1024) == 40         # 40 local layers
        # 5:1 repeating pattern: every 6th layer is global.
        assert all(w is None for w in windows[5::6])

    def test_granite_is_mqa(self):
        assert get_config("granite-20b").blocks[0].attn.n_kv_heads == 1

    def test_deepseek_v3_router_is_sigmoid_aux_free(self):
        cfg = get_config("deepseek-v3-671b")
        moe = cfg.blocks[1].ffn
        assert moe.router == "sigmoid"
        assert moe.n_experts == 256 and moe.top_k == 8
        assert cfg.mtp_depth == 1

    def test_mamba_attention_free(self):
        cfg = get_config("mamba2-130m")
        assert all(b.mixer == "ssm" for b in cfg.blocks)
        assert all(b.attn is None for b in cfg.blocks)

    def test_hymba_is_parallel_hybrid(self):
        cfg = get_config("hymba-1.5b")
        assert all(b.mixer == "hybrid" for b in cfg.blocks)
        assert cfg.blocks[0].ssm.d_state == 16

    def test_musicgen_codebooks_and_cross_attn(self):
        cfg = get_config("musicgen-medium")
        assert cfg.n_codebooks == 4
        assert cfg.blocks[0].cross_attn
        assert cfg.vocab_size == 2048
