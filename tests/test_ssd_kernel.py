"""ssd_chunk Pallas kernel vs the jnp oracle, and oracle vs ssd_forward.

Two layers of validation: the kernel matches ``ref_ssd_chunk`` across
shape sweeps, and chaining ref_ssd_chunk over chunks matches the
production ``ssd_forward`` (so kernel semantics == model semantics).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk import ref_ssd_chunk, ssd_chunk

RNG = np.random.default_rng(11)


def _chunk_inputs(b, q, h, n, p):
    x = jnp.asarray(RNG.normal(size=(b, q, h, p)).astype(np.float32))
    bb = jnp.asarray(RNG.normal(size=(b, q, h, n)).astype(np.float32))
    cc = jnp.asarray(RNG.normal(size=(b, q, h, n)).astype(np.float32))
    dt = jnp.asarray(np.abs(RNG.normal(size=(b, q, h))).astype(np.float32)
                     * 0.1)
    da = -dt * jnp.asarray(
        np.abs(RNG.normal(size=(b, q, h))).astype(np.float32))
    s0 = jnp.asarray(RNG.normal(size=(b, h, n, p)).astype(np.float32))
    return x, bb, cc, dt, da, s0


class TestSsdChunkKernel:
    @pytest.mark.parametrize("b,q,h,n,p", [
        (2, 64, 3, 32, 16),
        (1, 128, 24, 128, 64),   # mamba2-130m geometry
        (1, 256, 4, 16, 64),     # hymba geometry (d_state 16)
        (3, 32, 2, 16, 8),
    ])
    def test_matches_oracle(self, b, q, h, n, p):
        args = _chunk_inputs(b, q, h, n, p)
        y_ref, s_ref = ref_ssd_chunk(*args)
        y_got, s_got = ssd_chunk(*args)
        np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_state_carry_composes(self):
        """Two chained chunk calls == one call over the doubled chunk."""
        b, q, h, n, p = 1, 32, 2, 16, 8
        x, bb, cc, dt, da, s0 = _chunk_inputs(b, 2 * q, h, n, p)
        y_full, s_full = ref_ssd_chunk(x, bb, cc, dt, da, s0)

        y1, s1 = ssd_chunk(x[:, :q], bb[:, :q], cc[:, :q],
                           dt[:, :q], da[:, :q], s0)
        y2, s2 = ssd_chunk(x[:, q:], bb[:, q:], cc[:, q:],
                           dt[:, q:], da[:, q:], s1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                                   rtol=1e-4, atol=1e-4)

    def test_zero_state_zero_da_is_plain_attention(self):
        """With zero decay (da=0) and zero state, the chunk reduces to a
        causal (CB^T)-weighted sum — a direct linear-attention check."""
        b, q, h, n, p = 1, 16, 1, 8, 4
        x, bb, cc, dt, _, _ = _chunk_inputs(b, q, h, n, p)
        da = jnp.zeros((b, q, h))
        s0 = jnp.zeros((b, h, n, p))
        y, _ = ssd_chunk(x, bb, cc, dt, da, s0)
        xdt = np.asarray(x) * np.asarray(dt)[..., None]
        cb = np.einsum("bqhn,bkhn->bqkh", np.asarray(cc), np.asarray(bb))
        mask = np.tril(np.ones((q, q)))[None, :, :, None]
        want = np.einsum("bqkh,bkhp->bqhp", cb * mask, xdt)
        np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4,
                                   atol=1e-4)
