"""Cross-kernel differential harness: every Pallas kernel vs its oracle.

One shared geometry grid — including non-multiple-of-128 D/C/f and
batch-1 edge cases — drives every kernel in ``repro.kernels`` against
its pure-jnp ``ref`` oracle. Each per-kernel suite elsewhere tests its
own corner semantics; this file is the drift gate: a change to any
kernel, oracle, or the shared padding/tiling conventions must keep the
whole matrix exactly in agreement (bipolar operands make every result
integer-valued, so all assertions are bit-exact). CI runs exactly this
file as a dedicated step so oracle drift fails fast.

The packed paths additionally get hypothesis-generated geometries and
bit patterns (pack/unpack roundtrips and search parity over random
shapes), since byte-boundary bugs live in shapes nobody writes by hand.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding
from repro.core.types import EncoderConfig, ImcArrayConfig, ImcSimConfig
from repro.kernels import ops, ref

# Shared geometry grid: (batch, features, dim, columns). Covers the
# paper's flagship points, ragged everything, and batch-1 serving.
GEOMS = [
    (1, 16, 128, 128),    # batch-1, flagship 128x128 AM
    (8, 784, 128, 128),   # MNIST paper point
    (3, 100, 130, 257),   # D and C just over a tile boundary
    (5, 617, 512, 300),   # ISOLET f, ragged C
    (2, 64, 120, 26),     # D and C under one tile
    (1, 9, 9, 3),         # tiny batch-1 edge (sub-byte D)
]


def geom_rng(*key):
    """Per-test RNG seeded by the test's own geometry (plus a salt per
    call site), so inputs don't depend on which other tests ran first —
    any failure reproduces under ``-k`` selection."""
    return np.random.default_rng([1234, *key])


def bipolar(rng, shape):
    return jnp.asarray(rng.choice([-1.0, 1.0], size=shape)
                       .astype(np.float32))


def feats_mat(rng, b, f):
    return jnp.asarray(rng.random((b, f), dtype=np.float32))


@pytest.mark.parametrize("b,f,d,c", GEOMS)
class TestKernelOracleParity:
    """The differential sweep proper: kernel == oracle, bit for bit."""

    def test_binary_mvm(self, b, f, d, c):
        rng = geom_rng(b, f, d, 0)
        x = bipolar(rng, (b, f))  # bipolar x: integer-exact accumulation
        w = bipolar(rng, (f, d))
        np.testing.assert_array_equal(
            np.asarray(ops.encode_mvm(x, w)),
            np.asarray(ref.binary_mvm(x, w)))
        del c

    def test_am_search(self, b, f, d, c):
        rng = geom_rng(b, d, c, 1)
        q, am = bipolar(rng, (b, d)), bipolar(rng, (c, d))
        gi, gs = ops.am_search(q, am)
        wi, ws = ref.am_search(q, am.T)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        del f

    @pytest.mark.parametrize("mode", ["popcount", "unpack"])
    def test_am_search_packed(self, b, f, d, c, mode):
        rng = geom_rng(b, d, c, 2)
        q, am = bipolar(rng, (b, d)), bipolar(rng, (c, d))
        qp = ops.pack_rows(q)
        apt = ops.pack_rows(am).T
        gi, gs = ops.am_search_packed(qp, apt, n_dims=d, mode=mode)
        wi, ws = ref.am_search_packed(qp, apt, d)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        del f

    @pytest.mark.parametrize("adc_bits,rows,cols,with_offsets", [
        (16, 128, 128, False),   # exact-parity regime
        (6, 128, 128, False),    # lossy ADC: still kernel == oracle
        (8, 96, 80, True),       # ragged array geometry + tile drift
    ])
    def test_am_search_imc(self, b, f, d, c, adc_bits, rows, cols,
                           with_offsets):
        rng = geom_rng(b, d, c, adc_bits, rows, cols)
        q, am = bipolar(rng, (b, d)), bipolar(rng, (c, d))
        sim = ImcSimConfig(arr=ImcArrayConfig(rows=rows, cols=cols),
                           adc_bits=adc_bits)
        offsets = None
        if with_offsets:
            offsets = jnp.asarray(rng.normal(
                0, 0.3, (-(-d // rows), -(-c // cols))).astype(np.float32))
        gi, gs = ops.am_search_imc(q, am, sim=sim, offsets=offsets)
        wi, ws = ref.am_search_imc(
            q, am.T, tile_rows=rows, tile_cols=cols, adc_bits=adc_bits,
            adc_clip=sim.clip, offsets=offsets)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        del f

    @pytest.mark.parametrize("cell_bits,with_offsets", [
        (2, False),            # ternary codes, pure code-domain readout
        (4, True),             # int4 + per-tile readout drift
    ])
    def test_am_search_multibit(self, b, f, d, c, cell_bits,
                                with_offsets):
        rng = geom_rng(b, d, c, 4, cell_bits)
        qmax = 2 ** (cell_bits - 1) - 1
        q = bipolar(rng, (b, d))
        codes = rng.integers(-qmax, qmax + 1, size=(c, d))
        planes = ref.pack_planes(jnp.asarray(codes + qmax), cell_bits)
        offsets = None
        if with_offsets:
            offsets = jnp.asarray(rng.normal(
                0, 0.3, (-(-d // 128), -(-c // 128))).astype(np.float32))
        gi, gs = ops.am_search_multibit(q, planes, offsets=offsets)
        wi, ws = ref.am_search_multibit(q, planes, cell_bits=cell_bits,
                                        offsets=offsets)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        # Drift-free wide-ADC readout is the exact integer code MVM.
        if not with_offsets:
            exact = q @ jnp.asarray(codes, jnp.float32).T
            np.testing.assert_array_equal(
                np.asarray(gs), np.asarray(exact.max(axis=1)))
        del f

    def test_qail_update(self, b, f, d, c):
        k = max(2, c // 3)
        rng = geom_rng(b, d, c, 3)
        q = bipolar(rng, (b, d))
        upd = bipolar(rng, (b, d))  # update_with="binary": integer-exact
        am_t = bipolar(rng, (c, d)).T
        owners = jnp.asarray(rng.integers(0, k, size=(c,)), jnp.int32)
        # Every class needs a centroid for Eq. (5) to have a target.
        owners = owners.at[:k].set(jnp.arange(k, dtype=jnp.int32))
        mask = jnp.asarray((rng.random(b) < 0.8).astype(np.float32))
        if b > 1:  # keep at least one padded row in the sweep
            mask = mask.at[-1].set(0.0)
        labels = jnp.where(
            mask > 0,
            jnp.asarray(rng.integers(0, k, size=(b,)), jnp.int32), -1)
        gd, gm = ops.qail_update(q, upd, am_t, owners, labels, mask,
                                 lr=0.5)
        wd, wm = ref.qail_update_delta(q, upd, am_t, owners, labels,
                                       mask, 0.5)
        np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
        np.testing.assert_array_equal(np.asarray(gm), np.asarray(wm))
        del f

    def test_am_shortlist(self, b, f, d, c):
        # The AM rows play the G super-centroids; sweep S from 1 to
        # the full (ragged) column count.
        rng = geom_rng(b, d, c, 6)
        q, supers = bipolar(rng, (b, d)), bipolar(rng, (c, d))
        qp = ops.pack_rows(q)
        spt = ops.pack_rows(supers).T
        for s in sorted({1, min(3, c), c}):
            gi, gs = ops.am_shortlist(qp, spt, n_dims=d, s=s,
                                      use_kernel=True)
            wi, ws = ref.am_shortlist(qp, spt, d, s)
            np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
            np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        del f

    def test_am_search_sparse(self, b, f, d, c):
        # Random cluster layout over the ragged C; kernel path vs the
        # gather + ref-oracle path, including k > candidate count.
        from repro.deploy import hierarchical as hier
        rng = geom_rng(b, d, c, 7)
        g = max(1, c // 3)
        q, am = bipolar(rng, (b, d)), bipolar(rng, (c, d))
        qp = ops.pack_rows(q)
        apt = np.asarray(ops.pack_rows(am).T)
        assign = rng.integers(0, g, size=c).astype(np.int32)
        layout = hier.build_layout(apt, assign, g)
        slab = jnp.asarray(layout.slab)
        col_ids = jnp.asarray(layout.col_ids)
        t_start = jnp.asarray(layout.tile_start)
        t_count = jnp.asarray(layout.tile_count)
        s = min(2, g)
        short = jnp.asarray(
            np.stack([rng.permutation(g)[:s] for _ in range(b)])
            .astype(np.int32))
        for k in (1, min(3, c), c + 2):  # c + 2: exhausted slots
            args = (qp, slab, col_ids, short, t_start, t_count)
            kw = dict(n_dims=d, k=k, max_tiles=layout.max_tiles)
            gi, gs = ops.am_search_sparse(*args, use_kernel=True, **kw)
            wi, ws = ops.am_search_sparse(*args, use_kernel=False, **kw)
            np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
            np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        del f

    def test_encode_fused(self, b, f, d, c):
        rng = geom_rng(b, f, d, 4)
        x, w = feats_mat(rng, b, f), bipolar(rng, (f, d))
        got = ops.encode_pack(x, w)
        want = ref.encode_pack(x, w)
        assert got.dtype == jnp.uint8 and got.shape == (b, -(-d // 8))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        del c

    @pytest.mark.parametrize("mode", ["popcount", "unpack"])
    def test_fused_chain_matches_staged(self, b, f, d, c, mode):
        """predict_from_features == encode_query -> pack -> search,
        bit-exact including tie resolution (idx asserted, not just the
        class)."""
        rng = geom_rng(b, f, d, c, 5)
        x, w = feats_mat(rng, b, f), bipolar(rng, (f, d))
        am = bipolar(rng, (c, d))
        apt = ops.pack_rows(am).T
        owners = jnp.asarray(rng.integers(0, 10, size=(c,)), jnp.int32)

        # Staged chain, stage by stage (the pre-fusion serving path).
        h = jnp.dot(x, w)
        q = encoding.binarize_query(h)
        qp = ops.pack_rows(q)
        si, ss = ops.am_search_packed(qp, apt, n_dims=d, mode=mode)

        fi, fs = ops.search_from_features(x, w, apt, mode=mode)
        np.testing.assert_array_equal(np.asarray(fi), np.asarray(si))
        np.testing.assert_array_equal(np.asarray(fs), np.asarray(ss))
        pred = ops.predict_from_features(x, w, apt, owners, mode=mode)
        np.testing.assert_array_equal(np.asarray(pred),
                                      np.asarray(owners)[np.asarray(si)])


class TestEncodeFusedSemantics:
    """Fused-encoder corners the sweep can't hit."""

    def test_tail_bits_are_zero(self):
        # D=9 -> 2 bytes; the 7 tail bits must pack as 0 so they
        # XOR-cancel against the identically padded AM.
        rng = geom_rng(4, 16, 9, 6)
        x, w = feats_mat(rng, 4, 16), bipolar(rng, (16, 9))
        p = np.asarray(ops.encode_pack(x, w))
        assert np.all(p[:, 1] < 2)  # only bit 0 of byte 1 may be set

    def test_sign_zero_packs_as_one(self):
        # H == 0 rows: binarize_query maps sign(0) -> +1 -> bit 1.
        x = jnp.zeros((2, 8), jnp.float32)
        w = bipolar(geom_rng(2, 8, 16, 7), (8, 16))
        p = np.asarray(ops.encode_pack(x, w))
        assert np.all(p == 0xFF)

    def test_cycle_model_matches_mvm(self):
        from repro.core import imc
        from repro.kernels.binary_mvm import imc_cycles_for as mvm_cycles
        from repro.kernels.encode_fused import imc_cycles_for
        assert imc_cycles_for((8, 784), (784, 1024)) == \
            mvm_cycles((8, 784), (784, 1024))
        assert imc_cycles_for((8, 784), (784, 1024)) == \
            imc.map_basic(784, 1024, imc.ImcArrayConfig()).cycles


class TestEncoderChunkInvariance:
    """encode_id_level: H must not depend on the feature chunking —
    padded feature columns gather a neutral (masked-to-zero) level, so
    any chunk size gives the identical (exact, +-1-integer) H."""

    @pytest.mark.parametrize("f,chunk", [
        (100, 128), (100, 32), (100, 7), (128, 128), (130, 128),
    ])
    def test_chunk_size_invariance(self, f, chunk):
        cfg = EncoderConfig(kind="id_level", features=f, dim=64,
                            levels=8)
        params = encoding.init_id_level(jax.random.key(0), cfg)
        x = jnp.asarray(geom_rng(f, chunk, 8).random(
            (5, f), dtype=np.float32))
        base = encoding.encode_id_level(params, x, chunk=f)  # no pad
        got = encoding.encode_id_level(params, x, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))

    def test_padded_columns_are_neutral_even_for_nonfinite_levels(self):
        # The gather itself is masked: a poisoned lvls[0] must not leak
        # through the padded columns (0 * nan == nan would).
        cfg = EncoderConfig(kind="id_level", features=10, dim=16,
                            levels=4)
        params = encoding.init_id_level(jax.random.key(1), cfg)
        x = jnp.asarray(geom_rng(3, 10, 9).random(
            (3, 10), dtype=np.float32))
        poisoned = dict(params, levels=params["levels"].at[0].set(
            jnp.where(params["levels"][0] > 0, jnp.nan,
                      params["levels"][0])))
        # Keep valid columns away from level 0 so only the padded
        # columns ever gather the poisoned level.
        x_hi = 0.75 + 0.25 * x  # quantizes to levels >= 2
        want = encoding.encode_id_level(params, x_hi, chunk=10)
        got = encoding.encode_id_level(poisoned, x_hi, chunk=128)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestHierarchicalSemantics:
    """Coarse-to-fine corners the differential sweep can't pin: explicit
    tie-breaking on duplicated columns, the planted-cluster recall
    property, and the degenerate S = G bit-exactness contract."""

    def test_shortlist_ties_break_to_lower_id(self):
        rng = geom_rng(40)
        base = bipolar(rng, (4, 128))
        # Duplicate every super-centroid: ids 0..3 == ids 4..7.
        supers = jnp.concatenate([base, base], axis=0)
        q = bipolar(rng, (5, 128))
        qp, spt = ops.pack_rows(q), ops.pack_rows(supers).T
        gi, gs = ops.am_shortlist(qp, spt, n_dims=128, s=8,
                                  use_kernel=True)
        wi, ws = ref.am_shortlist(qp, spt, 128, 8)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        gi, gs = np.asarray(gi), np.asarray(gs)
        for r in range(gi.shape[0]):
            pos = {int(gi[r, a]): a for a in range(8)}
            for i in range(4):
                # Copy pair (i, i + 4) ties: equal sims, lower id first.
                assert gs[r, pos[i]] == gs[r, pos[i + 4]]
                assert pos[i] < pos[i + 4]
            # Global invariant: equal-sim runs are ordered by id.
            for a in range(7):
                assert (gs[r, a] > gs[r, a + 1]
                        or (gs[r, a] == gs[r, a + 1]
                            and gi[r, a] < gi[r, a + 1]))

    def test_sparse_ties_break_on_original_id(self):
        # Two clusters each holding one copy of every (duplicated)
        # centroid; with both clusters shortlisted, the winner per tie
        # pair must be the lower ORIGINAL id even though the layout
        # permutation scattered the copies into different tiles.
        from repro.deploy import hierarchical as hier
        rng = geom_rng(41)
        base = bipolar(rng, (6, 128))
        am = jnp.concatenate([base, base], axis=0)        # ids 0..5 == 6..11
        assign = np.array([0, 1] * 6, np.int32)           # interleaved
        apt = np.asarray(ops.pack_rows(am).T)
        layout = hier.build_layout(apt, assign, 2)
        q = bipolar(rng, (4, 128))
        qp = ops.pack_rows(q)
        short = jnp.broadcast_to(jnp.arange(2, dtype=jnp.int32)[None],
                                 (4, 2))
        idx, sims = ops.am_search_sparse(
            qp, jnp.asarray(layout.slab), jnp.asarray(layout.col_ids),
            short, jnp.asarray(layout.tile_start),
            jnp.asarray(layout.tile_count), n_dims=128, k=12,
            max_tiles=layout.max_tiles, use_kernel=True)
        idx, sims = np.asarray(idx), np.asarray(sims)
        for r in range(4):
            pos = {int(idx[r, a]): a for a in range(12)}
            for i in range(6):
                assert sims[r, pos[i]] == sims[r, pos[i + 6]]
                assert pos[i] < pos[i + 6]
            for a in range(11):
                assert (sims[r, a] > sims[r, a + 1]
                        or (sims[r, a] == sims[r, a + 1]
                            and idx[r, a] < idx[r, a + 1]))

    def _planted(self, rng, c, g, d=128, flip=0.05):
        protos = rng.choice(np.array([-1.0, 1.0], np.float32),
                            size=(g, d))
        assign = rng.integers(0, g, size=c)
        am = protos[assign]
        am = np.where(rng.random(am.shape) < flip, -am, am)
        return am.astype(np.float32), assign

    def test_recall_at_paper_scale(self):
        # Planted clusters at C=1024, G=32: the full pipeline (kmeans
        # clustering + coarse shortlist + sparse fine search) must find
        # the true best centroid for >= 99% of noisy queries at S=8.
        import jax as _jax
        from repro.deploy import hierarchical as hier
        rng = np.random.default_rng(99)
        c, g, d, s = 1024, 32, 128, 8
        am, _ = self._planted(rng, c, g, d)
        src = rng.integers(0, c, size=256)
        q = am[src]
        q = np.where(rng.random(q.shape) < 0.08, -q, q)
        spt, layout = hier.build_search_state(
            _jax.random.PRNGKey(0), am, g, kmeans_iters=6,
            kmeans_sample=1024)
        qp = ops.pack_rows(jnp.asarray(q))
        short, _ = ops.am_shortlist(qp, spt, n_dims=d, s=s)
        idx, sims = ops.am_search_sparse(
            qp, jnp.asarray(layout.slab), jnp.asarray(layout.col_ids),
            short, jnp.asarray(layout.tile_start),
            jnp.asarray(layout.tile_count), n_dims=d, k=1,
            max_tiles=layout.max_tiles)
        exact = (q.astype(np.float32) @ am.T).max(axis=1)
        recall = float(np.mean(np.asarray(sims)[:, 0] == exact))
        assert recall >= 0.99, f"recall@1 {recall} < 0.99 at S={s}"

    def test_s_equals_g_is_bit_exact_with_flat_scan(self):
        import jax as _jax
        from repro.deploy import hierarchical as hier
        rng = np.random.default_rng(7)
        c, g, d = 300, 16, 130  # ragged C and D
        am, _ = self._planted(rng, c, g, d)
        spt, layout = hier.build_search_state(
            _jax.random.PRNGKey(1), am, g, kmeans_iters=4,
            kmeans_sample=300)
        q = rng.choice(np.array([-1.0, 1.0], np.float32), size=(9, d))
        qp = ops.pack_rows(jnp.asarray(q))
        apt = ops.pack_rows(jnp.asarray(am)).T
        short, _ = ops.am_shortlist(qp, spt, n_dims=d, s=g)
        idx, sims = ops.am_search_sparse(
            qp, jnp.asarray(layout.slab), jnp.asarray(layout.col_ids),
            short, jnp.asarray(layout.tile_start),
            jnp.asarray(layout.tile_count), n_dims=d, k=1,
            max_tiles=layout.max_tiles)
        fi, fs = ops.am_search_packed(qp, apt, n_dims=d)
        np.testing.assert_array_equal(np.asarray(idx)[:, 0],
                                      np.asarray(fi))
        np.testing.assert_array_equal(np.asarray(sims)[:, 0],
                                      np.asarray(fs))


# -- hypothesis-generated packed-path inputs --------------------------------
# Guarded (not importorskip) so a missing hypothesis skips ONLY the
# property class — the deterministic differential sweep above must run
# everywhere.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev extra, see requirements-dev
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=20, deadline=None)

    @st.composite
    def packed_geometry(draw):
        """Random (B, D, C, seed); D lands on any byte boundary."""
        b = draw(st.integers(1, 8))
        d = draw(st.integers(1, 96))
        c = draw(st.integers(1, 40))
        seed = draw(st.integers(0, 2**31 - 1))
        return b, d, c, seed

    class TestPackedPathProperties:
        @settings(**SETTINGS)
        @given(packed_geometry())
        def test_pack_roundtrip(self, geom):
            b, d, _, seed = geom
            rng = np.random.default_rng(seed)
            x = jnp.asarray(rng.choice([-1.0, 1.0], size=(b, d))
                            .astype(np.float32))
            p = ops.pack_rows(x)
            np.testing.assert_array_equal(np.asarray(p),
                                          np.asarray(ref.pack_rows(x)))
            u = np.asarray(ops.unpack_bits(p))
            np.testing.assert_array_equal(u[:, :d], np.asarray(x))
            assert np.all(u[:, d:] == -1.0)  # tail bits packed as 0

        @settings(**SETTINGS)
        @given(packed_geometry(), st.sampled_from(["popcount", "unpack"]))
        def test_packed_search_parity(self, geom, mode):
            b, d, c, seed = geom
            rng = np.random.default_rng(seed)
            q = jnp.asarray(rng.choice([-1.0, 1.0], size=(b, d))
                            .astype(np.float32))
            am = jnp.asarray(rng.choice([-1.0, 1.0], size=(c, d))
                             .astype(np.float32))
            qp = ops.pack_rows(q)
            apt = ops.pack_rows(am).T
            gi, gs = ops.am_search_packed(qp, apt, n_dims=d, mode=mode)
            wi, ws = ref.am_search(q, am.T)
            np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
            np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))

        @settings(**SETTINGS)
        @given(packed_geometry())
        def test_encode_pack_parity(self, geom):
            b, d, c, seed = geom
            f = max(1, c)  # reuse the C draw as a ragged feature count
            rng = np.random.default_rng(seed)
            x = jnp.asarray(rng.random((b, f), dtype=np.float32))
            w = jnp.asarray(rng.choice([-1.0, 1.0], size=(f, d))
                            .astype(np.float32))
            np.testing.assert_array_equal(
                np.asarray(ops.encode_pack(x, w)),
                np.asarray(ref.encode_pack(x, w)))

    @st.composite
    def layout_geometry(draw):
        """Random (C, G, seed) for cluster-layout invariants."""
        c = draw(st.integers(1, 200))
        g = draw(st.integers(1, 24))
        seed = draw(st.integers(0, 2**31 - 1))
        return c, g, seed

    class TestClusterLayoutProperties:
        """build_layout invariants: the physical permutation is a
        bijection and every centroid lands in exactly one tile range —
        the contract the sparse gather's correctness rests on."""

        @settings(**SETTINGS)
        @given(layout_geometry())
        def test_layout_invariants(self, geom):
            from repro.deploy import hierarchical as hier
            c, g, seed = geom
            rng = np.random.default_rng(seed)
            am = rng.choice([-1.0, 1.0], size=(c, 64)).astype(np.float32)
            apt = np.asarray(ops.pack_rows(jnp.asarray(am)).T)
            assign = rng.integers(0, g, size=c).astype(np.int32)
            layout = hier.build_layout(apt, assign, g)
            col_ids = np.asarray(layout.col_ids)
            starts = np.asarray(layout.tile_start)
            counts = np.asarray(layout.tile_count)

            # Permutation bijection: the valid slab columns hold every
            # original centroid id exactly once, and nothing else.
            valid = col_ids[col_ids >= 0]
            assert sorted(valid.tolist()) == list(range(c))
            # Each centroid sits in exactly one cluster's tile range,
            # and it is its OWN cluster's range.
            sizes = np.bincount(assign, minlength=g)
            for grp in range(g):
                lo, hi = starts[grp] * 128, (starts[grp]
                                             + counts[grp]) * 128
                ids_here = col_ids[lo:hi]
                ids_here = ids_here[ids_here >= 0]
                assert len(ids_here) == sizes[grp]
                assert np.all(assign[ids_here] == grp)
                # ceil-division tile accounting, never over-allocated.
                assert counts[grp] == -(-int(sizes[grp]) // 128) or (
                    sizes[grp] == 0 and counts[grp] in (0, 1))
            # Trailing null tile: all-invalid, shared gather target.
            assert layout.slab.shape[1] == layout.n_tiles * 128
            assert np.all(col_ids[layout.null_tile * 128:] == -1)
            # Slab columns carry the permuted packed payloads.
            for col in range(min(c, 16)):  # spot-check the payload map
                dest = np.nonzero(col_ids == col)[0][0]
                np.testing.assert_array_equal(layout.slab[:, dest],
                                              apt[:, col])

        @settings(**SETTINGS)
        @given(layout_geometry())
        def test_expand_tiles_cover_exactly_the_shortlist(self, geom):
            from repro.deploy import hierarchical as hier
            from repro.kernels.am_search_sparse import (
                expand_shortlist_tiles,
            )
            c, g, seed = geom
            rng = np.random.default_rng(seed)
            am = rng.choice([-1.0, 1.0], size=(c, 64)).astype(np.float32)
            apt = np.asarray(ops.pack_rows(jnp.asarray(am)).T)
            assign = rng.integers(0, g, size=c).astype(np.int32)
            layout = hier.build_layout(apt, assign, g)
            s = min(3, g)
            short = np.stack([rng.permutation(g)[:s] for _ in range(4)])
            tiles = np.asarray(expand_shortlist_tiles(
                jnp.asarray(short.astype(np.int32)),
                jnp.asarray(layout.tile_start),
                jnp.asarray(layout.tile_count),
                max_tiles=layout.max_tiles, null_tile=layout.null_tile))
            col_ids = np.asarray(layout.col_ids)
            starts = np.asarray(layout.tile_start)
            counts = np.asarray(layout.tile_count)
            for r in range(4):
                want = {t for grp in short[r]
                        for t in range(starts[grp],
                                       starts[grp] + counts[grp])}
                got = set(tiles[r].tolist())
                assert got - {layout.null_tile} == want
                # Every centroid of every shortlisted cluster is
                # reachable through the expanded tiles.
                reach = {i for t in got
                         for i in col_ids[t * 128:(t + 1) * 128]
                         if i >= 0}
                assert reach == {int(i) for i in range(c)
                                 if assign[i] in set(short[r].tolist())}
