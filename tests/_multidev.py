"""Helper to run a python snippet under a fake multi-device CPU backend.

jax locks the device count at first init, so multi-device tests must run
in a fresh subprocess with XLA_FLAGS set before import.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_multidev(code: str, n_devices: int = 8, timeout: int = 560,
                 ) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-c", code], env=env, timeout=timeout,
        capture_output=True, text=True)


def check_multidev(code: str, n_devices: int = 8, timeout: int = 560):
    r = run_multidev(code, n_devices, timeout)
    assert r.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{r.stdout[-4000:]}\n"
        f"STDERR:\n{r.stderr[-4000:]}")
    return r.stdout
