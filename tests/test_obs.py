"""The obs layer: metrics registry semantics (bucket boundaries,
snapshot schema, Prometheus exposition), span nesting + Chrome-trace
export, JAX runtime introspection (recompile counting under a
deliberately shape-ragged jit), the dispatch-tier counters for all
nine kernels, unified logging, and the JSONL event stream."""
import json
import logging
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import jaxmon
from repro.obs.logs import EventLog, setup_logging
from repro.obs.metrics import Registry, log_buckets
from repro.obs.trace import Tracer


# ---------------------------------------------------------------- metrics

class TestLogBuckets:
    def test_log_spacing_and_coverage(self):
        bs = log_buckets(0.1, 100.0, per_decade=1)
        assert bs[0] == pytest.approx(0.1)
        assert bs[-1] >= 100.0
        ratios = [b / a for a, b in zip(bs, bs[1:])]
        assert all(r == pytest.approx(10.0, rel=1e-6) for r in ratios)

    def test_per_decade_density(self):
        bs = log_buckets(1.0, 10.0, per_decade=4)
        # 4 steps per decade: 1, 10^.25, 10^.5, 10^.75, 10
        assert len(bs) == 5
        assert bs[2] == pytest.approx(10 ** 0.5, rel=1e-9)

    def test_bad_args_rejected(self):
        with pytest.raises(ValueError):
            log_buckets(0.0, 10.0)
        with pytest.raises(ValueError):
            log_buckets(10.0, 1.0)
        with pytest.raises(ValueError):
            log_buckets(1.0, 10.0, per_decade=0)


class TestHistogram:
    def test_bucket_boundaries_inclusive_upper(self):
        reg = Registry()
        h = reg.histogram("h", buckets=[1.0, 10.0, 100.0])
        for v in (0.5, 1.0, 1.01, 10.0, 99.0, 100.0, 1e6):
            h.observe(v)
        snap = reg.snapshot()["h"]
        assert snap["buckets"] == [1.0, 10.0, 100.0]
        # Cumulative: <=1: {0.5, 1.0}; <=10: +{1.01, 10.0};
        # <=100: +{99.0, 100.0}; +Inf: +{1e6}.
        assert snap["values"][""]["counts"] == [2, 4, 6, 7]
        assert snap["values"][""]["count"] == 7
        assert snap["values"][""]["sum"] == pytest.approx(
            0.5 + 1.0 + 1.01 + 10.0 + 99.0 + 100.0 + 1e6)

    def test_labeled_series_are_independent(self):
        reg = Registry()
        h = reg.histogram("h", buckets=[1.0])
        h.observe(0.5, stage="a")
        h.observe(2.0, stage="b")
        snap = reg.snapshot()["h"]["values"]
        assert snap['stage="a"']["counts"] == [1, 1]
        assert snap['stage="b"']["counts"] == [0, 1]

    def test_unsorted_buckets_rejected(self):
        with pytest.raises(ValueError, match="increasing"):
            Registry().histogram("h", buckets=[10.0, 1.0])


class TestRegistry:
    def test_snapshot_schema_stable(self):
        """The snapshot dict is the --metrics-out contract: exact key
        set per instrument type, canonical sorted-label series keys."""
        reg = Registry()
        reg.counter("c", "help c").inc(2, b="2", a="1")
        reg.gauge("g").set(5.0)
        reg.histogram("h", buckets=[1.0]).observe(0.5)
        snap = reg.snapshot()
        assert list(snap) == ["c", "g", "h"]  # sorted names
        assert set(snap["c"]) == {"type", "help", "values"}
        assert set(snap["g"]) == {"type", "help", "values"}
        assert set(snap["h"]) == {"type", "help", "buckets", "values"}
        assert snap["c"]["type"] == "counter"
        # Label order in the call does not leak into the series key.
        assert list(snap["c"]["values"]) == ['a="1",b="2"']
        assert snap["c"]["values"]['a="1",b="2"'] == 2.0
        assert set(snap["h"]["values"][""]) == {"counts", "sum", "count"}
        # Identical state -> identical snapshot, and JSON-serializable.
        assert snap == reg.snapshot()
        json.dumps(snap)

    def test_idempotent_registration_and_kind_conflict(self):
        reg = Registry()
        c1 = reg.counter("x")
        assert reg.counter("x") is c1
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x")
        with pytest.raises(ValueError, match="buckets"):
            reg.histogram("h", buckets=[1.0])
            reg.histogram("h", buckets=[2.0])

    def test_counter_rejects_negative(self):
        with pytest.raises(ValueError):
            Registry().counter("c").inc(-1)

    def test_reset_keeps_families_live(self):
        """Listeners hold instrument references across reset()."""
        reg = Registry()
        c = reg.counter("c")
        c.inc(5)
        reg.reset()
        assert c.value() == 0.0
        c.inc()  # the old handle still feeds the registry
        assert reg.snapshot()["c"]["values"][""] == 1.0

    def test_thread_safety_of_counter(self):
        reg = Registry()
        c = reg.counter("c")

        def work():
            for _ in range(2000):
                c.inc(thread="x")

        threads = [threading.Thread(target=work) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value(thread="x") == 8000.0

    def test_prometheus_exposition(self):
        reg = Registry()
        reg.counter("reqs", "requests").inc(3, code="200")
        reg.histogram("lat", buckets=[1.0, 10.0]).observe(0.5)
        text = reg.render_prometheus()
        assert "# HELP reqs requests" in text
        assert "# TYPE reqs counter" in text
        assert 'reqs{code="200"} 3' in text
        assert 'lat_bucket{le="1"} 1' in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert "lat_sum 0.5" in text
        assert "lat_count 1" in text


# ------------------------------------------------------------------ trace

class TestTrace:
    def test_span_nesting_parent_ids(self):
        tr = Tracer()
        with tr.span("outer"):
            with tr.span("mid"):
                with tr.span("inner"):
                    pass
            with tr.span("mid2"):
                pass
        evs = {e.name: e for e in tr.events()}
        assert evs["inner"].parent_id == evs["mid"].span_id
        assert evs["mid"].parent_id == evs["outer"].span_id
        assert evs["mid2"].parent_id == evs["outer"].span_id
        assert evs["outer"].parent_id == 0
        # Nesting also shows in the timestamps: children are contained.
        assert evs["inner"].start_ns >= evs["mid"].start_ns
        assert (evs["inner"].start_ns + evs["inner"].dur_ns
                <= evs["mid"].start_ns + evs["mid"].dur_ns)

    def test_chrome_trace_json_valid(self, tmp_path):
        tr = Tracer()
        with tr.span("a", answer=42, note="x"):
            with tr.span("b"):
                pass
        path = tr.export(str(tmp_path / "t.json"))
        with open(path) as f:
            trace = json.load(f)
        assert set(trace) >= {"traceEvents", "displayTimeUnit"}
        evs = trace["traceEvents"]
        assert len(evs) == 2
        for e in evs:
            assert set(e) == {"name", "ph", "ts", "dur", "pid", "tid",
                              "args"}
            assert e["ph"] == "X"
            assert e["dur"] >= 0
        a = next(e for e in evs if e["name"] == "a")
        assert a["args"]["answer"] == 42 and a["args"]["note"] == "x"

    def test_span_survives_exception(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        assert [e.name for e in tr.events()] == ["boom"]
        assert tr.current_span_id() == 0  # stack unwound

    def test_bounded_recorder_drops_not_grows(self):
        tr = Tracer(max_events=2)
        for i in range(5):
            with tr.span(f"s{i}"):
                pass
        assert len(tr.events()) == 2
        assert tr.dropped == 3
        assert tr.to_chrome_trace()["otherData"]["dropped_events"] == 3

    def test_disabled_tracer_records_nothing(self):
        tr = Tracer()
        tr.enabled = False
        with tr.span("x"):
            pass
        assert tr.events() == []

    def test_device_bridge_is_noop_safe(self):
        tr = Tracer()
        with tr.span("annotated", device=True):
            jnp.ones((4,)).block_until_ready()
        assert [e.name for e in tr.events()] == ["annotated"]


# ----------------------------------------------------------------- jaxmon

class TestJaxmon:
    def test_recompile_counter_under_shape_ragged_jit(self):
        """A deliberately ragged call sequence: every new shape is a
        fresh trace + compile; repeats are cache hits and count 0."""
        obs.install()

        @jax.jit
        def f(x):
            return (x * 2.0).sum()

        shapes = [(4,), (8,), (12,)]
        for shape in shapes:  # warm one compile per shape
            f(jnp.ones(shape)).block_until_ready()
        n0 = jaxmon.compiles()
        for shape in shapes:  # all cached: no compile events
            f(jnp.ones(shape)).block_until_ready()
        assert jaxmon.compiles() == n0
        with obs.count_compiles() as delta:
            f(jnp.ones((16,))).block_until_ready()  # ragged: recompiles
            assert delta() >= 1

    def test_assert_no_recompiles_raises_and_passes(self):
        obs.install()

        @jax.jit
        def g(x):
            return x + 1.0

        g(jnp.ones((6,))).block_until_ready()
        with obs.assert_no_recompiles("steady"):
            g(jnp.ones((6,))).block_until_ready()
        with pytest.raises(obs.RecompileError, match="steady"):
            with obs.assert_no_recompiles("steady"):
                g(jnp.ones((7,))).block_until_ready()

    def test_install_idempotent(self):
        obs.install()
        before = jaxmon.compiles()
        obs.install()  # second install must not double-register
        jax.jit(lambda x: x - 3.0)(jnp.ones((5,))).block_until_ready()
        delta = jaxmon.compiles() - before
        assert delta >= 1
        # One listener: the compile histogram count matches the counter.
        snap = obs.snapshot()["jax_compile_seconds"]["values"][""]
        assert snap["count"] == jaxmon.compiles()

    def test_memory_gauges_handle_absent_stats(self):
        # CPU devices report no allocator stats: no gauges, no crash.
        out = obs.update_memory_gauges()
        for dev_stats in out.values():
            assert all(isinstance(v, float) for v in dev_stats.values())


# -------------------------------------------------- ops dispatch counting

def _bipolar(rng, shape):
    return jnp.asarray(rng.choice([-1.0, 1.0], size=shape)
                       .astype(np.float32))


class TestDispatchTiers:
    """Every kernel dispatch lands in kernel_dispatch_total with the
    tier that actually served it — the silent-fallback detector."""

    def _counts(self):
        from repro.kernels import ops
        return ops.dispatch_breakdown()

    def _delta(self, before, after, kernel):
        b, a = before.get(kernel, {}), after.get(kernel, {})
        return {t: a.get(t, 0) - b.get(t, 0) for t in a}

    def test_all_nine_kernels_counted(self):
        """binary_mvm, encode_pack, am_search, am_search_imc,
        am_search_multibit, am_search_packed, am_shortlist,
        am_search_sparse, qail_update: one dispatch each, on the tier
        the backend serves them with."""
        from repro.core.types import ImcArrayConfig, ImcSimConfig
        from repro.deploy import hierarchical as hier
        from repro.kernels import ops, ref
        rng = np.random.default_rng(42)
        b, f, d, c = 2, 16, 128, 6
        feats = jnp.asarray(rng.random((b, f), dtype=np.float32))
        proj = _bipolar(rng, (f, d))
        q, am = _bipolar(rng, (b, d)), _bipolar(rng, (c, d))
        qp = ops.pack_rows(q)
        apt = ops.pack_rows(am).T
        codes = rng.integers(-1, 2, size=(c, d))
        planes = ref.pack_planes(jnp.asarray(codes + 1), 2)

        before = self._counts()
        ops.encode_mvm(feats, proj)
        ops.encode_pack(feats, proj)
        ops.am_search(q, am)
        ops.am_search_imc(q, am, sim=ImcSimConfig(
            arr=ImcArrayConfig(rows=128, cols=128)))
        ops.am_search_multibit(q, planes)
        ops.am_search_packed(qp, apt, n_dims=d)
        ops.am_shortlist(qp, apt, n_dims=d, s=2)
        g = 2
        assign = rng.integers(0, g, size=c).astype(np.int32)
        layout = hier.build_layout(np.asarray(apt), assign, g)
        short = jnp.zeros((b, 1), jnp.int32)
        ops.am_search_sparse(
            qp, jnp.asarray(layout.slab), jnp.asarray(layout.col_ids),
            short, jnp.asarray(layout.tile_start),
            jnp.asarray(layout.tile_count), n_dims=d, k=1,
            max_tiles=layout.max_tiles)
        owners = jnp.arange(c, dtype=jnp.int32) % 3
        labels = jnp.zeros((b,), jnp.int32)
        mask = jnp.ones((b,), jnp.float32)
        ops.qail_update(q, q, am.T, owners, labels, mask, lr=0.5)
        after = self._counts()

        on_tpu = jax.default_backend() == "tpu"
        auto_tier = "pallas" if on_tpu else "xla-oracle"
        expect = {
            "binary_mvm": "pallas", "encode_pack": "pallas",
            "am_search": "pallas", "am_search_imc": "pallas",
            "am_search_multibit": "pallas",
            "am_search_packed": "pallas",
            "am_shortlist": auto_tier, "am_search_sparse": auto_tier,
            "qail_update": "pallas",
        }
        for kernel, tier in expect.items():
            delta = self._delta(before, after, kernel)
            assert delta.get(tier, 0) >= 1, (kernel, tier, delta)

    def test_ref_tier_counted_separately(self):
        from repro.kernels import ops
        rng = np.random.default_rng(7)
        q, am = _bipolar(rng, (2, 64)), _bipolar(rng, (3, 64))
        before = self._counts()
        ops.am_search(q, am, use_kernel=False)
        ops.am_search(q, am, use_kernel=True)
        delta = self._delta(before, self._counts(), "am_search")
        assert delta.get("ref", 0) == 1
        assert delta.get("pallas", 0) == 1

    def test_geometry_label_present(self):
        from repro.kernels import ops
        rng = np.random.default_rng(8)
        q, am = _bipolar(rng, (4, 32)), _bipolar(rng, (5, 32))
        ops.am_search(q, am)
        fam = obs.REGISTRY.get("kernel_dispatch_total")
        geoms = [labels["geometry"] for labels, _ in fam.series()
                 if labels.get("kernel") == "am_search"]
        assert "B=4,C=5,D=32" in geoms


# ------------------------------------------------------------------- logs

class TestLogging:
    def test_human_format(self, capsys):
        setup_logging()
        logging.getLogger("fmt_test").info("hello %d", 7)
        err = capsys.readouterr().err
        assert "I fmt_test :: hello 7" in err

    def test_json_mode_emits_parseable_lines(self, capsys):
        setup_logging(json_mode=True)
        logging.getLogger("json_test").warning("careful")
        err = capsys.readouterr().err.strip().splitlines()
        rec = json.loads(err[-1])
        assert rec["level"] == "WARNING"
        assert rec["logger"] == "json_test"
        assert rec["msg"] == "careful"
        assert isinstance(rec["ts"], float)
        setup_logging()  # restore the human default for later tests

    def test_event_log_jsonl(self, tmp_path):
        path = tmp_path / "run" / "events.jsonl"
        with EventLog(str(path)) as ev:
            ev.emit("epoch", step=1, miss=0.25)
            ev.emit("checkpoint", step=1, dur_s=0.01)
        lines = path.read_text().strip().splitlines()
        assert len(lines) == 2
        recs = [json.loads(ln) for ln in lines]
        assert recs[0]["event"] == "epoch" and recs[0]["step"] == 1
        assert recs[1]["event"] == "checkpoint"
        assert all("ts" in r for r in recs)

    def test_event_log_none_path_is_noop(self):
        ev = EventLog(None)
        ev.emit("anything", x=1)  # must not raise or write
        ev.close()
