"""Optimizer substrate tests: AdamW, schedules, int8 states, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamWConfig, ScheduleConfig, adamw_init, adamw_update, ef_int8_compress,
    make_schedule,
)
from repro.optim.adamw import _dq_v, _q_v


def _quadratic_loss(params):
    return sum(jnp.sum(jnp.square(p - 3.0)) for p in
               jax.tree.leaves(params))


class TestAdamW:
    @pytest.mark.parametrize("state_dtype,second", [
        ("fp32", "dense"), ("bf16", "dense"), ("fp32", "int8"),
    ])
    def test_converges_on_quadratic(self, state_dtype, second):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0,
                          state_dtype=state_dtype, second_moment=second)
        params = {"a": jnp.zeros((32, 8)), "b": jnp.zeros((5,))}
        state = adamw_init(params, cfg)
        loss0 = float(_quadratic_loss(params))
        for _ in range(150):
            grads = jax.grad(_quadratic_loss)(params)
            params, state = adamw_update(params, grads, state, cfg)
        assert float(_quadratic_loss(params)) < 0.01 * loss0

    def test_grad_clipping_bounds_update(self):
        cfg = AdamWConfig(lr=1.0, grad_clip_norm=1e-3, weight_decay=0.0)
        params = {"w": jnp.zeros((4,))}
        state = adamw_init(params, cfg)
        huge = {"w": jnp.full((4,), 1e9)}
        new_params, _ = adamw_update(params, huge, state, cfg)
        # First-step Adam update magnitude ~ lr regardless, but must be
        # finite and sane despite the 1e9 gradient.
        assert np.all(np.isfinite(np.asarray(new_params["w"])))

    def test_state_bytes_accounting(self):
        assert AdamWConfig(state_dtype="fp32").state_bytes_per_param() == 8
        assert AdamWConfig(state_dtype="bf16").state_bytes_per_param() == 4
        assert AdamWConfig(
            state_dtype="bf16",
            second_moment="int8").state_bytes_per_param() < 3.1

    def test_int8_v_quantization_error(self):
        v = jnp.abs(jax.random.normal(jax.random.key(0), (1000,))) * 1e-4
        q, s = _q_v(v)
        v2 = _dq_v(q, s, v.shape, v.size)
        rel = float(jnp.linalg.norm(v - v2) / jnp.linalg.norm(v))
        assert rel < 0.02, rel


class TestSchedule:
    def test_warmup_and_decay(self):
        fn = make_schedule(ScheduleConfig(kind="cosine", warmup_steps=10,
                                          total_steps=100, min_ratio=0.1))
        assert float(fn(0)) == 0.0
        assert abs(float(fn(10)) - 1.0) < 1e-6
        assert float(fn(100)) == pytest.approx(0.1, abs=1e-6)
        assert float(fn(55)) < float(fn(20))

    def test_linear(self):
        fn = make_schedule(ScheduleConfig(kind="linear", warmup_steps=0,
                                          total_steps=100, min_ratio=0.0))
        assert float(fn(50)) == pytest.approx(0.5, abs=1e-6)

    def test_constant(self):
        fn = make_schedule(ScheduleConfig(kind="constant", warmup_steps=5,
                                          total_steps=100))
        assert float(fn(50)) == pytest.approx(1.0)


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        """With EF, the *accumulated* quantization error stays bounded
        and the dequantized stream is unbiased over steps."""
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.normal(size=(4096,)).astype(np.float32))
        err = jnp.zeros_like(g_true)
        total_sent = jnp.zeros_like(g_true)
        for _ in range(20):
            q, s, err = ef_int8_compress(g_true, err)
            sent = (q.astype(jnp.float32) * s).reshape(-1)[:4096]
            total_sent = total_sent + sent
        # Sum of sent gradients ~ 20 * g_true (EF recovers what rounding
        # dropped).
        rel = float(jnp.linalg.norm(total_sent - 20 * g_true)
                    / jnp.linalg.norm(20 * g_true))
        assert rel < 0.01, rel

    def test_quantization_is_bounded(self):
        x = jnp.asarray([1e-9, -1e-9, 5.0, -5.0] * 256)
        q, s, err = ef_int8_compress(x, jnp.zeros_like(x))
        assert q.dtype == jnp.int8
        assert float(jnp.max(jnp.abs(err))) <= float(jnp.max(s)) + 1e-6
