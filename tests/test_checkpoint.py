"""Checkpoint manager: atomicity, verification, keep-k, resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager


@pytest.fixture
def tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,))},
        "opt": {"m": jnp.zeros((3, 4)), "step": jnp.asarray(7)},
    }


def test_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path)))
    mgr.save(10, tree, extra={"pipeline": {"seed": 1, "position": 42}})
    step, restored, extra = mgr.restore(tree)
    assert step == 10
    assert extra["pipeline"]["position"] == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_prunes(tmp_path, tree):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep=2))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_corruption_detected_and_skipped(tmp_path, tree):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep=5))
    mgr.save(1, tree)
    mgr.save(2, tree)
    # Corrupt a shard file of step 2.
    d = mgr._step_dir(2)
    mf = json.load(open(os.path.join(d, "manifest.json")))
    victim = next(iter(mf["files"].values()))["file"]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(0)
        f.write(b"\xde\xad\xbe\xef")
    step, _, _ = mgr.restore(tree)
    assert step == 1  # fell back to the previous valid checkpoint


def test_tmp_dirs_ignored_and_gced(tmp_path, tree):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path)))
    os.makedirs(os.path.join(str(tmp_path), "step_0000000099.tmp"))
    assert mgr.latest_step() is None
    mgr.save(5, tree)  # save GCs stray tmp dirs
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))


def test_restore_empty_dir(tmp_path, tree):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path)))
    step, restored, extra = mgr.restore(tree)
    assert step is None and extra == {}


def test_latest_symlink(tmp_path, tree):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path)))
    mgr.save(3, tree)
    mgr.save(7, tree)
    link = os.path.join(str(tmp_path), "latest")
    assert os.path.lexists(link)
    assert "0000000007" in os.readlink(link)
