"""Unified deployment-backend subsystem: registry dispatch, legacy-shim
compatibility, round-trip predict parity for every registered backend,
and pytree flatten/unflatten stability of every artifact under jax.jit."""
import jax
import numpy as np
import pytest

from repro.deploy import (DeployedArtifact, available_backends, deploy,
                          get_backend, register_backend)
from repro.deploy.base import pytree_artifact

BACKENDS = sorted(available_backends())
# The multibit backend reads out against the QUANTIZED float shadow,
# not the binary AM — bit-exact parity with model.predict is the wrong
# contract for it (its oracle parity lives in TestMultibitBackend).
BINARY_PARITY_BACKENDS = [t for t in BACKENDS if t != "multibit"]


@pytest.fixture(scope="module")
def trained(small_hdc_data):
    """A small trained model (shared across every backend check)."""
    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    ds = small_hdc_data
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                      epochs=1, kmeans_iters=3)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
    return ds, m


class TestRegistry:
    def test_builtin_backends_registered(self):
        assert {"packed", "unpacked", "imc", "hierarchical",
                "multibit"} <= set(BACKENDS)

    def test_unknown_target_error_names_backends(self, trained):
        _, m = trained
        with pytest.raises(ValueError, match="unknown deploy target"):
            m.deploy(target="bogus")
        with pytest.raises(ValueError) as ei:
            get_backend("bogus")
        # The error enumerates what IS registered.
        for name in ("packed", "unpacked", "imc"):
            assert name in str(ei.value)

    def test_registry_function_dispatch(self, trained):
        _, m = trained
        dep = deploy(m, "packed")
        assert dep.backend == "packed"
        assert get_backend("imc")(m).backend == "imc"

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_backend("packed")(lambda model: None)
        # Re-registering the SAME factory (module reload) is a no-op.
        factory = get_backend("packed")
        assert register_backend("packed")(factory) is factory

    def test_third_party_backend_plugs_in(self, trained):
        _, m = trained

        @register_backend("_test_echo")
        def _echo(model, *, tag="x"):
            return (model, tag)

        try:
            got_m, tag = m.deploy(target="_test_echo", tag="y")
            assert got_m is m and tag == "y"
        finally:
            from repro.deploy import registry
            registry._BACKENDS.pop("_test_echo")


class TestLegacyShims:
    """Old deploy() call forms and import paths keep working."""

    def test_import_paths(self):
        from repro.core import DeployedMemhd as d1
        from repro.core.memhd import DeployedMemhd as d2
        from repro.deploy.digital import DeployedMemhd as d3
        assert d1 is d2 is d3
        from repro.imcsim import ImcDeployedMemhd, deploy_imc  # noqa: F401

    def test_packed_kwarg(self, trained):
        _, m = trained
        assert m.deploy().backend == "packed"
        assert m.deploy(packed=True).backend == "packed"
        assert m.deploy(packed=False).backend == "unpacked"
        assert m.deploy(target="digital", packed=False).backend == \
            "unpacked"
        assert m.deploy(packed=True, mode="unpack").mode == "unpack"

    def test_imc_target_with_sim(self, trained):
        from repro.core import ImcSimConfig
        from repro.imcsim import ImcDeployedMemhd
        _, m = trained
        dep = m.deploy(target="imc", sim=ImcSimConfig(seed=3))
        assert isinstance(dep, ImcDeployedMemhd)
        assert dep.sim.seed == 3

    def test_sim_rejected_for_digital(self, trained):
        from repro.core import ImcSimConfig
        _, m = trained
        with pytest.raises(ValueError, match="target='imc'"):
            m.deploy(packed=True, sim=ImcSimConfig())

    def test_packed_kwarg_rejected_with_registry_target(self, trained):
        _, m = trained
        with pytest.raises(ValueError, match="legacy"):
            m.deploy(target="packed", packed=True)


class TestBackendParity:
    """deploy(target=t).predict == model.predict for every backend.

    (The imc backend's default sim is ideal — the fidelity-parity
    contract of tests/test_imcsim.py.)
    """

    @pytest.mark.parametrize("target", BINARY_PARITY_BACKENDS)
    def test_predict_roundtrip(self, trained, target):
        ds, m = trained
        dep = m.deploy(target=target)
        assert isinstance(dep, DeployedArtifact)
        np.testing.assert_array_equal(
            np.asarray(dep.predict(ds.test_x[:48])),
            np.asarray(m.predict(ds.test_x[:48])))
        # predict_features serves the same answers (fused or staged).
        np.testing.assert_array_equal(
            np.asarray(dep.predict_features(ds.test_x[:48])),
            np.asarray(m.predict(ds.test_x[:48])))

    @pytest.mark.parametrize("target", BINARY_PARITY_BACKENDS)
    def test_score_matches_model(self, trained, target):
        ds, m = trained
        dep = m.deploy(target=target)
        assert dep.score(ds.test_x, ds.test_y) == \
            m.score(ds.test_x, ds.test_y)

    @pytest.mark.parametrize("target", BACKENDS)
    def test_score_queries_matches_score(self, trained, target):
        ds, m = trained
        dep = m.deploy(target=target)
        q = m.encode_query(ds.test_x)
        assert dep.score_queries(q, ds.test_y) == \
            dep.score(ds.test_x, ds.test_y)

    @pytest.mark.parametrize("target", BACKENDS)
    def test_protocol_surface(self, trained, target):
        _, m = trained
        dep = m.deploy(target=target)
        assert dep.backend == target
        assert isinstance(dep.serving_mode, str)
        assert dep.resident_bytes > 0
        assert dep.resident_am_bytes == dep.resident_bytes
        assert dep.am_memory_ratio > 0
        assert dep.imc_cost().total_cycles >= 1


class TestMultibitBackend:
    """Bit-sliced multi-bit artifact: oracle parity, Table-I accounting
    at multi-level cells, refresh semantics, and sim validation."""

    @pytest.mark.parametrize("bits", [2, 4])
    def test_oracle_parity(self, trained, bits):
        from repro.core import am as am_lib
        ds, m = trained
        dep = m.deploy(target="multibit", cell_bits=bits)
        q = m.encode_query(ds.test_x[:32])
        np.testing.assert_array_equal(
            np.asarray(dep.predict_query(q)),
            np.asarray(am_lib.multibit_predict(
                dep.am_planes_t, dep.centroid_class, q, bits)))
        # search_query sims are the code-domain sims dequantized.
        from repro.kernels import ref
        _, sims = dep.search_query(q)
        _, code_sims = ref.am_search_multibit(q, dep.am_planes_t,
                                              cell_bits=bits)
        np.testing.assert_allclose(
            np.asarray(sims),
            np.asarray(code_sims) * float(dep.am_scale), rtol=1e-6)

    @pytest.mark.parametrize("bits", [2, 4])
    def test_memory_bits_table1(self, trained, bits):
        _, m = trained
        dep = m.deploy(target="multibit", cell_bits=bits)
        d, c = m.am_cfg.dim, m.am_cfg.columns
        assert m.am_cfg.am_memory_bits_at(bits) == c * d * bits
        assert dep.memory_bits == m.enc_cfg.memory_bits + c * d * bits
        # Plane residence: bits planes of ceil(D/8) bytes per column.
        plane_bytes = bits * (-(-d // 8)) * c
        assert dep.am_planes_t.size == plane_bytes
        assert dep.resident_bytes >= plane_bytes
        # vs the 1-bit point the packing is exactly `bits` planes.
        assert m.am_cfg.am_memory_bits_at(bits) == \
            bits * m.am_cfg.am_memory_bits

    def test_refresh_keeps_signature_and_opts(self, trained):
        _, m = trained
        dep = m.deploy(target="multibit", cell_bits=2)
        fresh = dep.refresh(m)
        assert fresh is not dep
        assert fresh.cell_bits == 2 and fresh.backend == "multibit"
        assert fresh.swap_signature == dep.swap_signature
        np.testing.assert_array_equal(np.asarray(fresh.am_planes_t),
                                      np.asarray(dep.am_planes_t))

    def test_rejects_bad_cell_bits(self, trained):
        _, m = trained
        with pytest.raises(ValueError, match="packed"):
            m.deploy(target="multibit", cell_bits=1)
        with pytest.raises(ValueError, match="outside"):
            m.deploy(target="multibit", cell_bits=9)

    def test_rejects_storage_perturbation_sims(self, trained):
        from repro.core import ImcSimConfig
        _, m = trained
        for bad in (ImcSimConfig(noise_sigma=0.5),
                    ImcSimConfig(fault_p0=0.01),
                    ImcSimConfig(fault_p1=0.01)):
            with pytest.raises(ValueError, match="1-bit storage"):
                m.deploy(target="multibit", cell_bits=4, sim=bad)

    def test_drift_sim_attaches_offsets(self, trained):
        from repro.core import ImcSimConfig
        _, m = trained
        dep = m.deploy(target="multibit", cell_bits=4,
                       sim=ImcSimConfig(drift_sigma=0.2, seed=5))
        gd = -(-m.am_cfg.dim // dep.sim.arr.rows)
        gc = -(-m.am_cfg.columns // dep.sim.arr.cols)
        assert dep.tile_offsets.shape == (gd, gc)
        # Same seed refreshes onto the same simulated readout.
        np.testing.assert_array_equal(
            np.asarray(dep.refresh(m).tile_offsets),
            np.asarray(dep.tile_offsets))


class TestPytreeStability:
    """Artifacts are pytrees: flatten/unflatten and jit round-trips."""

    @pytest.mark.parametrize("target", BACKENDS)
    def test_flatten_unflatten_roundtrip(self, trained, target):
        ds, m = trained
        dep = m.deploy(target=target)
        leaves, treedef = jax.tree_util.tree_flatten(dep)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        assert type(rebuilt) is type(dep)
        assert rebuilt.am_cfg == dep.am_cfg
        np.testing.assert_array_equal(
            np.asarray(rebuilt.predict(ds.test_x[:16])),
            np.asarray(dep.predict(ds.test_x[:16])))

    @pytest.mark.parametrize("target", BACKENDS)
    def test_artifact_flows_through_jit(self, trained, target):
        ds, m = trained
        dep = m.deploy(target=target)
        q = m.encode_query(ds.test_x[:24])

        f = jax.jit(lambda art, qq: art.predict_query(qq))
        want = np.asarray(dep.predict_query(q))
        np.testing.assert_array_equal(np.asarray(f(dep, q)), want)
        # A flatten/unflatten round-trip hits the same jit cache entry
        # (identical treedef + aux), i.e. the pytree is jit-stable.
        leaves, treedef = jax.tree_util.tree_flatten(dep)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_array_equal(np.asarray(f(rebuilt, q)), want)
        assert f._cache_size() == 1

    def test_artifact_field_declarations_checked(self):
        import dataclasses as dc

        with pytest.raises(TypeError, match="_leaf_fields"):
            @pytree_artifact
            @dc.dataclass
            class Bad(DeployedArtifact):  # noqa: F841
                x: int
                _leaf_fields = ()
                _static_fields = ()