"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(ref.py), plus the kernel-geometry == IMC-cost-model consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import imc
from repro.kernels import ops, ref
from repro.kernels.am_search import imc_cycles_for as search_cycles
from repro.kernels.binary_mvm import imc_cycles_for as mvm_cycles

RNG = np.random.default_rng(42)


def bipolar(shape, dtype=np.float32):
    return jnp.asarray(RNG.choice([-1.0, 1.0], size=shape).astype(dtype))


class TestBinaryMvm:
    @pytest.mark.parametrize("b,f,d", [
        (1, 128, 128), (4, 784, 256), (3, 617, 512), (37, 100, 130),
        (2, 129, 64), (256, 64, 64),
    ])
    def test_matches_oracle(self, b, f, d):
        x = jnp.asarray(RNG.normal(size=(b, f)).astype(np.float32))
        w = bipolar((f, d))
        got = ops.encode_mvm(x, w)
        want = ref.binary_mvm(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("dtype", [np.float32, np.int8])
    def test_dtypes(self, dtype):
        x = jnp.asarray(
            RNG.integers(-3, 3, size=(4, 256)).astype(dtype))
        w = bipolar((256, 128)).astype(dtype)
        got = ops.encode_mvm(x.astype(jnp.float32), w.astype(jnp.float32))
        want = ref.binary_mvm(x.astype(jnp.float32), w.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_exact_integer_arithmetic(self):
        # Bipolar x bipolar products are integers: results must be exact.
        x = bipolar((8, 512))
        w = bipolar((512, 256))
        got = np.asarray(ops.encode_mvm(x, w))
        want = np.asarray(ref.binary_mvm(x, w))
        np.testing.assert_array_equal(got, want)

    def test_cycle_model(self):
        assert mvm_cycles((8, 784), (784, 10240)) == \
            imc.map_basic(784, 10240, imc.ImcArrayConfig()).cycles


class TestAmSearch:
    @pytest.mark.parametrize("b,d,c", [
        (1, 128, 128), (8, 128, 128), (3, 256, 64), (5, 512, 300),
        (2, 130, 257), (300, 64, 26),
    ])
    def test_matches_oracle(self, b, d, c):
        q = bipolar((b, d))
        am = bipolar((c, d))
        gi, gs = ops.am_search(q, am)
        wi, ws = ref.am_search(q, am.T)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_allclose(np.asarray(gs), np.asarray(ws))

    def test_tie_breaking_first_wins(self):
        # Duplicate centroids force ties; argmax must take the first.
        q = bipolar((4, 128))
        row = bipolar((1, 128))
        am = jnp.concatenate([row, row, row], axis=0)
        gi, _ = ops.am_search(q, am)
        assert np.all(np.asarray(gi) == 0)

    def test_one_shot_for_paper_geometry(self):
        # The 128x128 AM search is exactly one grid step (one IMC cycle).
        assert search_cycles((128, 128)) == 1
        assert search_cycles((512, 128)) == \
            imc.map_memhd(512, 128, imc.ImcArrayConfig()).cycles

    def test_predict_classes(self):
        q = bipolar((16, 128))
        am = bipolar((64, 128))
        owners = jnp.asarray(RNG.integers(0, 10, size=(64,)),
                             dtype=jnp.int32)
        pred = ops.predict_classes(q, am, owners)
        sims = np.asarray(q) @ np.asarray(am).T
        want = np.asarray(owners)[sims.argmax(axis=1)]
        np.testing.assert_array_equal(np.asarray(pred), want)


class TestPackBits:
    @pytest.mark.parametrize("r,c", [(128, 128), (7, 64), (200, 1032),
                                     (1, 8), (300, 2048)])
    def test_roundtrip(self, r, c):
        x = bipolar((r, c))
        p = ops.pack_bits(x)
        assert p.dtype == jnp.uint8 and p.shape == (r, c // 8)
        np.testing.assert_array_equal(np.asarray(p),
                                      np.asarray(ref.pack_bits(x)))
        u = ops.unpack_bits(p)
        np.testing.assert_array_equal(np.asarray(u), np.asarray(x))

    def test_memory_ratio(self):
        # The point of the paper: 1 bit per cell.
        x = bipolar((128, 1024))
        p = ops.pack_bits(x)
        assert p.size * p.dtype.itemsize * 8 == x.size  # 1 bit per cell

    def test_rejects_bad_width(self):
        with pytest.raises(ValueError):
            ops.pack_bits(bipolar((4, 31)))


class TestKernelIntegration:
    def test_end_to_end_inference_path(self, small_hdc_data):
        """Kernel-path inference == jnp-path inference on a real model."""
        from repro.core import EncoderConfig, MemhdConfig, MemhdModel
        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=128)
        amc = MemhdConfig(dim=128, columns=64, classes=ds.classes,
                          epochs=2, kmeans_iters=5)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)

        q = m.encode_query(ds.test_x[:64])
        jnp_pred = np.asarray(m.predict(ds.test_x[:64]))
        kern_pred = np.asarray(ops.predict_classes(
            q, m.am_state["binary"], m.am_state["centroid_class"]))
        np.testing.assert_array_equal(jnp_pred, kern_pred)
