"""End-to-end behaviour tests for the paper's system (MEMHD pipeline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BaselineConfig, EncoderConfig, MemhdConfig, MemhdModel, fit_baseline,
)
from repro.core import qail


@pytest.fixture(scope="module")
def trained(small_hdc_data):
    ds = small_hdc_data
    enc = EncoderConfig(kind="projection", features=ds.features, dim=256)
    amc = MemhdConfig(dim=256, columns=64, classes=ds.classes, epochs=8,
                      kmeans_iters=10, lr=0.02)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, hist = m.fit(jax.random.key(1), ds.train_x, ds.train_y,
                    eval_feats=ds.test_x, eval_labels=ds.test_y)
    return ds, m, hist


class TestPipeline:
    def test_qail_improves_over_init(self, trained):
        _, _, hist = trained
        curve = [r["eval_acc"] for r in hist["curve"] if "eval_acc" in r]
        assert curve[-1] >= curve[0] - 0.02  # never collapses
        assert max(curve) > curve[0]          # and learning helps

    def test_full_utilization(self, trained):
        _, m, _ = trained
        assert m.am_state["fp"].shape == (64, 256)
        assert m.am_state["centroid_class"].shape == (64,)
        # Every class owns at least one centroid.
        owners = np.asarray(m.am_state["centroid_class"])
        assert set(owners.tolist()) == set(range(10))

    def test_binary_am_is_bipolar(self, trained):
        _, m, _ = trained
        vals = np.unique(np.asarray(m.am_state["binary"]))
        assert set(vals.tolist()) <= {-1.0, 1.0}

    def test_allocation_history_recorded(self, trained):
        _, _, hist = trained
        assert len(hist["init"]) >= 1
        budgets = hist["init"][-1]["budgets"]
        assert sum(budgets) <= 64

    def test_memory_accounting(self, trained):
        _, m, _ = trained
        # Table I: f*D + C*D bits.
        assert m.memory_bits == 784 * 256 + 64 * 256


class TestPaperClaims:
    """Relative accuracy claims (synthetic data -> relative, not absolute;
    see DESIGN.md §5)."""

    def test_multicentroid_beats_single_at_same_memory(self,
                                                       small_hdc_data):
        ds = small_hdc_data
        # Same total AM memory: 64 centroids x 256D vs 10 x 256D has
        # different memory; compare instead single-centroid (C=k) vs
        # multi-centroid (C=64) at same D: the paper's core claim is the
        # multi-centroid AM represents multimodal classes better.
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=256)
        accs = {}
        for cols in (10, 64):
            amc = MemhdConfig(dim=256, columns=cols, classes=ds.classes,
                              epochs=6, kmeans_iters=8, lr=0.02,
                              init_ratio=1.0 if cols == 10 else 0.8)
            m = MemhdModel.create(jax.random.key(0), enc, amc)
            m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
            accs[cols] = m.score(ds.test_x, ds.test_y)
        assert accs[64] > accs[10] + 0.02, accs

    def test_clustering_init_beats_random(self, small_hdc_data):
        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=256)
        amc = MemhdConfig(dim=256, columns=64, classes=ds.classes,
                          epochs=0, kmeans_iters=10)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m_c, _ = m.initialize_am(jax.random.key(1), ds.train_x, ds.train_y,
                                 method="clustering")
        m_r, _ = m.initialize_am(jax.random.key(1), ds.train_x, ds.train_y,
                                 method="random")
        acc_c = m_c.score(ds.test_x, ds.test_y)
        acc_r = m_r.score(ds.test_x, ds.test_y)
        # Fig. 5: clustering init starts substantially higher.
        assert acc_c > acc_r + 0.03, (acc_c, acc_r)

    def test_memhd_beats_basic_hdc_at_same_dim(self, small_hdc_data):
        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=256)
        amc = MemhdConfig(dim=256, columns=64, classes=ds.classes,
                          epochs=6, kmeans_iters=8, lr=0.02)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
        acc_memhd = m.score(ds.test_x, ds.test_y)

        bl = fit_baseline(jax.random.key(2),
                          BaselineConfig(kind="basic", dim=256,
                                         classes=ds.classes),
                          ds.train_x, ds.train_y)
        acc_basic = bl.score(ds.test_x, ds.test_y)
        assert acc_memhd > acc_basic + 0.05, (acc_memhd, acc_basic)


class TestBaselines:
    @pytest.mark.parametrize("kind", ["basic", "quanthd", "lehdc",
                                      "searchd"])
    def test_baseline_trains_above_chance(self, kind, small_hdc_data):
        ds = small_hdc_data
        # SearcHD's stochastic quantization needs more dimensions to
        # average out Bernoulli noise (paper runs it at 8000-D).
        dim = 2048 if kind == "searchd" else 512
        cfg = BaselineConfig(kind=kind, dim=dim, classes=ds.classes,
                             epochs=6, n_models=8)
        bl = fit_baseline(jax.random.key(0), cfg, ds.train_x, ds.train_y)
        acc = bl.score(ds.test_x, ds.test_y)
        assert acc > 2.0 / ds.classes, (kind, acc)

    def test_memory_accounting_table1(self):
        # Table I formulas.
        f, d, k, lvl = 784, 1024, 10, 256
        basic = BaselineConfig(kind="basic", dim=d, classes=k)
        assert basic.am_memory_bits() == k * d
        searchd = BaselineConfig(kind="searchd", dim=d, classes=k,
                                 n_models=64)
        assert searchd.am_memory_bits() == k * d * 64
        enc_proj = EncoderConfig(kind="projection", features=f, dim=d)
        assert enc_proj.memory_bits == f * d
        enc_idl = EncoderConfig(kind="id_level", features=f, dim=d,
                                levels=lvl)
        assert enc_idl.memory_bits == (f + lvl) * d


class TestQailMechanics:
    def test_update_targets_eq4_eq5(self):
        """Eq. (4): push-away = global argmax; Eq. (5): pull = best
        centroid of the true class."""
        sims = jnp.asarray([3.0, 9.0, 2.0, 5.0])
        owners = jnp.asarray([0, 1, 1, 0])
        mis, pred_t, true_t = qail.select_update_targets(
            sims, owners, jnp.asarray(0), 2)
        assert bool(mis)            # pred class 1 != true 0
        assert int(pred_t) == 1     # global max (9.0)
        assert int(true_t) == 3     # best of class 0 (5.0 > 3.0)

    def test_no_update_when_correct(self):
        sims = jnp.asarray([9.0, 3.0])
        owners = jnp.asarray([0, 1])
        mis, _, _ = qail.select_update_targets(
            sims, owners, jnp.asarray(0), 2)
        assert not bool(mis)

    def test_batched_tracks_sequential(self, small_hdc_data):
        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=128)
        amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                          epochs=0, kmeans_iters=5, lr=0.02, batch_size=64)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m, _ = m.initialize_am(jax.random.key(1), ds.train_x, ds.train_y)
        h = m.encode(ds.train_x)
        q = jnp.where(h >= 0, 1.0, -1.0)

        s_seq = qail.qail_epoch_sequential(m.am_state, amc, h, q,
                                           ds.train_y)
        s_bat, _ = qail.qail_epoch_batched(m.am_state, amc, h, q,
                                           ds.train_y)
        acc_seq = qail.evaluate(s_seq, q, ds.train_y)
        acc_bat = qail.evaluate(s_bat, q, ds.train_y)
        # Same data, same start: the two schedules land within a few
        # points of each other (they are different orderings of the same
        # updates, not identical algorithms).
        assert abs(acc_seq - acc_bat) < 0.1, (acc_seq, acc_bat)
