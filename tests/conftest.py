"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — unit tests must see the real
1-device CPU backend. Multi-device tests spawn subprocesses with
``--xla_force_host_platform_device_count`` set (see _multidev.py).
"""
import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running training/multi-device tests "
        "(deselect with -m 'not slow')")


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def small_hdc_data():
    from repro.data import load_dataset
    return load_dataset("mnist", train_per_class=150, test_per_class=40)
