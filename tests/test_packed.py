"""Packed-bit deployment path: pack/unpack roundtrips, bit-exact parity
of the XOR+popcount kernel with the float kernel and the jnp argmax
reference, the kernel-grid == IMC-cycle-model contract, and the
deploy(packed=True) serving artifact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import imc
from repro.kernels import ops, ref
from repro.kernels.am_search import imc_cycles_for as search_cycles
from repro.kernels.am_search_packed import imc_cycles_for as packed_cycles

RNG = np.random.default_rng(7)


def bipolar(shape, dtype=np.float32):
    return jnp.asarray(RNG.choice([-1.0, 1.0], size=shape).astype(dtype))


class TestPackRows:
    """pack_rows: the ragged-D packer (non-multiple-of-8 tails)."""

    @pytest.mark.parametrize("r,c", [
        (1, 1), (3, 7), (5, 8), (4, 9), (128, 128), (2, 130),
        (17, 617), (1, 1023),
    ])
    def test_roundtrip(self, r, c):
        x = bipolar((r, c))
        p = ops.pack_rows(x)
        assert p.dtype == jnp.uint8 and p.shape == (r, -(-c // 8))
        np.testing.assert_array_equal(np.asarray(p),
                                      np.asarray(ref.pack_rows(x)))
        # Valid bits roundtrip through the full-width unpacker...
        u = ops.unpack_bits(p)[:, :c]
        np.testing.assert_array_equal(np.asarray(u), np.asarray(x))
        # ...and tail bits are packed as 0 (they must XOR-cancel).
        tail = np.asarray(ops.unpack_bits(p))[:, c:]
        assert np.all(tail == -1.0)

    def test_one_bit_per_cell(self):
        x = bipolar((128, 128))
        p = ops.pack_rows(x)
        assert p.size * 8 == x.size


class TestPackedSearchParity:
    """am_search_packed == am_search == jnp.argmax, bit for bit."""

    @pytest.mark.parametrize("b,d,c", [
        (1, 128, 128), (8, 128, 128), (3, 256, 64), (5, 512, 300),
        (2, 130, 257), (7, 120, 26), (300, 64, 26), (4, 9, 3),
    ])
    @pytest.mark.parametrize("mode", ["popcount", "unpack"])
    def test_matches_unpacked_and_reference(self, b, d, c, mode):
        q = bipolar((b, d))
        am = bipolar((c, d))
        qp = ops.pack_rows(q)
        apt = ops.pack_rows(am).T

        gi, gs = ops.am_search_packed(qp, apt, n_dims=d, mode=mode)
        ui, us = ops.am_search(q, am)
        wi, ws = ref.am_search(q, am.T)

        np.testing.assert_array_equal(np.asarray(gi), np.asarray(ui))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(us))
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))

    def test_packed_oracle_matches_reference(self):
        q, am = bipolar((6, 200)), bipolar((40, 200))
        ri, rs = ref.am_search_packed(
            ref.pack_rows(q), ref.pack_rows(am).T, 200)
        wi, ws = ref.am_search(q, am.T)
        np.testing.assert_array_equal(np.asarray(ri), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(rs), np.asarray(ws))

    @pytest.mark.parametrize("mode", ["popcount", "unpack"])
    def test_tie_breaking_first_wins(self, mode):
        # Duplicate centroids force ties; argmax must take the first —
        # including across C-tile boundaries (c=150 spans two tiles).
        q = bipolar((4, 128))
        row = bipolar((1, 128))
        am = jnp.concatenate([row] * 150, axis=0)
        gi, _ = ops.am_search_packed(
            ops.pack_rows(q), ops.pack_rows(am).T, n_dims=128, mode=mode)
        assert np.all(np.asarray(gi) == 0)

    def test_hamming_identity(self):
        # sim = D - 2*hamming on the packed bits.
        q, am = bipolar((5, 96)), bipolar((12, 96))
        ham = np.asarray(ref.hamming_distances(
            ref.pack_rows(q), ref.pack_rows(am).T))
        sims = np.asarray(q) @ np.asarray(am).T
        np.testing.assert_array_equal(96 - 2 * ham, sims)

    def test_rejects_bad_args(self):
        qp = ops.pack_rows(bipolar((2, 64)))
        apt = ops.pack_rows(bipolar((8, 64))).T
        with pytest.raises(ValueError):
            ops.am_search_packed(qp, apt, n_dims=64, mode="bogus")
        with pytest.raises(ValueError):
            ops.am_search_packed(qp, apt, n_dims=32)  # Dp mismatch


class TestPackedGridContract:
    """Kernel geometry == IMC cost model, packed == unpacked."""

    def test_one_shot_for_paper_flagship(self):
        # The paper's 128x128 flagship: the packed search is literally
        # ONE grid step — one IMC array cycle, as am_search.py promises.
        apt_shape = (128 // 8, 128)  # (Dp, C) of the packed AM
        assert packed_cycles(apt_shape) == 1
        assert packed_cycles(apt_shape) == \
            imc.map_memhd(128, 128, imc.ImcArrayConfig()).cycles

    @pytest.mark.parametrize("d,c", [
        (128, 128), (256, 256), (512, 128), (1024, 1024), (130, 257),
        (617, 26),
    ])
    def test_matches_unpacked_and_cost_model(self, d, c):
        apt_shape = (-(-d // 8), c)
        assert packed_cycles(apt_shape) == search_cycles((d, c))
        assert packed_cycles(apt_shape) == \
            imc.map_memhd(d, c, imc.ImcArrayConfig()).cycles


class TestDeployedModel:
    @pytest.fixture(scope="class")
    def trained(self, small_hdc_data):
        from repro.core import EncoderConfig, MemhdConfig, MemhdModel
        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=128)
        amc = MemhdConfig(dim=128, columns=64, classes=ds.classes,
                          epochs=2, kmeans_iters=5)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
        return ds, m

    def test_packed_deploy_bit_exact_and_8x_smaller(self, trained):
        ds, m = trained
        dep_p = m.deploy(packed=True)
        dep_u = m.deploy(packed=False)
        pp = np.asarray(dep_p.predict(ds.test_x))
        np.testing.assert_array_equal(pp, np.asarray(dep_u.predict(
            ds.test_x)))
        np.testing.assert_array_equal(pp, np.asarray(m.predict(
            ds.test_x)))
        assert dep_p.score(ds.test_x, ds.test_y) == \
            m.score(ds.test_x, ds.test_y)
        # Resident AM: 1 bit/cell vs 1 byte/cell vs float32 cells.
        assert dep_p.resident_am_bytes * 8 == 64 * 128
        assert dep_p.am_memory_ratio == 8.0
        assert dep_u.resident_am_bytes == 4 * dep_p.am_memory_ratio * \
            dep_p.resident_am_bytes

    def test_unpack_mode_matches(self, trained):
        ds, m = trained
        pred_pop = m.deploy(packed=True, mode="popcount").predict(
            ds.test_x[:32])
        pred_unp = m.deploy(packed=True, mode="unpack").predict(
            ds.test_x[:32])
        np.testing.assert_array_equal(np.asarray(pred_pop),
                                      np.asarray(pred_unp))

    def test_deployed_is_a_pytree(self, trained):
        _, m = trained
        dep = m.deploy(packed=True)
        leaves = jax.tree_util.tree_leaves(dep)
        assert any(leaf.dtype == jnp.uint8 for leaf in leaves)
        rebuilt = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(dep), leaves)
        assert rebuilt.packed and rebuilt.am_cfg == dep.am_cfg

    def test_packed_predict_helper(self, trained):
        ds, m = trained
        from repro.core import am as am_lib
        q = m.encode_query(ds.test_x[:20])
        apt = am_lib.pack_am(m.am_state["binary"])
        pred = am_lib.packed_predict(
            apt, m.am_state["centroid_class"], q, m.am_cfg.dim)
        np.testing.assert_array_equal(
            np.asarray(pred), np.asarray(m.predict(ds.test_x[:20])))
        assert am_lib.packed_am_bytes(m.am_cfg.dim, m.am_cfg.columns) \
            == apt.size


class TestServeBatching:
    """serve_memhd request batching: tile padding, no request splits."""

    def _reqs(self, sizes):
        from repro.launch.serve_memhd import Request
        return [Request(rid=i, feats=np.zeros((n, 4), np.float32))
                for i, n in enumerate(sizes)]

    def test_greedy_batching_never_splits(self):
        from repro.launch.serve_memhd import make_batches
        batches = make_batches(self._reqs([10, 10, 10, 50, 100, 3]), 64)
        assert [sorted(r.rid for r in b) for b in batches] == \
            [[0, 1, 2], [3], [4], [5]]
        assert all(sum(r.size for r in b) <= 64
                   for b in batches if len(b) > 1)

    def test_oversize_request_gets_own_batch(self):
        from repro.launch.serve_memhd import make_batches
        batches = make_batches(self._reqs([200]), 64)
        assert len(batches) == 1 and batches[0][0].size == 200

    def test_pad_to_multiple(self):
        from repro.launch.serve_memhd import pad_to_multiple
        x = np.ones((13, 4), np.float32)
        padded, n = pad_to_multiple(x, 8)
        assert padded.shape == (16, 4) and n == 13
        assert np.all(padded[13:] == 0)
        same, n2 = pad_to_multiple(np.ones((16, 4), np.float32), 8)
        assert same.shape == (16, 4) and n2 == 16

    def test_serve_batches_routes_responses(self, small_hdc_data):
        from repro.core import EncoderConfig, MemhdConfig, MemhdModel
        from repro.launch.serve_memhd import (Request, serve_batches,
                                              synthetic_requests)
        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=128)
        amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                          epochs=1, kmeans_iters=3)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
        dep = m.deploy(packed=True)

        feats = np.asarray(ds.test_x)
        reqs = synthetic_requests(feats, n_requests=9, max_size=11,
                                  seed=3)
        responses, stats = serve_batches(dep, reqs, max_batch=32)
        assert stats["rows_real"] == sum(r.size for r in reqs)
        assert stats["rows_padded"] % 8 == 0
        for r in reqs:
            want = np.asarray(dep.predict(r.feats))
            np.testing.assert_array_equal(responses[r.rid], want)
