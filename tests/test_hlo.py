"""HLO inspection layer: loop-corrected cost analysis + wire models."""
import jax
import jax.numpy as jnp
import pytest

from repro.distributed import hlo_cost
from repro.distributed.hlo import _wire_bytes, collective_bytes
from repro.distributed.roofline import V5E, model_flops, roofline


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _builtin_flops(compiled) -> float:
    # cost_analysis() returns a dict in newer jax, a 1-list of dicts in
    # older releases.
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca["flops"]


class TestLoopCorrectedFlops:
    def test_scan_multiplied_by_trip_count(self):
        B, D, L = 64, 128, 12

        def f(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=L)
            return y.sum()

        compiled = _compile(
            f, jax.ShapeDtypeStruct((B, D), jnp.float32),
            jax.ShapeDtypeStruct((D, D), jnp.float32))
        tot = hlo_cost.analyze(compiled.as_text(), 1)
        expected = L * 2 * B * D * D
        assert abs(tot.flops - expected) / expected < 0.02
        # Built-in cost_analysis undercounts (body counted once) — that
        # is the bug this module exists to fix.
        naive = _builtin_flops(compiled)
        assert naive < 0.2 * expected

    def test_nested_scan(self):
        B, D = 16, 64

        def f(x, w):
            def outer(c, _):
                def inner(c2, _):
                    return c2 @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y.sum()

        compiled = _compile(
            f, jax.ShapeDtypeStruct((B, D), jnp.float32),
            jax.ShapeDtypeStruct((D, D), jnp.float32))
        tot = hlo_cost.analyze(compiled.as_text(), 1)
        expected = 15 * 2 * B * D * D
        assert abs(tot.flops - expected) / expected < 0.05

    def test_no_loop_matches_cost_analysis(self):
        def f(x, w):
            return (x @ w).sum()

        compiled = _compile(
            f, jax.ShapeDtypeStruct((32, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 16), jnp.float32))
        tot = hlo_cost.analyze(compiled.as_text(), 1)
        ca = _builtin_flops(compiled)
        assert abs(tot.flops - ca) / max(ca, 1) < 0.02


class TestWireModel:
    def test_ring_formulas(self):
        assert _wire_bytes("all-gather", 100, 4) == pytest.approx(75.0)
        assert _wire_bytes("all-reduce", 100, 4) == pytest.approx(150.0)
        assert _wire_bytes("reduce-scatter", 100, 4) == pytest.approx(300.0)
        assert _wire_bytes("collective-permute", 100, 4) == 100.0
        assert _wire_bytes("all-reduce", 100, 1) == 0.0

    def test_collective_parsing_from_real_hlo(self):
        hlo = (
            "ENTRY %main (p: f32[8,16]) -> f32[] {\n"
            "  %ag = f32[32,16] all-gather(%p), replica_groups=[2,4]<=[8]\n"
            "  %ar = f32[] all-reduce(%x), replica_groups=[1,8]<=[8]\n"
            "}\n")
        out = collective_bytes(hlo, 8)
        assert out["all-gather"] == pytest.approx(32 * 16 * 4 * 3 / 4)
        assert "total" in out


class TestRoofline:
    def test_terms_and_dominance(self):
        rep = roofline(
            arch="x", shape="train_4k", mesh_name="16x16", chips=256,
            flops_per_dev=V5E.peak_flops,          # exactly 1 s compute
            bytes_per_dev=V5E.hbm_bw / 2,          # 0.5 s memory
            wire_by_kind={"total": V5E.link_bw / 4},  # 0.25 s collective
            model_flops_global=V5E.peak_flops * 256 * 0.5,
        )
        assert rep.t_compute == pytest.approx(1.0)
        assert rep.t_memory == pytest.approx(0.5)
        assert rep.t_collective == pytest.approx(0.25)
        assert rep.dominant == "compute"
        assert rep.useful_flops_ratio == pytest.approx(0.5)
        assert rep.mfu_bound == pytest.approx(0.5)

    def test_model_flops(self):
        assert model_flops(1_000_000, 10, "train") == 6e7
        assert model_flops(1_000_000, 10, "decode") == 2e7
