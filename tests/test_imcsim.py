"""Device-fidelity simulation subsystem: the fidelity-parity contract
(ideal sim == exact digital search, bit for bit, ties included), kernel
== oracle under lossy fidelity, kernel grid == IMC cycle model, seeded
device models, the imc deployment artifact, and noise-aware QAIL
recovering accuracy at the flagship 128x128 point."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EncoderConfig, ImcArrayConfig, ImcSimConfig, MemhdConfig, MemhdModel,
    imc, qail,
)
from repro.imcsim import device as device_lib
from repro.imcsim import (
    imc_accuracy, noise_aware_finetune, recovery_experiment,
    sweep_adc_bits, sweep_fault_rate, sweep_noise_sigma, tile_grid,
)
from repro.kernels import ops, ref
from repro.kernels.am_search_imc import imc_cycles_for

RNG = np.random.default_rng(11)


def bipolar(shape):
    return jnp.asarray(RNG.choice([-1.0, 1.0], size=shape).astype(
        np.float32))


class TestImcSimConfig:
    def test_defaults(self):
        sim = ImcSimConfig()
        assert sim.clip == 128.0         # arr.rows
        assert sim.adc_step == 256.0 / 2 ** 16
        assert sim.ideal

    def test_validation(self):
        with pytest.raises(ValueError):
            ImcSimConfig(adc_bits=0)
        with pytest.raises(ValueError):
            ImcSimConfig(noise_sigma=-1.0)
        with pytest.raises(ValueError):
            ImcSimConfig(fault_p0=0.7, fault_p1=0.7)
        with pytest.raises(ValueError):
            ImcSimConfig(adc_clip=0.0)

    def test_not_ideal_when_perturbed(self):
        assert not ImcSimConfig(noise_sigma=0.1).ideal
        assert not ImcSimConfig(fault_p0=0.1).ideal
        assert not ImcSimConfig(drift_sigma=0.1).ideal

    def test_hashable_static_jit_arg(self):
        assert hash(ImcSimConfig()) == hash(ImcSimConfig())
        assert ImcSimConfig() != ImcSimConfig(adc_bits=8)


class TestAdcQuantize:
    def test_identity_on_integers_when_step_le_1(self):
        # 2*clip/2^bits <= 1: every integer partial sum is a code.
        x = jnp.asarray(np.arange(-128, 129, dtype=np.float32))
        for bits in (8, 12, 16):
            np.testing.assert_array_equal(
                np.asarray(ref.adc_quantize(x, bits, 128.0)),
                np.asarray(x))

    def test_coarse_quantization_snaps_to_codes(self):
        x = jnp.asarray(np.linspace(-128, 128, 257, dtype=np.float32))
        q = np.asarray(ref.adc_quantize(x, 3, 128.0))
        step = 256.0 / 8
        assert set(np.unique(q)) <= set(np.arange(-128, 129, step))

    def test_clipping(self):
        x = jnp.asarray([-1e4, 1e4, 0.0], dtype=jnp.float32)
        q = np.asarray(ref.adc_quantize(x, 8, 128.0))
        np.testing.assert_array_equal(q, [-128.0, 128.0, 0.0])


class TestFidelityParityContract:
    """Ideal sim (>=16-bit ADC, zero noise/faults/drift) == am_search,
    bit for bit: indices, similarities, and tie-breaks."""

    @pytest.mark.parametrize("b,d,c", [
        (1, 128, 128), (8, 128, 128), (3, 256, 64), (5, 512, 300),
        (2, 130, 257), (7, 120, 26), (300, 64, 26), (4, 9, 3),
    ])
    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_bit_exact_with_digital_search(self, b, d, c, use_kernel):
        q, am = bipolar((b, d)), bipolar((c, d))
        sim = ImcSimConfig(adc_bits=16)
        gi, gs = ops.am_search_imc(q, am, sim=sim, use_kernel=use_kernel)
        ui, us = ops.am_search(q, am)
        wi, ws = ref.am_search(q, am.T)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(ui))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(us))
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))

    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_tie_breaking_first_wins(self, use_kernel):
        # Duplicate centroids force ties across C-tile boundaries.
        q = bipolar((4, 128))
        am = jnp.concatenate([bipolar((1, 128))] * 150, axis=0)
        gi, _ = ops.am_search_imc(q, am, sim=ImcSimConfig(),
                                  use_kernel=use_kernel)
        assert np.all(np.asarray(gi) == 0)

    def test_eight_bit_adc_already_exact_at_128(self):
        # step = 2*128/2^8 = 1: integer partial sums are codes.
        q, am = bipolar((6, 128)), bipolar((90, 128))
        gi, gs = ops.am_search_imc(q, am, sim=ImcSimConfig(adc_bits=8))
        ui, us = ops.am_search(q, am)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(ui))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(us))

    def test_non_square_array_geometry(self):
        arr = ImcArrayConfig(rows=64, cols=32)
        sim = ImcSimConfig(arr=arr, adc_bits=16)
        q, am = bipolar((3, 200)), bipolar((70, 200))
        gi, gs = ops.am_search_imc(q, am, sim=sim)
        ui, us = ops.am_search(q, am)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(ui))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(us))


class TestLossyKernelOracleParity:
    """Kernel and pure-jnp oracle agree bit for bit under every
    perturbation the ADC path models."""

    @pytest.mark.parametrize("b,d,c,bits", [
        (6, 300, 40, 4), (2, 128, 128, 3), (5, 130, 257, 5),
        (3, 64, 26, 2),
    ])
    def test_quantized_with_offsets(self, b, d, c, bits):
        sim = ImcSimConfig(adc_bits=bits, noise_sigma=0.3, fault_p0=0.02,
                           fault_p1=0.02, drift_sigma=0.5, seed=3)
        q, am = bipolar((b, d)), bipolar((c, d))
        am_p, off = device_lib.perturb_am(jax.random.key(3), am, sim)
        assert off is not None
        gi, gs = ops.am_search_imc(q, am_p, sim=sim, offsets=off)
        ri, rs = ops.am_search_imc(q, am_p, sim=sim, offsets=off,
                                   use_kernel=False)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(rs))

    def test_coarse_adc_changes_results(self):
        # 2-bit ADC must actually distort similarities (sanity check
        # that the fidelity knob does something).
        q, am = bipolar((16, 128)), bipolar((128, 128))
        _, gs = ops.am_search_imc(q, am, sim=ImcSimConfig(adc_bits=2))
        _, us = ops.am_search(q, am)
        assert not np.array_equal(np.asarray(gs), np.asarray(us))

    def test_offsets_shape_validated(self):
        q, am = bipolar((2, 128)), bipolar((128, 128))
        with pytest.raises(ValueError):
            ops.am_search_imc(q, am, sim=ImcSimConfig(),
                              offsets=jnp.zeros((3, 3)))


class TestGridContract:
    """Kernel geometry == IMC cycle model, any array shape."""

    def test_one_shot_for_paper_flagship(self):
        assert imc_cycles_for((128, 128)) == 1
        assert imc_cycles_for((128, 128)) == \
            imc.map_memhd(128, 128, ImcArrayConfig()).cycles

    @pytest.mark.parametrize("d,c", [
        (128, 128), (512, 128), (1024, 1024), (256, 64), (130, 257),
    ])
    def test_matches_cost_model_128(self, d, c):
        arr = ImcArrayConfig()
        assert imc_cycles_for((d, c), arr.rows, arr.cols) == \
            imc.map_memhd(d, c, arr).cycles
        imc.assert_consistent_sim(d, c, arr)

    @pytest.mark.parametrize("rows,cols", [(64, 64), (64, 32), (256, 128)])
    def test_matches_cost_model_any_array(self, rows, cols):
        arr = ImcArrayConfig(rows=rows, cols=cols)
        for d, c in [(128, 128), (200, 70), (512, 256)]:
            assert imc_cycles_for((d, c), rows, cols) == \
                imc.map_memhd(d, c, arr).cycles
            imc.assert_consistent_sim(d, c, arr)
        assert imc.sim_grid(200, 70, ImcArrayConfig(rows=64, cols=32)) \
            == (4, 3)


class TestDeviceModels:
    def test_seeded_determinism(self):
        am = bipolar((64, 128))
        sim = ImcSimConfig(noise_sigma=0.4, fault_p0=0.05, fault_p1=0.05,
                           drift_sigma=0.2, seed=9)
        a1, o1 = device_lib.perturb_am(jax.random.key(9), am, sim)
        a2, o2 = device_lib.perturb_am(jax.random.key(9), am, sim)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        a3, _ = device_lib.perturb_am(jax.random.key(10), am, sim)
        assert not np.array_equal(np.asarray(a1), np.asarray(a3))

    def test_zero_perturbation_is_identity(self):
        am = bipolar((64, 128))
        out, off = device_lib.perturb_am(jax.random.key(0), am,
                                         ImcSimConfig())
        np.testing.assert_array_equal(np.asarray(out), np.asarray(am))
        assert off is None

    def test_stuck_at_values_and_rate(self):
        am = bipolar((256, 256))
        out = np.asarray(device_lib.stuck_at_faults(
            jax.random.key(1), am, 0.1, 0.1))
        assert set(np.unique(out)) <= {-1.0, 1.0}
        flipped = (out != np.asarray(am)).mean()
        # ~10% of cells flip (half the faults land on matching bits).
        assert 0.05 < flipped < 0.15

    def test_conductance_noise_scale(self):
        am = jnp.ones((128, 128))
        out = np.asarray(device_lib.conductance_noise(
            jax.random.key(2), am, 0.5))
        assert abs((out - 1.0).std() - 0.5) < 0.05

    def test_tile_grid_and_drift(self):
        sim = ImcSimConfig(arr=ImcArrayConfig(rows=64, cols=32),
                           drift_sigma=1.0)
        grid = tile_grid(200, 70, sim)
        assert grid == (4, 3)
        off = device_lib.tile_drift(jax.random.key(0), grid, 1.0)
        assert off.shape == grid
        assert np.any(np.asarray(off) != 0)

    def test_device_instance_key_matches_deploy_split(self):
        k = jax.random.key(5)
        k_cells, _ = jax.random.split(k)
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(
                device_lib.device_instance_key(ImcSimConfig(seed=5)))),
            np.asarray(jax.random.key_data(k_cells)))


@pytest.fixture(scope="module")
def trained(small_hdc_data):
    """Flagship-geometry (128x128) model trained on the shared dataset."""
    ds = small_hdc_data
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=128, classes=ds.classes, epochs=6,
                      kmeans_iters=10, lr=0.02)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
    return ds, m


class TestImcDeployment:
    def test_ideal_sim_bit_exact_with_digital(self, trained):
        ds, m = trained
        dep = m.deploy(target="imc", sim=ImcSimConfig())
        np.testing.assert_array_equal(
            np.asarray(dep.predict(ds.test_x)),
            np.asarray(m.predict(ds.test_x)))
        assert dep.score(ds.test_x, ds.test_y) == \
            m.score(ds.test_x, ds.test_y)

    def test_default_sim_is_ideal(self, trained):
        _, m = trained
        dep = m.deploy(target="imc")
        assert dep.sim.ideal and dep.tile_offsets is None

    def test_flagship_one_shot_cycles(self, trained):
        _, m = trained
        dep = m.deploy(target="imc")
        assert dep.cycles == 1
        assert dep.cycles == dep.imc_cost().am.cycles

    def test_same_seed_same_device(self, trained):
        ds, m = trained
        sim = ImcSimConfig(noise_sigma=0.5, fault_p0=0.02, seed=13)
        p1 = np.asarray(m.deploy(target="imc", sim=sim).predict(
            ds.test_x[:64]))
        p2 = np.asarray(m.deploy(target="imc", sim=sim).predict(
            ds.test_x[:64]))
        np.testing.assert_array_equal(p1, p2)

    def test_is_a_pytree(self, trained):
        _, m = trained
        dep = m.deploy(target="imc",
                       sim=ImcSimConfig(drift_sigma=0.1, seed=2))
        leaves = jax.tree_util.tree_leaves(dep)
        rebuilt = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(dep), leaves)
        assert rebuilt.sim == dep.sim
        np.testing.assert_array_equal(np.asarray(rebuilt.am_analog),
                                      np.asarray(dep.am_analog))

    def test_bad_target_and_sim_combos(self, trained):
        _, m = trained
        with pytest.raises(ValueError):
            m.deploy(target="fpga")
        with pytest.raises(ValueError):
            m.deploy(target="digital", sim=ImcSimConfig())

    def test_noise_degrades_accuracy(self, trained):
        ds, m = trained
        clean = imc_accuracy(m, ds.test_x, ds.test_y, ImcSimConfig())
        noisy = imc_accuracy(
            m, ds.test_x, ds.test_y,
            ImcSimConfig(noise_sigma=1.5, seed=7))
        assert noisy < clean

    def test_score_traces_once_on_ragged_set(self, trained):
        """Regression: ``score`` routes through the padded batched
        evaluator, so a ragged tail batch must NOT retrace/recompile
        the predict path — every batch it issues has ONE shape."""
        ds, m = trained
        dep = m.deploy(target="imc")
        n, batch = 77, 32  # 77 = 2 full batches + a ragged 13-row tail
        traces = []
        inner = type(dep).predict

        @jax.jit
        def counting_predict(feats):
            traces.append(feats.shape)  # runs only when (re)tracing
            return inner(dep, feats)

        dep.predict = counting_predict  # instance shadows the method
        acc = dep.score(ds.test_x[:n], ds.test_y[:n], batch=batch)
        assert len(traces) == 1, f"retraced: {traces}"
        assert traces[0] == (batch, ds.test_x.shape[1])
        want = float(np.mean(np.asarray(m.predict(ds.test_x[:n]))
                             == np.asarray(ds.test_y[:n])))
        assert acc == pytest.approx(want)


class TestRobustnessSweeps:
    def test_sweep_rows(self, trained):
        ds, m = trained
        rows = sweep_adc_bits(m, ds.test_x, ds.test_y, bits=(16, 2))
        assert [r["adc_bits"] for r in rows] == [16, 2]
        assert rows[0]["accuracy"] >= rows[1]["accuracy"]
        rows = sweep_noise_sigma(m, ds.test_x, ds.test_y,
                                 sigmas=(0.0, 2.0))
        assert rows[0]["accuracy"] > rows[1]["accuracy"]
        rows = sweep_fault_rate(m, ds.test_x, ds.test_y, rates=(0.0, 0.3))
        assert rows[0]["accuracy"] > rows[1]["accuracy"]

    def test_report_is_jsonable(self, trained):
        import json
        ds, m = trained
        from repro.imcsim import robustness_report
        rep = robustness_report(m, ds.test_x[:80], ds.test_y[:80],
                                adc_bits=(16,), noise_sigmas=(0.0,),
                                fault_rates=(0.0,))
        text = json.loads(json.dumps(rep))
        assert text["geometry"] == "128x128"
        assert text["cycles"] == 1
        assert text["base_sim_accuracy"] == text["digital_accuracy"]


class TestNoiseAwareQail:
    def test_noise_key_required(self, trained):
        _, m = trained
        sim = ImcSimConfig(noise_sigma=0.5)
        h = jnp.zeros((4, 128))
        hb, qb, yb, mask = qail.prebatch(h, h, jnp.zeros(4, jnp.int32), 4)
        with pytest.raises(ValueError, match="noise_key"):
            qail.qail_epoch_scan(m.am_state, m.am_cfg, hb, qb, yb, mask,
                                 sim=sim)

    def test_fixed_mode_is_deterministic(self, trained):
        ds, m = trained
        sim = ImcSimConfig(noise_sigma=0.5, seed=3)
        t1, _ = noise_aware_finetune(m, jax.random.key(2), ds.train_x,
                                     ds.train_y, sim, epochs=2)
        t2, _ = noise_aware_finetune(m, jax.random.key(2), ds.train_x,
                                     ds.train_y, sim, epochs=2)
        np.testing.assert_array_equal(
            np.asarray(t1.am_state["binary"]),
            np.asarray(t2.am_state["binary"]))

    def test_noise_changes_training(self, trained):
        ds, m = trained
        sim = ImcSimConfig(noise_sigma=1.0, seed=3)
        noisy, _ = noise_aware_finetune(m, jax.random.key(2), ds.train_x,
                                        ds.train_y, sim, epochs=2)
        clean, _ = m.fit(jax.random.key(2), ds.train_x, ds.train_y,
                         init_method="keep", epochs=2)
        assert not np.array_equal(np.asarray(noisy.am_state["fp"]),
                                  np.asarray(clean.am_state["fp"]))

    def test_keep_init_keeps_am(self, trained):
        ds, m = trained
        kept, hist = m.fit(jax.random.key(2), ds.train_x, ds.train_y,
                           init_method="keep", epochs=0)
        assert hist["init"] == []
        np.testing.assert_array_equal(np.asarray(kept.am_state["fp"]),
                                      np.asarray(m.am_state["fp"]))

    def test_storage_noise_free_sim_rejected(self, trained):
        # A sim whose only non-ideality is the ADC (or drift) would make
        # the "noise-aware" fine-tune a silent no-op — it must raise.
        ds, m = trained
        with pytest.raises(ValueError, match="no-op"):
            noise_aware_finetune(m, jax.random.key(2), ds.train_x,
                                 ds.train_y, ImcSimConfig(adc_bits=3),
                                 epochs=1)

    def test_sequential_mode_rejects_noise(self, trained):
        ds, m = trained
        with pytest.raises(ValueError):
            m.fit(jax.random.key(2), ds.train_x, ds.train_y,
                  mode="sequential", noise_sim=ImcSimConfig(noise_sigma=1))

    def test_fresh_mode_runs(self, trained):
        ds, m = trained
        sim = ImcSimConfig(noise_sigma=0.5, seed=3)
        tuned, _ = noise_aware_finetune(m, jax.random.key(2), ds.train_x,
                                        ds.train_y, sim, epochs=1,
                                        noise_mode="fresh")
        assert tuned.am_state["binary"].shape == (128, 128)


class TestNoiseAwareRecovery:
    """The acceptance contract: at the flagship 128x128 point, under the
    documented setting (conductance sigma 0.5, 16-bit ADC, device seed
    7), chip-in-the-loop noise-aware QAIL recovers >= half the accuracy
    the analog readout lost."""

    def test_recovers_half_the_loss(self, trained):
        ds, m = trained
        sim = ImcSimConfig(noise_sigma=0.5, seed=7)
        rep = recovery_experiment(
            m, jax.random.key(2), ds.train_x, ds.train_y,
            ds.test_x, ds.test_y, sim, epochs=10)
        assert rep["lost"] > 0.05, rep          # the setting really hurts
        assert rep["recovered_frac"] >= 0.5, rep
        assert rep["noisy_accuracy_after"] <= rep["digital_accuracy"] + 0.05
