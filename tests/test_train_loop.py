"""Fault-tolerance integration: loss decreases; kill/restart resumes."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_train(ckpt_dir: str, steps: int, fail_at: int = -1,
               arch: str = "mamba2-130m",
               ) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = f"""
import json
from repro.launch.train import TrainRunConfig, run
cfg = TrainRunConfig(arch={arch!r}, smoke=True, steps={steps},
                     seq_len=64, global_batch=2, ckpt_dir={ckpt_dir!r},
                     ckpt_every=5, fail_at_step={fail_at}, log_every=100)
print("RESULT:" + json.dumps(run(cfg)))
"""
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=560)


def _result(proc) -> dict:
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(
        f"no RESULT in stdout\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")


def test_loss_decreases(tmp_path):
    proc = _run_train(str(tmp_path / "run"), steps=25)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = _result(proc)
    assert out["last_loss"] < out["first_loss"]


def test_crash_and_resume_bit_exact(tmp_path):
    """A hard kill at step 12 (after a step-10 checkpoint) must resume
    from step 10 and finish with the same final state as an uninterrupted
    run (identical data stream + deterministic updates)."""
    d_crash = str(tmp_path / "crash")
    d_clean = str(tmp_path / "clean")

    p1 = _run_train(d_crash, steps=20, fail_at=12)
    assert p1.returncode == 42  # injected hard death
    p2 = _run_train(d_crash, steps=20)  # auto-resume
    assert p2.returncode == 0, p2.stderr[-2000:]
    out2 = _result(p2)
    assert out2["resumed_from"] == 10  # newest checkpoint before death

    p3 = _run_train(d_clean, steps=20)
    out3 = _result(p3)
    assert abs(out2["last_loss"] - out3["last_loss"]) < 1e-5, \
        (out2["last_loss"], out3["last_loss"])


def test_memhd_miss_decreases(tmp_path):
    """QAIL under the driver: the train miss rate drops over epochs."""
    proc = _run_train(str(tmp_path / "run"), steps=8, arch="memhd")
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = _result(proc)
    assert out["last_miss"] < out["first_miss"]
    assert out["eval_acc"] > 0.5


def test_memhd_crash_and_resume_bit_exact(tmp_path):
    """A hard kill at epoch 7 (after an epoch-5 checkpoint) must resume
    from epoch 5 and land on exactly the same binary AM as an
    uninterrupted run (same data stream + deterministic scan epochs) —
    asserted via the sha256 digest of the deployed artifact."""
    d_crash = str(tmp_path / "crash")
    d_clean = str(tmp_path / "clean")

    p1 = _run_train(d_crash, steps=10, fail_at=7, arch="memhd")
    assert p1.returncode == 42  # injected hard death
    p2 = _run_train(d_crash, steps=10, arch="memhd")  # auto-resume
    assert p2.returncode == 0, p2.stderr[-2000:]
    out2 = _result(p2)
    assert out2["resumed_from"] == 5  # newest checkpoint before death

    p3 = _run_train(d_clean, steps=10, arch="memhd")
    out3 = _result(p3)
    assert out2["am_digest"] == out3["am_digest"]
    assert out2["eval_acc"] == out3["eval_acc"]
