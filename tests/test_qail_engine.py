"""Device-resident QAIL training engine: scan epochs, fused kernel,
encode-once fit, checkpointed resume, unified evaluator."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EncoderConfig, MemhdConfig, MemhdModel, qail
from repro.core import am as am_lib
from repro.core import encoding, evaluate as eval_lib
from repro.core.memhd import MemhdTrainState
from repro.kernels import ops, ref


def _random_problem(rng, n, d, c, k):
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    q = jnp.where(h >= 0, 1.0, -1.0)
    y = jnp.asarray(rng.integers(0, k, size=(n,)).astype(np.int32))
    fp = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    owners = jnp.asarray((np.arange(c) % k).astype(np.int32))
    return h, q, y, am_lib.make_am_state(fp, owners)


class TestScanEpoch:
    def test_bit_exact_vs_sequential_at_bs1(self):
        """batch_size=1 + epoch-end refresh == the paper-exact
        sample-by-sample schedule, bit for bit."""
        rng = np.random.default_rng(0)
        n, d, c, k = 97, 64, 16, 4
        h, q, y, state = _random_problem(rng, n, d, c, k)
        cfg = MemhdConfig(dim=d, columns=c, classes=k, lr=0.03,
                          batch_size=1)
        s_seq = qail.qail_epoch_sequential(state, cfg, h, q, y)
        s_scan, _ = qail.qail_epoch_batched(state, cfg, h, q, y,
                                            refresh_every=n)
        np.testing.assert_array_equal(np.asarray(s_seq["fp"]),
                                      np.asarray(s_scan["fp"]))
        np.testing.assert_array_equal(np.asarray(s_seq["binary"]),
                                      np.asarray(s_scan["binary"]))

    @pytest.mark.parametrize("refresh_every", [1, 2, 4])
    def test_tracks_hostloop(self, refresh_every):
        """Scan engine == pre-refactor host loop (fixed semantics),
        including the ragged final batch and mid-epoch refreshes."""
        rng = np.random.default_rng(1)
        n, d, c, k = 101, 32, 12, 3  # 101 % 32 != 0: ragged tail
        h, q, y, state = _random_problem(rng, n, d, c, k)
        cfg = MemhdConfig(dim=d, columns=c, classes=k, lr=0.05,
                          batch_size=32)
        s_hl, mr_hl = qail.qail_epoch_hostloop(
            state, cfg, h, q, y, refresh_every=refresh_every)
        s_sc, mr_sc = qail.qail_epoch_batched(
            state, cfg, h, q, y, refresh_every=refresh_every)
        np.testing.assert_allclose(np.asarray(s_hl["fp"]),
                                   np.asarray(s_sc["fp"]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(s_hl["binary"]),
                                      np.asarray(s_sc["binary"]))
        assert abs(mr_hl - float(mr_sc)) < 1e-6

    def test_no_double_finalize_when_refresh_divides(self, monkeypatch):
        """n_batches % refresh_every == 0 -> the last in-loop refresh IS
        the epoch finalize; the old trailing (redundant) one is gone."""
        calls = {"n": 0}
        orig = qail.qail_finalize_epoch

        def counting(state, cfg):
            calls["n"] += 1
            return orig(state, cfg)

        monkeypatch.setattr(qail, "qail_finalize_epoch", counting)
        rng = np.random.default_rng(2)
        h, q, y, state = _random_problem(rng, 128, 32, 8, 4)
        cfg = MemhdConfig(dim=32, columns=8, classes=4, batch_size=32)
        qail.qail_epoch_hostloop(state, cfg, h, q, y, refresh_every=2)
        assert calls["n"] == 2  # 4 batches / refresh_every=2; NOT 3

        calls["n"] = 0
        qail.qail_epoch_hostloop(state, cfg, h, q, y, refresh_every=3)
        assert calls["n"] == 2  # one at batch 3 + the trailing finalize

    def test_one_dispatch_per_epoch(self):
        """A multi-epoch fit traces the scan-epoch body exactly once and
        never falls back to per-batch python dispatch — the compiled-
        trainer contract (one jit call, one host sync per epoch)."""
        rng = np.random.default_rng(3)
        # Unique geometry so the jit cache can't already hold this shape.
        n, d, c, k = 210, 48, 12, 4
        h, q, y, state = _random_problem(rng, n, d, c, k)
        cfg = MemhdConfig(dim=d, columns=c, classes=k, batch_size=33)
        hb, qb, yb, mask = qail.prebatch(h, q, y, cfg.batch_size)
        before = qail._scan_trace_count
        for _ in range(5):
            state, n_miss = qail.qail_epoch_scan(state, cfg, hb, qb, yb,
                                                 mask)
        assert qail._scan_trace_count - before == 1  # 5 epochs, 1 trace
        assert isinstance(n_miss, jax.Array)  # sync is the caller's call

    def test_prebatch_mask(self):
        h = jnp.ones((5, 4))
        q = jnp.ones((5, 4))
        y = jnp.arange(5, dtype=jnp.int32)
        hb, qb, yb, mask = qail.prebatch(h, q, y, 3)
        assert hb.shape == (2, 3, 4)
        np.testing.assert_array_equal(np.asarray(mask),
                                      [[1, 1, 1], [1, 1, 0]])
        assert int(yb[1, 2]) == -1  # padded label can't match any class


class TestQailUpdateKernel:
    @pytest.mark.parametrize("b,c,d", [(17, 13, 100), (64, 32, 128),
                                       (256, 130, 257), (5, 3, 8),
                                       (33, 128, 512)])
    def test_parity_vs_ref(self, b, c, d):
        rng = np.random.default_rng(b * 1000 + c)
        k = max(2, c // 3)
        q = jnp.asarray(rng.choice([-1., 1.], size=(b, d))
                        .astype(np.float32))
        upd = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
        am_t = jnp.asarray(rng.choice([-1., 1.], size=(d, c))
                           .astype(np.float32))
        owners = jnp.asarray(rng.integers(0, k, size=(c,))
                             .astype(np.int32))
        labels = jnp.asarray(rng.integers(0, k, size=(b,))
                             .astype(np.int32))
        mask = jnp.asarray((rng.random(b) > 0.2).astype(np.float32))
        d_ref, m_ref = ref.qail_update_delta(q, upd, am_t, owners,
                                             labels, mask, 0.05)
        d_k, m_k = ops.qail_update(q, upd, am_t, owners, labels, mask,
                                   lr=0.05)
        np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_ref))
        assert float(m_k) == float(m_ref)

    def test_delta_matches_scatter_path(self):
        """The one-hot-matmul delta == the scatter-based batch update."""
        rng = np.random.default_rng(7)
        n, d, c, k = 64, 32, 16, 4
        h, q, y, state = _random_problem(rng, n, d, c, k)
        cfg = MemhdConfig(dim=d, columns=c, classes=k, lr=0.02,
                          batch_size=n)
        new_state, _ = qail.qail_batch_update(state, cfg, h, q, y)
        scatter_delta = np.asarray(new_state["fp"]) - np.asarray(
            state["fp"])
        mask = jnp.ones((n,), jnp.float32)
        kern_delta, _ = ops.qail_update(
            q, h, state["binary"].T, state["centroid_class"], y, mask,
            lr=cfg.lr)
        np.testing.assert_allclose(np.asarray(kern_delta), scatter_delta,
                                   rtol=1e-5, atol=1e-5)

    def test_scan_epoch_kernel_path(self):
        rng = np.random.default_rng(8)
        n, d, c, k = 100, 64, 16, 4
        h, q, y, state = _random_problem(rng, n, d, c, k)
        cfg = MemhdConfig(dim=d, columns=c, classes=k, lr=0.03,
                          batch_size=32)
        s_jnp, mr_j = qail.qail_epoch_batched(state, cfg, h, q, y)
        s_ker, mr_k = qail.qail_epoch_batched(state, cfg, h, q, y,
                                              use_kernel=True)
        np.testing.assert_allclose(np.asarray(s_jnp["fp"]),
                                   np.asarray(s_ker["fp"]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(s_jnp["binary"]),
                                      np.asarray(s_ker["binary"]))
        assert abs(float(mr_j) - float(mr_k)) < 1e-6


class TestEncodeOnce:
    def test_fit_encodes_training_set_exactly_once(self, small_hdc_data,
                                                   monkeypatch):
        ds = small_hdc_data
        calls = {"n": 0}
        orig = encoding.encode

        def counting(params, cfg, feats):
            calls["n"] += 1
            return orig(params, cfg, feats)

        monkeypatch.setattr(encoding, "encode", counting)
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=128)
        amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                          epochs=3, kmeans_iters=5, batch_size=128)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m.fit(jax.random.key(1), ds.train_x, ds.train_y)
        assert calls["n"] == 1  # init + every epoch share ONE encode


class TestCheckpointedFit:
    def test_resume_is_bit_exact(self, small_hdc_data, tmp_path):
        from repro.checkpoint import CheckpointConfig, CheckpointManager

        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=128)
        amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                          epochs=6, kmeans_iters=5, batch_size=128)
        m = MemhdModel.create(jax.random.key(0), enc, amc)

        m_clean, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)

        ck = CheckpointManager(CheckpointConfig(str(tmp_path / "ck")))
        m.fit(jax.random.key(1), ds.train_x, ds.train_y, epochs=4,
              ckpt=ck, ckpt_every=2)  # "crashes" after epoch 4
        m_res, hist = m.fit(jax.random.key(1), ds.train_x, ds.train_y,
                            epochs=6, ckpt=ck, ckpt_every=2)  # resume
        np.testing.assert_array_equal(np.asarray(m_clean.am_state["fp"]),
                                      np.asarray(m_res.am_state["fp"]))
        np.testing.assert_array_equal(
            np.asarray(m_clean.am_state["binary"]),
            np.asarray(m_res.am_state["binary"]))
        # The restored curve is continuous across the resume.
        assert [r["epoch"] for r in hist["curve"]] == [1, 2, 3, 4, 5, 6]

    def test_train_state_roundtrip(self, tmp_path):
        from repro.checkpoint import CheckpointConfig, CheckpointManager

        state = am_lib.make_am_state(
            jnp.arange(12.0).reshape(4, 3), jnp.arange(4))
        ck = CheckpointManager(CheckpointConfig(str(tmp_path / "ts")))
        ck.save(3, MemhdTrainState.create(state, 3))
        step, tree, _ = ck.restore(MemhdTrainState.create(
            jax.tree.map(jnp.zeros_like, state)))
        assert step == 3
        assert int(tree.epoch) == 3
        np.testing.assert_array_equal(np.asarray(tree.am_state["fp"]),
                                      np.asarray(state["fp"]))


class TestFitSharded:
    def test_matches_plain_fit_on_single_device_mesh(self,
                                                     small_hdc_data):
        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=128)
        amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                          epochs=3, kmeans_iters=5, batch_size=128)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m_fit, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
        m_sh, hist = m.fit_sharded(jax.random.key(1), ds.train_x,
                                   ds.train_y)
        # Sharded syncs Eq.-6 deltas in bf16 (wire dtype), so the float
        # trajectories differ slightly; the deployed binary AM must
        # agree almost everywhere and accuracy must match closely.
        agree = (np.asarray(m_sh.am_state["binary"])
                 == np.asarray(m_fit.am_state["binary"])).mean()
        assert agree > 0.95, agree
        acc_f = m_fit.score(ds.test_x, ds.test_y)
        acc_s = m_sh.score(ds.test_x, ds.test_y)
        assert abs(acc_f - acc_s) < 0.05, (acc_f, acc_s)
        assert len(hist["curve"]) == 3


class TestUnifiedEvaluator:
    def test_ragged_tail_accuracy(self):
        labels = jnp.asarray(np.arange(10) % 3, dtype=jnp.int32)
        inputs = jnp.asarray(np.arange(10, dtype=np.float32))[:, None]
        # predict_fn: correct iff input index is even
        def predict(x):
            i = x[:, 0].astype(jnp.int32)
            return jnp.where(i % 2 == 0, i % 3, (i + 1) % 3)
        acc = eval_lib.batched_accuracy(predict, inputs, labels, batch=4)
        assert acc == 0.5

    def test_padding_never_counts(self):
        labels = jnp.zeros((5,), jnp.int32)
        inputs = jnp.zeros((5, 2))
        acc = eval_lib.batched_accuracy(
            lambda x: jnp.zeros((x.shape[0],), jnp.int32),
            inputs, labels, batch=4)
        assert acc == 1.0  # 5/5, not 8/5 or 5/8

    def test_qail_evaluate_matches_naive(self):
        rng = np.random.default_rng(11)
        _, q, y, state = _random_problem(rng, 101, 32, 12, 3)
        naive = float(np.mean(np.asarray(
            am_lib.predict(state["binary"], state["centroid_class"], q))
            == np.asarray(y)))
        assert qail.evaluate(state, q, y, batch=32) == pytest.approx(naive)

    def test_deployed_score_uses_padded_evaluator(self, small_hdc_data):
        ds = small_hdc_data
        enc = EncoderConfig(kind="projection", features=ds.features,
                            dim=128)
        amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                          epochs=1, kmeans_iters=4, batch_size=128)
        m = MemhdModel.create(jax.random.key(0), enc, amc)
        m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
        dep = m.deploy(packed=True)
        # 150*10 train samples scored with a non-dividing batch: the
        # ragged tail goes through the padded path and must not change
        # the result vs the model-side evaluator.
        acc_m = m.score(ds.test_x, ds.test_y, batch=96)
        acc_d = dep.score(ds.test_x, ds.test_y, batch=96)
        assert acc_m == pytest.approx(acc_d)
