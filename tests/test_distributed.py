"""Multi-device tests (8 fake CPU devices via subprocess).

Covers: ring collectives, shard_map MoE == local MoE, pjit'd train step
on a small mesh, the dry-run path end-to-end on a test mesh, and elastic
checkpoint re-shard (8 -> 4 devices).
"""
import pytest

from tests._multidev import check_multidev

pytestmark = pytest.mark.slow


def test_ring_collectives_match_allreduce():
    check_multidev("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compression import ring_reduce_scatter_int8, ring_all_gather, _BLOCK
from repro.compat import shard_map

mesh = jax.make_mesh((8,), ("pod",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 16, _BLOCK)).astype(np.float32))

def f(gl):
    red = ring_reduce_scatter_int8(gl[0], "pod")
    return ring_all_gather(red, "pod")[None]

out = shard_map(f, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))(g)
want = np.asarray(g.sum(axis=0))
got = np.asarray(out)[3]
rel = np.abs(got - want).max() / np.abs(want).max()
assert rel < 0.05, rel
# all members agree exactly
for i in range(8):
    np.testing.assert_array_equal(np.asarray(out)[i], np.asarray(out)[0])
print("OK")
""")


def test_sharded_moe_matches_local():
    check_multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import layers as L
from repro.models.config import FfnSpec
from repro.models.sharding import ShardingRules, use_rules

spec = FfnSpec(kind="moe", d_ff=64, n_experts=8, n_shared=1, top_k=2,
               d_ff_expert=32, router="softmax", capacity_factor=8.0)
p, _ = L.init_moe_ffn(jax.random.key(0), 64, spec, jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 16, 64))

y_local, aux_local = L._moe_ffn_local(p, spec, x)

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh)
with mesh, use_rules(rules):
    y_sh, aux_sh = jax.jit(lambda pp, xx: L._moe_ffn_sharded(
        pp, spec, xx, rules))(p, x)

# Same routing, same experts -> same outputs (capacity_factor is large
# enough that neither path drops tokens).
np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_sh),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(aux_local["expert_counts"]),
                           np.asarray(aux_sh["expert_counts"]))
print("OK")
""")


def test_pjit_train_step_runs_and_matches_single_device():
    check_multidev("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.distributed.steps import make_train_step
from repro.models import transformer as T
from repro.models.sharding import ShardingRules, param_sharding_tree
from repro.optim import AdamWConfig, ScheduleConfig, make_schedule, adamw_init

cfg = get_smoke_config("qwen1.5-32b")
params, axes = T.init_params(jax.random.key(0), cfg)
opt_cfg = AdamWConfig(lr=1e-3)
opt = adamw_init(params, opt_cfg)
sched = make_schedule(ScheduleConfig(warmup_steps=1, total_steps=10))
toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "targets": toks}

# Single-device reference.
step1 = jax.jit(make_train_step(cfg, opt_cfg, sched))
p1, o1, m1 = step1(params, opt, batch, jnp.asarray(0, jnp.int32))

# 2x4 mesh pjit.
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh, fsdp=True)
p_sh = param_sharding_tree(axes, rules, params)
with mesh:
    step8 = jax.jit(make_train_step(cfg, opt_cfg, sched, rules),
                    in_shardings=(p_sh, {"m": p_sh, "v": p_sh,
                                         "step": NamedSharding(mesh, P())},
                                  {"tokens": NamedSharding(mesh, P("data", None)),
                                   "targets": NamedSharding(mesh, P("data", None))},
                                  NamedSharding(mesh, P())),
                    out_shardings=(p_sh, None, None))
    p8, o8, m8 = step8(params, opt, batch, jnp.asarray(0, jnp.int32))

assert abs(float(m1["loss"]) - float(m8["loss"])) < 1e-3, (m1["loss"], m8["loss"])
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-3)
print("OK")
""")


def test_dryrun_cell_on_test_mesh():
    """The full dry-run path (abstract state, shardings, lower, compile,
    roofline extraction) on a 2x4 mesh with a smoke config."""
    check_multidev("""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.distributed import hlo_cost
from repro.distributed.steps import abstract_train_state, make_train_step
from repro.models.sharding import ShardingRules, param_sharding_tree
from repro.optim import AdamWConfig, ScheduleConfig, make_schedule

cfg = get_smoke_config("deepseek-v2-lite-16b")
cfg = dataclasses.replace(cfg, remat=True)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh, fsdp=True)
opt_cfg = AdamWConfig()
params_sds, opt_sds, axes = abstract_train_state(cfg, opt_cfg)
p_sh = param_sharding_tree(axes, rules, params_sds)
batch_sds = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "targets": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
b_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch_sds}
sched = make_schedule(ScheduleConfig())
with mesh:
    step = jax.jit(make_train_step(cfg, opt_cfg, sched, rules),
                   in_shardings=(p_sh, {"m": p_sh, "v": p_sh,
                                        "step": NamedSharding(mesh, P())},
                                 b_sh, NamedSharding(mesh, P())),
                   out_shardings=(p_sh, None, None))
    lowered = step.lower(params_sds, opt_sds, batch_sds,
                         jax.ShapeDtypeStruct((), jnp.int32))
    compiled = lowered.compile()
ma = compiled.memory_analysis()
assert ma.argument_size_in_bytes > 0
tot = hlo_cost.analyze(compiled.as_text(), 8)
assert tot.flops > 0
assert tot.wire_bytes > 0  # sharded model must communicate
print("OK", tot.flops, tot.wire_bytes)
""")


def test_elastic_checkpoint_reshard():
    """Save on an '8-chip' mesh, restore onto a '4-chip' mesh."""
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        check_multidev(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointConfig, CheckpointManager

mesh = jax.make_mesh((8,), ("model",))
w = jnp.arange(64.0).reshape(8, 8)
w = jax.device_put(w, NamedSharding(mesh, P("model", None)))
mgr = CheckpointManager(CheckpointConfig({d!r}))
mgr.save(1, {{"w": w}})
print("SAVED")
""", n_devices=8)
        check_multidev(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointConfig, CheckpointManager

mesh = jax.make_mesh((4,), ("model",))
mgr = CheckpointManager(CheckpointConfig({d!r}))
step, tree, _ = mgr.restore({{"w": jnp.zeros((8, 8))}})
assert step == 1
w = jax.device_put(tree["w"], NamedSharding(mesh, P("model", None)))
np.testing.assert_array_equal(np.asarray(w),
                              np.arange(64.0).reshape(8, 8))
print("RESHARDED OK")
""", n_devices=4)


def test_distributed_memhd_qail_matches_single_device():
    check_multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import EncoderConfig, MemhdConfig, MemhdModel, qail
from repro.core.distributed import fit_distributed
from repro.data import load_dataset

ds = load_dataset("mnist", train_per_class=40, test_per_class=10)
enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
amc = MemhdConfig(dim=128, columns=32, classes=ds.classes, epochs=2,
                  kmeans_iters=5, lr=0.02)
m = MemhdModel.create(jax.random.key(0), enc, amc)
m, _ = m.initialize_am(jax.random.key(1), ds.train_x, ds.train_y)

# Single-device reference: batched QAIL with one full-dataset batch.
h = m.encode(ds.train_x); q = jnp.where(h >= 0, 1.0, -1.0)
state = m.am_state
for _ in range(2):
    state, _ = qail.qail_batch_update(state, amc, h, q, ds.train_y)
    state = qail.qail_finalize_epoch(state, amc)

mesh = jax.make_mesh((2, 4), ("data", "model"))
m2 = fit_distributed(mesh, m, ds.train_x, ds.train_y, epochs=2)

# The distributed epoch syncs Eq.-6 deltas in bf16 (EXPERIMENTS §Perf Q2),
# so agreement is to bf16-delta precision, not bit-exact.
# (Eq.-4/5 argmax targets may flip for borderline samples after the
# first epoch's rounding, so the float trajectories diverge slightly
# beyond pure rounding — and by a run-dependent amount, since CPU
# scatter-add ordering is nondeterministic. The float check is a loose
# sanity bound; the assertion with teeth is on the binary AM — the
# artifact that actually deploys.)
fp_a, fp_b = np.asarray(state["fp"]), np.asarray(m2.am_state["fp"])
scale = np.abs(fp_a).max()
assert np.abs(fp_a - fp_b).max() < 0.15 * scale, \
    np.abs(fp_a - fp_b).max() / scale
bin_agree = (np.asarray(state["binary"])
             == np.asarray(m2.am_state["binary"])).mean()
assert bin_agree > 0.99, bin_agree
print("OK distributed QAIL == single-device QAIL (bf16 sync tolerance)")
""")


def test_memhd_fit_sharded_matches_single_device():
    """fit_sharded (shard_map scan epochs, bf16 delta wire) vs plain
    fit on one device: same init, same schedule — the deployed binary
    AM must agree almost everywhere and accuracy must match."""
    check_multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import EncoderConfig, MemhdConfig, MemhdModel
from repro.data import load_dataset

ds = load_dataset("mnist", train_per_class=40, test_per_class=10)
enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
amc = MemhdConfig(dim=128, columns=32, classes=ds.classes, epochs=3,
                  kmeans_iters=5, lr=0.02, batch_size=128)
m = MemhdModel.create(jax.random.key(0), enc, amc)
m_fit, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)

mesh = jax.make_mesh((8,), ("data",))
m_sh, hist = m.fit_sharded(jax.random.key(1), ds.train_x, ds.train_y,
                           mesh=mesh)
agree = (np.asarray(m_sh.am_state["binary"])
         == np.asarray(m_fit.am_state["binary"])).mean()
assert agree > 0.95, agree
acc_f = m_fit.score(ds.test_x, ds.test_y)
acc_s = m_sh.score(ds.test_x, ds.test_y)
assert abs(acc_f - acc_s) < 0.08, (acc_f, acc_s)
assert len(hist["curve"]) == 3
print("OK fit_sharded binary agreement", agree)
""")


def test_memhd_dryrun_epoch_on_test_mesh():
    check_multidev("""
import jax
from repro.core.distributed import dryrun_epoch
mesh = jax.make_mesh((2, 4), ("data", "model"))
rep = dryrun_epoch(mesh, n_samples=512, dim=256, columns=256)
r = rep["roofline"]
assert r["flops_per_dev"] > 0 and r["useful_flops_ratio"] > 0.2, r
print("OK", r["dominant"], r["useful_flops_ratio"])
""")


def test_seq_parallel_flash_decode_matches_reference():
    check_multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import layers as L
from repro.models.config import AttnSpec
from repro.models.sharding import ShardingRules, use_rules

spec = AttnSpec(kind="gqa", n_heads=8, n_kv_heads=2, head_dim=16)
d = 64
p, _ = L.init_gqa(jax.random.key(0), d, spec, jnp.float32)
B, S = 4, 64
cache = L.init_gqa_cache(spec, B, S, jnp.float32)
xs = jax.random.normal(jax.random.key(1), (B, S, d))

c_ref = cache
for t in range(8):
    y_ref, c_ref = L.gqa_decode(p, spec, xs[:, t:t+1], c_ref)

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh, shard_seq=True)
c_sp = cache
with mesh, use_rules(rules):
    f = jax.jit(lambda pp, xx, cc: L.gqa_decode(pp, spec, xx, cc,
                                                seq_parallel=True))
    for t in range(8):
        y_sp, c_sp = f(p, xs[:, t:t+1], c_sp)

np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sp),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(c_ref["k"]), np.asarray(c_sp["k"]),
                           rtol=1e-5, atol=1e-5)
print("OK")
""")
