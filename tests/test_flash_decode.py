"""flash_decode Pallas kernel vs the jnp attention_decode reference."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import BLOCK, flash_decode
from repro.models.layers import _repeat_kv, attention_decode

RNG = np.random.default_rng(7)


def _case(b, s, h, kv, dh, valid, dtype=np.float32):
    q = jnp.asarray(RNG.normal(size=(b, h, dh)).astype(dtype))
    k = jnp.asarray(RNG.normal(size=(b, s, kv, dh)).astype(dtype))
    v = jnp.asarray(RNG.normal(size=(b, s, kv, dh)).astype(dtype))
    lens = jnp.asarray(valid, jnp.int32)
    return q, k, v, lens


class TestFlashDecode:
    @pytest.mark.parametrize("b,s,h,kv,dh", [
        (2, 256, 8, 2, 64),     # GQA 4x
        (1, 384, 4, 4, 128),    # MHA
        (3, 130, 6, 1, 32),     # MQA, ragged S
        (2, 128, 16, 8, 64),    # exactly one block
    ])
    def test_matches_reference(self, b, s, h, kv, dh):
        q, k, v, _ = _case(b, s, h, kv, dh, [s] * b)
        lens = jnp.full((b,), s, jnp.int32)
        got = flash_decode(q, k, v, lens)
        want = attention_decode(q[:, None], _repeat_kv(k, h // kv),
                                _repeat_kv(v, h // kv), lens)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)

    def test_ragged_lengths_per_row(self):
        b, s, h, kv, dh = 3, 256, 4, 2, 64
        q, k, v, lens = _case(b, s, h, kv, dh, [17, 200, 256])
        got = flash_decode(q, k, v, lens)
        want = attention_decode(q[:, None], _repeat_kv(k, h // kv),
                                _repeat_kv(v, h // kv), lens)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)

    def test_single_valid_token(self):
        b, s, h, kv, dh = 1, BLOCK, 2, 2, 32
        q, k, v, _ = _case(b, s, h, kv, dh, [s])
        lens = jnp.asarray([1], jnp.int32)
        got = flash_decode(q, k, v, lens)
        # Attention over one key == that key's value.
        np.testing.assert_allclose(np.asarray(got[0, 0]),
                                   np.asarray(v[0, 0, 0]), rtol=2e-5,
                                   atol=2e-5)

    def test_bf16_inputs(self):
        b, s, h, kv, dh = 2, 256, 4, 2, 64
        q, k, v, _ = _case(b, s, h, kv, dh, [s] * b, dtype=np.float32)
        q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))
        lens = jnp.full((b,), s, jnp.int32)
        got = flash_decode(q, k, v, lens)
        want = attention_decode(
            q[:, None].astype(jnp.float32),
            _repeat_kv(k, h // kv).astype(jnp.float32),
            _repeat_kv(v, h // kv).astype(jnp.float32), lens)[:, 0]
        np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                                   np.asarray(want), rtol=2e-2, atol=2e-2)
