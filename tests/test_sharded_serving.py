"""Multi-device sharded serving: ShardedArtifact parity (every backend),
ragged-tail masking, serve_batches integration + report fields, and the
8-forced-device bit-exactness contract (subprocess)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.deploy import ShardedArtifact, serving_mesh

from _multidev import check_multidev


def _random_model(features=24, dim=128, columns=48, classes=10, seed=0):
    """An untrained model with a random AM — serving needs no fit."""
    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    from repro.core import am as am_lib
    enc = EncoderConfig(kind="projection", features=features, dim=dim)
    amc = MemhdConfig(dim=dim, columns=columns, classes=classes)
    m = MemhdModel.create(jax.random.key(seed), enc, amc)
    rng = np.random.default_rng(seed)
    fp = jnp.asarray(rng.normal(size=(columns, dim)).astype(np.float32))
    owners = jnp.asarray(np.arange(columns) % classes, np.int32)
    state = am_lib.make_am_state(fp, owners, amc.threshold)
    return dataclasses.replace(m, am_state=state)


@pytest.fixture(scope="module")
def model():
    return _random_model()


@pytest.fixture(scope="module")
def feats():
    rng = np.random.default_rng(3)
    return rng.normal(size=(53, 24)).astype(np.float32)  # ragged: 53


class TestShardedWrapper:
    """In-process checks on a 1-device mesh (the real multi-device
    parity runs in the subprocess tests below)."""

    @pytest.mark.parametrize("target", ["packed", "unpacked", "imc",
                                        "multibit"])
    def test_parity_every_backend(self, model, feats, target):
        dep = model.deploy(target=target)
        sh = ShardedArtifact(dep, devices=1)
        want = np.asarray(dep.predict(feats))
        np.testing.assert_array_equal(np.asarray(sh.predict(feats)),
                                      want)
        np.testing.assert_array_equal(
            np.asarray(sh.predict_features(feats)), want)

    def test_predict_topk_parity_hierarchical(self, model, feats):
        dep = model.deploy(target="hierarchical")
        sh = ShardedArtifact(dep, devices=1)
        want = dep.predict_topk(feats, 3)
        got = sh.predict_topk(feats, 3)
        for g, w in zip(got, want):  # (classes, ids, sims) triple
            assert g.shape == (feats.shape[0], 3)
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        # Ragged batches mask the padded tail rows of every leaf.
        cls, idx, sims = sh.predict_topk(feats[:5], 2)
        assert cls.shape == idx.shape == sims.shape == (5, 2)

    def test_non_f32_parity(self, model, feats):
        """The wrapper must hand the shard_map the caller's dtype:
        the old ``np.asarray(feats, np.float32)`` silently upcast f16
        queries, so sharded and single-device paths saw different
        inputs (and every non-f32 caller paid a hidden cast)."""
        dep = model.deploy(target="packed")
        sh = ShardedArtifact(dep, devices=1)
        for dtype in (np.float16, np.float64):
            x = feats.astype(dtype)
            np.testing.assert_array_equal(
                np.asarray(sh.predict(x)),
                np.asarray(dep.predict(x)))

    def test_ragged_rows_masked(self, model, feats):
        # Any batch size — including one not divisible by the mesh —
        # returns exactly n predictions (pad rows are dropped).
        dep = model.deploy(target="packed")
        sh = ShardedArtifact(dep, devices=1)
        for n in (1, 7, 8, 13):
            assert sh.predict(feats[:n]).shape == (n,)

    def test_predict_query_and_score(self, model, feats):
        dep = model.deploy(target="packed")
        sh = ShardedArtifact(dep, devices=1)
        q = model.encode_query(feats)
        np.testing.assert_array_equal(
            np.asarray(sh.predict_query(q)),
            np.asarray(dep.predict_query(q)))
        labels = np.asarray(model.predict(feats))
        assert sh.score(feats, labels) == 1.0

    def test_protocol_delegation(self, model):
        dep = model.deploy(target="packed")
        sh = ShardedArtifact(dep, devices=1)
        assert sh.backend == "packed"
        assert sh.serving_mode == dep.serving_mode
        assert sh.resident_am_bytes == dep.resident_am_bytes
        assert sh.am_cfg == dep.am_cfg
        assert sh.n_devices == 1 and sh.row_multiple == 1
        with pytest.raises(TypeError, match="already sharded"):
            ShardedArtifact(sh, devices=1)

    def test_mesh_validation(self, model):
        with pytest.raises(ValueError, match="devices"):
            serving_mesh(n=len(jax.devices()) + 1)

    def test_serve_batches_and_report(self, model, feats):
        from repro.launch.serve_memhd import (Request, build_report,
                                              serve_batches,
                                              synthetic_requests)
        dep = model.deploy(target="packed")
        sh = ShardedArtifact(dep, devices=1)
        reqs = synthetic_requests(feats, n_requests=6, max_size=9,
                                  seed=1)
        plain, _ = serve_batches(dep, reqs, max_batch=24)
        shard, stats = serve_batches(sh, reqs, max_batch=24)
        assert plain.keys() == shard.keys()
        for rid in plain:
            np.testing.assert_array_equal(plain[rid], shard[rid])
        rep = build_report(sh, reqs, stats, wall_s=0.5)
        assert rep["devices"] == 1 and rep["backend"] == "packed"
        del Request  # imported for the namespace check only


_SUBPROCESS_PARITY = r"""
import dataclasses
import jax, numpy as np
import jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from repro.core import EncoderConfig, MemhdConfig, MemhdModel
from repro.core import am as am_lib
from repro.deploy import ShardedArtifact

enc = EncoderConfig(kind="projection", features=24, dim=128)
amc = MemhdConfig(dim=128, columns=48, classes=10)
m = MemhdModel.create(jax.random.key(0), enc, amc)
rng = np.random.default_rng(0)
fp = jnp.asarray(rng.normal(size=(48, 128)).astype(np.float32))
owners = jnp.asarray(np.arange(48) % 10, np.int32)
m = dataclasses.replace(
    m, am_state=am_lib.make_am_state(fp, owners, amc.threshold))
x = rng.normal(size=(83, 24)).astype(np.float32)  # 83 % 8 != 0

for target, opts in (("packed", {}), ("imc", {}),
                     ("multibit", {"cell_bits": 2}),
                     ("multibit", {"cell_bits": 4})):
    dep = m.deploy(target=target, **opts)
    want = np.asarray(dep.predict(x))
    sh = ShardedArtifact(dep, devices=8)
    assert sh.n_devices == 8
    got = np.asarray(sh.predict(x))
    assert got.shape == want.shape
    assert (got == want).all(), target
    got_f = np.asarray(sh.predict_features(x))
    assert (got_f == want).all(), target
print("SHARDED_PARITY_OK")
"""

_SUBPROCESS_SERVE = r"""
import dataclasses
import jax, numpy as np
import jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from repro.core import EncoderConfig, MemhdConfig, MemhdModel
from repro.core import am as am_lib
from repro.deploy import ShardedArtifact
from repro.launch.serve_memhd import (build_report, serve_batches,
                                      synthetic_requests)

enc = EncoderConfig(kind="projection", features=24, dim=128)
amc = MemhdConfig(dim=128, columns=48, classes=10)
m = MemhdModel.create(jax.random.key(0), enc, amc)
rng = np.random.default_rng(0)
fp = jnp.asarray(rng.normal(size=(48, 128)).astype(np.float32))
owners = jnp.asarray(np.arange(48) % 10, np.int32)
m = dataclasses.replace(
    m, am_state=am_lib.make_am_state(fp, owners, amc.threshold))
pool = rng.normal(size=(200, 24)).astype(np.float32)
reqs = synthetic_requests(pool, n_requests=11, max_size=9, seed=7)

dep = m.deploy(target="packed")
sh = ShardedArtifact(dep, devices=8)
plain, _ = serve_batches(dep, reqs, max_batch=32)
shard, stats = serve_batches(sh, reqs, max_batch=32, depth=3)
assert plain.keys() == shard.keys()
for rid in plain:
    assert (plain[rid] == shard[rid]).all(), rid
assert stats["rows_padded"] % 8 == 0  # every batch splits evenly
rep = build_report(sh, reqs, stats, wall_s=0.5)
assert rep["devices"] == 8 and rep["backend"] == "packed"
assert rep["rows_per_s_per_device"] == round(rep["rows_per_s"] / 8, 1)
print("SHARDED_SERVE_OK")
"""


class TestShardedMultiDevice:
    """8 forced host devices (fresh subprocess): sharded serving is
    bit-exact with the single-device path, ragged tails included."""

    def test_bit_exact_8_devices(self):
        out = check_multidev(_SUBPROCESS_PARITY, n_devices=8)
        assert "SHARDED_PARITY_OK" in out

    def test_serve_batches_8_devices(self):
        out = check_multidev(_SUBPROCESS_SERVE, n_devices=8)
        assert "SHARDED_SERVE_OK" in out